"""End-to-end training driver: a ~100M llama-family model, synthetic corpus,
FLARE daemon attached, checkpointing + fault-tolerant supervisor.

CPU-friendly default is the 10M scale for a few hundred steps; pass
--scale 100m for the full-size run (same code path):

    PYTHONPATH=src python examples/train_e2e.py --steps 120
    PYTHONPATH=src python examples/train_e2e.py --scale 100m --steps 300
"""
import argparse
import tempfile

import numpy as np

from repro.configs import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime.supervisor import SimulatedFault, Supervisor
from repro.runtime.train import RunConfig, Trainer

SCALES = {
    "10m": ModelConfig(name="llama-10m", family="dense", num_layers=4,
                       d_model=256, num_heads=8, num_kv_heads=4, d_ff=1024,
                       vocab_size=4096, tie_embeddings=True),
    "100m": ModelConfig(name="llama-100m", family="dense", num_layers=12,
                        d_model=768, num_heads=12, num_kv_heads=4,
                        d_ff=3072, vocab_size=8192, tie_embeddings=True),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="10m", choices=sorted(SCALES))
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--inject-fault", action="store_true",
                    help="crash mid-run to demo checkpoint/restart")
    args = ap.parse_args()

    cfg = SCALES[args.scale]
    print(f"model: {cfg.name} ({cfg.param_count() / 1e6:.1f}M params)")
    crashed = {"done": False}

    def fault_hook(step):
        if args.inject_fault and step == args.steps // 2 \
                and not crashed["done"]:
            crashed["done"] = True
            raise SimulatedFault("injected node failure")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        def make_trainer():
            run = RunConfig(
                model=cfg, global_batch=args.batch, seq_len=args.seq,
                steps=args.steps, peak_lr=3e-3,
                warmup_steps=max(args.steps // 10, 5),
                opt=AdamWConfig(lr=3e-3), flare=True,
                checkpoint_dir=ckpt_dir,
                checkpoint_every=max(args.steps // 6, 5))
            return Trainer(run, fault_hook=fault_hook)

        sup = Supervisor(max_restarts=2)
        hist = sup.run(make_trainer, steps=args.steps)

    losses = [h["loss"] for h in hist]
    for h in hist[:: max(len(hist) // 12, 1)]:
        print(f"  step {h['step']:4d}  loss {h['loss']:.4f}  "
              f"{h['tokens_per_s']:7.0f} tok/s")
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({'DECREASED' if last < first else 'NOT DECREASED'})")
    if sup.restarts:
        print(f"supervisor: {sup.restarts} restart(s) — "
              f"{[a.note for a in sup.actions]}")
    assert last < first, "training must make progress"


if __name__ == "__main__":
    main()
