"""Quickstart: attach FLARE to a training run and read its diagnosis.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

from repro.configs import get_reduced
from repro.core.events import load_jsonl
from repro.core.metrics import aggregate_step, steps_in
from repro.core.report import ascii_timeline
from repro.optim.adamw import AdamWConfig
from repro.runtime.train import RunConfig, Trainer


def main():
    cfg = get_reduced("llama3.2-1b")
    with tempfile.TemporaryDirectory() as d:
        log = os.path.join(d, "trace.jsonl")
        run = RunConfig(model=cfg, global_batch=4, seq_len=64, steps=20,
                        peak_lr=3e-3, warmup_steps=5,
                        opt=AdamWConfig(lr=3e-3),
                        flare=True, flare_log=log)
        trainer = Trainer(run)
        hist = trainer.train()
        print(f"trained {len(hist)} steps: loss {hist[0]['loss']:.3f} -> "
              f"{hist[-1]['loss']:.3f} "
              f"({hist[-1]['tokens_per_s']:.0f} tok/s)")
        print(f"FLARE logged {trainer.daemon.bytes_logged / 1e3:.1f} KB "
              f"({trainer.daemon.events_emitted} events)")
        events = load_jsonl(log)
        by_rank = {0: events}
        step = steps_in(by_rank)[-2]
        m = aggregate_step(by_rank, step)
        print(f"step {step}: throughput={m.throughput:.0f} tok/s  "
              f"V_inter={m.v_inter:.3f}  V_minority={m.v_minority:.3f}")
        print(ascii_timeline(events, rank=0, step=step))


if __name__ == "__main__":
    main()
