"""Batched serving with FLARE attached: prefill + greedy decode.

    PYTHONPATH=src python examples/serve_batched.py --arch qwen2-0.5b
"""
import argparse
import time

import numpy as np

from repro.configs import get_reduced, list_archs
from repro.runtime.serve import ServeConfig, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    server = Server(ServeConfig(model=cfg, batch=args.batch, max_seq=96))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.perf_counter()
    out = server.generate(prompts, new_tokens=args.new_tokens)
    dt = time.perf_counter() - t0
    print(f"arch={args.arch} generated {out.shape[0]}x{args.new_tokens} "
          f"tokens in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
    print("sample:", out[0, -10:])
    d = server.daemon
    print(f"FLARE events: {d.events_emitted}")
    server.close()


if __name__ == "__main__":
    main()
