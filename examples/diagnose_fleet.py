"""FLARE as a fleet service: N concurrent jobs, diagnosed while they run.

Simulates a cluster operating several training jobs at once — some
healthy, some with injected anomalies (GC stalls, an underclocked GPU,
a misaligned kernel, network jitter, a communication hang) — and streams
their per-step event chunks round-robin into a ``FleetMultiplexer``.
Anomalies surface incrementally with job tags and team routing as each
job's watermark closes steps; the hung job is diagnosed the moment a
majority of its daemons report.

The fleet-scope detector tier is on: every job is placed on a rack
(``mux.set_topology``) and the registered ``cross_job_failslow``
correlator watches the merged stream — the two jitter-afflicted jobs
sharing rack0 are reclassified from per-job operations findings to a
shared-rack INFRASTRUCTURE diagnosis (``origin="fleet"`` lines).

    PYTHONPATH=src python examples/diagnose_fleet.py --jobs 6 --ranks 128
"""
import argparse

from repro.configs import get_config
from repro.core.engine import DiagnosticEngine, EngineConfig
from repro.core.history import HistoryStore
from repro.core.timeline import (ClusterSimulator, Injection,
                                 program_from_config)
from repro.fleet import FleetConfig, FleetMultiplexer


def job_scenarios(n_jobs: int, num_ranks: int):
    """Cycle through the paper's anomaly classes across the fleet.  The
    first two slots are network jitter ON THE SAME RACK — the cross-job
    correlator's bread and butter."""
    jitter = [Injection(kind="network_jitter", factor=3.0, start_step=3)]
    templates = [
        ("net-jitter", jitter),
        ("net-jitter", jitter),
        ("healthy", []),
        ("gc-stalls", [Injection(kind="gc", duration=0.05, period_ops=4)]),
        ("underclock", [Injection(kind="underclock",
                                  ranks=(137 % num_ranks,), factor=2.4,
                                  start_step=3)]),
        ("misaligned-ffn", [Injection(kind="slow_compute",
                                      op_match="ffn_matmul", factor=2.9)]),
        ("comm-hang", [Injection(kind="hang", ranks=(611 % num_ranks,),
                                 at_step=2)]),
    ]
    return [(f"job-{i}-{templates[i % len(templates)][0]}",
             templates[i % len(templates)][1]) for i in range(n_jobs)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=6)
    ap.add_argument("--ranks", type=int, default=128)
    ap.add_argument("--steps", type=int, default=6)
    args = ap.parse_args()
    N = args.ranks

    cfg = get_config("llama-20b-paper")
    prog = program_from_config(cfg, num_chips=N, layer_groups=6)
    store = HistoryStore()
    learn = DiagnosticEngine(
        EngineConfig(backend="dense-train", num_ranks=N), store)
    print(f"learning healthy profile from 2 runs x {N} ranks ...")
    for seed in range(2):
        learn.ingest_batch(ClusterSimulator(N, prog, seed=seed).run_batch(3))
    learn.learn_healthy()

    shapes = {f"ffn_matmul[{g}]": (8192, 8484) for g in range(6)}
    # fleet-scope tier: the cross-job fail-slow correlator, resolved by
    # registry name exactly like the per-job detector set
    mux = FleetMultiplexer(FleetConfig(
        watermark_delay=1, fleet_detectors=["cross_job_failslow"]),
        history=store)

    # run every job's simulator, pre-split into per-step chunks (each chunk
    # stands in for one drain of that job's daemons)
    chunks = {}
    for i, (job_id, inj) in enumerate(job_scenarios(args.jobs, N)):
        mux.add_job(job_id, EngineConfig(backend="dense-train", num_ranks=N,
                                         kernel_shapes=shapes))
        # placement: jobs 0 and 1 (both jittery) share rack0
        mux.set_topology(job_id, rack="rack0" if i < 2 else f"rack{i}",
                         switch=f"sw{i // 2}")
        batch = ClusterSimulator(N, prog, seed=77,
                                 injections=inj).run_batch(args.steps)
        order, uniq, bounds = batch.step_index()
        chunks[job_id] = [batch.take(order[bounds[i]:bounds[i + 1]])
                          for i in range(uniq.size)]

    print(f"streaming {args.jobs} jobs x {N} ranks, round-robin per step\n")
    round_no = 0
    while any(chunks.values()):
        for job_id, pending in chunks.items():
            if pending:
                mux.ingest(job_id, pending.pop(0))
        round_no += 1
        for fa in mux.poll():
            print(f"  r{round_no:02d} {fa}")
    for fa in mux.finalize():
        print(f"  fin {fa}")

    print("\n=== fleet summary ===")
    total_ev = 0
    for job_id, st in mux.stats().items():
        total_ev += st["events"]
        flag = "HANG" if st["hang_reported"] else \
            f"{st['anomalies']} anomalies"
        print(f"  {job_id:26s} {st['events']:>9d} ev  "
              f"{st['steps_evaluated']} steps  {flag}")
    print(f"  fleet total: {total_ev} events, "
          f"{len(mux.interner.names)} shared interned names")


if __name__ == "__main__":
    main()
