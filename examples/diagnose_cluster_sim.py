"""FLARE at scale: diagnose anomalies on a 1024-rank simulated cluster.

Learns a healthy profile, then runs four unhealthy jobs (GC stalls, a
straggler GPU, a misaligned kernel, and a communication hang at rank 611)
and prints FLARE's routed diagnosis plus the ops-team runbook actions.

    PYTHONPATH=src python examples/diagnose_cluster_sim.py --ranks 1024
"""
import argparse

from repro.configs import get_config
from repro.core.engine import DiagnosticEngine, EngineConfig
from repro.core.history import HistoryStore
from repro.core.inspecting import inspect_cost_model, probe_search_cost
from repro.core.report import anomaly_report
from repro.core.timeline import (ClusterSimulator, Injection,
                                 program_from_config)
from repro.runtime.supervisor import Supervisor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ranks", type=int, default=1024)
    args = ap.parse_args()
    N = args.ranks

    cfg = get_config("llama-20b-paper")
    prog = program_from_config(cfg, num_chips=N, layer_groups=6)
    store = HistoryStore()
    learn = DiagnosticEngine(EngineConfig(backend="dense-train",
                                          num_ranks=N), store)
    print(f"learning healthy profile from 2 runs x {N} ranks ...")
    for seed in range(2):
        learn.ingest_batch(ClusterSimulator(N, prog, seed=seed).run_batch(3))
    prof = learn.learn_healthy()
    print(f"  W1 threshold={prof.issue_w1_threshold:.4f}  "
          f"V_inter thr={prof.v_inter_threshold:.3f}  "
          f"V_minority thr={prof.v_minority_threshold:.3f}\n")

    jobs = [
        ("job-1: python GC stalls",
         [Injection(kind="gc", duration=0.3, period_ops=4)]),
        ("job-2: straggler GPU (underclocked)",
         [Injection(kind="underclock", ranks=(137 % N,), factor=2.4,
                    start_step=3)]),
        ("job-3: misaligned FFN after backend migration",
         [Injection(kind="slow_compute", op_match="ffn_matmul",
                    factor=2.9)]),
        ("job-4: comm hang at rank 611",
         [Injection(kind="hang", ranks=(611 % N,), at_step=2)]),
    ]
    shapes = {f"ffn_matmul[{g}]": (8192, 8484) for g in range(6)}
    sup = Supervisor()
    for name, inj in jobs:
        eng = DiagnosticEngine(EngineConfig(
            backend="dense-train", num_ranks=N, kernel_shapes=shapes), store)
        sim = ClusterSimulator(N, prog, seed=77, injections=inj)
        eng.ingest_batch(sim.run_batch(6))
        if sim.hang:
            anomalies = [eng.diagnose_hang(sim.hang.stacks,
                                           sim.hang.ring_progress)]
            print(f"=== {name} ===")
            print(f"  O(1) inspection: {inspect_cost_model(N):.0f}s vs "
                  f"NCCL-test sweep: {probe_search_cost(N) / 60:.0f}min")
        else:
            anomalies = eng.evaluate_all()
            print(f"=== {name} ===")
        print(anomaly_report(anomalies))
        actions = sup.apply_diagnosis(anomalies)
        for a in actions:
            print(f"  -> cluster action: {a.kind} {a.ranks} ({a.note})")
        print()


if __name__ == "__main__":
    main()
