"""The on-call workflow against a recorded fleet: query, don't replay.

Records a 3-job fleet (job-a healthy, job-b with two GPUs underclocked
from step 40, job-c with GC stalls) to rotated FCS v3 segments — the
stats-directory format a production daemon spill would leave behind —
then answers the questions an on-call engineer actually asks, through
``TraceArchive``:

  1. "How did job-b's throughput move?"  ``query_metrics`` off cached
     per-step rollups (warm queries never touch the trace bytes).
  2. "WHICH ranks regressed after step 40?"  Compare per-rank FLOPS
     rollups before/after the onset — the culprits fall out as the
     ranks whose compute rate dropped the most.
  3. "Show me the raw events for one culprit in the bad window."
     ``query_events`` pushes the (step-range, rank) predicate into the
     v3 stats directory and decodes only the segments that can match.
  4. "How's the fleet?"  ``fleet_weather`` + anomaly counts by team,
     and the pipeline's own telemetry exported next to the traces.

    PYTHONPATH=src python examples/query_archive.py --ranks 32
"""
import argparse
import json
import os
import tempfile

from repro import store
from repro.archive import TraceArchive, format_fleet_weather
from repro.configs import get_config
from repro.core.engine import DiagnosticEngine, EngineConfig
from repro.core.history import HistoryStore
from repro.core.timeline import (ClusterSimulator, Injection,
                                 program_from_config)

ONSET = 40          # job-b's bad GPUs kick in here
CULPRITS = (5, 11)


def record_fleet(logdir: str, prog, num_ranks: int, steps: int) -> None:
    """One rotated .fcs3 stream per job, one segment per step — the
    shape a size-rotating daemon spill converges to."""
    jobs = {
        "job-a": [],
        "job-b": [Injection(kind="underclock", ranks=CULPRITS, factor=2.6,
                            start_step=ONSET)],
        "job-c": [Injection(kind="gc", duration=0.03, period_ops=6)],
    }
    for i, (job_id, inj) in enumerate(jobs.items()):
        batch = ClusterSimulator(num_ranks, prog, seed=31 + i,
                                 injections=inj).run_batch(steps)
        w = store.SegmentedTraceWriter(
            os.path.join(logdir, f"{job_id}.fcs3"), codec="fcs3",
            rotate_bytes=96 << 10)
        order, uniq, bounds = batch.step_index()
        for j in range(uniq.size):
            w.write(batch.take(order[bounds[j]:bounds[j + 1]]))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ranks", type=int, default=32)
    ap.add_argument("--steps", type=int, default=48)
    args = ap.parse_args()
    N, steps = args.ranks, max(args.steps, ONSET + 4)

    cfg = get_config("llama-20b-paper")
    prog = program_from_config(cfg, num_chips=N, layer_groups=6)
    hist = HistoryStore()
    learn = DiagnosticEngine(
        EngineConfig(backend="dense-train", num_ranks=N), hist)
    print(f"learning healthy profile from 2 runs x {N} ranks ...")
    for seed in range(2):
        learn.ingest_batch(ClusterSimulator(N, prog, seed=seed).run_batch(3))
    learn.learn_healthy()

    with tempfile.TemporaryDirectory() as logdir:
        print(f"recording 3 jobs x {N} ranks x {steps} steps "
              f"to rotated FCS v3 segments ...")
        record_fleet(logdir, prog, N, steps)
        files = sorted(os.listdir(logdir))
        print(f"  {len(files)} files, e.g. {files[:3]}")

        ar = TraceArchive(logdir, history=hist,
                          engine_config=EngineConfig(
                              backend="dense-train", num_ranks=N))

        # 1. throughput curve around the onset, off cached rollups
        print(f"\n=== job-b throughput (tok/s), steps {ONSET - 3}"
              f"..{ONSET + 3} ===")
        for s, thr in ar.query_metrics(
                "job-b", step_range=(ONSET - 3, ONSET + 3)):
            bar = "#" * int(thr / 2000)
            print(f"  step {s:>3}  {thr:>10.0f}  {bar}")

        # 2. which ranks regressed after step 40?  per-rank FLOPS
        # rollups, after-vs-before ratio, worst first
        before = dict(ar.query_metrics("job-b", step_range=(0, ONSET - 1),
                                       metric="rank_flops", bucket=ONSET))
        after = dict(ar.query_metrics("job-b",
                                      step_range=(ONSET, steps - 1),
                                      metric="rank_flops", bucket=steps))
        b, a = next(iter(before.values())), next(iter(after.values()))
        ratios = sorted(((a[r] / b[r], r) for r in a if b.get(r)),
                        key=lambda t: t[0])
        print(f"\n=== job-b per-rank FLOPS, after/before step {ONSET} ===")
        for ratio, r in ratios[:4]:
            tag = "  <-- regressed" if ratio < 0.7 else ""
            print(f"  rank {r:>3}  {ratio:5.2f}x{tag}")
        flagged = tuple(sorted(r for ratio, r in ratios if ratio < 0.7))
        print(f"  flagged: {flagged} (injected: {tuple(CULPRITS)})")

        # 3. raw events for one culprit in the bad window — the stats
        # directory prunes the segments that can't match
        batch, scan = ar.query_events(
            "job-b", step_range=(ONSET, ONSET + 3), ranks=[flagged[0]],
            with_scan=True)
        print(f"\n=== raw events: job-b rank {flagged[0]}, steps "
              f"{ONSET}..{ONSET + 3} ===")
        print(f"  {len(batch)} rows; pushdown skipped "
              f"{scan.segments_skipped}/{scan.segments} segments, "
              f"decoded {scan.bytes_decoded >> 10} KiB "
              f"(skipped {scan.bytes_skipped >> 10} KiB)")

        # 4. fleet weather + anomaly routing + self-telemetry
        print("\n=== fleet weather ===")
        print(format_fleet_weather(ar.fleet_weather()))
        crit = ar.query_anomalies(job="job-b")
        print(f"\njob-b anomalies ({len(crit)}), first 3:")
        for fa in crit[:3]:
            print(f"  {fa}")

        path = ar.export_telemetry()
        snap = json.load(open(path))
        interesting = {k: v for k, v in snap["counters"].items()
                       if k.startswith(("archive.", "replay."))}
        print(f"\npipeline telemetry -> {os.path.basename(path)}")
        for k in sorted(interesting):
            print(f"  {k:<42} {interesting[k]}")


if __name__ == "__main__":
    main()
