"""Fault-injection demo: run the labelled scenario matrix on one config
and print the scored verdict table — what fired, whether the fault was
caught, routed to the right team, attributed to the right ranks, and the
resulting per-detector precision/recall.

Also shows the plugin seam end-to-end: registers a custom
``pcie_downgrade`` injector, grades it against a hand-written ground
truth, then unregisters it.

    PYTHONPATH=src python examples/inject_faults.py [--config qwen2-0.5b]
"""
import argparse

from repro.core.injectors import (FaultInjector, Injection,
                                  register_injector, unregister_injector)
from repro.scenarios import (GroundTruth, Scenario, SCENARIOS_BY_NAME,
                             run_cell, run_matrix, score_matrix)


def verdict_table(cells):
    head = (f"{'scenario':<24} {'verdict':<8} {'team':>5} {'ranks':>5} "
            f"{'onset':>5}  fired")
    print(head)
    print("-" * len(head))
    for c in cells:
        verdict = "OK" if c.ok else "FAIL"
        if c.healthy:
            verdict = "clean" if c.ok else "NOISY"
        mark = lambda b: "yes" if b else "NO"   # noqa: E731
        print(f"{c.scenario:<24} {verdict:<8} "
              f"{mark(c.team_ok):>5} {mark(c.ranks_ok):>5} "
              f"{mark(c.onset_ok):>5}  {', '.join(c.fired) or '-'}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="qwen2-0.5b")
    args = ap.parse_args()

    print(f"scenario matrix on {args.config!r} "
          f"(every fault labelled with ground truth)\n")
    cells = run_matrix([args.config])
    verdict_table(cells)

    s = score_matrix(cells)
    print(f"\nper-detector precision/recall over {s['cells']} cells "
          f"({s['faulty_cells']} faulty):")
    for key, d in s["detectors"].items():
        print(f"  {key:<32} P={d['precision']:.2f} R={d['recall']:.2f} "
              f"(tp={d['tp']} fp={d['fp']} fn={d['fn']})")
    print(f"  micro P={s['micro_precision']:.2f} "
          f"R={s['micro_recall']:.2f}  missed={s['missed'] or 'none'}")

    # ---- the plugin seam: a fault class this repo never shipped ------- #
    print("\ncustom injector: pcie_downgrade (registered at runtime)")

    @register_injector
    class PcieDowngradeInjector(FaultInjector):
        name = "pcie_downgrade"

        def device_duration(self, sim, op, step, dur):
            if op.kind != "comm" or step < self.inj.start_step:
                return dur
            out = dur.copy()
            out[sim.hit_ranks(self.inj)] *= self.inj.factor
            return out

    scn = Scenario(
        name="pcie_downgrade",
        description="PCIe link drops a generation on two ranks",
        inject=lambda step_s, n: [Injection(
            kind="pcie_downgrade", ranks=(4, 5), factor=4.0,
            start_step=3)],
        truth=GroundTruth(kind="fail_slow", team="operations",
                          expect=("fail_slow:bandwidth",
                                  "fail_slow:throughput",),
                          onset_step=3))
    try:
        c = run_cell(scn, args.config)
        verdict_table([c])
    finally:
        unregister_injector("pcie_downgrade")

    known = SCENARIOS_BY_NAME["gpu_underclock"]
    print(f"\n(compare: {known.name!r} expects {known.truth.expect} "
          f"culprits {known.truth.culprit_ranks})")


if __name__ == "__main__":
    main()
