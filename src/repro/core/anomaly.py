"""Anomaly record + team routing targets (paper Table 1).

Split out of ``engine.py`` so detector plugins (``repro.core.detectors``)
can construct anomalies without importing the engine that drives them.
``repro.core.engine`` re-exports both names, so existing
``from repro.core.engine import Anomaly, Team`` call sites keep working.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field


class Team(str, enum.Enum):
    OPERATIONS = "operations"
    ALGORITHM = "algorithm"
    INFRASTRUCTURE = "infrastructure"
    CROSS_TEAM = "cross-team"


@dataclass
class Anomaly:
    kind: str            # hang | fail_slow | regression
    metric: str          # detector that fired
    team: Team
    root_cause: str
    step: int = -1
    severity: float = 1.0
    ranks: list = field(default_factory=list)
    evidence: dict = field(default_factory=dict)

    def __str__(self):
        return (f"[{self.kind}/{self.metric}] -> {self.team.value}: "
                f"{self.root_cause} (step {self.step}, "
                f"severity {self.severity:.2f})")
