"""Fault-injection protocol + the Injection record.

An :class:`Injection` is pure *data*: which fault, when, where, how hard.
A :class:`FaultInjector` is the *behavior* bound to one Injection — a
plugin the :class:`~repro.core.timeline.ClusterSimulator` drives through
fixed hook points of its emission loop.  The registry
(``repro.core.injectors.registry``) maps ``Injection.kind`` to the
injector class, exactly like ``EngineConfig.detectors`` maps names to
detector classes: the simulator never hardcodes a fault taxonomy again.

Hook points, in the order the simulator calls them for every op::

    hang_at(sim, step, oi, op)            -> bool: freeze the cluster here
    pre_op(sim, b, step, oi, op, cpu)     host-side stall BEFORE dispatch
                                          (mutate ``cpu``, append events)
    cpu_duration(sim, op, step, dur)      transform host-op durations
    device_duration(sim, op, step, dur)   transform device-op durations
    minority_time(sim, op, step, extra)   add un-instrumented device time
    post_comm(sim, b, step, op, cpu, end) host sync AFTER a collective

Duration hooks receive and return per-rank ``np.ndarray`` vectors (length
``sim.n``); they run BEFORE the simulator applies its healthy noise draw,
so a no-op hook chain is byte-identical to an uninjected run.  Injectors
that need randomness must draw from ``sim.rng`` (never a private RNG) so
a seeded simulation stays reproducible for any injector mix.

``Injection`` is re-exported from ``repro.core.timeline`` for
back-compat; new code should import it from here.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class Injection:
    """One fault to inject.  ``kind`` names a registered injector; see
    ``repro.core.injectors.registry.injector_names()`` for the taxonomy
    (nine legacy kinds + the L4 production set).  Kind-specific knobs
    beyond the shared fields below travel in ``meta``."""

    kind: str
    # gc | sync_after_comm | straggler | underclock | network_jitter |
    # hang | slow_dataloader | minority_kernels | slow_compute |
    # pyapi_stall | checkpoint_write_storm | ecc_throttle | network_flap |
    # moe_straggler | serving_interference | <your-registered-kind>
    start_step: int = 0
    ranks: tuple = ()              # affected ranks (empty = all)
    factor: float = 1.0            # slowdown multiplier
    duration: float = 0.0          # injected span length (gc/pyapi/dataloader)
    period_ops: int = 6            # one injection every N ops (gc/pyapi)
    op_match: str = ""             # substring matched against op names
    api_name: str = "gc.collect"   # emitted event name (pyapi_stall)
    at_step: int = 1               # hang step
    at_op: int = -1                # hang op index (-1 = first comm)
    meta: dict = field(default_factory=dict)

    def hits_rank(self, r: int) -> bool:
        return not self.ranks or r in self.ranks


def stall_phase(step: int, kind: str, period: int) -> int:
    """Deterministic per-(step, kind) phase for periodic in-step stalls.

    The legacy emitter used ``hash((step, kind))`` here — Python string
    hashing is salted per process (PYTHONHASHSEED), so the *same seed*
    emitted *different traces* across runs.  CRC32 is stable everywhere.
    """
    return zlib.crc32(f"{step}:{kind}".encode("ascii")) % max(period, 1)


class FaultInjector:
    """Base class for injector plugins.  Subclass, set ``name`` (the
    registry key, matched against ``Injection.kind``), override the hooks
    you need, and register with ``@register_injector``.  One instance is
    created per Injection per simulator, so hooks may keep state across
    steps (ramp counters, duty-cycle phase) on ``self``."""

    name: str = ""

    def __init__(self, inj: Injection):
        self.inj = inj

    # -------------------------- hook points --------------------------- #
    def hang_at(self, sim, step: int, oi: int, op) -> bool:
        """Return True to freeze the cluster at this op (hang faults)."""
        return False

    def pre_op(self, sim, b, step: int, oi: int, op, cpu: np.ndarray) -> None:
        """Host-side stall before the op is dispatched: mutate ``cpu`` for
        the hit ranks and append the corresponding host-span events."""

    def cpu_duration(self, sim, op, step: int,
                     dur: np.ndarray) -> np.ndarray:
        """Transform a host op's per-rank duration vector (pre-noise)."""
        return dur

    def device_duration(self, sim, op, step: int,
                        dur: np.ndarray) -> np.ndarray:
        """Transform a device op's per-rank duration vector (pre-noise)."""
        return dur

    def minority_time(self, sim, op, step: int,
                      extra: np.ndarray) -> np.ndarray:
        """Add per-rank *un-instrumented* device time after this op."""
        return extra

    def post_comm(self, sim, b, step: int, op, cpu: np.ndarray,
                  end: np.ndarray) -> None:
        """Host behavior after a collective completes (e.g. forced sync):
        mutate ``cpu`` and append host-span events."""
