"""The nine legacy injection kinds as registered plugins.

Each class reproduces the behavior of the pre-registry
``ClusterSimulator`` if-chain — same hook order, same ``sim.rng`` draw
sequence — so a seeded simulation emits a byte-identical ``EventBatch``
through the registry (pinned against a frozen oracle in
``tests/test_injectors.py``).  Two deliberate fixes ARE folded in (and
pinned by the same oracle):

  * periodic gc/pyapi stalls phase with :func:`~repro.core.injectors.
    base.stall_phase` (CRC32) instead of salted ``hash()`` — traces are
    now reproducible across processes;
  * ``minority_kernels`` and ``network_jitter`` honour ``Injection.
    ranks`` (the legacy emitter silently hit every rank), and the
    ``straggler``/``underclock`` per-rank Python loop is vectorized.

``network_jitter`` still draws a full ``sim.n``-wide jitter vector even
when only a rank subset is hit, so adding/removing rank targeting never
shifts the RNG stream consumed by later ops.
"""
from __future__ import annotations

import numpy as np

from repro.core.events import EventKind
from repro.core.injectors.base import FaultInjector, stall_phase
from repro.core.injectors.registry import register_injector


@register_injector
class GcStallInjector(FaultInjector):
    """Periodic host-side GC pause: every ``period_ops`` ops (CRC32
    phase per step), the hit ranks stall ``duration * U[0.75, 1.25)``
    seconds before dispatching — compressing issue latencies (④)."""

    name = "gc"
    emit_kind = EventKind.GC

    def pre_op(self, sim, b, step, oi, op, cpu):
        inj = self.inj
        if step < inj.start_step:
            return
        period = max(inj.period_ops, 1)
        if oi % period != stall_phase(step, inj.kind, period):
            return
        hit = sim.hit_ranks(inj)
        t0 = cpu[hit].copy()
        cpu[hit] += inj.duration * (0.75 + 0.5 * sim.rng.random(hit.size))
        b.append_block(self.emit_kind, inj.api_name, hit, t0, t0,
                       cpu[hit], step)


@register_injector
class PyApiStallInjector(GcStallInjector):
    """Periodic stall in an arbitrary traced Python API (``api_name``):
    package checks, version pings, host-side timers."""

    name = "pyapi_stall"
    emit_kind = EventKind.PY_API


@register_injector
class SyncAfterCommInjector(FaultInjector):
    """Case-1: an unnecessary ``block_until_ready`` after every
    collective — the host waits for the device, serializing dispatch."""

    name = "sync_after_comm"

    def post_comm(self, sim, b, step, op, cpu, end):
        inj = self.inj
        if step < inj.start_step:
            return
        hit = sim.hit_ranks(inj)
        t0 = cpu[hit].copy()
        cpu[hit] = np.maximum(cpu[hit], end[hit])
        b.append_block(EventKind.SYNC, "jax@block_until_ready", hit,
                       t0, t0, cpu[hit], step)


@register_injector
class StragglerInjector(FaultInjector):
    """Persistent compute slowdown on the hit ranks (thermal throttling,
    a downclocked GPU): every compute kernel runs ``factor`` slower."""

    name = "straggler"

    def device_duration(self, sim, op, step, dur):
        inj = self.inj
        if step >= inj.start_step and op.kind == "compute":
            dur[sim.hit_ranks(inj)] *= inj.factor
        return dur


@register_injector
class UnderclockInjector(StragglerInjector):
    """Alias kind: GPU underclocking is the straggler fault under its
    fail-slow-attribution name (paper §5.2.3)."""

    name = "underclock"


@register_injector
class SlowComputeInjector(FaultInjector):
    """Uniform slowdown of kernels whose name contains ``op_match`` on
    ALL hit ranks — the Case-2 software/layout regression shape."""

    name = "slow_compute"

    def device_duration(self, sim, op, step, dur):
        inj = self.inj
        if step >= inj.start_step and op.kind == "compute" \
                and inj.op_match in op.name:
            dur[sim.hit_ranks(inj)] *= inj.factor
        return dur


@register_injector
class NetworkJitterInjector(FaultInjector):
    """Persistent noisy slowdown of collectives on the hit ranks:
    ``factor * U[0.8, 1.2)`` per rank per op (congestion, CRC retries)."""

    name = "network_jitter"

    def device_duration(self, sim, op, step, dur):
        inj = self.inj
        if step >= inj.start_step and op.kind == "comm":
            # full-width draw keeps the RNG stream independent of rank
            # targeting (see module docstring)
            r = sim.rng.random(sim.n)
            hit = sim.hit_ranks(inj)
            dur[hit] *= inj.factor * (0.8 + 0.4 * r[hit])
        return dur


@register_injector
class SlowDataloaderInjector(FaultInjector):
    """Case-3: the host dataloader takes ``factor``x longer plus a flat
    ``duration`` seconds — V_inter grows, the device starves."""

    name = "slow_dataloader"

    def cpu_duration(self, sim, op, step, dur):
        inj = self.inj
        if step >= inj.start_step and "dataloader" in op.name:
            dur = dur * inj.factor + inj.duration
        return dur


@register_injector
class MinorityKernelsInjector(FaultInjector):
    """Table-5: un-instrumented minority kernels silently occupy the
    device for ``factor`` of each compute op's span on the hit ranks —
    V_minority grows with no matching trace spans."""

    name = "minority_kernels"

    def minority_time(self, sim, op, step, extra):
        inj = self.inj
        if step >= inj.start_step and op.kind == "compute":
            extra[sim.hit_ranks(inj)] += op.duration * inj.factor
        return extra


@register_injector
class HangInjector(FaultInjector):
    """Freeze the cluster at (``at_step``, ``at_op``); ``at_op == -1``
    means the first collective of that step.  The simulator snapshots
    per-rank stacks + ring progress (``sim.hang``) and emits the
    majority HANG_SUSPECT heartbeat block."""

    name = "hang"

    def hang_at(self, sim, step, oi, op):
        inj = self.inj
        if step != inj.at_step:
            return False
        return inj.at_op == oi or (inj.at_op == -1 and op.kind == "comm")
