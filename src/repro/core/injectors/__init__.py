"""Pluggable fault injection — the simulator's extension seam.

The :class:`~repro.core.timeline.ClusterSimulator` does not hardcode a
fault taxonomy: every ``Injection`` in its ``injections`` list resolves
through the registry to a :class:`FaultInjector` plugin driven at fixed
hook points of the emission loop (host pre-op stalls, cpu/device duration
transforms, minority device time, post-collective sync, hang triggers).
The nine legacy kinds are themselves registered plugins
(``builtins.py``), byte-equivalent to the pre-registry emitter; the L4
production taxonomy (``l4.py``) adds checkpoint-write storms, ECC/thermal
throttling, network flaps, straggly MoE experts, and serving-mix
interference.  Adding a fault class is a subclass + one
``@register_injector``, never a simulator edit — see
``src/repro/scenarios/README.md`` for the worked example and the
detector-signature map.
"""
from repro.core.injectors.base import (FaultInjector, Injection,  # noqa: F401
                                       stall_phase)
from repro.core.injectors.builtins import (GcStallInjector,  # noqa: F401
                                           HangInjector,
                                           MinorityKernelsInjector,
                                           NetworkJitterInjector,
                                           PyApiStallInjector,
                                           SlowComputeInjector,
                                           SlowDataloaderInjector,
                                           StragglerInjector,
                                           SyncAfterCommInjector,
                                           UnderclockInjector)
from repro.core.injectors.l4 import (CheckpointWriteStormInjector,  # noqa: F401
                                     EccThrottleInjector,
                                     MoEStragglerInjector,
                                     NetworkFlapInjector,
                                     ServingInterferenceInjector)
from repro.core.injectors.registry import (DuplicateInjectorError,  # noqa: F401
                                           InjectorError,
                                           UnknownInjectorError,
                                           get_injector, injector_names,
                                           register_injector,
                                           resolve_injections,
                                           unregister_injector)

__all__ = [
    "Injection", "FaultInjector", "stall_phase",
    "GcStallInjector", "PyApiStallInjector", "SyncAfterCommInjector",
    "StragglerInjector", "UnderclockInjector", "SlowComputeInjector",
    "NetworkJitterInjector", "SlowDataloaderInjector",
    "MinorityKernelsInjector", "HangInjector",
    "CheckpointWriteStormInjector", "EccThrottleInjector",
    "NetworkFlapInjector", "MoEStragglerInjector",
    "ServingInterferenceInjector",
    "register_injector", "unregister_injector", "resolve_injections",
    "get_injector", "injector_names",
    "InjectorError", "UnknownInjectorError", "DuplicateInjectorError",
]
