"""Production fault taxonomy beyond the paper's five (L4 / ARGUS,
PAPERS.md): checkpoint-write storms, ECC/thermal throttling, network
flaps, straggly MoE experts, serving-mix interference.

These are ordinary registered plugins — nothing in the simulator knows
they exist — and each maps to a distinct detector signature the scenario
matrix scores (``src/repro/scenarios/``):

    checkpoint_write_storm -> issue_latency regression (checkpoint API)
    ecc_throttle           -> fail_slow, gpu_underclock on culprit ranks
    network_flap           -> fail_slow, per-group bandwidth drop
    moe_straggler          -> flops regression on the hot expert's kernel
    serving_interference   -> fail_slow throughput changepoint, no rank
                              or network attribution (external cause)

Kind-specific knobs ride in ``Injection.meta`` (documented per class);
the shared fields (``start_step``, ``ranks``, ``factor``, ``duration``,
``period_ops``, ``op_match``) keep their usual meaning.
"""
from __future__ import annotations

from repro.core.events import EventKind
from repro.core.injectors.base import FaultInjector, stall_phase
from repro.core.injectors.registry import register_injector


def _duty_on(step: int, start: int, on_steps: int, off_steps: int) -> bool:
    period = max(on_steps + off_steps, 1)
    return (step - start) % period < on_steps


@register_injector
class CheckpointWriteStormInjector(FaultInjector):
    """Checkpoint-write storm: every ``meta.period_steps`` steps, the job
    spends ``meta.storm_steps`` consecutive steps flushing checkpoint
    shards — multi-``duration``-second host stalls (one every
    ``period_ops`` ops, CRC32-phased like gc) that compress issue
    latencies and starve the device.

    meta: ``period_steps`` (default 8), ``storm_steps`` (default 2),
    ``api_name`` (default ``"checkpoint.save_sync"``)."""

    name = "checkpoint_write_storm"

    def pre_op(self, sim, b, step, oi, op, cpu):
        inj = self.inj
        if step < inj.start_step:
            return
        period_steps = max(int(inj.meta.get("period_steps", 8)), 1)
        storm_steps = max(int(inj.meta.get("storm_steps", 2)), 1)
        if (step - inj.start_step) % period_steps >= storm_steps:
            return
        period = max(inj.period_ops, 1)
        if oi % period != stall_phase(step, inj.kind, period):
            return
        hit = sim.hit_ranks(inj)
        t0 = cpu[hit].copy()
        cpu[hit] += inj.duration * (0.75 + 0.5 * sim.rng.random(hit.size))
        b.append_block(EventKind.PY_API,
                       inj.meta.get("api_name", "checkpoint.save_sync"),
                       hit, t0, t0, cpu[hit], step)


@register_injector
class EccThrottleInjector(FaultInjector):
    """ECC error storm / thermal throttling on a rank subset: compute
    slows down progressively, ramping from 1x at ``start_step`` to
    ``factor``x after ``meta.ramp_steps`` steps (step-correlated, unlike
    the flat ``straggler``).

    meta: ``ramp_steps`` (default 4)."""

    name = "ecc_throttle"

    def device_duration(self, sim, op, step, dur):
        inj = self.inj
        if step >= inj.start_step and op.kind == "compute":
            ramp_steps = max(int(inj.meta.get("ramp_steps", 4)), 1)
            ramp = min(1.0, (step - inj.start_step + 1) / ramp_steps)
            dur[sim.hit_ranks(inj)] *= 1.0 + (inj.factor - 1.0) * ramp
        return dur


@register_injector
class NetworkFlapInjector(FaultInjector):
    """Flapping link / lossy switch: collectives on the hit ranks run
    ``factor``x slower (with per-rank noise) during ON windows of a
    ``meta.on_steps`` / ``meta.off_steps`` duty cycle, and at full speed
    in between — the transient cousin of ``network_jitter``.

    meta: ``on_steps`` (default 2), ``off_steps`` (default 2)."""

    name = "network_flap"

    def device_duration(self, sim, op, step, dur):
        inj = self.inj
        if step >= inj.start_step and op.kind == "comm" and _duty_on(
                step, inj.start_step,
                int(inj.meta.get("on_steps", 2)),
                int(inj.meta.get("off_steps", 2))):
            # full-width draw: rank targeting never shifts the RNG stream
            r = sim.rng.random(sim.n)
            hit = sim.hit_ranks(inj)
            dur[hit] *= inj.factor * (0.9 + 0.2 * r[hit])
        return dur


@register_injector
class MoEStragglerInjector(FaultInjector):
    """Straggly MoE expert: among the per-expert FFN kernels (names
    matched by ``op_match``, e.g. ``"moe_ffn"`` — see
    ``program_from_config(..., moe_experts=)``), the hot expert
    ``meta.hot_expert`` runs ``factor``x slower on every hit rank (token
    skew / a cold cache), the rest run at ``meta.base_factor``.

    meta: ``hot_expert`` (default 0), ``base_factor`` (default 1.0)."""

    name = "moe_straggler"

    def device_duration(self, sim, op, step, dur):
        inj = self.inj
        match = inj.op_match or "moe_ffn"
        if step < inj.start_step or op.kind != "compute" \
                or match not in op.name:
            return dur
        hot = int(inj.meta.get("hot_expert", 0))
        if f".expert{hot}" in op.name:
            dur[sim.hit_ranks(inj)] *= inj.factor
        else:
            base = float(inj.meta.get("base_factor", 1.0))
            if base != 1.0:
                dur[sim.hit_ranks(inj)] *= base
        return dur


@register_injector
class ServingInterferenceInjector(FaultInjector):
    """Serving-mix interference: a co-located inference/background
    workload steals compute from the hit ranks on a duty cycle — every
    compute kernel runs ``factor``x slower during ON windows.  Uniform
    across ranks and gone between bursts, so neither the underclock nor
    the network attribution applies: the textbook "sudden slowdown,
    cause unresolved" fail-slow.

    meta: ``on_steps`` (default 2), ``off_steps`` (default 2)."""

    name = "serving_interference"

    def device_duration(self, sim, op, step, dur):
        inj = self.inj
        if step >= inj.start_step and op.kind == "compute" and _duty_on(
                step, inj.start_step,
                int(inj.meta.get("on_steps", 2)),
                int(inj.meta.get("off_steps", 2))):
            dur[sim.hit_ranks(inj)] *= inj.factor
        return dur
