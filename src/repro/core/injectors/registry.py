"""Injector registry: ``Injection.kind`` -> injector class.

Mirrors the detector registry (``repro.core.detectors.registry``):
built-ins self-register at import, third-party injectors register with
the same decorator, and the simulator resolves its ``injections`` list
through :func:`resolve_injections` — an unknown kind is a loud
:class:`UnknownInjectorError` naming what IS registered, never a fault
that silently fails to happen (which would corrupt every scenario score
built on top).
"""
from __future__ import annotations

from typing import Optional

from repro.core.injectors.base import FaultInjector, Injection


class InjectorError(ValueError):
    """Base for registry errors."""


class UnknownInjectorError(InjectorError):
    pass


class DuplicateInjectorError(InjectorError):
    pass


_REGISTRY: dict[str, type] = {}    # kind -> FaultInjector subclass


def register_injector(cls=None, *, name: Optional[str] = None,
                      replace: bool = False):
    """Class decorator (or direct call): register a FaultInjector subclass
    under ``cls.name``.  ``name=`` overrides the class attribute;
    ``replace=True`` allows overriding an existing registration (e.g. a
    site-specific variant of a built-in fault)."""
    def _register(c):
        key = name or getattr(c, "name", "")
        if not key:
            raise InjectorError(
                f"{c.__name__} has no injector name: set a class-level "
                "``name`` or pass register_injector(name=...)")
        if key in _REGISTRY and not replace:
            raise DuplicateInjectorError(
                f"injector {key!r} is already registered to "
                f"{_REGISTRY[key].__name__}; pass replace=True to "
                "override it")
        if name is not None:
            c.name = name
        _REGISTRY[key] = c
        return c
    return _register(cls) if cls is not None else _register


def unregister_injector(name: str) -> None:
    """Remove a registration (tests / plugin teardown)."""
    _REGISTRY.pop(name, None)


def injector_names() -> list[str]:
    return sorted(_REGISTRY)


def get_injector(kind: str) -> type:
    try:
        return _REGISTRY[kind]
    except KeyError:
        raise UnknownInjectorError(
            f"unknown injection kind {kind!r}; registered: "
            f"{injector_names()}") from None


def resolve_injections(entries) -> list[FaultInjector]:
    """Turn a simulator-level injection list into bound injector
    instances, preserving order (the order injections are listed is the
    order their hooks run — and therefore the RNG draw order).

    Each entry may be an :class:`Injection` (kind looked up in the
    registry) or an already-constructed :class:`FaultInjector` instance
    (used as-is — the escape hatch for one-off experiment faults that
    are not worth a registration)."""
    out: list[FaultInjector] = []
    for e in entries or ():
        if isinstance(e, Injection):
            out.append(get_injector(e.kind)(e))
        elif isinstance(e, FaultInjector):
            out.append(e)
        else:
            raise InjectorError(
                f"injection entry {e!r} is neither an Injection nor a "
                "FaultInjector")
    return out
