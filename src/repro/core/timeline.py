"""Device-timeline + cluster simulator (stream semantics, Fig 7).

The diagnostic engine consumes *event streams*; this module produces them
for an N-rank cluster with exact GPU-stream semantics:

    issue_ts   = per-rank CPU dispatch time (bounded run-ahead queue)
    exec_start = max(issue_ts, device_free)          [compute]
    exec_start = max over group of per-rank ready    [collectives]

so kernel-issue stalls (GC, unnecessary sync), fail-slows (underclock,
jitter), void time (uninstrumented kernels, slow dataloader) and hangs all
reproduce the paper's timeline behaviour deterministically — at 1024+
simulated ranks on one host.  A real fleet feeds the same engine from the
per-process daemons instead; nothing in the engine knows which source it is.

Fault injection is PLUGGABLE (``repro.core.injectors``): every
``Injection`` resolves through the injector registry to a
:class:`~repro.core.injectors.FaultInjector` whose hooks this loop drives
at fixed points — host pre-op stalls, cpu/device duration transforms,
minority device time, post-collective sync, hang triggers.  The nine
legacy kinds are themselves registered plugins, byte-equivalent to the
historical inline if-chain; the L4 production taxonomy (checkpoint
storms, ECC throttling, network flaps, MoE stragglers, serving
interference) and any site-specific fault register the same way.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.configs import ModelConfig
from repro.core.columnar import EventBatch, EventBatchBuilder
from repro.core.events import EventKind, TraceEvent
from repro.core.injectors import (FaultInjector, Injection,  # noqa: F401
                                  resolve_injections)

# ----------------------------------------------------------------------- #
# Program model
# ----------------------------------------------------------------------- #
@dataclass(frozen=True)
class SimOp:
    name: str
    kind: str                      # compute | comm | cpu
    duration: float                # seconds (healthy)
    flops: float = 0.0
    bytes: int = 0
    group: str = "dp"              # comm group id (comm ops)
    cpu_overhead: float = 20e-6    # host time to issue this op
    meta: dict = field(default_factory=dict)  # e.g. {"shape": (8192, 8484)}


def program_from_config(cfg: ModelConfig, *, tokens_global: int = 262144,
                        num_chips: int = 32, layer_groups: int = 8,
                        mfu: float = 0.45, chip_flops: float = 197e12,
                        link_bw: float = 5e10,
                        moe_experts: int = 0) -> list[SimOp]:
    """Per-chip, per-step op program whose durations follow the arch FLOPs.

    The model+batch are sharded over ``num_chips``; durations/flops/bytes
    are the per-chip share, so issue-latency scales stay realistic.
    ``moe_experts > 0`` splits each MoE group's FFN share into that many
    per-expert kernels (``moe_ffn[g].expert{e}``) for expert-skew
    scenarios; it is ignored for non-MoE architectures.
    """
    n_active = cfg.active_param_count()
    step_flops = 6.0 * n_active * tokens_global / num_chips
    per_group = step_flops / layer_groups
    ops: list[SimOp] = [SimOp("dataloader.next_batch", "cpu", 1e-3)]
    # split each group: attention-ish op (40%), ffn-ish op (60%), one comm
    comm_bytes = int(2 * 2 * n_active / (layer_groups * num_chips))
    experts = moe_experts if cfg.num_experts else 0
    for g in range(layer_groups):
        ops.append(SimOp(f"attn_core[{g}]", "compute",
                         0.4 * per_group / (chip_flops * mfu),
                         flops=0.4 * per_group))
        if experts:
            share = 0.6 * per_group / experts
            for e in range(experts):
                ops.append(SimOp(f"moe_ffn[{g}].expert{e}", "compute",
                                 share / (chip_flops * mfu), flops=share))
        else:
            ops.append(SimOp(f"ffn_matmul[{g}]", "compute",
                             0.6 * per_group / (chip_flops * mfu),
                             flops=0.6 * per_group,
                             meta={"shape": (8192, cfg.d_ff or 8192)}))
        ops.append(SimOp(f"allreduce[{g}]", "comm",
                         comm_bytes / link_bw, bytes=comm_bytes, group="dp"))
    ops.append(SimOp("optimizer.update", "compute",
                     0.02 * step_flops / (chip_flops * mfu),
                     flops=0.02 * step_flops))
    return ops


@dataclass
class HangSnapshot:
    step: int
    op_index: int
    op_name: str
    comm: bool
    stacks: dict                     # rank -> list[str]
    ring_progress: Optional[np.ndarray]  # per-rank completed ring steps
    group_ranks: list
    truth_rank: int                  # ground truth (for tests/benchmarks)


# ----------------------------------------------------------------------- #
# Simulator
# ----------------------------------------------------------------------- #
class ClusterSimulator:
    def __init__(self, num_ranks: int, program: list[SimOp], *,
                 seed: int = 0, queue_depth: int = 4096,
                 injections: list[Injection] | None = None,
                 ring_total_steps: int | None = None):
        self.n = num_ranks
        self.program = program
        self.rng = np.random.default_rng(seed)
        self.queue_depth = queue_depth
        self._injectors = resolve_injections(injections)
        self.injections = [h.inj for h in self._injectors
                           if h.inj is not None]
        self.ring_total_steps = ring_total_steps or 2 * (num_ranks - 1)
        self.hang: Optional[HangSnapshot] = None

    # ------------------------------------------------------------------ #
    def run(self, num_steps: int) -> dict[int, list[TraceEvent]]:
        """Legacy per-event view; delegates to the columnar fast path."""
        return self.run_batch(num_steps).to_events_by_rank()

    def hit_ranks(self, inj: Injection) -> np.ndarray:
        """The rank-index vector an injection targets (empty = all) —
        deduped/bounded, the way the legacy emitter membership-tested."""
        if not inj.ranks:
            return np.arange(self.n)
        return np.asarray(sorted({r for r in inj.ranks if 0 <= r < self.n}),
                          np.int64)

    _hit_ranks = hit_ranks          # pre-registry spelling (back-compat)

    def run_batch(self, num_steps: int) -> EventBatch:
        """Emit the trace as an ``EventBatch``: whole rank-vectors per op,
        no per-rank Python loops.  Injector hooks run in injection-list
        order at every hook point, and vector draws consume the same
        PCG64 stream as the scalar draws they replaced — so for the
        legacy kinds, timestamps (and therefore every diagnosis) are
        bit-for-bit identical to the historical inline emitter."""
        n = self.n
        all_ranks = np.arange(n)
        b = EventBatchBuilder()
        cpu = np.zeros(n)
        gpu = np.zeros(n)
        ring = np.zeros((n, max(self.queue_depth, 1)))  # issue-queue ends
        qi = 0

        for step in range(num_steps):
            step_t0 = cpu.copy()
            for oi, op in enumerate(self.program):
                inj_hang = self._hang_at(step, oi, op)
                if inj_hang is not None:
                    self._finalize_hang(b, step, oi, op, inj_hang, cpu, gpu)
                    return b.build()
                # ---- host-side pre-op stalls (GC / sync / storms) ------ #
                for h in self._injectors:
                    h.pre_op(self, b, step, oi, op, cpu)
                # ---- issue-queue bound (CPU can't run ahead forever) --- #
                cpu = np.maximum(cpu, ring[:, qi % ring.shape[1]])
                # ---- per-op host overhead ------------------------------ #
                over = op.cpu_overhead * (0.5 + self.rng.random(n))
                issue = cpu + over
                cpu = issue.copy()

                if op.kind == "cpu":
                    dur = self._cpu_duration(op, step)
                    is_dl = "dataloader" in op.name
                    b.append_block(
                        EventKind.DATALOADER if is_dl else EventKind.PY_API,
                        op.name, all_ranks, issue, issue, issue + dur, step,
                        tokens=self.program_tokens() if is_dl else None)
                    cpu = issue + dur
                    continue

                dur = self._device_duration(op, step)
                if op.kind == "compute":
                    start = np.maximum(issue, gpu)
                    end = start + dur
                    gpu = end
                else:  # collective: starts when every rank is ready
                    ready = np.maximum(issue, gpu)
                    start_all = float(ready.max())
                    start = np.full(n, start_all)
                    end = start + float(dur.max())
                    gpu = end.copy()
                # uninstrumented minority kernels occupy the device silently
                gpu = gpu + self._minority_time(op, step)
                ring[:, qi % ring.shape[1]] = end
                qi += 1
                if op.kind == "compute":
                    b.append_block(
                        EventKind.KERNEL_COMPUTE, op.name, all_ranks,
                        issue, start, end, step,
                        flops=op.flops if op.flops else None,
                        extra=op.meta or None)
                else:
                    b.append_block(
                        EventKind.KERNEL_COMM, op.name, all_ranks,
                        issue, start, end, step,
                        nbytes=op.bytes, group=op.group,
                        extra=op.meta or None)
                # ---- post-collective host behavior (Case-1 sync) ------- #
                if op.kind == "comm":
                    for h in self._injectors:
                        h.post_comm(self, b, step, op, cpu, end)
            # ---- step event per rank ------------------------------------ #
            step_end = np.maximum(cpu, gpu)
            b.append_block(EventKind.STEP, f"step_{step}", all_ranks,
                           step_t0, step_t0, step_end, step,
                           tokens=self.program_tokens())
            # step-boundary sync: the loop reads back loss/metrics, so the
            # CPU drains to the device each step (bounds run-ahead; makes
            # healthy issue latencies spread ~uniformly over the step)
            cpu = np.maximum(cpu, gpu)
        return b.build()

    # ------------------------------------------------------------------ #
    def program_tokens(self) -> int:
        return 8192

    def _cpu_duration(self, op: SimOp, step: int) -> np.ndarray:
        dur = np.full(self.n, op.duration)
        for h in self._injectors:
            dur = h.cpu_duration(self, op, step, dur)
        return dur * (0.9 + 0.2 * self.rng.random(self.n))

    def _device_duration(self, op: SimOp, step: int) -> np.ndarray:
        dur = np.full(self.n, op.duration)
        for h in self._injectors:
            dur = h.device_duration(self, op, step, dur)
        return dur * (0.98 + 0.04 * self.rng.random(self.n))

    def _minority_time(self, op: SimOp, step: int) -> np.ndarray:
        extra = np.zeros(self.n)
        for h in self._injectors:
            extra = h.minority_time(self, op, step, extra)
        return extra

    # ------------------------------------------------------------------ #
    def _hang_at(self, step: int, oi: int, op: SimOp) -> Optional[Injection]:
        for h in self._injectors:
            if h.hang_at(self, step, oi, op):
                return h.inj
        return None

    def _finalize_hang(self, b: EventBatchBuilder, step, oi, op, inj,
                       cpu, gpu):
        """Produce the hang snapshot: per-rank stacks + ring progress."""
        r_fault = inj.ranks[0] if inj.ranks else 0
        comm = op.kind == "comm" and not inj.meta.get("noncomm_crash", False)
        stacks = {}
        for r in range(self.n):
            if comm:
                stacks[r] = ["train_step", "backward", op.name]
            else:
                if r == r_fault:
                    stacks[r] = ["train_step", "dataloader.next_batch",
                                 "os.read"]
                else:
                    nxt = next((o.name for o in self.program[oi:]
                                if o.kind == "comm"), "allreduce[0]")
                    stacks[r] = ["train_step", "backward", nxt]
        progress = None
        if comm:
            total = self.ring_total_steps
            s0 = min(int(inj.meta.get("frozen_at", total // 3)),
                     max(total - 1, 0))
            fifo = int(inj.meta.get("fifo_depth", 2))
            progress = np.zeros(self.n, np.int64)
            for d in range(self.n):
                r = (r_fault + d) % self.n
                if d == 0:
                    progress[r] = min(s0 + fifo, total)
                elif d == 1:
                    progress[r] = s0
                else:
                    progress[r] = min(s0 + min(d - 1, fifo), total)
        self.hang = HangSnapshot(
            step=step, op_index=oi, op_name=op.name, comm=comm,
            stacks=stacks, ring_progress=progress,
            group_ranks=list(range(self.n)), truth_rank=r_fault)
        # heartbeat-style HANG_SUSPECT events from every healthy daemon
        now = float(max(cpu.max(), gpu.max()) + 30.0)
        b.append_block(
            EventKind.HANG_SUSPECT, "hang_suspect", np.arange(self.n),
            now, now, now, step,
            extra=[{"stack": stacks[r], "silent_s": 30.0}
                   for r in range(self.n)])
