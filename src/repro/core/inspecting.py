"""Intra-kernel inspecting: O(1) communication-hang localization (§5.1, Fig 6).

Given the per-rank ring-step progress counters of a hung ring collective
(exported by repro.parallel.collectives, or read live by the simulator),
the faulty *connection* is the one with the minimum completed step: its
sender/receiver pair is the isolation set.  This is O(1) in the number of
communication groups — no NCCL-test-style probe sweep.

``probe_search_cost`` models the paper's baseline (terminate job, run
pairwise tests group by group): O(#groups), >=30 min at thousand-GPU scale.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class RingDiagnosis:
    link: tuple            # (sender, receiver) ranks of the stalled link
    machines: list         # isolation candidates (both ends)
    min_step: int
    confidence: str        # "high" if unique minimum else "review"


def diagnose_ring(progress: np.ndarray) -> RingDiagnosis:
    """progress[r] = ring steps completed by rank r in the hung collective.

    The receiver that stalled first (global min) identifies the broken
    incoming link; the sender on that link is the primary suspect.
    """
    progress = np.asarray(progress)
    n = progress.shape[0]
    lo = int(progress.min())
    receivers = np.flatnonzero(progress == lo)
    rx = int(receivers[0])
    tx = (rx - 1) % n
    confidence = "high" if receivers.size == 1 else "review"
    return RingDiagnosis(link=(tx, rx), machines=[tx, rx],
                         min_step=lo, confidence=confidence)


def inspect_cost_model(num_ranks: int, protocol: str = "SIMPLE",
                       inter_server: bool = True,
                       gpus_per_server: int = 8) -> float:
    """Wall-clock model of the inspector, calibrated to the paper's Fig 10
    (29.4–309.2 s on 16 A100s): attach + scan threadblocks, fully parallel
    across GPUs => constant in cluster size (O(1)).

    SIMPLE scans only thread 0 per block; LL/LL128 scan whole blocks.
    Inter-server rings have fewer blocks (NIC links < NVLink links).
    """
    attach = 20.0  # cuda-gdb attach + script bootstrap
    blocks = 8 if inter_server else 24
    per_block = {"SIMPLE": 1.0, "LL128": 6.5, "LL": 9.0}[protocol]
    return attach + blocks * per_block


def probe_search_cost(num_ranks: int, tp: int = 8, pp: int = 8,
                      ep: int = 1, test_seconds: float = 75.0) -> float:
    """NCCL-test baseline: every configured communication group must be
    probed (paper: 'exhaustive and blind search ... over half an hour')."""
    dp = max(num_ranks // (tp * pp * ep), 1)
    groups = 0
    groups += num_ranks // tp          # TP groups
    groups += num_ranks // pp          # PP groups
    groups += max(num_ranks // dp, 1)  # DP rings
    if ep > 1:
        groups += num_ranks // ep
    return groups * test_seconds / 32.0 + groups * 2.0
    # /32: tests on disjoint groups batched 32-way, +2 s orchestration each
