"""Detector protocol + per-job binding context.

A :class:`Detector` is a *stateful, per-job* plugin: the engine creates a
fresh instance per job (via the registry), binds it once to the job's
:class:`DetectorContext`, then feeds it every closed step's
:class:`~repro.core.metrics.StepMetrics` in ascending step order.  State
(rolling baselines, debounce counters) lives on the instance, which is
what makes streaming diagnosis equal terminal diagnosis: the fleet path
and ``evaluate_all`` advance the same objects through the same calls.

Lifecycle::

    d = DetectorClass(**options)      # from the registry / a DetectorSpec
    d.bind(ctx)                       # once, before any step
    d.observe_step(m, step)           # per closed step, ascending
    d.on_hang(stacks, ring_progress)  # when a majority of daemons report
    d.finalize()                      # once, at end of stream

``observe_step``/``finalize`` return ``list[Anomaly]``; ``on_hang``
returns one ``Anomaly`` or ``None``.  Detectors must not mutate the
metrics object or the context (except detector-private attributes).

Fleet-scope detectors (cross-job correlation) live in
``repro.core.detectors.fleet`` and use ``scope = "fleet"``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core.anomaly import Anomaly


@dataclass
class DetectorContext:
    """What a bound detector may read about its job.

    ``config`` is the job's ``EngineConfig`` (thresholds, rank count,
    kernel shapes).  ``profile`` looks up the learned healthy profile for
    the job's backend/scale *at call time* — profiles may be learned after
    the detector was bound, so do not cache it across steps.  ``baseline``
    is the metrics of the job's FIRST evaluated step (the engine sets it
    before any detector observes that step); ``None`` until then.
    """
    config: object                   # EngineConfig (duck-typed: no import cycle)
    history: object                  # HistoryStore
    baseline: Optional[object] = None   # StepMetrics of the first step

    @property
    def profile(self):
        return self.history.get(self.config.backend, self.config.num_ranks)


@dataclass(frozen=True)
class DetectorSpec:
    """A registry name plus constructor options — the config-file-friendly
    way to parameterize a detector in ``EngineConfig.detectors``."""
    name: str
    options: dict = field(default_factory=dict)


class Detector:
    """Base class for per-job detectors.  Subclass, set ``name`` (the
    registry key) and ``kind`` (the anomaly kind it emits), override the
    lifecycle hooks you need, and register with ``@register_detector``."""

    name: str = ""
    kind: str = ""                   # "fail_slow" | "regression" | "hang" | ...
    scope: str = "job"

    def bind(self, ctx: DetectorContext) -> None:
        self.ctx = ctx

    def state_dict(self) -> dict:
        """Picklable instance state for service checkpoints: everything
        on the instance except the bound context (which the restoring
        engine re-binds).  Detectors holding unpicklable state must
        override this pair."""
        return {k: v for k, v in self.__dict__.items() if k != "ctx"}

    def load_state(self, state: dict) -> None:
        """Inverse of :meth:`state_dict`; call after :meth:`bind`."""
        self.__dict__.update(state)

    def observe_step(self, m, step: int) -> list[Anomaly]:
        return []

    def on_hang(self, stacks: dict, ring_progress=None) -> Optional[Anomaly]:
        return None

    def finalize(self) -> list[Anomaly]:
        return []
