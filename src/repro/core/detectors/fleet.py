"""Fleet-scope detectors: cross-job correlation over the merged stream.

Per-job detectors see one job's metrics; a :class:`FleetDetector` sees
every job's anomalies as their steps close, plus the job -> rack/switch
topology the operator registered with the multiplexer.  That is the seam
for ARGUS-style diagnosis: separating "this job regressed" from "this
machine/network degraded" requires knowing that several *different* jobs
on the *same* hardware went bad at the same time — a question no per-job
engine can answer.

The multiplexer calls ``observe_step(job_id, step, anomalies, ts)`` after
each closed step that produced anomalies (and after a hang, with
``step = -1``); detectors return ``(job_id, Anomaly)`` pairs which the
multiplexer pushes onto the merged stream tagged ``origin="fleet"``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core.anomaly import Anomaly, Team
from repro.core.detectors.registry import register_detector


@dataclass
class FleetContext:
    """What a bound fleet detector may read: the shared job -> attrs
    topology dict (``{"rack": ..., "switch": ...}``; live — jobs may be
    annotated after bind) and the fleet config."""
    topology: dict[str, dict] = field(default_factory=dict)
    config: object = None            # FleetConfig (duck-typed)

    def attrs(self, job_id: str) -> dict:
        return self.topology.get(job_id, {})


class FleetDetector:
    """Base class for fleet-scope detectors (registry scope ``"fleet"``)."""

    name: str = ""
    scope: str = "fleet"

    def bind(self, ctx: FleetContext) -> None:
        self.ctx = ctx

    def state_dict(self) -> dict:
        """Picklable instance state for service checkpoints (the bound
        context is excluded; the restoring multiplexer re-binds)."""
        return {k: v for k, v in self.__dict__.items() if k != "ctx"}

    def load_state(self, state: dict) -> None:
        """Inverse of :meth:`state_dict`; call after :meth:`bind`."""
        self.__dict__.update(state)

    def observe_step(self, job_id: str, step: int,
                     anomalies: list[Anomaly],
                     ts: float) -> list[tuple[str, Anomaly]]:
        return []

    def finalize(self) -> list[tuple[str, Anomaly]]:
        return []


@register_detector
class CrossJobFailSlowCorrelator(FleetDetector):
    """Reclassify co-occurring fail-slows on shared hardware.

    A fail-slow inside one job is routed to operations as that job's
    problem.  But when ``min_jobs`` *distinct* jobs sharing a rack or
    switch all report fail-slows within ``window_s`` of event time, the
    job-scoped diagnosis is wrong: the shared hardware is degrading.  This
    detector re-emits the finding per affected job as INFRASTRUCTURE with
    the shared rack/switch as root cause, evidence listing every
    correlated job and the underlying per-job anomalies.

    Each (scope attr, job) pair is reclassified once — repeated fail-slow
    steps from an already-correlated job do not spam the stream, but a new
    job joining the degraded hardware does emit (for the new job, with the
    grown job set in evidence).
    """

    name = "cross_job_failslow"

    def __init__(self, window_s: float = 60.0, min_jobs: int = 2,
                 attrs: tuple = ("rack", "switch")):
        self.window_s = window_s
        self.min_jobs = min_jobs
        self.attrs = tuple(attrs)
        # (attr, value) -> job_id -> (ts, step, metric) of latest fail-slow
        self._seen: dict[tuple, dict[str, tuple]] = {}
        self._emitted: set[tuple] = set()      # (attr, value, job_id)

    def observe_step(self, job_id, step, anomalies, ts):
        slow = [a for a in anomalies if a.kind == "fail_slow"]
        if not slow:
            return []
        topo = self.ctx.attrs(job_id)
        out: list[tuple[str, Anomaly]] = []
        for attr in self.attrs:
            value = topo.get(attr)
            if value is None:
                continue
            group = self._seen.setdefault((attr, value), {})
            group[job_id] = (float(ts), step, slow[-1].metric)
            # event-time window: jobs advance at their own pace, so prune
            # against the newest observation in THIS group, not wall time
            newest = max(t for t, _, _ in group.values())
            stale = [j for j, (t, _, _) in group.items()
                     if newest - t > self.window_s]
            for j in stale:
                del group[j]
            if len(group) < self.min_jobs:
                continue
            jobs = sorted(group)
            for victim in jobs:
                key = (attr, value, victim)
                if key in self._emitted:
                    continue
                self._emitted.add(key)
                v_ts, v_step, v_metric = group[victim]
                out.append((victim, Anomaly(
                    kind="fail_slow", metric="cross_job_correlation",
                    team=Team.INFRASTRUCTURE,
                    root_cause=f"shared {attr} {value!r} degradation: "
                               f"{len(jobs)} jobs failing slow within "
                               f"{self.window_s:.0f}s — hardware, not the "
                               "job (reclassified from operations)",
                    step=v_step, severity=float(len(jobs)),
                    evidence={attr: value, "jobs": jobs,
                              "window_s": self.window_s,
                              "co_occurring": {
                                  j: {"ts": t, "step": s, "metric": mt}
                                  for j, (t, s, mt) in group.items()}})))
        return out
