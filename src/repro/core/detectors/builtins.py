"""The built-in detector set: the paper's five checks plus hang analysis,
as registered plugins.

Each class wraps the stateless primitives in ``repro.core.failslow`` /
``repro.core.regression`` / ``repro.core.hang`` (which stay importable on
their own — benchmarks and tests use them directly) and owns the per-job
STATE the old engine if-chain kept inline: the throughput changepoint
baseline, the first-step metrics comparison, and the consecutive-step
debounce counters.

The default registry order (``DEFAULT_DETECTORS``) reproduces the
pre-registry engine byte for byte: ``failslow`` (macro ① + sudden
bandwidth), then the regression tier ``issue_latency`` (④),
``voids`` (⑤), ``flops`` (②), ``bandwidth`` (③), then ``hang``.
"""
from __future__ import annotations

from typing import Optional

from repro.core import failslow as fs
from repro.core import regression as rg
from repro.core.anomaly import Anomaly, Team
from repro.core.detectors.base import Detector, DetectorContext
from repro.core.detectors.registry import register_detector
from repro.core.hang import HangDiagnosis, diagnose_hang
from repro.core.metrics import StepMetrics


@register_detector
class FailSlowDetector(Detector):
    """Macro fail-slow (①): rolling-median throughput changepoint with
    micro attribution (per-rank FLOPS -> underclock, per-group bandwidth
    -> network), plus the SUDDEN mid-job bandwidth drop — the paper's
    taxonomy keys on sudden-vs-persistent, so a mid-job drop is a
    fail-slow routed to operations, never a regression."""

    name = "failslow"
    kind = "fail_slow"

    def __init__(self, window: Optional[int] = None,
                 drop: Optional[float] = None):
        self._window = window
        self._drop = drop

    def bind(self, ctx: DetectorContext) -> None:
        super().bind(ctx)
        self._monitor = fs.ThroughputMonitor(
            self._window if self._window is not None
            else ctx.config.failslow_window,
            self._drop if self._drop is not None
            else ctx.config.failslow_drop)

    def observe_step(self, m: StepMetrics, step: int) -> list[Anomaly]:
        found: list[Anomaly] = []
        baseline = self.ctx.baseline

        # ---- macro ①, then micro attribution -------------------------- #
        drop = self._monitor.observe(m.throughput)
        if drop is not None:
            f = fs.attribute_failslow(m, baseline, step, drop)
            found.append(Anomaly(
                kind="fail_slow", metric="throughput", team=Team.OPERATIONS,
                root_cause={"gpu_underclock":
                            f"GPU underclocking on ranks {f.ranks}",
                            "network":
                            "network degradation (jitter/congestion); "
                            "binary-search probe plan attached",
                            "unknown": "sudden slowdown, cause unresolved"
                            }[f.cause],
                step=step, severity=1.0 + drop, ranks=f.ranks,
                evidence={"drop_frac": drop, **f.evidence,
                          "probe_plan": f.probe_plan}))

        # ---- mid-job bandwidth drop => fail-slow (network) ------------ #
        base_bw = baseline.bandwidth
        slow_groups = [(n, bw / base_bw[n]) for n, bw in m.bandwidth.items()
                       if n in base_bw and base_bw[n] > 0
                       and bw < 0.75 * base_bw[n]]
        if slow_groups and m is not baseline:
            found.append(Anomaly(
                kind="fail_slow", metric="bandwidth", team=Team.OPERATIONS,
                root_cause="network degradation on "
                           f"{len(slow_groups)} collective group(s) "
                           "(jitter/CRC/congestion); probe plan attached",
                step=step, severity=1.0 / min(f for _, f in slow_groups),
                evidence={"slow_groups": slow_groups[:6],
                          "probe_plan": fs.binary_search_plan(m.num_ranks)}))
        return found


class RegressionDetector(Detector):
    """Shared debounce machinery for the regression tier (②-⑤): a micro
    finding must persist ``regression_consecutive`` steps before it
    becomes an anomaly, and any step without the finding resets its
    counter.  Subclasses implement ``propose(m, prof)`` returning raw
    :class:`~repro.core.regression.RegressionFinding`s; without a learned
    healthy profile the whole tier is silent."""

    kind = "regression"

    def __init__(self):
        self._pending: dict[str, int] = {}

    def propose(self, m: StepMetrics, prof) -> list[rg.RegressionFinding]:
        raise NotImplementedError

    def observe_step(self, m: StepMetrics, step: int) -> list[Anomaly]:
        prof = self.ctx.profile
        if prof is None:
            return []
        findings = self.propose(m, prof)
        out: list[Anomaly] = []
        for f in findings:
            self._pending[f.metric] = self._pending.get(f.metric, 0) + 1
            if self._pending[f.metric] >= \
                    self.ctx.config.regression_consecutive:
                out.append(Anomaly(
                    kind="regression", metric=f.metric,
                    team=Team(f.suggested_team),
                    root_cause=f.root_cause, step=step,
                    severity=f.severity, evidence=f.evidence))
        fired = {f.metric for f in findings}
        for key in list(self._pending):
            if key not in fired:
                self._pending[key] = 0
        return out


@register_detector
class IssueLatencyDetector(RegressionDetector):
    """Issue-latency W1 drift (④) -> kernel-issue stall, API narrowing."""

    name = "issue_latency"

    def propose(self, m, prof):
        f = rg.check_issue_latency(m, prof)
        if f is None:
            return []
        # prefer the specific detector: when V_inter also fires this step
        # (the voids plugin will report the dataloader), drop the
        # duplicate issue-latency finding with a dataloader root cause.
        if "dataloader" in f.root_cause.lower() \
                and m.v_inter > prof.v_inter_threshold:
            return []
        return [f]


@register_detector
class VoidsDetector(RegressionDetector):
    """Void percentages (⑤): V_inter (dataloader / host preprocessing)
    and V_minority (un-instrumented minority kernels)."""

    name = "voids"

    def propose(self, m, prof):
        return rg.check_voids(m, prof)


@register_detector
class FlopsDetector(RegressionDetector):
    """Uniform per-kernel FLOPS deficit (②) -> software regression, with
    the Case-2 layout advisor on configured kernel shapes."""

    name = "flops"

    def propose(self, m, prof):
        findings = rg.check_flops(m, prof)
        rg.annotate_layout(findings, self.ctx.config.kernel_shapes)
        return findings


@register_detector
class BandwidthDetector(RegressionDetector):
    """Persistent bandwidth deficit (③) -> configuration/software (e.g.
    GDR module down).  Must be low from the job's FIRST step — sudden
    mid-job drops belong to the fail-slow plugin."""

    name = "bandwidth"

    def propose(self, m, prof):
        return [f for f in rg.check_bandwidth(m, prof)
                if self._also_low_at_start(f, prof)]

    def _also_low_at_start(self, finding, prof) -> bool:
        name = finding.evidence.get("kernel", "")
        base = self.ctx.baseline.bandwidth.get(name)
        exp = prof.expected_bandwidth.get(name)
        if base is None or not exp:
            return True
        return base < rg.BW_REGRESSION_FRAC * exp


@register_detector
class HangAnalysisDetector(Detector):
    """Hang path (①): call-stack analysis, escalating to intra-kernel
    inspecting when all ranks sit in the same collective."""

    name = "hang"
    kind = "hang"

    def on_hang(self, stacks: dict, ring_progress=None) -> Anomaly:
        d: HangDiagnosis = diagnose_hang(stacks, ring_progress)
        return Anomaly(
            kind="hang",
            metric="intra_kernel_inspecting" if d.used_inspector
            else "call_stack_analysis",
            team=Team.OPERATIONS,
            root_cause=d.detail, ranks=d.faulty_ranks,
            evidence={"hang_kind": d.kind, "link": d.link})
