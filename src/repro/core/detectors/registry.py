"""Detector registry: name -> detector class, per scope.

Mirrors the ``TraceCodec`` registry in ``repro.store``: built-ins
self-register at import, third-party detectors register with the same
decorator, and ``EngineConfig.detectors`` / ``FleetConfig.fleet_detectors``
resolve through :func:`resolve_detectors` — the engine never hardcodes a
detector list again.

Names are namespaced by scope (``"job"`` for per-job detectors driven by
the engine, ``"fleet"`` for cross-job detectors driven by the
multiplexer), so a fleet detector may reuse a job detector's name without
clashing.  Registering an existing (scope, name) raises
:class:`DuplicateDetectorError` unless ``replace=True`` — silent
shadowing of a built-in is how diagnosis quietly changes meaning.
"""
from __future__ import annotations

from typing import Optional

from repro.core.detectors.base import Detector, DetectorSpec


class DetectorError(ValueError):
    """Base for registry errors."""


class UnknownDetectorError(DetectorError):
    pass


class DuplicateDetectorError(DetectorError):
    pass


_REGISTRY: dict[tuple[str, str], type] = {}    # (scope, name) -> class

#: The engine's default per-job set.  ORDER IS CONTRACT: it reproduces the
#: pre-registry engine's emission order per step (fail-slows first, then
#: regressions in paper order ②-⑤), which the byte-equivalence tests pin.
DEFAULT_DETECTORS: tuple[str, ...] = (
    "failslow", "issue_latency", "voids", "flops", "bandwidth", "hang")


def register_detector(cls=None, *, name: Optional[str] = None,
                      replace: bool = False):
    """Class decorator (or direct call): register a Detector subclass under
    ``cls.name``/``cls.scope``.  ``name=`` overrides the class attribute;
    ``replace=True`` allows overriding an existing registration (e.g. a
    site-specific variant of a built-in)."""
    def _register(c):
        key_name = name or getattr(c, "name", "")
        scope = getattr(c, "scope", "job")
        if not key_name:
            raise DetectorError(
                f"{c.__name__} has no detector name: set a class-level "
                "``name`` or pass register_detector(name=...)")
        key = (scope, key_name)
        if key in _REGISTRY and not replace:
            raise DuplicateDetectorError(
                f"detector {key_name!r} (scope {scope!r}) is already "
                f"registered to {_REGISTRY[key].__name__}; pass "
                "replace=True to override it")
        if name is not None:
            c.name = name
        _REGISTRY[key] = c
        return c
    return _register(cls) if cls is not None else _register


def unregister_detector(name: str, scope: str = "job") -> None:
    """Remove a registration (tests / plugin teardown)."""
    _REGISTRY.pop((scope, name), None)


def detector_names(scope: str = "job") -> list[str]:
    return sorted(n for (s, n) in _REGISTRY if s == scope)


def get_detector(name: str, scope: str = "job") -> type:
    try:
        return _REGISTRY[(scope, name)]
    except KeyError:
        raise UnknownDetectorError(
            f"unknown {scope} detector {name!r}; registered: "
            f"{detector_names(scope)}") from None


def resolve_detectors(entries, scope: str = "job") -> list[Detector]:
    """Turn a config-level detector list into fresh, unbound instances.

    Each entry may be a registry name (``"failslow"``), a
    :class:`DetectorSpec` (name + constructor options), a Detector
    subclass, or an already-constructed instance (used as-is — the caller
    owns cross-engine state sharing if it passes one instance twice).
    ``entries=None`` resolves the default set for the scope (the built-in
    five + hang for ``"job"``, empty for ``"fleet"``).
    """
    if entries is None:
        entries = DEFAULT_DETECTORS if scope == "job" else ()
    out: list[Detector] = []
    for e in entries:
        if isinstance(e, str):
            out.append(get_detector(e, scope)())
        elif isinstance(e, DetectorSpec):
            out.append(get_detector(e.name, scope)(**e.options))
        elif isinstance(e, type):
            out.append(e())
        else:
            out.append(e)                      # instance
        got = getattr(out[-1], "scope", "job")
        if got != scope:
            raise DetectorError(
                f"detector {getattr(out[-1], 'name', out[-1])!r} has scope "
                f"{got!r}, expected {scope!r}")
    return out
