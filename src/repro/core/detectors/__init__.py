"""Pluggable anomaly detectors — the diagnosis extension seam.

The engine does not hardcode its checks: ``EngineConfig.detectors`` is a
list of registry names / :class:`DetectorSpec`s resolved through
:func:`resolve_detectors`, and ``evaluate_all`` / the fleet's incremental
path simply drive every resolved plugin's lifecycle.  The paper's five
checks (plus hang analysis) are themselves registered plugins
(``builtins.py``); adding a sixth anomaly class is a new class + one
``@register_detector``, never an engine edit.  See ``README.md`` in this
package for the contract and a worked third-party example.

Two scopes:

  * ``"job"`` — stateful per-job detectors bound to one
    :class:`DetectorContext`, observing one job's ``StepMetrics`` stream
    (``base.py``, ``builtins.py``);
  * ``"fleet"`` — cross-job detectors bound to a :class:`FleetContext`,
    observing every job's anomalies + rack/switch topology through the
    multiplexer (``fleet.py``) — e.g. :class:`CrossJobFailSlowCorrelator`
    reclassifies co-occurring fail-slows on shared hardware as
    INFRASTRUCTURE.
"""
from repro.core.detectors.base import (Detector, DetectorContext,  # noqa: F401
                                       DetectorSpec)
from repro.core.detectors.builtins import (BandwidthDetector,  # noqa: F401
                                           FailSlowDetector,
                                           FlopsDetector,
                                           HangAnalysisDetector,
                                           IssueLatencyDetector,
                                           RegressionDetector,
                                           VoidsDetector)
from repro.core.detectors.fleet import (CrossJobFailSlowCorrelator,  # noqa: F401
                                        FleetContext, FleetDetector)
from repro.core.detectors.registry import (DEFAULT_DETECTORS,  # noqa: F401
                                           DetectorError,
                                           DuplicateDetectorError,
                                           UnknownDetectorError,
                                           detector_names, get_detector,
                                           register_detector,
                                           resolve_detectors,
                                           unregister_detector)

__all__ = [
    "Detector", "DetectorContext", "DetectorSpec",
    "FleetDetector", "FleetContext", "CrossJobFailSlowCorrelator",
    "RegressionDetector", "FailSlowDetector", "IssueLatencyDetector",
    "VoidsDetector", "FlopsDetector", "BandwidthDetector",
    "HangAnalysisDetector",
    "DEFAULT_DETECTORS", "register_detector", "unregister_detector",
    "resolve_detectors", "get_detector", "detector_names",
    "DetectorError", "UnknownDetectorError", "DuplicateDetectorError",
]
