"""The diagnostic engine (paper §3, §5): streaming ingest -> detection ->
root-cause narrowing -> team routing (Table 1).

Pipeline (paper Fig 2):
  ① hang errors: daemon heartbeats -> call-stack analysis -> intra-kernel
     inspecting -> OPERATIONS team.
  ① fail-slows: macro throughput changepoint, validated + attributed with
     micro metrics (per-rank FLOPS, bandwidth) -> OPERATIONS team.
  ② regressions: micro metrics (issue-latency W1, voids, FLOPS, bandwidth)
     vs the healthy historical profile -> ALGORITHM or INFRASTRUCTURE team.
  ③ anything unresolved escalates to cross-team review.

Storage: events live in a step-partitioned columnar ``EventBatch`` — the
engine never keeps per-rank Python lists.  Producers may feed it TraceEvent
lists (the daemon sink), the legacy rank -> events dict, or EventBatches
directly (``ingest_batch``, zero-copy append); ``evaluate_all`` computes
every step's five metrics in ONE vectorized sweep (``aggregate_all``)
instead of rescanning events per step.  Fleet operation evaluates
INCREMENTALLY instead: ``evaluate_step_batch`` (slice held by the fleet
store) or ``evaluate_new_steps`` (own store, watermark-gated) advance the
same stateful detectors step by step, so a job is diagnosed while it runs
— see ``repro.fleet``.

Conservative policy (paper §8.2): the engine *reports*; it never kills jobs.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core import failslow as fs
from repro.core import regression as rg
from repro.core.columnar import KIND_TO_CODE, EventBatch
from repro.core.events import EventKind, TraceEvent
from repro.core.hang import HangDiagnosis, diagnose_hang
from repro.core.history import HealthyProfile, HistoryStore
from repro.core.metrics import StepMetrics, aggregate_all, aggregate_slice

_C_HANG = KIND_TO_CODE[EventKind.HANG_SUSPECT]


class Team(str, enum.Enum):
    OPERATIONS = "operations"
    ALGORITHM = "algorithm"
    INFRASTRUCTURE = "infrastructure"
    CROSS_TEAM = "cross-team"


@dataclass
class Anomaly:
    kind: str            # hang | fail_slow | regression
    metric: str          # detector that fired
    team: Team
    root_cause: str
    step: int = -1
    severity: float = 1.0
    ranks: list = field(default_factory=list)
    evidence: dict = field(default_factory=dict)

    def __str__(self):
        return (f"[{self.kind}/{self.metric}] -> {self.team.value}: "
                f"{self.root_cause} (step {self.step}, "
                f"severity {self.severity:.2f})")


@dataclass
class EngineConfig:
    backend: str = "dense-train"
    num_ranks: int = 1
    kernel_shapes: dict = field(default_factory=dict)  # name -> shape (layout advisor)
    failslow_window: int = 8
    failslow_drop: float = 0.12
    regression_consecutive: int = 2   # steps a micro signal must persist


def _also_low_at_start(finding, baseline: StepMetrics,
                       prof) -> bool:
    name = finding.evidence.get("kernel", "")
    base = baseline.bandwidth.get(name)
    exp = prof.expected_bandwidth.get(name)
    if base is None or not exp:
        return True
    return base < rg.BW_REGRESSION_FRAC * exp


class DiagnosticEngine:
    def __init__(self, config: EngineConfig,
                 history: Optional[HistoryStore] = None):
        self.cfg = config
        self.history = history or HistoryStore()
        self._chunks: list[EventBatch] = []
        self._merged: Optional[EventBatch] = None
        self._metrics_cache: Optional[dict[int, StepMetrics]] = None
        self.metrics: dict[int, StepMetrics] = {}
        self.anomalies: list[Anomaly] = []
        self.baseline_metrics: Optional[StepMetrics] = None
        self._tp_monitor = fs.ThroughputMonitor(
            config.failslow_window, config.failslow_drop)
        self._pending_regressions: dict[str, int] = {}
        self._evaluated: set[int] = set()   # steps seen by the incremental path

    # ------------------------------------------------------------------ #
    # ingest — all producers land in the columnar store
    # ------------------------------------------------------------------ #
    def ingest(self, events: list[TraceEvent]):
        """Daemon-sink entry point: a flat TraceEvent list."""
        if events:
            self._add(EventBatch.from_events(events))

    def ingest_all(self, events_by_rank):
        """Legacy rank -> event-list dict, or an EventBatch."""
        if isinstance(events_by_rank, EventBatch):
            self._add(events_by_rank)
        elif events_by_rank:
            self._add(EventBatch.from_events_by_rank(events_by_rank))

    def ingest_batch(self, batch: EventBatch):
        """Zero-conversion columnar append (the scale path)."""
        self._add(batch)

    def _add(self, batch: EventBatch):
        if len(batch):
            self._chunks.append(batch)
            self._merged = None
            self._metrics_cache = None

    @property
    def batch(self) -> EventBatch:
        """The consolidated columnar store (chunks merged lazily)."""
        if self._merged is None:
            self._merged = EventBatch.concat(self._chunks)
            self._chunks = [self._merged] if len(self._merged) else []
        return self._merged

    @property
    def events_by_rank(self) -> dict[int, list[TraceEvent]]:
        """Materialized per-event view — conversion cost, debugging only."""
        return self.batch.to_events_by_rank()

    def _all_metrics(self) -> dict[int, StepMetrics]:
        if self._metrics_cache is None:
            self._metrics_cache = aggregate_all(self.batch)
        return self._metrics_cache

    @property
    def profile(self) -> Optional[HealthyProfile]:
        return self.history.get(self.cfg.backend, self.cfg.num_ranks)

    # ------------------------------------------------------------------ #
    # per-step evaluation
    # ------------------------------------------------------------------ #
    def evaluate_step(self, step: int) -> list[Anomaly]:
        m = self._all_metrics().get(step)
        if m is None:
            return []
        return self._evaluate_metrics(m, step)

    def _evaluate_metrics(self, m: StepMetrics, step: int) -> list[Anomaly]:
        self.metrics[step] = m
        if self.baseline_metrics is None:
            self.baseline_metrics = m
        found: list[Anomaly] = []

        # ---- fail-slow (macro ①, then micro attribution) -------------- #
        drop = self._tp_monitor.observe(m.throughput)
        if drop is not None:
            f = fs.attribute_failslow(m, self.baseline_metrics, step, drop)
            found.append(Anomaly(
                kind="fail_slow", metric="throughput", team=Team.OPERATIONS,
                root_cause={"gpu_underclock":
                            f"GPU underclocking on ranks {f.ranks}",
                            "network":
                            "network degradation (jitter/congestion); "
                            "binary-search probe plan attached",
                            "unknown": "sudden slowdown, cause unresolved"
                            }[f.cause],
                step=step, severity=1.0 + drop, ranks=f.ranks,
                evidence={"drop_frac": drop, **f.evidence,
                          "probe_plan": f.probe_plan}))

        # ---- mid-job bandwidth drop => fail-slow (network), not a
        # regression: the paper's taxonomy keys on SUDDEN vs PERSISTENT ---- #
        base_bw = self.baseline_metrics.bandwidth
        slow_groups = [(n, bw / base_bw[n]) for n, bw in m.bandwidth.items()
                       if n in base_bw and base_bw[n] > 0
                       and bw < 0.75 * base_bw[n]]
        if slow_groups and m is not self.baseline_metrics:
            found.append(Anomaly(
                kind="fail_slow", metric="bandwidth", team=Team.OPERATIONS,
                root_cause="network degradation on "
                           f"{len(slow_groups)} collective group(s) "
                           "(jitter/CRC/congestion); probe plan attached",
                step=step, severity=1.0 / min(f for _, f in slow_groups),
                evidence={"slow_groups": slow_groups[:6],
                          "probe_plan": fs.binary_search_plan(m.num_ranks)}))

        # ---- regressions (micro ②-⑤ vs healthy history) --------------- #
        prof = self.profile
        if prof is not None:
            findings: list[rg.RegressionFinding] = []
            il = rg.check_issue_latency(m, prof)
            if il:
                findings.append(il)
            findings.extend(rg.check_voids(m, prof))
            flops_f = rg.check_flops(m, prof)
            rg.annotate_layout(flops_f, self.cfg.kernel_shapes)
            findings.extend(flops_f)
            # bandwidth regression must be low from the job's FIRST step
            # (persistent config/software issue, e.g. GDR module down)
            bw_f = rg.check_bandwidth(m, prof)
            bw_f = [f for f in bw_f
                    if _also_low_at_start(f, self.baseline_metrics, prof)]
            findings.extend(bw_f)
            # prefer the specific detector: if v_inter fired and the issue-
            # latency culprit is the dataloader, drop the duplicate finding
            if any(f.metric == "v_inter" for f in findings):
                findings = [f for f in findings
                            if not (f.metric == "issue_latency"
                                    and "dataloader" in f.root_cause.lower())]
            for f in findings:
                key = f.metric
                self._pending_regressions[key] = \
                    self._pending_regressions.get(key, 0) + 1
                if self._pending_regressions[key] >= \
                        self.cfg.regression_consecutive:
                    found.append(Anomaly(
                        kind="regression", metric=f.metric,
                        team=Team(f.suggested_team),
                        root_cause=f.root_cause, step=step,
                        severity=f.severity, evidence=f.evidence))
            fired = {f.metric for f in findings}
            for key in list(self._pending_regressions):
                if key not in fired:
                    self._pending_regressions[key] = 0

        self.anomalies.extend(found)
        return found

    def evaluate_all(self) -> list[Anomaly]:
        """One vectorized metrics sweep, then the per-step detector pass."""
        ms = self._all_metrics()
        out = []
        for step in sorted(ms):
            out.extend(self._evaluate_metrics(ms[step], step))
        out.extend(self.check_hangs())
        return out

    # ------------------------------------------------------------------ #
    # incremental evaluation (the fleet path)
    # ------------------------------------------------------------------ #
    def evaluate_step_batch(self, step_batch: EventBatch, step: int,
                            num_ranks: Optional[int] = None) -> list[Anomaly]:
        """Evaluate ONE completed step from its columnar slice, held by an
        external step-partitioned store (the fleet multiplexer).
        ``step_batch`` must contain only rows of ``step``, in insertion
        order — exactly what ``StepPartitionedStore.pop_step`` yields.

        Detector state (throughput monitor, baseline metrics, pending-
        regression counters) advances exactly as in ``evaluate_all``, so
        feeding every step's slice in ascending order — then the hang check
        — yields identical anomalies to a terminal ``evaluate_all`` on the
        concatenated batch.  ``num_ranks`` should be the job-wide rank
        count (a single step's slice may not show every rank)."""
        m = aggregate_slice(step_batch, step, num_ranks=num_ranks)
        if m is None:
            return []
        self._evaluated.add(step)
        return self._evaluate_metrics(m, step)

    @property
    def evaluated_steps(self) -> set:
        """Steps the incremental path has diagnosed (single source of
        truth for watermark/late-event bookkeeping in the fleet)."""
        return self._evaluated

    def evaluate_new_steps(self, upto: Optional[int] = None) -> list[Anomaly]:
        """Incremental evaluation over the engine's OWN store: evaluate, in
        ascending order, every step not yet evaluated — optionally only
        steps below ``upto`` (the caller's watermark).  Detector work runs
        on the pending steps only, but the store merge + step index are
        still O(total events) per call, so for long-running streamed jobs
        use the fleet path (``repro.fleet``), whose step-partitioned store
        makes each evaluation proportional to the new data.  A terminal
        ``finalize`` is simply ``evaluate_new_steps()`` followed by
        ``check_hangs()``.  Do not mix with ``evaluate_all`` on the same
        engine (it re-runs every step through the stateful detectors)."""
        pending = [s for s in self.batch.steps()
                   if s not in self._evaluated
                   and (upto is None or s < upto)]
        if not pending:
            return []
        ms = aggregate_all(self.batch, steps=pending)
        out: list[Anomaly] = []
        for step in sorted(ms):
            self._evaluated.add(step)
            out.extend(self._evaluate_metrics(ms[step], step))
        return out

    # ------------------------------------------------------------------ #
    # hang path (①)
    # ------------------------------------------------------------------ #
    def check_hangs(self, ring_progress=None) -> list[Anomaly]:
        b = self.batch
        if not len(b):
            return []
        suspects = {}
        for row in np.nonzero(b.kind == _C_HANG)[0].tolist():
            stack = (b.extra.get(row) or {}).get("stack", [])
            suspects[int(b.rank[row])] = stack
        if len(suspects) < max(b.num_distinct_ranks() // 2, 1):
            return []
        return [self.diagnose_hang(suspects, ring_progress)]

    def diagnose_hang(self, stacks: dict,
                      ring_progress=None) -> Anomaly:
        d: HangDiagnosis = diagnose_hang(stacks, ring_progress)
        a = Anomaly(
            kind="hang",
            metric="intra_kernel_inspecting" if d.used_inspector
            else "call_stack_analysis",
            team=Team.OPERATIONS,
            root_cause=d.detail, ranks=d.faulty_ranks,
            evidence={"hang_kind": d.kind, "link": d.link})
        self.anomalies.append(a)
        return a

    # ------------------------------------------------------------------ #
    # profile learning helper
    # ------------------------------------------------------------------ #
    def learn_healthy(self, steps: Optional[list[int]] = None,
                      margin: float = 1.5) -> HealthyProfile:
        ms_all = self._all_metrics()
        steps = steps if steps is not None else sorted(ms_all)
        ms = [ms_all[s] for s in steps if s in ms_all]
        return self.history.learn_from_metrics(
            self.cfg.backend, self.cfg.num_ranks, ms, margin=margin)
