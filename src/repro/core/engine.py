"""The diagnostic engine (paper §3, §5): streaming ingest -> detection ->
root-cause narrowing -> team routing (Table 1).

Pipeline (paper Fig 2):
  ① hang errors: daemon heartbeats -> call-stack analysis -> intra-kernel
     inspecting -> OPERATIONS team.
  ① fail-slows: macro throughput changepoint, validated + attributed with
     micro metrics (per-rank FLOPS, bandwidth) -> OPERATIONS team.
  ② regressions: micro metrics (issue-latency W1, voids, FLOPS, bandwidth)
     vs the healthy historical profile -> ALGORITHM or INFRASTRUCTURE team.
  ③ anything unresolved escalates to cross-team review.

Detection is PLUGGABLE (``repro.core.detectors``): ``EngineConfig.
detectors`` names the per-job detector set, resolved through the registry
into fresh stateful instances bound to this job's ``DetectorContext``.
The paper's checks are themselves registered plugins; the default set
(``DEFAULT_DETECTORS``) reproduces the historical engine byte for byte.
The engine's job is only to aggregate metrics and drive the lifecycle:
``observe_step`` per closed step in ascending order, ``on_hang`` when a
majority of daemons report, ``finalize`` at end of stream.

Storage: events live in a step-partitioned columnar ``EventBatch`` — the
engine never keeps per-rank Python lists.  Producers may feed it TraceEvent
lists (the daemon sink), the legacy rank -> events dict, or EventBatches
directly (``ingest_batch``, zero-copy append); ``evaluate_all`` computes
every step's five metrics in ONE vectorized sweep (``aggregate_all``)
instead of rescanning events per step.  Fleet operation evaluates
INCREMENTALLY instead: ``evaluate_step_batch`` (slice held by the fleet
store) or ``evaluate_new_steps`` (own store, watermark-gated) advance the
same stateful detectors step by step, so a job is diagnosed while it runs
— see ``repro.fleet``.

Conservative policy (paper §8.2): the engine *reports*; it never kills jobs.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.anomaly import Anomaly, Team  # noqa: F401  (re-export)
from repro.core.columnar import KIND_TO_CODE, EventBatch
from repro.core.detectors import DetectorContext, resolve_detectors
from repro.core.events import EventKind, TraceEvent
from repro.core.history import HealthyProfile, HistoryStore
from repro.core.metrics import StepMetrics, aggregate_all, aggregate_slice

_C_HANG = KIND_TO_CODE[EventKind.HANG_SUSPECT]


@dataclass
class EngineConfig:
    backend: str = "dense-train"
    num_ranks: int = 1
    kernel_shapes: dict = field(default_factory=dict)  # name -> shape (layout advisor)
    failslow_window: int = 8
    failslow_drop: float = 0.12
    regression_consecutive: int = 2   # steps a micro signal must persist
    # per-job detector set: registry names, DetectorSpecs, classes, or
    # instances (see repro.core.detectors).  None = DEFAULT_DETECTORS —
    # the paper's five checks + hang analysis, byte-equivalent to the
    # pre-registry engine.
    detectors: Optional[list] = None


class DiagnosticEngine:
    def __init__(self, config: EngineConfig,
                 history: Optional[HistoryStore] = None):
        self.cfg = config
        self.history = history or HistoryStore()
        self._chunks: list[EventBatch] = []
        self._merged: Optional[EventBatch] = None
        self._metrics_cache: Optional[dict[int, StepMetrics]] = None
        self.metrics: dict[int, StepMetrics] = {}
        self.anomalies: list[Anomaly] = []
        self._evaluated: set[int] = set()   # steps seen by the incremental path
        self._finalized = False
        self._finalize_lock = threading.Lock()
        self.ctx = DetectorContext(config=config, history=self.history)
        self.detectors = resolve_detectors(config.detectors)
        for d in self.detectors:
            d.bind(self.ctx)

    @property
    def baseline_metrics(self) -> Optional[StepMetrics]:
        """Metrics of the first evaluated step (shared with detectors)."""
        return self.ctx.baseline

    @baseline_metrics.setter
    def baseline_metrics(self, m: Optional[StepMetrics]):
        self.ctx.baseline = m

    # ------------------------------------------------------------------ #
    # ingest — all producers land in the columnar store
    # ------------------------------------------------------------------ #
    def ingest(self, events: list[TraceEvent]):
        """Daemon-sink entry point: a flat TraceEvent list."""
        if events:
            self._add(EventBatch.from_events(events))

    def ingest_all(self, events_by_rank):
        """Legacy rank -> event-list dict, or an EventBatch."""
        if isinstance(events_by_rank, EventBatch):
            self._add(events_by_rank)
        elif events_by_rank:
            self._add(EventBatch.from_events_by_rank(events_by_rank))

    def ingest_batch(self, batch: EventBatch):
        """Zero-conversion columnar append (the scale path)."""
        self._add(batch)

    def _add(self, batch: EventBatch):
        if len(batch):
            self._chunks.append(batch)
            self._merged = None
            self._metrics_cache = None

    @property
    def batch(self) -> EventBatch:
        """The consolidated columnar store (chunks merged lazily)."""
        if self._merged is None:
            self._merged = EventBatch.concat(self._chunks)
            self._chunks = [self._merged] if len(self._merged) else []
        return self._merged

    @property
    def events_by_rank(self) -> dict[int, list[TraceEvent]]:
        """Materialized per-event view — conversion cost, debugging only."""
        return self.batch.to_events_by_rank()

    def _all_metrics(self) -> dict[int, StepMetrics]:
        if self._metrics_cache is None:
            self._metrics_cache = aggregate_all(self.batch)
        return self._metrics_cache

    @property
    def profile(self) -> Optional[HealthyProfile]:
        return self.history.get(self.cfg.backend, self.cfg.num_ranks)

    # ------------------------------------------------------------------ #
    # per-step evaluation: drive the detector plugins
    # ------------------------------------------------------------------ #
    def evaluate_step(self, step: int) -> list[Anomaly]:
        m = self._all_metrics().get(step)
        if m is None:
            return []
        return self._evaluate_metrics(m, step)

    def _evaluate_metrics(self, m: StepMetrics, step: int) -> list[Anomaly]:
        self.metrics[step] = m
        if self.ctx.baseline is None:
            self.ctx.baseline = m
        found: list[Anomaly] = []
        for d in self.detectors:
            found.extend(d.observe_step(m, step))
        self.anomalies.extend(found)
        return found

    def evaluate_all(self) -> list[Anomaly]:
        """One vectorized metrics sweep, then the per-step detector pass."""
        ms = self._all_metrics()
        out = []
        for step in sorted(ms):
            out.extend(self._evaluate_metrics(ms[step], step))
        out.extend(self.check_hangs())
        out.extend(self.finalize_detectors())
        return out

    def finalize_detectors(self) -> list[Anomaly]:
        """End-of-stream hook: every detector's ``finalize()``, once —
        the check-and-set is locked so an engine driven from a replay
        worker thread and finalized from the main thread can't run a
        stateful detector's flush twice.  The built-ins return nothing
        here; stateful third-party detectors (e.g. trend accumulators)
        flush their tail findings."""
        with self._finalize_lock:
            if self._finalized:
                return []
            self._finalized = True
        found: list[Anomaly] = []
        for d in self.detectors:
            found.extend(d.finalize())
        self.anomalies.extend(found)
        return found

    # ------------------------------------------------------------------ #
    # incremental evaluation (the fleet path)
    # ------------------------------------------------------------------ #
    def evaluate_step_batch(self, step_batch: EventBatch, step: int,
                            num_ranks: Optional[int] = None) -> list[Anomaly]:
        """Evaluate ONE completed step from its columnar slice, held by an
        external step-partitioned store (the fleet multiplexer).
        ``step_batch`` must contain only rows of ``step``, in insertion
        order — exactly what ``StepPartitionedStore.pop_step`` yields.

        Detector state (throughput monitor, baseline metrics, pending-
        regression counters) advances exactly as in ``evaluate_all``, so
        feeding every step's slice in ascending order — then the hang check
        — yields identical anomalies to a terminal ``evaluate_all`` on the
        concatenated batch.  ``num_ranks`` should be the job-wide rank
        count (a single step's slice may not show every rank)."""
        m = aggregate_slice(step_batch, step, num_ranks=num_ranks)
        if m is None:
            return []
        self._evaluated.add(step)
        return self._evaluate_metrics(m, step)

    @property
    def evaluated_steps(self) -> set:
        """Steps the incremental path has diagnosed (single source of
        truth for watermark/late-event bookkeeping in the fleet)."""
        return self._evaluated

    def adopt_evaluated(self, steps) -> None:
        """Mark ``steps`` as already diagnosed — by ANOTHER engine whose
        results this one is mirroring (a fleet replay worker process ran
        the job's evaluation; the parent adopts its record so late-row
        bookkeeping and re-flush stay consistent).  Detector state does
        NOT transfer; only the evaluated-step set does."""
        self._evaluated.update(int(s) for s in steps)

    # ------------------------------------------------------------------ #
    # service checkpoints: full incremental-path state transfer
    # ------------------------------------------------------------------ #
    def snapshot_state(self) -> dict:
        """Picklable state of the INCREMENTAL evaluation path, complete
        enough that a fresh engine restored from it continues the stream
        byte-equivalently: evaluated-step set, finalize flag, the
        first-step baseline, and every detector's instance state (in
        configured order).  The ``metrics``/``anomalies`` histories are
        deliberately NOT included — they are debug/query conveniences
        reconstructed from the archive, not inputs to diagnosis."""
        return {
            "evaluated": sorted(self._evaluated),
            "finalized": self._finalized,
            "baseline": self.ctx.baseline,
            "detectors": [(type(d).name, d.state_dict())
                          for d in self.detectors],
        }

    def restore_state(self, state: dict) -> None:
        """Inverse of :meth:`snapshot_state` on a freshly constructed
        engine with the SAME config (the detector set must match — the
        checkpoint records instance state, not instances)."""
        have = [type(d).name for d in self.detectors]
        want = [nm for nm, _ in state["detectors"]]
        if have != want:
            raise ValueError(
                f"detector set mismatch restoring engine state: "
                f"checkpoint has {want}, engine has {have}")
        self._evaluated = {int(s) for s in state["evaluated"]}
        self._finalized = bool(state["finalized"])
        self.ctx.baseline = state["baseline"]
        for d, (_nm, ds) in zip(self.detectors, state["detectors"]):
            d.load_state(ds)

    def evaluate_new_steps(self, upto: Optional[int] = None) -> list[Anomaly]:
        """Incremental evaluation over the engine's OWN store: evaluate, in
        ascending order, every step not yet evaluated — optionally only
        steps below ``upto`` (the caller's watermark).  Detector work runs
        on the pending steps only, but the store merge + step index are
        still O(total events) per call, so for long-running streamed jobs
        use the fleet path (``repro.fleet``), whose step-partitioned store
        makes each evaluation proportional to the new data.  A terminal
        ``finalize`` is simply ``evaluate_new_steps()`` followed by
        ``check_hangs()``.  Do not mix with ``evaluate_all`` on the same
        engine (it re-runs every step through the stateful detectors)."""
        pending = [s for s in self.batch.steps()
                   if s not in self._evaluated
                   and (upto is None or s < upto)]
        if not pending:
            return []
        ms = aggregate_all(self.batch, steps=pending)
        out: list[Anomaly] = []
        for step in sorted(ms):
            self._evaluated.add(step)
            out.extend(self._evaluate_metrics(ms[step], step))
        return out

    # ------------------------------------------------------------------ #
    # hang path (①)
    # ------------------------------------------------------------------ #
    def check_hangs(self, ring_progress=None) -> list[Anomaly]:
        b = self.batch
        if not len(b):
            return []
        suspects = {}
        for row in np.nonzero(b.kind == _C_HANG)[0].tolist():
            stack = (b.extra.get(row) or {}).get("stack", [])
            suspects[int(b.rank[row])] = stack
        if len(suspects) < max(b.num_distinct_ranks() // 2, 1):
            return []
        return self.on_hang(suspects, ring_progress)

    def on_hang(self, stacks: dict, ring_progress=None) -> list[Anomaly]:
        """Fan a majority-hang report out to every detector's ``on_hang``;
        with the default set, exactly the hang-analysis plugin answers."""
        found: list[Anomaly] = []
        for d in self.detectors:
            a = d.on_hang(stacks, ring_progress)
            if a is not None:
                found.append(a)
        self.anomalies.extend(found)
        return found

    def diagnose_hang(self, stacks: dict,
                      ring_progress=None) -> Optional[Anomaly]:
        """Back-compat single-anomaly hang entry point: first detector
        answer (``None`` only if the configured set has no hang handler)."""
        found = self.on_hang(stacks, ring_progress)
        return found[0] if found else None

    # ------------------------------------------------------------------ #
    # profile learning helper
    # ------------------------------------------------------------------ #
    def learn_healthy(self, steps: Optional[list[int]] = None,
                      margin: float = 1.5) -> HealthyProfile:
        ms_all = self._all_metrics()
        steps = steps if steps is not None else sorted(ms_all)
        ms = [ms_all[s] for s in steps if s in ms_all]
        return self.history.learn_from_metrics(
            self.cfg.backend, self.cfg.num_ranks, ms, margin=margin)
