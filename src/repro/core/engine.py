"""The diagnostic engine (paper §3, §5): streaming ingest -> detection ->
root-cause narrowing -> team routing (Table 1).

Pipeline (paper Fig 2):
  ① hang errors: daemon heartbeats -> call-stack analysis -> intra-kernel
     inspecting -> OPERATIONS team.
  ① fail-slows: macro throughput changepoint, validated + attributed with
     micro metrics (per-rank FLOPS, bandwidth) -> OPERATIONS team.
  ② regressions: micro metrics (issue-latency W1, voids, FLOPS, bandwidth)
     vs the healthy historical profile -> ALGORITHM or INFRASTRUCTURE team.
  ③ anything unresolved escalates to cross-team review.

Conservative policy (paper §8.2): the engine *reports*; it never kills jobs.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core import failslow as fs
from repro.core import regression as rg
from repro.core.events import EventKind, TraceEvent
from repro.core.hang import HangDiagnosis, diagnose_hang
from repro.core.history import HealthyProfile, HistoryStore
from repro.core.metrics import StepMetrics, aggregate_step, steps_in


class Team(str, enum.Enum):
    OPERATIONS = "operations"
    ALGORITHM = "algorithm"
    INFRASTRUCTURE = "infrastructure"
    CROSS_TEAM = "cross-team"


@dataclass
class Anomaly:
    kind: str            # hang | fail_slow | regression
    metric: str          # detector that fired
    team: Team
    root_cause: str
    step: int = -1
    severity: float = 1.0
    ranks: list = field(default_factory=list)
    evidence: dict = field(default_factory=dict)

    def __str__(self):
        return (f"[{self.kind}/{self.metric}] -> {self.team.value}: "
                f"{self.root_cause} (step {self.step}, "
                f"severity {self.severity:.2f})")


@dataclass
class EngineConfig:
    backend: str = "dense-train"
    num_ranks: int = 1
    kernel_shapes: dict = field(default_factory=dict)  # name -> shape (layout advisor)
    failslow_window: int = 8
    failslow_drop: float = 0.12
    regression_consecutive: int = 2   # steps a micro signal must persist


def _also_low_at_start(finding, baseline: StepMetrics,
                       prof) -> bool:
    name = finding.evidence.get("kernel", "")
    base = baseline.bandwidth.get(name)
    exp = prof.expected_bandwidth.get(name)
    if base is None or not exp:
        return True
    return base < rg.BW_REGRESSION_FRAC * exp


class DiagnosticEngine:
    def __init__(self, config: EngineConfig,
                 history: Optional[HistoryStore] = None):
        self.cfg = config
        self.history = history or HistoryStore()
        self.events_by_rank: dict[int, list[TraceEvent]] = {}
        self.metrics: dict[int, StepMetrics] = {}
        self.anomalies: list[Anomaly] = []
        self.baseline_metrics: Optional[StepMetrics] = None
        self._tp_monitor = fs.ThroughputMonitor(
            config.failslow_window, config.failslow_drop)
        self._pending_regressions: dict[str, int] = {}

    # ------------------------------------------------------------------ #
    # ingest
    # ------------------------------------------------------------------ #
    def ingest(self, events: list[TraceEvent]):
        for ev in events:
            self.events_by_rank.setdefault(ev.rank, []).append(ev)

    def ingest_all(self, events_by_rank: dict[int, list[TraceEvent]]):
        for r, evs in events_by_rank.items():
            self.events_by_rank.setdefault(r, []).extend(evs)

    @property
    def profile(self) -> Optional[HealthyProfile]:
        return self.history.get(self.cfg.backend, self.cfg.num_ranks)

    # ------------------------------------------------------------------ #
    # per-step evaluation
    # ------------------------------------------------------------------ #
    def evaluate_step(self, step: int) -> list[Anomaly]:
        m = aggregate_step(self.events_by_rank, step)
        if m is None:
            return []
        self.metrics[step] = m
        if self.baseline_metrics is None:
            self.baseline_metrics = m
        found: list[Anomaly] = []

        # ---- fail-slow (macro ①, then micro attribution) -------------- #
        drop = self._tp_monitor.observe(m.throughput)
        if drop is not None:
            f = fs.attribute_failslow(m, self.baseline_metrics, step, drop)
            found.append(Anomaly(
                kind="fail_slow", metric="throughput", team=Team.OPERATIONS,
                root_cause={"gpu_underclock":
                            f"GPU underclocking on ranks {f.ranks}",
                            "network":
                            "network degradation (jitter/congestion); "
                            "binary-search probe plan attached",
                            "unknown": "sudden slowdown, cause unresolved"
                            }[f.cause],
                step=step, severity=1.0 + drop, ranks=f.ranks,
                evidence={"drop_frac": drop, **f.evidence,
                          "probe_plan": f.probe_plan}))

        # ---- mid-job bandwidth drop => fail-slow (network), not a
        # regression: the paper's taxonomy keys on SUDDEN vs PERSISTENT ---- #
        base_bw = self.baseline_metrics.bandwidth
        slow_groups = [(n, bw / base_bw[n]) for n, bw in m.bandwidth.items()
                       if n in base_bw and base_bw[n] > 0
                       and bw < 0.75 * base_bw[n]]
        if slow_groups and m is not self.baseline_metrics:
            found.append(Anomaly(
                kind="fail_slow", metric="bandwidth", team=Team.OPERATIONS,
                root_cause="network degradation on "
                           f"{len(slow_groups)} collective group(s) "
                           "(jitter/CRC/congestion); probe plan attached",
                step=step, severity=1.0 / min(f for _, f in slow_groups),
                evidence={"slow_groups": slow_groups[:6],
                          "probe_plan": fs.binary_search_plan(m.num_ranks)}))

        # ---- regressions (micro ②-⑤ vs healthy history) --------------- #
        prof = self.profile
        if prof is not None:
            findings: list[rg.RegressionFinding] = []
            il = rg.check_issue_latency(m, prof)
            if il:
                findings.append(il)
            findings.extend(rg.check_voids(m, prof))
            flops_f = rg.check_flops(m, prof)
            rg.annotate_layout(flops_f, self.cfg.kernel_shapes)
            findings.extend(flops_f)
            # bandwidth regression must be low from the job's FIRST step
            # (persistent config/software issue, e.g. GDR module down)
            bw_f = rg.check_bandwidth(m, prof)
            bw_f = [f for f in bw_f
                    if _also_low_at_start(f, self.baseline_metrics, prof)]
            findings.extend(bw_f)
            # prefer the specific detector: if v_inter fired and the issue-
            # latency culprit is the dataloader, drop the duplicate finding
            if any(f.metric == "v_inter" for f in findings):
                findings = [f for f in findings
                            if not (f.metric == "issue_latency"
                                    and "dataloader" in f.root_cause.lower())]
            for f in findings:
                key = f.metric
                self._pending_regressions[key] = \
                    self._pending_regressions.get(key, 0) + 1
                if self._pending_regressions[key] >= \
                        self.cfg.regression_consecutive:
                    found.append(Anomaly(
                        kind="regression", metric=f.metric,
                        team=Team(f.suggested_team),
                        root_cause=f.root_cause, step=step,
                        severity=f.severity, evidence=f.evidence))
            fired = {f.metric for f in findings}
            for key in list(self._pending_regressions):
                if key not in fired:
                    self._pending_regressions[key] = 0

        self.anomalies.extend(found)
        return found

    def evaluate_all(self) -> list[Anomaly]:
        out = []
        for step in steps_in(self.events_by_rank):
            out.extend(self.evaluate_step(step))
        out.extend(self.check_hangs())
        return out

    # ------------------------------------------------------------------ #
    # hang path (①)
    # ------------------------------------------------------------------ #
    def check_hangs(self, ring_progress=None) -> list[Anomaly]:
        suspects = {}
        for r, evs in self.events_by_rank.items():
            for e in evs:
                if e.kind == EventKind.HANG_SUSPECT:
                    suspects[r] = e.meta.get("stack", [])
        if len(suspects) < max(len(self.events_by_rank) // 2, 1):
            return []
        return [self.diagnose_hang(suspects, ring_progress)]

    def diagnose_hang(self, stacks: dict,
                      ring_progress=None) -> Anomaly:
        d: HangDiagnosis = diagnose_hang(stacks, ring_progress)
        a = Anomaly(
            kind="hang",
            metric="intra_kernel_inspecting" if d.used_inspector
            else "call_stack_analysis",
            team=Team.OPERATIONS,
            root_cause=d.detail, ranks=d.faulty_ranks,
            evidence={"hang_kind": d.kind, "link": d.link})
        self.anomalies.append(a)
        return a

    # ------------------------------------------------------------------ #
    # profile learning helper
    # ------------------------------------------------------------------ #
    def learn_healthy(self, steps: Optional[list[int]] = None,
                      margin: float = 1.5) -> HealthyProfile:
        steps = steps or steps_in(self.events_by_rank)
        ms = [aggregate_step(self.events_by_rank, s) for s in steps]
        ms = [m for m in ms if m is not None]
        return self.history.learn_from_metrics(
            self.cfg.backend, self.cfg.num_ranks, ms, margin=margin)
