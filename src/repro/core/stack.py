"""Call-stack reconstruction from span intervals (paper §4.2).

Plug-and-play instrumentation times Python APIs and kernels through
*separate* mechanisms, so the call stack linking them is lost.  The paper
reconstructs the nesting from (start, end) timestamps before events reach
the engine.  We do the same: sort spans, maintain an open-interval stack,
and annotate every event with its enclosing call path.

Invariant (property-tested): spans from a single thread are either nested
or disjoint; partial overlaps are resolved by treating the later-starting
span as a child until its own end (clock skew tolerance `eps`).
"""
from __future__ import annotations

from typing import Iterable

from repro.core.events import EventKind, TraceEvent

_EPS = 1e-9


def reconstruct_stacks(events: list[TraceEvent]) -> list[TraceEvent]:
    """Annotates events in-place with meta['callpath'] per rank."""
    by_rank: dict[int, list[TraceEvent]] = {}
    for ev in events:
        if ev.kind in (EventKind.HEARTBEAT, EventKind.HANG_SUSPECT):
            continue
        by_rank.setdefault(ev.rank, []).append(ev)
    for rank_events in by_rank.values():
        _reconstruct_one(rank_events)
    return events


def _reconstruct_one(events: list[TraceEvent]):
    # host-side nesting uses issue_ts..end for CPU spans; kernels nest under
    # whatever host span was open at their ISSUE time (they execute later).
    order = sorted(events, key=lambda e: (e.issue_ts, -e.end_ts))
    stack: list[TraceEvent] = []
    for ev in order:
        t = ev.issue_ts
        while stack and stack[-1].end_ts <= t + _EPS:
            stack.pop()
        if stack:
            parent = stack[-1]
            ppath = parent.meta.get("callpath", parent.name)
            ev.meta["callpath"] = f"{ppath}/{ev.name}"
            ev.meta["parent"] = parent.name
        else:
            ev.meta["callpath"] = ev.name
        # only host spans can contain others (kernels run on device)
        if ev.kind not in (EventKind.KERNEL_COMPUTE, EventKind.KERNEL_COMM):
            stack.append(ev)


def children_of(events: Iterable[TraceEvent], parent_name: str):
    return [e for e in events if e.meta.get("parent") == parent_name]
