"""Trace event model, bounded ring buffer, and compact JSONL codec.

Events are the single currency between the tracing daemon, the cluster
simulator and the diagnostic engine: any producer that emits this schema
(real process, simulated rank, or a replayed log) exercises the identical
diagnosis code path.
"""
from __future__ import annotations

import enum
import json
import threading
from dataclasses import dataclass, field
from typing import Any, Iterable, Optional


class EventKind(str, enum.Enum):
    PY_API = "py_api"            # intercepted Python API span (sync)
    GC = "gc"                    # Python garbage collection pause
    DATALOADER = "dataloader"    # metric ① seam
    KERNEL_COMPUTE = "k_comp"    # registered compute kernel
    KERNEL_COMM = "k_comm"       # registered communication kernel
    STEP = "step"                # whole training/serving step span
    SYNC = "sync"                # device synchronization span
    HEARTBEAT = "heartbeat"      # daemon liveness
    HANG_SUSPECT = "hang"        # daemon-reported potential hang


# kinds the engine treats as occupying the device timeline
DEVICE_KINDS = (EventKind.KERNEL_COMPUTE, EventKind.KERNEL_COMM)


@dataclass(slots=True)
class TraceEvent:
    kind: EventKind
    name: str
    rank: int
    issue_ts: float          # host-side issue (dispatch) timestamp
    start_ts: float          # device-side execution start (== issue for CPU spans)
    end_ts: float
    step: int = -1
    meta: dict = field(default_factory=dict)
    # meta keys used by the engine:
    #   flops, bytes, comm_group (tuple of ranks), shape, layout,
    #   tokens (dataloader), stack (list[str]), parent (callpath str)

    @property
    def duration(self) -> float:
        return self.end_ts - self.start_ts

    @property
    def issue_latency(self) -> float:
        return self.start_ts - self.issue_ts

    # ---------------------------- codec ------------------------------- #
    def to_json(self) -> str:
        d = {"k": self.kind.value, "n": self.name, "r": self.rank,
             "i": round(self.issue_ts, 6), "s": round(self.start_ts, 6),
             "e": round(self.end_ts, 6), "t": self.step}
        if self.meta:
            d["m"] = {k: v for k, v in self.meta.items() if k != "stack"}
            if "stack" in self.meta:
                d["m"]["stack"] = list(self.meta["stack"])[-4:]
        return json.dumps(d, separators=(",", ":"))

    @classmethod
    def from_json(cls, line: str) -> "TraceEvent":
        d = json.loads(line)
        return cls(kind=EventKind(d["k"]), name=d["n"], rank=d["r"],
                   issue_ts=d["i"], start_ts=d["s"], end_ts=d["e"],
                   step=d.get("t", -1), meta=d.get("m", {}))


class EventRingBuffer:
    """Bounded, thread-safe buffer; overflow drops oldest (counted)."""

    def __init__(self, capacity: int = 200_000):
        self.capacity = capacity
        self._buf: list[Optional[TraceEvent]] = [None] * capacity
        self._head = 0
        self._size = 0
        self.dropped = 0
        self._lock = threading.Lock()

    def append(self, ev: TraceEvent):
        with self._lock:
            idx = (self._head + self._size) % self.capacity
            if self._size == self.capacity:
                self._head = (self._head + 1) % self.capacity
                self.dropped += 1
            else:
                self._size += 1
            self._buf[idx] = ev

    def drain(self) -> list[TraceEvent]:
        with self._lock:
            out = [self._buf[(self._head + i) % self.capacity]
                   for i in range(self._size)]
            self._head = 0
            self._size = 0
            return out  # type: ignore[return-value]

    def __len__(self) -> int:
        return self._size


def dump_jsonl(events, path: str) -> int:
    """Write events; returns bytes written (Fig 9 log-size accounting).

    Accepts any iterable of TraceEvent, or a columnar batch exposing
    ``to_jsonl_lines()`` (duck-typed so this module stays dependency-free).
    """
    if hasattr(events, "to_jsonl_lines"):
        lines = events.to_jsonl_lines()
    else:
        lines = (ev.to_json() for ev in events)
    n = 0
    with open(path, "a") as f:
        for line in lines:
            f.write(line + "\n")
            n += len(line) + 1
    return n


def load_jsonl(path: str) -> list[TraceEvent]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(TraceEvent.from_json(line))
    return out
