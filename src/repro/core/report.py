"""Reporting: anomaly summaries, ASCII timelines, JSON export.

The paper's FLARE also ships a distributed-visualization UI; here we render
the aggregated timeline (Fig 7 style) as ASCII for terminals/logs and emit
machine-readable JSON for dashboards.
"""
from __future__ import annotations

import json
from typing import Iterable

import numpy as np

from repro.core.engine import Anomaly
from repro.core.events import DEVICE_KINDS, EventKind, TraceEvent


def anomaly_report(anomalies: Iterable[Anomaly]) -> str:
    lines = ["=" * 72, "FLARE anomaly report", "=" * 72]
    by_team: dict[str, list[Anomaly]] = {}
    for a in anomalies:
        by_team.setdefault(a.team.value, []).append(a)
    if not by_team:
        lines.append("no anomalies detected")
    for team, items in sorted(by_team.items()):
        lines.append(f"\n--> routed to {team.upper()} "
                     f"({len(items)} finding(s))")
        for a in items:
            lines.append(f"  {a}")
            for k, v in list(a.evidence.items())[:4]:
                if k == "api_spans":
                    top = sorted(v.items(), key=lambda kv: -kv[1])[:3]
                    v = {n: round(t, 4) for n, t in top}
                lines.append(f"      {k}: {v}")
    return "\n".join(lines)


def _json_coerce(o):
    """Fallback serializer for detector evidence: vectorized detectors
    attach numpy scalars/arrays (np.float64 severities, outlier-rank
    arrays), and custom plugins attach whatever they like — dashboards
    still need valid JSON, so coerce instead of raising."""
    if isinstance(o, np.generic):
        return o.item()
    if isinstance(o, np.ndarray):
        return o.tolist()
    if isinstance(o, (set, frozenset)):
        return sorted(o, key=repr)
    return str(o)


def anomalies_json(anomalies: Iterable[Anomaly]) -> str:
    return json.dumps([{
        "kind": a.kind, "metric": a.metric, "team": a.team.value,
        "root_cause": a.root_cause, "step": int(a.step),
        "severity": float(a.severity), "ranks": list(a.ranks),
        "evidence": a.evidence,
    } for a in anomalies], indent=1, default=_json_coerce)


def ascii_timeline(events: list[TraceEvent], rank: int, step: int,
                   width: int = 96) -> str:
    """Two-lane (CPU/device) timeline for one rank+step, Fig 7 style."""
    evs = [e for e in events if e.rank == rank and e.step == step]
    if not evs:
        return "(no events)"
    t0 = min(e.issue_ts for e in evs)
    t1 = max(e.end_ts for e in evs)
    span = max(t1 - t0, 1e-12)

    def bar(e: TraceEvent, char: str) -> tuple[int, int, str]:
        a = int((e.start_ts - t0) / span * (width - 1))
        b = max(int((e.end_ts - t0) / span * (width - 1)), a + 1)
        return a, b, char

    cpu_lane = [" "] * width
    dev_lane = [" "] * width
    for e in sorted(evs, key=lambda x: x.start_ts):
        if e.kind in DEVICE_KINDS:
            a, b, c = bar(e, "#" if e.kind == EventKind.KERNEL_COMPUTE else "~")
            for i in range(a, min(b, width)):
                dev_lane[i] = c
        elif e.kind in (EventKind.PY_API, EventKind.GC, EventKind.SYNC,
                        EventKind.DATALOADER):
            a, b, c = bar(e, "G" if e.kind == EventKind.GC else
                          ("S" if e.kind == EventKind.SYNC else
                           ("D" if e.kind == EventKind.DATALOADER else "p")))
            for i in range(a, min(b, width)):
                cpu_lane[i] = c
    return (f"rank {rank} step {step}  ({span * 1e3:.1f} ms)\n"
            f"CPU |{''.join(cpu_lane)}|\n"
            f"DEV |{''.join(dev_lane)}|\n"
            f"      # compute  ~ comm  G gc  S sync  D dataloader  p py-api")
