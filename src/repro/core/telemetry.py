"""Self-telemetry: one counter/gauge registry for the whole pipeline.

Flare is judged by how observable it makes *training jobs* — but an
eight-month deployment also needs the pipeline itself to be observable:
is the daemon's spill failing?  How far behind is a job's watermark?
How fast did last night's replay decode?  Those numbers existed as
scattered ad-hoc attributes (``daemon.spill_errors``, ``FleetJob
.late_events``, ``ReplayStats``); this module gives them one home.

Design goals, in order:

  * **hot-path cheap**: a :class:`Counter` is one Python int add behind
    an attribute — no lock, no dict lookup per increment.  Handles are
    resolved once (``registry.counter("daemon.events")``) and cached by
    the instrumented component.  Unlocked increments race exactly as
    benignly as the plain ``+= 1`` attributes they replace: a dropped
    tick under contention, never a crash or a negative value.
  * **tagged**: series are keyed ``name{k=v,...}`` with sorted tags, so
    per-job series (``fleet.late_rows{job=b}``) aggregate naturally and
    render stably.
  * **snapshot-exportable**: :meth:`TelemetryRegistry.snapshot` returns
    a plain-JSON dict (``{"counters": {...}, "gauges": {...}}``); the
    archive layer (``repro.archive``) writes these next to the trace
    segments so "pipeline weather" rides along with the data it
    produced.  ``extra_tags`` lets an aggregator (the multiplexer
    merging its daemons' registries) re-tag a whole snapshot by job.

Components accept a registry via their config (``DaemonConfig
.telemetry``, ``FleetConfig.telemetry``) and default to a private one,
so tests and single-component uses need no global state; pass one
shared registry to see the whole pipeline in one snapshot.
"""
from __future__ import annotations

import threading
import time
from typing import Optional


def series_key(name: str, tags: Optional[dict] = None) -> str:
    """Canonical series key: ``name`` or ``name{k=v,...}``, tags sorted
    so the same (name, tags) always renders the same key."""
    if not tags:
        return name
    inner = ",".join(f"{k}={tags[k]}" for k in sorted(tags))
    return f"{name}{{{inner}}}"


def parse_series_key(key: str) -> tuple[str, dict]:
    """Inverse of :func:`series_key`: ``(name, tags)`` from a serialized
    key.  Tag values come back as strings — the only form they ever had
    in a key."""
    if "{" not in key:
        return key, {}
    name, _, inner = key.partition("{")
    tags = dict(kv.split("=", 1) for kv in inner.rstrip("}").split(","))
    return name, tags


class Counter:
    """Monotonic counter handle.  ``inc`` returns the post-increment
    value so warn-once patterns (``if c.inc() == 1: warn(...)``) need no
    second read."""

    __slots__ = ("name", "tags", "value")

    def __init__(self, name: str, tags: Optional[dict] = None):
        self.name = name
        self.tags = dict(tags) if tags else {}
        self.value = 0

    def inc(self, n: int = 1) -> int:
        self.value += n
        return self.value

    @property
    def key(self) -> str:
        return series_key(self.name, self.tags)


class Gauge:
    """Last-value-wins gauge handle (queue depths, lags, rates)."""

    __slots__ = ("name", "tags", "value")

    def __init__(self, name: str, tags: Optional[dict] = None):
        self.name = name
        self.tags = dict(tags) if tags else {}
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    @property
    def key(self) -> str:
        return series_key(self.name, self.tags)


class TelemetryRegistry:
    """Get-or-create registry of counters and gauges.

    Handle creation is locked (it happens once per series); the handles
    themselves are lock-free.  Re-requesting a (name, tags) pair returns
    the SAME handle, so two components counting the same series add into
    one number instead of shadowing each other."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}

    def counter(self, name: str, **tags) -> Counter:
        key = series_key(name, tags)
        with self._lock:
            c = self._counters.get(key)
            if c is None:
                c = self._counters[key] = Counter(name, tags)
            return c

    def gauge(self, name: str, **tags) -> Gauge:
        key = series_key(name, tags)
        with self._lock:
            g = self._gauges.get(key)
            if g is None:
                g = self._gauges[key] = Gauge(name, tags)
            return g

    def value(self, name: str, **tags) -> float:
        """Current value of a series, 0.0 if it was never touched —
        counter first, gauge as fallback.  Read-only: unlike
        :meth:`counter`/:meth:`gauge` it never materializes the series,
        so probing (tests, the chaos harness asserting on recovery
        counters) leaves snapshots unchanged."""
        key = series_key(name, tags)
        with self._lock:
            c = self._counters.get(key)
            if c is not None:
                return c.value
            g = self._gauges.get(key)
            return g.value if g is not None else 0.0

    # ------------------------------------------------------------------ #
    def snapshot(self, extra_tags: Optional[dict] = None) -> dict:
        """Plain-JSON snapshot of every series.  ``extra_tags`` are
        merged into each series' tags (without mutating the handles) —
        the multiplexer uses this to job-tag its daemons' registries
        when merging them into one fleet snapshot."""
        with self._lock:
            counters = list(self._counters.values())
            gauges = list(self._gauges.values())
        out = {"ts": time.time(), "counters": {}, "gauges": {}}
        for c in counters:
            tags = {**c.tags, **extra_tags} if extra_tags else c.tags
            out["counters"][series_key(c.name, tags)] = c.value
        for g in gauges:
            tags = {**g.tags, **extra_tags} if extra_tags else g.tags
            out["gauges"][series_key(g.name, tags)] = g.value
        return out

    def merge_snapshot(self, snap: dict, into: Optional[dict] = None,
                       extra_tags: Optional[dict] = None) -> dict:
        """Fold an already-taken snapshot dict into ``into`` (or a fresh
        snapshot of this registry): counters ADD on key collision,
        gauges last-write-win.  ``extra_tags`` re-tag the incoming
        series."""
        base = into if into is not None else self.snapshot()
        for kind, combine in (("counters", lambda a, b: a + b),
                              ("gauges", lambda a, b: b)):
            for key, val in snap.get(kind, {}).items():
                k = _retag(key, extra_tags) if extra_tags else key
                if k in base[kind]:
                    base[kind][k] = combine(base[kind][k], val)
                else:
                    base[kind][k] = val
        return base

    def absorb(self, snap: dict,
               extra_tags: Optional[dict] = None) -> None:
        """Fold a snapshot INTO this registry's live handles: counters
        add their value, gauges last-write-win.  Unlike
        :meth:`merge_snapshot` (which merges dicts), this materializes
        handles, so a process-sharded worker's telemetry lands on the
        parent's registry exactly as if the worker had incremented the
        parent's counters directly — the fleet replay path uses this to
        merge per-job worker registries across the IPC boundary."""
        for key, val in snap.get("counters", {}).items():
            k = _retag(key, extra_tags) if extra_tags else key
            name, tags = parse_series_key(k)
            if val:
                self.counter(name, **tags).inc(val)
            else:
                self.counter(name, **tags)       # materialize zero series
        for key, val in snap.get("gauges", {}).items():
            k = _retag(key, extra_tags) if extra_tags else key
            name, tags = parse_series_key(k)
            self.gauge(name, **tags).set(val)


def _retag(key: str, extra_tags: dict) -> str:
    """Re-render a serialized series key with extra tags merged in."""
    name, tags = parse_series_key(key)
    tags.update({k: str(v) for k, v in extra_tags.items()})
    return series_key(name, tags)
