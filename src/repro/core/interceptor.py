"""Plug-and-play Python API interception (paper §4.1).

The paper hooks CPython's profiling API (``PyEval_SetProfile``) against the
bytecode of a configured list of APIs, so no backend codebase is patched.
We use the modern equivalent, ``sys.monitoring`` (PEP 669): LOCAL
PY_START/PY_RETURN events are enabled *only* on the registered code
objects, giving the same only-the-traced-APIs-fire selectivity.  APIs
implemented in C (no bytecode — e.g. ``gc.collect``) fall back to a wrapper
installed by the daemon at attach time (still zero backend modification),
and GC pauses themselves are additionally captured via ``gc.callbacks``.

On Python < 3.12 ``sys.monitoring`` does not exist; EVERY registered API
then takes the wrapper path, which preserves the plug-and-play contract
(install at attach, restore at detach, daemon threads never traced).

Easy-to-play interface (paper): environment variable
    FLARE_TRACED_PYTHON_API="jax@block_until_ready,gc@collect,mod.sub@fn"
"""
from __future__ import annotations

import gc
import importlib
import os
import sys
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

ENV_VAR = "FLARE_TRACED_PYTHON_API"
_TOOL_NAME = "flare"
_HAS_MONITORING = hasattr(sys, "monitoring")   # PEP 669, Python >= 3.12


def parse_api_spec(spec: str) -> list[tuple[str, str]]:
    """'mod.sub@fn,mod2@fn2' -> [('mod.sub','fn'), ...]"""
    out = []
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        if "@" not in item:
            raise ValueError(
                f"bad {ENV_VAR} entry {item!r}: expected 'module@function'")
        mod, fn = item.split("@", 1)
        out.append((mod, fn))
    return out


@dataclass
class _Traced:
    module: str
    func: str
    code: object = None          # code object (sys.monitoring path)
    wrapper_installed: bool = False
    original: Callable = None


class PyApiInterceptor:
    """Intercepts configured Python APIs; emits (name, t0, t1) to a callback."""

    def __init__(self, on_span: Callable[[str, float, float], None],
                 on_gc: Optional[Callable[[str, float, float], None]] = None):
        self.on_span = on_span
        self.on_gc = on_gc or on_span
        self._traced: dict[object, _Traced] = {}   # code obj -> info
        self._wrapped: list[_Traced] = []
        self._tool_id: Optional[int] = None
        self._starts: dict[tuple, float] = {}      # (thread, code) -> t0
        self._gc_t0: Optional[float] = None
        self._gc_cb_installed = False

    # ------------------------------------------------------------------ #
    def register_from_env(self):
        spec = os.environ.get(ENV_VAR, "")
        for mod, fn in parse_api_spec(spec):
            self.register(mod, fn)

    def register(self, module: str, func: str):
        try:
            obj = importlib.import_module(module)
        except ImportError:
            return False
        target = obj
        parts = func.split(".")
        for p in parts[:-1]:
            target = getattr(target, p)
        f = getattr(target, parts[-1], None)
        if f is None:
            return False
        code = getattr(f, "__code__", None)
        name = f"{module}@{func}"
        if code is not None and _HAS_MONITORING:
            self._traced[code] = _Traced(module, func, code=code)
            if self._tool_id is not None:
                self._enable_local(code)
        else:
            # C-implemented API — or an interpreter without sys.monitoring:
            # wrapper fallback (installed at attach, not backend-edited)
            info = _Traced(module, func, original=f)

            def wrapper(*a, __flare_name=name, __orig=f, **kw):
                if self._own_thread():   # observer-effect guard
                    return __orig(*a, **kw)
                t0 = time.perf_counter()
                try:
                    return __orig(*a, **kw)
                finally:
                    self.on_span(__flare_name, t0, time.perf_counter())

            setattr(target, parts[-1], wrapper)
            info.wrapper_installed = True
            self._wrapped.append(info)
        return True

    # ------------------------------------------------------------------ #
    def install(self):
        if _HAS_MONITORING:
            mon = sys.monitoring
            for tid in range(6):
                try:
                    mon.use_tool_id(tid, _TOOL_NAME)
                    self._tool_id = tid
                    break
                except ValueError:
                    continue
            if self._tool_id is None:
                raise RuntimeError("no free sys.monitoring tool id")
            E = mon.events
            mon.register_callback(self._tool_id, E.PY_START, self._py_start)
            mon.register_callback(self._tool_id, E.PY_RETURN, self._py_return)
            for code in self._traced:
                self._enable_local(code)
        if not self._gc_cb_installed:
            gc.callbacks.append(self._gc_cb)
            self._gc_cb_installed = True

    def _enable_local(self, code):
        E = sys.monitoring.events
        sys.monitoring.set_local_events(
            self._tool_id, code, E.PY_START | E.PY_RETURN)

    def uninstall(self):
        if _HAS_MONITORING and self._tool_id is not None:
            for code in self._traced:
                sys.monitoring.set_local_events(self._tool_id, code, 0)
            sys.monitoring.free_tool_id(self._tool_id)
            self._tool_id = None
        for info in self._wrapped:
            try:
                obj = importlib.import_module(info.module)
                target = obj
                parts = info.func.split(".")
                for p in parts[:-1]:
                    target = getattr(target, p)
                setattr(target, parts[-1], info.original)
            except Exception:
                pass
        self._wrapped.clear()
        if self._gc_cb_installed:
            try:
                gc.callbacks.remove(self._gc_cb)
            except ValueError:
                pass
            self._gc_cb_installed = False

    # ------------------------------------------------------------------ #
    @staticmethod
    def _own_thread() -> bool:
        # never trace the daemon's own threads (observer effect: e.g. the
        # JSONL writer itself calls json.dumps)
        return threading.current_thread().name.startswith("flare-")

    def _py_start(self, code, _offset):
        if code in self._traced and not self._own_thread():
            self._starts[(threading.get_ident(), id(code))] = time.perf_counter()

    def _py_return(self, code, _offset, _retval):
        info = self._traced.get(code)
        if info is None or self._own_thread():
            return
        t0 = self._starts.pop((threading.get_ident(), id(code)), None)
        if t0 is not None:
            self.on_span(f"{info.module}@{info.func}", t0, time.perf_counter())

    def _gc_cb(self, phase, info):
        if phase == "start":
            self._gc_t0 = time.perf_counter()
        elif phase == "stop" and self._gc_t0 is not None:
            self.on_gc(f"gc.collect(gen={info.get('generation', '?')})",
                       self._gc_t0, time.perf_counter())
            self._gc_t0 = None

    @property
    def traced_names(self) -> list[str]:
        names = [f"{t.module}@{t.func}" for t in self._traced.values()]
        names += [f"{t.module}@{t.func}" for t in self._wrapped]
        return names
