"""Wasserstein-1 distance over empirical samples + healthy-profile thresholds.

The paper (§5.2.2) learns healthy issue-latency distributions per
(backend, scale) and uses the **maximum pairwise W1 distance between
healthy runs** as the alarm threshold.  W1 between empirical distributions
with equal sample weights reduces to the mean absolute difference of the
sorted samples (quantile coupling); for unequal sizes we integrate
|CDF1 - CDF2| exactly over the merged support.
"""
from __future__ import annotations

import numpy as np


def w1_distance(a, b) -> float:
    return w1_distance_sorted(np.sort(np.asarray(a, np.float64)),
                              np.sort(np.asarray(b, np.float64)))


def w1_distance_sorted(a: np.ndarray, b: np.ndarray) -> float:
    """``w1_distance`` for ALREADY-SORTED float64 samples.  The healthy
    reference distribution is fixed per profile, so the per-step detector
    sorts only the current step's samples and reuses the cached sorted
    reference (identical result to ``w1_distance``)."""
    if a.size == 0 or b.size == 0:
        return float("inf") if a.size != b.size else 0.0
    if a.size == b.size:
        return float(np.mean(np.abs(a - b)))
    # exact integral of |F_a - F_b| over merged support
    allv = np.concatenate([a, b])
    allv.sort(kind="mergesort")
    deltas = np.diff(allv)
    ca = np.searchsorted(a, allv[:-1], side="right") / a.size
    cb = np.searchsorted(b, allv[:-1], side="right") / b.size
    return float(np.sum(np.abs(ca - cb) * deltas))


def normalized_w1(a, b) -> float:
    """W1 scaled by the healthy distribution's mean (scale invariance across
    cluster sizes / model sizes)."""
    b = np.asarray(b, np.float64)
    scale = max(float(np.mean(b)), 1e-12)
    return w1_distance(a, b) / scale


def healthy_threshold(healthy_runs: list, margin: float = 1.5) -> float:
    """max pairwise (normalized) W1 among healthy runs, x safety margin."""
    if len(healthy_runs) < 2:
        return 0.25 * margin
    worst = 0.0
    for i in range(len(healthy_runs)):
        for j in range(i + 1, len(healthy_runs)):
            worst = max(worst, normalized_w1(healthy_runs[i],
                                             healthy_runs[j]))
    return worst * margin
