"""FLARE: full-stack tracing daemon + diagnostic engine (the paper's core)."""
from repro.core.events import EventKind, TraceEvent  # noqa: F401
from repro.core.daemon import TracingDaemon, DaemonConfig, attach, get_daemon  # noqa: F401
from repro.core.engine import Anomaly, DiagnosticEngine, EngineConfig, Team  # noqa: F401
from repro.core.detectors import (Detector, DetectorSpec,  # noqa: F401
                                  register_detector)
