"""FLARE: full-stack tracing daemon + diagnostic engine (the paper's core)."""
from repro.core.events import EventKind, TraceEvent  # noqa: F401
from repro.core.daemon import TracingDaemon, DaemonConfig, attach, get_daemon  # noqa: F401
from repro.core.engine import Anomaly, DiagnosticEngine, Team  # noqa: F401
