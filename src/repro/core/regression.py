"""Regression detection + root-cause narrowing (paper §5.2.2, §5.2.4).

Detectors (all compare against the HealthyProfile for this backend/scale):
  * issue-latency distribution drift (normalized W1 > learned threshold)
      -> kernel-issue stall; root cause = traced Python APIs that precede
         the stalled kernels (GC, sync, timers, package checks)
  * V_inter above threshold -> inter-step CPU (dataloader — Case-3)
  * V_minority above threshold -> un-instrumented minority kernels (Table 5)
  * per-kernel FLOPS below expectation on ALL ranks uniformly -> software
      regression; the layout advisor checks input layouts for tensor-core /
      MXU alignment (Case-2: pad 8484 -> 8512)
  * bandwidth below expectation persistently from job start -> software
      (e.g. GDR disabled); sudden mid-job drops are fail-slows, not
      regressions (handled in failslow.py)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.history import HealthyProfile
from repro.core.metrics import StepMetrics
from repro.core.wasserstein import w1_distance_sorted

ALIGN_BYTES = 128          # tensor-core/MXU tile alignment (paper Case-2)
FLOPS_REGRESSION_FRAC = 0.75
BW_REGRESSION_FRAC = 0.7

# APIs owned by algorithm teams vs infrastructure (routing, Table 1).
# Checkpoint writes are storage-subsystem work: a checkpoint-write storm
# (L4 taxonomy) pages infrastructure, not the model owners.
ALGORITHM_APIS = ("block_until_ready", "synchronize", "timer", "gc.collect",
                  "package", "version", "mask")
INFRA_APIS = ("memory", "allocator", "cuda_malloc", "compile",
              "checkpoint", "ckpt")


@dataclass
class RegressionFinding:
    metric: str          # issue_latency | v_inter | v_minority | flops | bandwidth
    severity: float      # how far past threshold
    root_cause: str
    suggested_team: str  # "algorithm" | "infrastructure"
    evidence: dict = field(default_factory=dict)


def check_issue_latency(m: StepMetrics,
                        prof: HealthyProfile) -> Optional[RegressionFinding]:
    if m.issue_latencies.size < 8 or prof.reference_latencies.size < 8:
        return None
    cur = np.sort(np.asarray(m.issue_latencies, np.float64))
    d = w1_distance_sorted(cur, prof.reference_sorted) \
        / max(prof.reference_mean, 1e-12)
    if d <= prof.issue_w1_threshold:
        return None
    # one-sided: kernel-issue stalls COMPRESS issue latencies (§5.2.2 /
    # Fig 11 — unhealthy CDFs rise much faster).  Larger-than-healthy
    # latencies mean a busier device (jitter, stragglers), which belongs
    # to the fail-slow path, not this detector.
    median_cur = float(np.median(cur))
    if median_cur >= prof.reference_median:
        return None
    # §5.2.4: find traced APIs invoked just before the stalled kernels
    culprit, team = _narrow_api(m)
    return RegressionFinding(
        metric="issue_latency", severity=d / prof.issue_w1_threshold,
        root_cause=culprit or "kernel-issue stall (no traced API matched)",
        suggested_team=team,
        evidence={"w1": d, "threshold": prof.issue_w1_threshold,
                  "median_latency": median_cur,
                  "healthy_median": prof.reference_median,
                  "api_spans": dict(m.api_spans)})


def _narrow_api(m: StepMetrics) -> tuple[Optional[str], str]:
    if not m.api_spans:
        return None, "infrastructure"
    top = max(m.api_spans.items(), key=lambda kv: kv[1])
    name = top[0]
    low = name.lower()
    if "dataloader" in low or "next_batch" in low:
        return f"host dataloader stall ({name})", "algorithm"
    if any(a in low for a in ALGORITHM_APIS):
        team = "algorithm"
        if "gc" in low:
            name = f"python runtime GC ({name})"
        elif "sync" in low or "block_until_ready" in low:
            name = f"unnecessary device synchronization ({name})"
    elif any(a in low for a in INFRA_APIS):
        team = "infrastructure"
    else:
        team = "algorithm"
    return name, team


def check_voids(m: StepMetrics,
                prof: HealthyProfile) -> list[RegressionFinding]:
    out = []
    if m.v_inter > prof.v_inter_threshold:
        out.append(RegressionFinding(
            metric="v_inter", severity=m.v_inter / prof.v_inter_threshold,
            root_cause="inter-step CPU time (dataloader / host preprocessing)",
            suggested_team="algorithm",
            evidence={"v_inter": m.v_inter,
                      "threshold": prof.v_inter_threshold,
                      "t_inter_s": m.t_inter,
                      "api_spans": dict(m.api_spans)}))
    if m.v_minority > prof.v_minority_threshold:
        out.append(RegressionFinding(
            metric="v_minority",
            severity=m.v_minority / prof.v_minority_threshold,
            root_cause="un-instrumented minority GPU kernels "
                       "(un-fused PE/ACT/NORM ops — fusion candidates)",
            suggested_team="infrastructure",
            evidence={"v_minority": m.v_minority,
                      "threshold": prof.v_minority_threshold}))
    return out


def check_flops(m: StepMetrics, prof: HealthyProfile) -> list[RegressionFinding]:
    """Uniform (all-rank) FLOPS deficits => software regression (Case-2)."""
    out = []
    for name, per_rank in m.flops.items():
        if name in m.flops_overlapped or name not in prof.expected_flops:
            continue
        vals = np.asarray(list(per_rank.values()))
        exp = prof.expected_flops[name]
        if exp <= 0 or vals.size == 0:
            continue
        ratio = vals / exp
        # uniform: ALL ranks depressed (rank-specific deficits = fail-slow)
        if float(ratio.max()) < FLOPS_REGRESSION_FRAC:
            finding = RegressionFinding(
                metric="flops", severity=float(exp / max(vals.mean(), 1.0)),
                root_cause=f"kernel {name!r} running at "
                           f"{100 * float(vals.mean()) / exp:.0f}% of expected FLOPS "
                           f"on all ranks (software/layout change)",
                suggested_team="infrastructure",
                evidence={"kernel": name, "expected": exp,
                          "achieved_mean": float(vals.mean())})
            out.append(finding)
    return out


def layout_advice(shape: tuple, dtype_bytes: int = 2) -> Optional[dict]:
    """Case-2 advisor: flag dims misaligned to the 128-byte tile boundary
    and suggest the padded dim (8484 -> 8512)."""
    elems = ALIGN_BYTES // dtype_bytes
    bad = [int(d) for d in shape if d % elems]
    if not bad:
        return None
    return {"misaligned_dims": bad,
            "padded_dims": [int(-(-d // elems) * elems) for d in bad],
            "alignment_elems": elems,
            "suggestion": "pad with repro.kernels.padded_matmul "
                          f"({bad[0]} -> {-(-bad[0] // elems) * elems})"}


def annotate_layout(findings: list[RegressionFinding],
                    kernel_shapes: dict) -> None:
    for f in findings:
        if f.metric != "flops":
            continue
        shape = kernel_shapes.get(f.evidence.get("kernel", ""))
        if shape:
            adv = layout_advice(tuple(shape))
            if adv:
                f.evidence["layout_advice"] = adv
                f.root_cause += (
                    f"; layout advisor: dims {adv['misaligned_dims']} not "
                    f"{ALIGN_BYTES}-byte aligned -> pad to {adv['padded_dims']}")


def check_bandwidth(m: StepMetrics,
                    prof: HealthyProfile) -> list[RegressionFinding]:
    out = []
    for name, bw in m.bandwidth.items():
        exp = prof.expected_bandwidth.get(name)
        if not exp:
            continue
        if bw < BW_REGRESSION_FRAC * exp:
            out.append(RegressionFinding(
                metric="bandwidth", severity=exp / max(bw, 1.0),
                root_cause=f"collective {name!r} at "
                           f"{100 * bw / exp:.0f}% of expected bandwidth "
                           "from job start (configuration/software)",
                suggested_team="infrastructure",
                evidence={"kernel": name, "expected_Bps": exp,
                          "achieved_Bps": bw}))
    return out
