"""Hang detection and two-step diagnosis (paper §5.1, Fig 5).

Step 1 — call-stack analysis: when daemons report a hang, ranks whose last
stack frame is NOT a communication function are the suspects (everyone else
is parked inside a collective waiting for them).  Step 2 — if *all* ranks
sit in the same collective, it is a communication hang: run intra-kernel
inspecting on that collective's ring-progress counters.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.inspecting import RingDiagnosis, diagnose_ring

COMM_MARKERS = ("allreduce", "all_reduce", "allgather", "all_gather",
                "reduce_scatter", "all_to_all", "collective", "ppermute",
                "psum", "sendrecv")


def is_comm_frame(frame: str) -> bool:
    f = frame.lower()
    return any(m in f for m in COMM_MARKERS)


@dataclass
class HangDiagnosis:
    kind: str                       # "non_comm" | "comm" | "unknown"
    faulty_ranks: list
    link: Optional[tuple] = None
    detail: str = ""
    used_inspector: bool = False


def classify_stacks(stacks: dict) -> tuple[str, list]:
    """stacks: rank -> list[str] (innermost last).  Returns (kind, suspects)."""
    non_comm = [r for r, s in stacks.items()
                if not s or not is_comm_frame(s[-1])]
    if non_comm and len(non_comm) < max(len(stacks) // 2, 1):
        return "non_comm", sorted(non_comm)
    if not non_comm:
        return "comm", []
    return "unknown", sorted(non_comm)


def diagnose_hang(stacks: dict,
                  ring_progress: Optional[np.ndarray] = None) -> HangDiagnosis:
    kind, suspects = classify_stacks(stacks)
    if kind == "non_comm":
        return HangDiagnosis(
            kind=kind, faulty_ranks=suspects,
            detail="rank(s) halted outside any collective while peers wait "
                   f"in {_common_comm_frame(stacks)!r}")
    if kind == "comm":
        if ring_progress is None:
            return HangDiagnosis(
                kind="comm", faulty_ranks=[],
                detail="all ranks inside the same collective; ring progress "
                       "unavailable — escalating to probe search")
        d: RingDiagnosis = diagnose_ring(ring_progress)
        return HangDiagnosis(
            kind="comm", faulty_ranks=d.machines, link=d.link,
            used_inspector=True,
            detail=f"ring link {d.link[0]}->{d.link[1]} stalled at step "
                   f"{d.min_step} (confidence={d.confidence})")
    return HangDiagnosis(kind="unknown", faulty_ranks=suspects,
                         detail="mixed stacks; manual review")


def _common_comm_frame(stacks: dict) -> str:
    for s in stacks.values():
        if s and is_comm_frame(s[-1]):
            return s[-1]
    return "?"
