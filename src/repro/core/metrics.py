"""The five aggregated metrics of FLARE (paper §5.2, Fig 7).

  ① training throughput        (macro — fail-slow detection)
  ② compute-kernel FLOPS        (micro — underclock / layout regressions)
  ③ collective bandwidth        (micro — jitter / GDR regressions)
  ④ issue-latency distribution  (micro — kernel-issue stalls: GC, sync)
  ⑤ void percentage V_inter / V_minority (micro — uncovered operations)

All are computed per training step.  FLOPS of compute kernels that overlap
a communication kernel are flagged so they are not mistakenly treated as
regressed (§5.2.2, MoE overlap).

Two code paths produce identical StepMetrics:

  * the legacy per-event path (``aggregate_step`` on a rank -> event-list
    dict) — kept for hand-built timelines and as the equivalence oracle;
  * the columnar path (``aggregate_all`` on an ``EventBatch``) — a single
    vectorized sweep computing EVERY step's metrics with numpy group-bys,
    no per-step rescans.  This is what the engine uses at thousand-plus
    rank scale.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.columnar import (KIND_TO_CODE, NO_INT, EventBatch, next_ge,
                                 prev_le)
from repro.core.events import DEVICE_KINDS, EventKind, TraceEvent

_C_STEP = KIND_TO_CODE[EventKind.STEP]
_C_COMP = KIND_TO_CODE[EventKind.KERNEL_COMPUTE]
_C_COMM = KIND_TO_CODE[EventKind.KERNEL_COMM]
_C_DL = KIND_TO_CODE[EventKind.DATALOADER]
_C_PY = KIND_TO_CODE[EventKind.PY_API]
_C_GC = KIND_TO_CODE[EventKind.GC]
_C_SYNC = KIND_TO_CODE[EventKind.SYNC]


@dataclass
class StepMetrics:
    step: int
    t_step: float
    throughput: float                       # tokens / s   (①)
    flops: dict                             # name -> per-rank achieved FLOP/s (②)
    flops_overlapped: set                   # kernel names excluded from ② checks
    bandwidth: dict                         # (name) -> achieved B/s          (③)
    issue_latencies: np.ndarray             # comm-kernel issue latencies     (④)
    v_inter: float                          # ⑤
    v_minority: float                       # ⑤
    t_inter: float
    api_spans: dict                         # api name -> total host seconds
    num_ranks: int = 1


def _step_events(events: list[TraceEvent], step: int):
    return [e for e in events if e.step == step]


# ----------------------------------------------------------------------- #
# legacy per-event path (oracle; hand-built timelines)
# ----------------------------------------------------------------------- #
def aggregate_step(events_by_rank, step: int) -> Optional[StepMetrics]:
    """Aggregate one step.  Accepts either the legacy rank -> event-list
    dict or an ``EventBatch`` (routed to the columnar fast path)."""
    if isinstance(events_by_rank, EventBatch):
        return aggregate_all(events_by_rank, steps=[step]).get(step)
    return _aggregate_step_events(events_by_rank, step)


def _aggregate_step_events(events_by_rank: dict[int, list[TraceEvent]],
                           step: int) -> Optional[StepMetrics]:
    ranks = sorted(events_by_rank)
    per_rank = {r: _step_events(events_by_rank[r], step) for r in ranks}
    if not any(per_rank.values()):
        return None

    # ---- step span & throughput (①) ---------------------------------- #
    step_spans = [e for r in ranks for e in per_rank[r]
                  if e.kind == EventKind.STEP]
    if step_spans:
        t_step = float(np.mean([e.duration for e in step_spans]))
        tokens = sum(e.meta.get("tokens", 0) for e in step_spans)
    else:
        all_ev = [e for r in ranks for e in per_rank[r]]
        t0 = min(e.start_ts for e in all_ev)
        t1 = max(e.end_ts for e in all_ev)
        t_step = t1 - t0
        tokens = 0
    throughput = tokens / t_step if t_step > 0 else 0.0

    # ---- device kernels ------------------------------------------------ #
    flops: dict[str, dict[int, float]] = {}
    overlapped: set[str] = set()
    bandwidth: dict[str, float] = {}
    issue_lat: list[float] = []

    for r in ranks:
        comm_iv = [(e.start_ts, e.end_ts) for e in per_rank[r]
                   if e.kind == EventKind.KERNEL_COMM]
        for e in per_rank[r]:
            if e.kind == EventKind.KERNEL_COMPUTE and e.meta.get("flops"):
                f = e.meta["flops"] / max(e.duration, 1e-12)
                flops.setdefault(e.name, {})[r] = f
                # comp/comm overlap accounting (§5.2.2)
                for (s, t) in comm_iv:
                    inter = min(t, e.end_ts) - max(s, e.start_ts)
                    if inter > 0.5 * e.duration:
                        overlapped.add(e.name)
                        break
            elif e.kind == EventKind.KERNEL_COMM:
                issue_lat.append(e.issue_latency)

    # bandwidth (③): per comm-op instance, last-issuer start to end
    comm_by_name: dict[str, list[TraceEvent]] = {}
    for r in ranks:
        for e in per_rank[r]:
            if e.kind == EventKind.KERNEL_COMM:
                comm_by_name.setdefault(e.name, []).append(e)
    for name, evs in comm_by_name.items():
        start = max(e.start_ts for e in evs)
        end = max(e.end_ts for e in evs)
        nbytes = evs[0].meta.get("bytes", 0)
        if end > start and nbytes:
            bandwidth[name] = nbytes / (end - start)

    # ---- void percentages (⑤) ----------------------------------------- #
    v_inters, v_minors, t_inters = [], [], []
    for r in ranks:
        evs = per_rank[r]
        dl = [e for e in evs if e.kind == EventKind.DATALOADER]
        dev = sorted([e for e in evs if e.kind in DEVICE_KINDS],
                     key=lambda e: e.start_ts)
        sspan = next((e for e in evs if e.kind == EventKind.STEP), None)
        tstep_r = sspan.duration if sspan else t_step
        if not dev or tstep_r <= 0:
            continue
        # T_inter: last kernel before the dataloader to first kernel after
        t_inter = 0.0
        for d in dl:
            before = [e.end_ts for e in dev if e.end_ts <= d.start_ts]
            after = [e.start_ts for e in dev if e.start_ts >= d.end_ts]
            lo = max(before) if before else d.start_ts
            hi = min(after) if after else d.end_ts
            t_inter += max(hi - lo, 0.0)
        if not dl:  # no dataloader in step (serving) -> t_inter = 0
            t_inter = 0.0
        # V_minority: device gaps where the NEXT kernel was already issued
        # before the device went idle — i.e. the device was busy running
        # something outside FLARE's tracing (paper: "launched but remain
        # un-executed").  Gaps where the next kernel was issued late are
        # kernel-issue stalls (metric ④), not minority kernels.
        # gaps before COMM kernels are collective barrier waits (peer
        # stragglers), not minority kernels — bandwidth (③) covers those.
        gaps = 0.0
        for a, b in zip(dev[:-1], dev[1:]):
            gap = b.start_ts - a.end_ts
            if gap > 0.0 and b.issue_ts <= a.end_ts \
                    and b.kind == EventKind.KERNEL_COMPUTE:
                gaps += gap
        denom = max(tstep_r - t_inter, 1e-12)
        v_inters.append(min(t_inter / tstep_r, 1.0))
        v_minors.append(min(gaps / denom, 1.0))
        t_inters.append(t_inter)

    # ---- host API spans (root-cause narrowing) ------------------------- #
    api_spans: dict[str, float] = {}
    for r in ranks:
        for e in per_rank[r]:
            if e.kind in (EventKind.PY_API, EventKind.GC, EventKind.SYNC,
                          EventKind.DATALOADER):
                api_spans[e.name] = api_spans.get(e.name, 0.0) + e.duration

    flops_mean = {k: v for k, v in flops.items()}
    return StepMetrics(
        step=step, t_step=t_step, throughput=throughput,
        flops=flops_mean, flops_overlapped=overlapped, bandwidth=bandwidth,
        issue_latencies=np.asarray(issue_lat, np.float64),
        v_inter=float(np.mean(v_inters)) if v_inters else 0.0,
        v_minority=float(np.mean(v_minors)) if v_minors else 0.0,
        t_inter=float(np.mean(t_inters)) if t_inters else 0.0,
        api_spans=api_spans, num_ranks=len(ranks))


def steps_in(events_by_rank) -> list[int]:
    """Sorted distinct steps.  Accepts the legacy dict or an EventBatch."""
    if isinstance(events_by_rank, EventBatch):
        return events_by_rank.steps()
    s = {e.step for evs in events_by_rank.values() for e in evs if e.step >= 0}
    return sorted(s)


# ----------------------------------------------------------------------- #
# columnar path: every step's metrics in one vectorized sweep
# ----------------------------------------------------------------------- #
def aggregate_all(batch: EventBatch,
                  steps: Optional[list[int]] = None) -> dict[int, StepMetrics]:
    """Compute StepMetrics for every step of ``batch`` (or the requested
    subset) without re-filtering per-rank event lists per step.

    Numerically equivalent to ``aggregate_step`` on the converted events;
    float reduction order may differ at the 1-ulp level, and the order of
    ``issue_latencies`` is insertion order rather than rank-major (every
    consumer — W1 distance, medians, profile learning — is order-free).
    """
    if len(batch) == 0:
        return {}
    num_ranks = batch.num_distinct_ranks()
    order, uniq, bounds = batch.step_index()
    want = None if steps is None else set(steps)
    out: dict[int, StepMetrics] = {}
    for i, s in enumerate(uniq.tolist()):
        if s < 0 or (want is not None and s not in want):
            continue
        rows = order[bounds[i]:bounds[i + 1]]
        out[s] = _aggregate_rows(batch, rows, s, num_ranks)
    return out


def aggregate_slice(batch: EventBatch, step: int,
                    num_ranks: Optional[int] = None) -> Optional[StepMetrics]:
    """StepMetrics for a batch KNOWN to hold exactly one step's rows in
    insertion order (a fleet-store slice): skips the ``step_index``
    argsort/unique that ``aggregate_all`` would redo per call.  Identical
    result to ``aggregate_all(batch)[step]``."""
    if len(batch) == 0:
        return None
    if num_ranks is None:
        num_ranks = batch.num_distinct_ranks()
    return _aggregate_rows(batch, None, step, int(num_ranks))


def _group_bounds(keys: np.ndarray):
    """(order, unique_keys, bounds) for a stable group-by over ``keys``."""
    o = np.argsort(keys, kind="stable")
    sorted_keys = keys[o]
    u, starts = np.unique(sorted_keys, return_index=True)
    return o, u, np.append(starts, keys.size)


def _appearance_order(o: np.ndarray, bounds: np.ndarray) -> list[int]:
    """Group iteration order by FIRST APPEARANCE in the original rows (the
    stable sort puts each group's earliest row at its segment start).  Keys
    are interning ids, which the fleet shares across jobs — dict key order
    must not depend on which job interned a name first."""
    return np.argsort(o[bounds[:-1]], kind="stable").tolist()


def _aggregate_rows(b: EventBatch, rows: Optional[np.ndarray], step: int,
                    num_ranks: int) -> StepMetrics:
    names = b.names
    if rows is None:
        # whole-batch fast path (``aggregate_slice``): reference the
        # columns directly — a fancy-index with arange would copy every
        # column of every step slice on the fleet hot path
        k, rk, iss, st, en = b.kind, b.rank, b.issue_ts, b.start_ts, b.end_ts
        nid, fl, nb, tk = b.name_id, b.flops, b.nbytes, b.tokens
        rows = np.arange(len(b))       # only sparse lookups index this
    else:
        k = b.kind[rows]
        rk = b.rank[rows]
        iss = b.issue_ts[rows]
        st = b.start_ts[rows]
        en = b.end_ts[rows]
        nid = b.name_id[rows]
        fl = b.flops[rows]
        nb = b.nbytes[rows]
        tk = b.tokens[rows]

    # ---- step span & throughput (①) ---------------------------------- #
    ms = k == _C_STEP
    if ms.any():
        t_step = float(np.mean(en[ms] - st[ms]))
        tk_s = tk[ms]
        present = tk_s != NO_INT
        tokens = int(tk_s[present].sum())
        if b.extra and not present.all():
            # rare: non-int tokens live in the extra dicts
            for row in rows[ms][~present].tolist():
                v = (b.extra.get(row) or {}).get("tokens", 0)
                if isinstance(v, (int, float)) and not isinstance(v, bool):
                    tokens += v
    else:
        t_step = float(en.max() - st.min())
        tokens = 0
    throughput = tokens / t_step if t_step > 0 else 0.0

    # ---- compute FLOPS (②) -------------------------------------------- #
    m_comp = k == _C_COMP
    m_comm = k == _C_COMM
    m_flop = m_comp & ~np.isnan(fl)
    flops: dict[str, dict[int, float]] = {}
    if m_flop.any():
        cn = nid[m_flop]
        cf = fl[m_flop] / np.maximum(en[m_flop] - st[m_flop], 1e-12)
        o, u, gb = _group_bounds(cn)
        cr_l = rk[m_flop][o].tolist()
        cf_l = cf[o].tolist()
        u_l = u.tolist()
        for j in _appearance_order(o, gb):
            lo, hi = gb[j], gb[j + 1]
            # dict(zip(...)) keeps last-wins semantics for duplicate ranks
            flops[names[u_l[j]]] = dict(zip(cr_l[lo:hi], cf_l[lo:hi]))

    # ---- comp/comm overlap flags (§5.2.2) ----------------------------- #
    overlapped: set[str] = set()
    if m_flop.any() and m_comm.any():
        kr, ks, ke, kn = rk[m_flop], st[m_flop], en[m_flop], nid[m_flop]
        cr, cs, ce = rk[m_comm], st[m_comm], en[m_comm]
        max_r = int(max(kr.max(), cr.max())) + 1
        c_cnt = np.bincount(cr, minlength=max_r)
        c_off = np.concatenate(([0], np.cumsum(c_cnt)[:-1]))
        co = np.argsort(cr, kind="stable")
        cs_s, ce_s = cs[co], ce[co]
        rep = c_cnt[kr]                    # comm partners per compute row
        total = int(rep.sum())
        if total:
            pk = np.repeat(np.arange(kr.size), rep)
            within = np.arange(total) - np.repeat(np.cumsum(rep) - rep, rep)
            pc = np.repeat(c_off[kr], rep) + within
            inter = np.minimum(ce_s[pc], ke[pk]) - np.maximum(cs_s[pc], ks[pk])
            hit = inter > 0.5 * (ke[pk] - ks[pk])
            for nm_id in np.unique(kn[pk[hit]]).tolist():
                overlapped.add(names[nm_id])

    # ---- issue latencies (④) + bandwidth (③) -------------------------- #
    issue_lat = (st - iss)[m_comm]
    bandwidth: dict[str, float] = {}
    if m_comm.any():
        o, u, gb = _group_bounds(nid[m_comm])
        st_s, en_s = st[m_comm][o], en[m_comm][o]
        nb_s = nb[m_comm][o]
        rows_comm = rows[m_comm][o]
        u_l = u.tolist()
        for j in _appearance_order(o, gb):
            nm_id = u_l[j]
            lo, hi = gb[j], gb[j + 1]
            start = float(st_s[lo:hi].max())
            end = float(en_s[lo:hi].max())
            first = int(nb_s[lo])
            if first == NO_INT:
                nbytes = (b.extra.get(int(rows_comm[lo])) or {}) \
                    .get("bytes", 0) if b.extra else 0
            else:
                nbytes = first
            if end > start and nbytes:
                bandwidth[names[nm_id]] = nbytes / (end - start)

    # ---- void percentages (⑤) ----------------------------------------- #
    v_inter = v_minority = t_inter = 0.0
    m_dev = m_comp | m_comm
    if m_dev.any():
        dr, ds, de = rk[m_dev], st[m_dev], en[m_dev]
        di, dk = iss[m_dev], k[m_dev]
        o = np.lexsort((ds, dr))           # stable (rank, start_ts) order
        dr_s, ds_s, de_s = dr[o], ds[o], de[o]
        di_s, dk_s = di[o], dk[o]
        ranks_dev = np.unique(dr_s)        # only ranks with device events

        # per-rank step span: first STEP event per rank, else global t_step
        tstep_r = np.full(ranks_dev.size, t_step)
        if ms.any():
            so, su, sgb = _group_bounds(rk[ms])
            first_rows = so[sgb[:-1]]      # first STEP row per rank
            dur = (en[ms] - st[ms])[first_rows]
            pos = np.searchsorted(ranks_dev, su)
            ok = (pos < ranks_dev.size)
            ok &= ranks_dev[np.minimum(pos, ranks_dev.size - 1)] == su
            tstep_r[pos[ok]] = dur[ok]

        # T_inter: dataloader windows widened to the surrounding kernels
        t_inter_r = np.zeros(ranks_dev.size)
        m_dl = k == _C_DL
        if m_dl.any():
            qs, qe, qr = st[m_dl], en[m_dl], rk[m_dl]
            pos = np.searchsorted(ranks_dev, qr)
            ok = (pos < ranks_dev.size)
            ok &= ranks_dev[np.minimum(pos, ranks_dev.size - 1)] == qr
            if ok.any():
                qs, qe, qr, pos = qs[ok], qe[ok], qr[ok], pos[ok]
                bi = prev_le(de_s, dr_s, qs, qr)
                lo_ = np.where(bi >= 0, de_s[np.maximum(bi, 0)], qs)
                ai = next_ge(ds_s, dr_s, qe, qr)
                hi_ = np.where(ai >= 0, ds_s[np.maximum(ai, 0)], qe)
                t_inter_r = np.bincount(
                    pos, weights=np.maximum(hi_ - lo_, 0.0),
                    minlength=ranks_dev.size)

        # V_minority: same-rank consecutive device gaps with an
        # already-issued next COMPUTE kernel
        gaps_r = np.zeros(ranks_dev.size)
        if dr_s.size > 1:
            same = dr_s[1:] == dr_s[:-1]
            gap = ds_s[1:] - de_s[:-1]
            cond = same & (gap > 0.0) & (di_s[1:] <= de_s[:-1]) \
                & (dk_s[1:] == _C_COMP)
            if cond.any():
                gaps_r = np.bincount(
                    np.searchsorted(ranks_dev, dr_s[1:][cond]),
                    weights=gap[cond], minlength=ranks_dev.size)

        keep = tstep_r > 0
        if keep.any():
            ti, ts_, g = t_inter_r[keep], tstep_r[keep], gaps_r[keep]
            v_inter = float(np.mean(np.minimum(ti / ts_, 1.0)))
            v_minority = float(np.mean(
                np.minimum(g / np.maximum(ts_ - ti, 1e-12), 1.0)))
            t_inter = float(np.mean(ti))

    # ---- host API spans ------------------------------------------------ #
    api_spans: dict[str, float] = {}
    m_api = (k == _C_PY) | (k == _C_GC) | (k == _C_SYNC) | (k == _C_DL)
    if m_api.any():
        an = nid[m_api]
        totals = np.bincount(an, weights=(en - st)[m_api],
                             minlength=len(names))
        o, u, gb = _group_bounds(an)
        u_l = u.tolist()
        for j in _appearance_order(o, gb):
            api_spans[names[u_l[j]]] = float(totals[u_l[j]])

    return StepMetrics(
        step=step, t_step=t_step, throughput=throughput,
        flops=flops, flops_overlapped=overlapped, bandwidth=bandwidth,
        issue_latencies=np.asarray(issue_lat, np.float64),
        v_inter=v_inter, v_minority=v_minority, t_inter=t_inter,
        api_spans=api_spans, num_ranks=num_ranks)
