"""The five aggregated metrics of FLARE (paper §5.2, Fig 7).

  ① training throughput        (macro — fail-slow detection)
  ② compute-kernel FLOPS        (micro — underclock / layout regressions)
  ③ collective bandwidth        (micro — jitter / GDR regressions)
  ④ issue-latency distribution  (micro — kernel-issue stalls: GC, sync)
  ⑤ void percentage V_inter / V_minority (micro — uncovered operations)

All are computed from per-rank event lists for one training step.  FLOPS of
compute kernels that overlap a communication kernel are flagged so they are
not mistakenly treated as regressed (§5.2.2, MoE overlap).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.events import DEVICE_KINDS, EventKind, TraceEvent


@dataclass
class StepMetrics:
    step: int
    t_step: float
    throughput: float                       # tokens / s   (①)
    flops: dict                             # name -> per-rank achieved FLOP/s (②)
    flops_overlapped: set                   # kernel names excluded from ② checks
    bandwidth: dict                         # (name) -> achieved B/s          (③)
    issue_latencies: np.ndarray             # comm-kernel issue latencies     (④)
    v_inter: float                          # ⑤
    v_minority: float                       # ⑤
    t_inter: float
    api_spans: dict                         # api name -> total host seconds
    num_ranks: int = 1


def _step_events(events: list[TraceEvent], step: int):
    return [e for e in events if e.step == step]


def aggregate_step(events_by_rank: dict[int, list[TraceEvent]],
                   step: int) -> Optional[StepMetrics]:
    ranks = sorted(events_by_rank)
    per_rank = {r: _step_events(events_by_rank[r], step) for r in ranks}
    if not any(per_rank.values()):
        return None

    # ---- step span & throughput (①) ---------------------------------- #
    step_spans = [e for r in ranks for e in per_rank[r]
                  if e.kind == EventKind.STEP]
    if step_spans:
        t_step = float(np.mean([e.duration for e in step_spans]))
        tokens = sum(e.meta.get("tokens", 0) for e in step_spans)
    else:
        all_ev = [e for r in ranks for e in per_rank[r]]
        t0 = min(e.start_ts for e in all_ev)
        t1 = max(e.end_ts for e in all_ev)
        t_step = t1 - t0
        tokens = 0
    throughput = tokens / t_step if t_step > 0 else 0.0

    # ---- device kernels ------------------------------------------------ #
    flops: dict[str, dict[int, float]] = {}
    overlapped: set[str] = set()
    bandwidth: dict[str, float] = {}
    issue_lat: list[float] = []

    for r in ranks:
        comm_iv = [(e.start_ts, e.end_ts) for e in per_rank[r]
                   if e.kind == EventKind.KERNEL_COMM]
        for e in per_rank[r]:
            if e.kind == EventKind.KERNEL_COMPUTE and e.meta.get("flops"):
                f = e.meta["flops"] / max(e.duration, 1e-12)
                flops.setdefault(e.name, {})[r] = f
                # comp/comm overlap accounting (§5.2.2)
                for (s, t) in comm_iv:
                    inter = min(t, e.end_ts) - max(s, e.start_ts)
                    if inter > 0.5 * e.duration:
                        overlapped.add(e.name)
                        break
            elif e.kind == EventKind.KERNEL_COMM:
                issue_lat.append(e.issue_latency)

    # bandwidth (③): per comm-op instance, last-issuer start to end
    comm_by_name: dict[str, list[TraceEvent]] = {}
    for r in ranks:
        for e in per_rank[r]:
            if e.kind == EventKind.KERNEL_COMM:
                comm_by_name.setdefault(e.name, []).append(e)
    for name, evs in comm_by_name.items():
        start = max(e.start_ts for e in evs)
        end = max(e.end_ts for e in evs)
        nbytes = evs[0].meta.get("bytes", 0)
        if end > start and nbytes:
            bandwidth[name] = nbytes / (end - start)

    # ---- void percentages (⑤) ----------------------------------------- #
    v_inters, v_minors, t_inters = [], [], []
    for r in ranks:
        evs = per_rank[r]
        dl = [e for e in evs if e.kind == EventKind.DATALOADER]
        dev = sorted([e for e in evs if e.kind in DEVICE_KINDS],
                     key=lambda e: e.start_ts)
        sspan = next((e for e in evs if e.kind == EventKind.STEP), None)
        tstep_r = sspan.duration if sspan else t_step
        if not dev or tstep_r <= 0:
            continue
        # T_inter: last kernel before the dataloader to first kernel after
        t_inter = 0.0
        for d in dl:
            before = [e.end_ts for e in dev if e.end_ts <= d.start_ts]
            after = [e.start_ts for e in dev if e.start_ts >= d.end_ts]
            lo = max(before) if before else d.start_ts
            hi = min(after) if after else d.end_ts
            t_inter += max(hi - lo, 0.0)
        if not dl:  # no dataloader in step (serving) -> t_inter = 0
            t_inter = 0.0
        # V_minority: device gaps where the NEXT kernel was already issued
        # before the device went idle — i.e. the device was busy running
        # something outside FLARE's tracing (paper: "launched but remain
        # un-executed").  Gaps where the next kernel was issued late are
        # kernel-issue stalls (metric ④), not minority kernels.
        # gaps before COMM kernels are collective barrier waits (peer
        # stragglers), not minority kernels — bandwidth (③) covers those.
        gaps = 0.0
        for a, b in zip(dev[:-1], dev[1:]):
            gap = b.start_ts - a.end_ts
            if gap > 0.0 and b.issue_ts <= a.end_ts \
                    and b.kind == EventKind.KERNEL_COMPUTE:
                gaps += gap
        denom = max(tstep_r - t_inter, 1e-12)
        v_inters.append(min(t_inter / tstep_r, 1.0))
        v_minors.append(min(gaps / denom, 1.0))
        t_inters.append(t_inter)

    # ---- host API spans (root-cause narrowing) ------------------------- #
    api_spans: dict[str, float] = {}
    for r in ranks:
        for e in per_rank[r]:
            if e.kind in (EventKind.PY_API, EventKind.GC, EventKind.SYNC,
                          EventKind.DATALOADER):
                api_spans[e.name] = api_spans.get(e.name, 0.0) + e.duration

    flops_mean = {k: v for k, v in flops.items()}
    return StepMetrics(
        step=step, t_step=t_step, throughput=throughput,
        flops=flops_mean, flops_overlapped=overlapped, bandwidth=bandwidth,
        issue_latencies=np.asarray(issue_lat, np.float64),
        v_inter=float(np.mean(v_inters)) if v_inters else 0.0,
        v_minority=float(np.mean(v_minors)) if v_minors else 0.0,
        t_inter=float(np.mean(t_inters)) if t_inters else 0.0,
        api_spans=api_spans, num_ranks=len(ranks))


def steps_in(events_by_rank: dict[int, list[TraceEvent]]) -> list[int]:
    s = {e.step for evs in events_by_rank.values() for e in evs if e.step >= 0}
    return sorted(s)
