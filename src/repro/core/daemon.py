"""Per-process tracing daemon (paper §4): timing manager + background thread.

Responsibilities (mirroring Fig 4):
  * collect spans from the Python interceptor, the dataloader seam, GC,
    registered kernel entry points, and step boundaries;
  * time asynchronous device work without blocking the training thread —
    completion probing happens on the daemon thread against shadow futures
    (the CUDA-event analogue; see DESIGN.md §2);
  * reconstruct Python<->kernel call stacks from span intervals (stack.py)
    before streaming;
  * heartbeat: if no event completes within ``hang_timeout`` while a step
    is in flight, report a suspected hang to the engine;
  * stream, in the background, to any sink: the in-process diagnostic
    engine and/or a JSONL file.

Kernel registration is the explicit "C++ interface" of the paper: the op
library (repro.kernels.*, repro.parallel.collectives) self-registers when a
daemon is attached; backends are never patched.
"""
from __future__ import annotations

import os
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.core.events import EventKind, EventRingBuffer, TraceEvent
from repro.core.interceptor import PyApiInterceptor
from repro.core.stack import reconstruct_stacks
from repro.core.telemetry import TelemetryRegistry

_GLOBAL_DAEMON: Optional["TracingDaemon"] = None


@dataclass
class DaemonConfig:
    rank: int = 0
    backend: str = "dense-train"   # historical-profile key (paper §8.2)
    hang_timeout: float = 30.0
    drain_interval: float = 0.05
    log_path: Optional[str] = None
    # spill codec: None = infer from log_path extension ("jsonl" default;
    # ".fcs" spills binary columnar segments, ".fcs2" compressed archival
    # segments — see repro.store).  "fcs2" may also be named explicitly
    # to write v2 segments into a ".fcs" path (readers dispatch on the
    # segment version byte, so mixed files replay fine).
    log_codec: Optional[str] = None
    # archival-spill compression: backend name ("zstd"/"zlib"; None =
    # best available) and level for FCS v2 segments.  Setting either
    # implies log_codec="fcs2".
    log_compression: Optional[str] = None
    log_compression_level: Optional[int] = None
    # rotate the spill to <stem>.segNNN<ext> once the current file passes
    # this size; None = single file forever (historical behavior)
    log_rotate_bytes: Optional[int] = None
    buffer_capacity: int = 200_000
    reconstruct: bool = True
    enabled: bool = True
    # detector set for the engine diagnosing this daemon's job when it is
    # attached to a fleet without an explicit EngineConfig (registry names
    # / DetectorSpecs — see repro.core.detectors); None = default set
    detectors: Optional[list] = None
    num_ranks: int = 1             # job-wide rank count for that engine
    # self-telemetry registry (repro.core.telemetry); None = a private
    # one per daemon.  Pass a shared registry (or attach to a fleet,
    # whose snapshot merges daemon registries) for one pipeline view.
    telemetry: Optional[TelemetryRegistry] = None
    # live fleet service endpoint ("host:port"): each flushed batch is
    # FCS-framed (repro.serve wire protocol) and shipped from the daemon
    # thread with reconnect/backoff; a dead or slow service costs
    # COUNTED drops (daemon.live_dropped) — it can never block the
    # heartbeat or kill the daemon, and the spill/tail plane recovers
    # whatever live frames were lost
    live_endpoint: Optional[str] = None
    live_job_id: Optional[str] = None      # default: "job-rank<rank>"
    live_topology: Optional[dict] = None   # rack/switch attrs, HELLO'd


class TracingDaemon:
    def __init__(self, config: DaemonConfig | None = None):
        self.cfg = config or DaemonConfig()
        self.buffer = EventRingBuffer(self.cfg.buffer_capacity)
        self.interceptor = PyApiInterceptor(self._on_api_span, self._on_gc)
        self._sinks: list[Callable[[list[TraceEvent]], None]] = []
        self._batch_sinks: list = []
        self._hang_cb: Optional[Callable[[dict], None]] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._step = -1
        self._step_t0 = 0.0
        self._in_step = False
        self._last_completion = time.perf_counter()
        self._pending: "queue.Queue" = queue.Queue()
        self._last_stack: list[str] = []
        # self-telemetry: handles resolved once, incremented lock-free on
        # the hot path (these replace the old plain-int attributes; the
        # read-only properties below keep that surface)
        self.telemetry = self.cfg.telemetry or TelemetryRegistry()
        self._c_bytes = self.telemetry.counter("daemon.bytes_logged")
        self._c_events = self.telemetry.counter("daemon.events_emitted")
        self._c_spill_errors = self.telemetry.counter("daemon.spill_errors")
        self._g_heartbeat = self.telemetry.gauge("daemon.heartbeat_age_s")
        self._g_queue = self.telemetry.gauge("daemon.queue_depth")
        self._g_rate = self.telemetry.gauge("daemon.events_per_s")
        self._rate_t0 = time.perf_counter()
        self._rate_n0 = 0
        self._attached = False
        self._spill = None
        if self.cfg.log_path:
            from repro.store import FcsV2Codec, SegmentedTraceWriter
            codec = self.cfg.log_codec
            if (self.cfg.log_compression is not None
                    or self.cfg.log_compression_level is not None):
                # an explicit compression knob means the archival (v2)
                # spill, with a per-daemon backend/level instance
                codec = FcsV2Codec(
                    compression=self.cfg.log_compression,
                    level=self.cfg.log_compression_level)
            self._spill = SegmentedTraceWriter(
                self.cfg.log_path, codec=codec,
                rotate_bytes=self.cfg.log_rotate_bytes)
        self._live = None
        if self.cfg.live_endpoint:
            from repro.serve.client import LiveBatchSink
            self._live = LiveBatchSink(
                self.cfg.live_endpoint,
                self.cfg.live_job_id or f"job-rank{self.cfg.rank}",
                topology=self.cfg.live_topology,
                telemetry=self.telemetry)
            self.add_batch_sink(self._live)

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    def attach(self):
        """Attach to the current training process (plug-and-play)."""
        if self._attached or not self.cfg.enabled:
            return self
        self.interceptor.register_from_env()
        self.interceptor.install()
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._run, daemon=True, name="flare-daemon")
        self._thread.start()
        self._attached = True
        global _GLOBAL_DAEMON
        _GLOBAL_DAEMON = self
        return self

    def detach(self):
        if not self._attached:
            return
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
        self.interceptor.uninstall()
        self._flush()
        if self._live is not None:
            self._live.close()        # best-effort BYE; reconnects if
            #                           the daemon re-attaches later
        self._attached = False
        global _GLOBAL_DAEMON
        if _GLOBAL_DAEMON is self:
            _GLOBAL_DAEMON = None

    def stop(self):
        """Idempotent shutdown: safe on a never-attached or already-stopped
        daemon and safe to call repeatedly — the fleet close path stops
        every job's daemons without tracking which already exited."""
        self.detach()

    def attach_fleet(self, mux, job_id: Optional[str] = None,
                     engine_cfg=None):
        """Fleet seam: stream this daemon's drains into a
        ``repro.fleet.FleetMultiplexer`` as job ``job_id`` (columnar batch
        sink, no per-event dicts) and hand the daemon to the multiplexer so
        ``mux.close()`` can ``stop()`` it with the rest of the fleet.

        ``engine_cfg`` configures the job's diagnostic engine (detector
        set, rank count).  Without one, the daemon builds it from its own
        config — ``DaemonConfig.detectors``/``num_ranks``/``backend`` —
        so a process can pick its diagnosis plugins at daemon-attach time
        without ever importing the engine."""
        jid = job_id if job_id is not None else f"job-rank{self.cfg.rank}"
        if engine_cfg is None and (self.cfg.detectors is not None
                                   or self.cfg.num_ranks > 1
                                   or self.cfg.backend != DaemonConfig.backend):
            # any non-default engine-relevant daemon setting wins over the
            # multiplexer's fallback EngineConfig; an all-default daemon
            # keeps the historical behavior (fleet-configured backend)
            from repro.core.engine import EngineConfig
            engine_cfg = EngineConfig(
                backend=self.cfg.backend, num_ranks=self.cfg.num_ranks,
                detectors=self.cfg.detectors)
        mux.register_daemon(jid, self, engine_cfg)
        self.add_batch_sink(lambda batch, _jid=jid: mux.ingest(_jid, batch))
        return self

    def add_sink(self, sink: Callable[[list[TraceEvent]], None]):
        self._sinks.append(sink)

    def add_batch_sink(self, sink):
        """Columnar sink: receives each drain as one ``EventBatch`` (e.g.
        ``engine.ingest_batch``), skipping per-event dict handling in the
        consumer."""
        self._batch_sinks.append(sink)

    def on_hang(self, cb: Callable[[dict], None]):
        self._hang_cb = cb

    # ------------------------------------------------------------------ #
    # event entry points
    # ------------------------------------------------------------------ #
    # telemetry-backed views of the historical plain-int attributes
    @property
    def bytes_logged(self) -> int:
        return self._c_bytes.value

    @property
    def events_emitted(self) -> int:
        return self._c_events.value

    @property
    def spill_errors(self) -> int:
        return self._c_spill_errors.value

    def _emit(self, ev: TraceEvent):
        self.buffer.append(ev)
        self._c_events.inc()
        self._last_completion = time.perf_counter()

    def _on_api_span(self, name: str, t0: float, t1: float):
        self._emit(TraceEvent(EventKind.PY_API, name, self.cfg.rank,
                              t0, t0, t1, step=self._step))

    def _on_gc(self, name: str, t0: float, t1: float):
        self._emit(TraceEvent(EventKind.GC, name, self.cfg.rank,
                              t0, t0, t1, step=self._step))

    def record_span(self, kind: EventKind, name: str, t0: float, t1: float,
                    **meta):
        self._emit(TraceEvent(kind, name, self.cfg.rank, t0, t0, t1,
                              step=self._step, meta=meta))

    def step_begin(self, step: int):
        self._step = step
        self._step_t0 = time.perf_counter()
        self._in_step = True

    def step_end(self, **meta):
        t1 = time.perf_counter()
        self._emit(TraceEvent(EventKind.STEP, f"step_{self._step}",
                              self.cfg.rank, self._step_t0, self._step_t0,
                              t1, step=self._step, meta=meta))
        self._in_step = False

    def set_stack(self, stack: list[str]):
        """Training thread publishes its logical call stack (hang analysis)."""
        self._last_stack = list(stack)

    # ------------------------------------------------------------------ #
    # kernel registration — the explicit infra-team interface
    # ------------------------------------------------------------------ #
    def register_kernel(self, name: str, kind: EventKind,
                        meta_fn: Optional[Callable[..., dict]] = None):
        """Decorator: wraps an op-library entry point.

        Issue timestamp is taken at dispatch.  Completion is probed on the
        daemon thread via a shadow `block_until_ready` on (a sample of) the
        returned arrays — the training thread is never blocked (Fig 4).
        """
        def deco(fn):
            def wrapped(*args, **kwargs):
                if not self._attached:
                    return fn(*args, **kwargs)
                issue = time.perf_counter()
                out = fn(*args, **kwargs)
                meta = meta_fn(*args, **kwargs) if meta_fn else {}
                self._pending.put((name, kind, issue, self._step, out, meta))
                return out
            wrapped.__name__ = getattr(fn, "__name__", name)
            wrapped.__wrapped__ = fn
            return wrapped
        return deco

    # ------------------------------------------------------------------ #
    # background thread: timing manager + heartbeat + streaming
    # ------------------------------------------------------------------ #
    def _run(self):
        while not self._stop.is_set():
            self._probe_pending()
            self._flush()
            self._heartbeat()
            time.sleep(self.cfg.drain_interval)
        self._probe_pending()
        self._flush()

    def _probe_pending(self):
        try:
            while True:
                name, kind, issue, step, out, meta = self._pending.get_nowait()
                start = time.perf_counter()
                try:
                    import jax
                    jax.block_until_ready(out)
                except Exception:
                    pass
                end = time.perf_counter()
                self._emit(TraceEvent(kind, name, self.cfg.rank, issue,
                                      start, end, step=step, meta=meta))
        except queue.Empty:
            pass

    def _flush(self):
        events = self.buffer.drain()
        if not events:
            return
        if self.cfg.reconstruct:
            reconstruct_stacks(events)
        for sink in self._sinks:
            try:
                sink(events)
            except Exception:
                pass
        if self._batch_sinks or self._spill is not None:
            from repro.core.columnar import EventBatch
            batch = EventBatch.from_events(events)
            for sink in self._batch_sinks:
                try:
                    sink(batch)
                except Exception:
                    pass
            if self._spill is not None:
                # one codec segment (or JSONL line run) per drain; guarded
                # like the sinks — a spill error (disk full, unserializable
                # user meta) must not kill the daemon thread, which would
                # silently end hang-heartbeat detection too.  Counted and
                # warned once so a permanently failing spill is observable.
                try:
                    self._c_bytes.inc(self._spill.write(batch))
                except Exception as e:
                    if self._c_spill_errors.inc() == 1:
                        import warnings
                        warnings.warn(
                            f"trace spill to {self.cfg.log_path} failing "
                            f"({type(e).__name__}: {e}); events continue to "
                            "stream to sinks but are NOT being persisted",
                            stacklevel=2)

    @property
    def log_paths(self) -> list[str]:
        """Every spill file written so far (>1 once rotation kicks in)."""
        return list(self._spill.paths) if self._spill is not None else []

    def _heartbeat(self):
        now = time.perf_counter()
        silent = now - self._last_completion
        self._g_heartbeat.set(silent)
        self._g_queue.set(self._pending.qsize())
        dt = now - self._rate_t0
        if dt >= 1.0:
            n = self._c_events.value
            self._g_rate.set((n - self._rate_n0) / dt)
            self._rate_t0, self._rate_n0 = now, n
        if self._in_step and silent > self.cfg.hang_timeout:
            report = {"rank": self.cfg.rank, "silent_s": silent,
                      "step": self._step, "stack": self._last_stack}
            self._emit(TraceEvent(EventKind.HANG_SUSPECT, "hang_suspect",
                                  self.cfg.rank, now, now, now,
                                  step=self._step, meta=report))
            if self._hang_cb:
                try:
                    self._hang_cb(report)
                except Exception:
                    pass
            self._last_completion = now  # rate-limit repeat reports


# --------------------------------------------------------------------------- #
def attach(config: DaemonConfig | None = None) -> TracingDaemon:
    """Module-level convenience: attach a daemon to this process."""
    d = TracingDaemon(config)
    return d.attach()


def get_daemon() -> Optional[TracingDaemon]:
    return _GLOBAL_DAEMON
