"""Historical profile store (paper §8.2).

Healthy profiles are keyed by (backend family, cluster scale bucket) — the
paper's requirement that e.g. an attention-free SSM backend or a CPU-
embedding recommendation backend gets its *own* healthy distribution
(their two §7.3 false positives came from violating this).  Profiles hold:
issue-latency samples, void-percentage thresholds, per-kernel expected
FLOPS and per-group expected bandwidth.
"""
from __future__ import annotations

import json
import math
import os
from dataclasses import asdict, dataclass, field
from typing import Optional

import numpy as np

from repro.core.wasserstein import healthy_threshold


def scale_bucket(num_ranks: int) -> str:
    if num_ranks <= 0:
        return "0"
    return f"2^{int(math.ceil(math.log2(max(num_ranks, 1))))}"


@dataclass
class HealthyProfile:
    backend: str
    scale: str
    issue_latency_runs: list = field(default_factory=list)  # list[list[float]]
    issue_w1_threshold: float = 0.25
    v_inter_threshold: float = 0.05
    v_minority_threshold: float = 0.12
    expected_flops: dict = field(default_factory=dict)      # name -> FLOP/s
    expected_bandwidth: dict = field(default_factory=dict)  # name -> B/s

    def finalize(self, margin: float = 1.5):
        self.issue_w1_threshold = healthy_threshold(
            self.issue_latency_runs, margin)
        self.__dict__.pop("_ref_cache", None)   # runs may have changed

    def _ref(self):
        """(concatenated, sorted, median, mean) of the healthy latency
        samples, cached — the W1 detector compares EVERY step of EVERY
        fleet job against this fixed reference; re-concatenating and
        re-sorting it per step dominated the incremental hot path.  The
        cache keys on (run count, sample count) and ``finalize`` clears
        it, so re-learning invalidates; mutating a run IN PLACE without
        re-finalizing would serve stale values."""
        key = (len(self.issue_latency_runs),
               sum(len(r) for r in self.issue_latency_runs))
        cached = self.__dict__.get("_ref_cache")
        if cached is not None and cached[0] == key:
            return cached
        if self.issue_latency_runs:
            arr = np.concatenate(
                [np.asarray(r, np.float64) for r in self.issue_latency_runs])
        else:
            arr = np.asarray([], np.float64)
        srt = np.sort(arr)
        med = float(np.median(srt)) if srt.size else 0.0
        mean = float(np.mean(arr)) if arr.size else 0.0
        cached = (key, arr, srt, med, mean)
        self.__dict__["_ref_cache"] = cached
        return cached

    @property
    def reference_latencies(self) -> np.ndarray:
        return self._ref()[1]

    @property
    def reference_sorted(self) -> np.ndarray:
        return self._ref()[2]

    @property
    def reference_median(self) -> float:
        return self._ref()[3]

    @property
    def reference_mean(self) -> float:
        return self._ref()[4]


class HistoryStore:
    def __init__(self, directory: Optional[str] = None):
        self.dir = directory
        self._mem: dict[tuple, HealthyProfile] = {}
        if directory:
            os.makedirs(directory, exist_ok=True)
            self._load_all()

    def key(self, backend: str, num_ranks: int) -> tuple:
        return (backend, scale_bucket(num_ranks))

    def get(self, backend: str, num_ranks: int) -> Optional[HealthyProfile]:
        return self._mem.get(self.key(backend, num_ranks))

    def put(self, profile: HealthyProfile):
        self._mem[(profile.backend, profile.scale)] = profile
        if self.dir:
            fname = f"{profile.backend}__{profile.scale}.json".replace("^", "")
            with open(os.path.join(self.dir, fname), "w") as f:
                json.dump(asdict(profile), f)

    def snapshot_profiles(self) -> dict:
        """Picklable view of every learned healthy profile (service
        checkpoints capture it so a restarted daemon judges regressions
        against the same references even with an empty profile dir)."""
        return dict(self._mem)

    def restore_profiles(self, profiles: dict) -> None:
        """Fold checkpointed profiles back in.  Profiles already present
        win — they are the same or newer than the checkpointed ones."""
        for key, prof in profiles.items():
            self._mem.setdefault(key, prof)

    def _load_all(self):
        for name in os.listdir(self.dir):
            if not name.endswith(".json"):
                continue
            with open(os.path.join(self.dir, name)) as f:
                d = json.load(f)
            p = HealthyProfile(**d)
            self._mem[(p.backend, p.scale)] = p

    # ------------------------------------------------------------------ #
    def learn_from_metrics(self, backend: str, num_ranks: int,
                           metrics_list, margin: float = 1.5,
                           void_margin: float = 1.6) -> HealthyProfile:
        """Build a healthy profile from several healthy-run StepMetrics."""
        prof = HealthyProfile(backend=backend, scale=scale_bucket(num_ranks))
        flops_acc: dict[str, list[float]] = {}
        bw_acc: dict[str, list[float]] = {}
        v_inters, v_minors = [], []
        for m in metrics_list:
            if m.issue_latencies.size:
                prof.issue_latency_runs.append(
                    m.issue_latencies.tolist())
            for name, per_rank in m.flops.items():
                flops_acc.setdefault(name, []).extend(per_rank.values())
            for name, bw in m.bandwidth.items():
                bw_acc.setdefault(name, []).append(bw)
            v_inters.append(m.v_inter)
            v_minors.append(m.v_minority)
        prof.expected_flops = {k: float(np.median(v))
                               for k, v in flops_acc.items()}
        prof.expected_bandwidth = {k: float(np.median(v))
                                   for k, v in bw_acc.items()}
        if v_inters:
            prof.v_inter_threshold = max(
                float(np.max(v_inters)) * void_margin, 0.02)
        if v_minors:
            prof.v_minority_threshold = max(
                float(np.max(v_minors)) * void_margin, 0.05)
        prof.finalize(margin)
        self.put(prof)
        return prof
