"""Fail-slow detection (macro metric ①) + root-cause attribution (§5.2.3).

Fail-slows are *sudden* throughput drops vs earlier steps of the SAME job.
Detection: robust rolling baseline (median + MAD) over a trailing window.
Attribution: per-rank FLOPS outliers => GPU underclocking (route the
machine); per-group bandwidth drops => network (jitter / congestion), with
a binary-search probe plan over the group's links.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.metrics import StepMetrics


@dataclass
class FailSlowFinding:
    step: int
    drop_frac: float
    cause: str               # "gpu_underclock" | "network" | "unknown"
    ranks: list = field(default_factory=list)
    probe_plan: list = field(default_factory=list)
    evidence: dict = field(default_factory=dict)


class ThroughputMonitor:
    def __init__(self, window: int = 8, drop_threshold: float = 0.12):
        self.window = window
        self.drop_threshold = drop_threshold
        self.history: list[float] = []

    def observe(self, throughput: float) -> Optional[float]:
        """Returns drop fraction if this step is a sudden slowdown."""
        out = None
        if len(self.history) >= max(self.window // 2, 3):
            base = float(np.median(self.history[-self.window:]))
            if base > 0 and throughput < base * (1 - self.drop_threshold):
                out = 1.0 - throughput / base
        if out is None:
            # only healthy-looking steps update the baseline
            self.history.append(throughput)
        return out


def attribute_failslow(m: StepMetrics, baseline: StepMetrics,
                       step: int, drop: float) -> FailSlowFinding:
    # ---- per-rank FLOPS outliers -> GPU underclocking ------------------ #
    slow_ranks: set[int] = set()
    for name, per_rank in m.flops.items():
        base = baseline.flops.get(name)
        if not base:
            continue
        base_med = float(np.median(list(base.values())))
        if base_med <= 0:
            continue
        for r, f in per_rank.items():
            if f < 0.75 * base_med:
                slow_ranks.add(r)
    if slow_ranks and len(slow_ranks) < max(m.num_ranks // 4, 1):
        return FailSlowFinding(
            step=step, drop_frac=drop, cause="gpu_underclock",
            ranks=sorted(slow_ranks),
            evidence={"flops_outlier_ranks": sorted(slow_ranks)})

    # ---- bandwidth drop -> network --------------------------------------#
    slow_groups = []
    for name, bw in m.bandwidth.items():
        base = baseline.bandwidth.get(name)
        if base and bw < 0.75 * base:
            slow_groups.append((name, bw / base))
    if slow_groups:
        plan = binary_search_plan(m.num_ranks)
        return FailSlowFinding(
            step=step, drop_frac=drop, cause="network",
            probe_plan=plan,
            evidence={"slow_groups": slow_groups})
    return FailSlowFinding(step=step, drop_frac=drop, cause="unknown",
                           evidence={})


def binary_search_plan(num_ranks: int) -> list:
    """Bisection probe plan over the ring (paper: 'communication test using
    binary search to pinpoint machines')."""
    plan, lo, hi = [], 0, num_ranks
    while hi - lo > 2:
        mid = (lo + hi) // 2
        plan.append({"test_ranks": (lo, mid), "then": (mid, hi)})
        hi = mid
    plan.append({"test_ranks": (lo, hi), "then": None})
    return plan
