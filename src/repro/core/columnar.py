"""Columnar (structure-of-arrays) event store — the hot-path event currency.

``TraceEvent`` dataclasses are convenient at the edges (the per-process
daemon, hand-built tests, JSONL logs) but far too slow as the interchange
format between a thousand-plus-rank simulator and the diagnostic engine:
appending N Python objects per op and re-filtering every rank's list per
step is superlinear in steps and allocates millions of dicts.

``EventBatch`` holds the same information as ``list[TraceEvent]`` in numpy
columns:

    kind      uint8    code into ``KINDS`` (the EventKind declaration order)
    name_id   int32    index into the interned ``names`` table
    rank      int32
    issue_ts  float64  host-side dispatch timestamp
    start_ts  float64  device-side execution start
    end_ts    float64
    step      int32    (-1 = no step attribution)

The common numeric ``meta`` keys get dedicated sparse columns (``flops``
NaN-absent, ``bytes``/``tokens`` INT-sentinel-absent, interned ``group``),
so aggregation never touches a Python dict; every remaining meta key lives
in ``extra`` (row -> dict), which only the slow conversion paths read.
Conversion to/from ``list[TraceEvent]`` and the compact JSONL schema of
``events.py`` is lossless, so the daemon, the hang path, and previously
recorded logs keep working unchanged.

A step index (stable argsort over the step column) is built once per batch
and cached; ``metrics.aggregate_all`` and the engine consume row slices
from it instead of rescanning event lists.

On-disk persistence lives in ``repro.store`` (JSONL + the binary FCS
segment format behind one codec API); the ``from_jsonl``/``write_jsonl``
methods here are thin deprecated shims kept for old call sites.
"""
from __future__ import annotations

import json
from typing import Iterable, Iterator, Optional, Sequence

import numpy as np

from repro.core.events import EventKind, TraceEvent, dump_jsonl

# stable kind <-> code mapping (declaration order of EventKind)
KINDS: tuple[EventKind, ...] = tuple(EventKind)
KIND_TO_CODE: dict[EventKind, int] = {k: i for i, k in enumerate(KINDS)}
_VALUE_TO_CODE: dict[str, int] = {k.value: i for i, k in enumerate(KINDS)}

# sentinel for "meta key absent" in the integer columns
NO_INT = np.iinfo(np.int64).min
_INT_MAX = 2 ** 62


def _split_meta(meta: dict):
    """Split a TraceEvent meta dict into column values + leftover dict.

    Columns only take values whose round-trip is exact (ints for bytes and
    tokens, truthy numbers for flops, str for group); everything else goes
    to the leftover dict so conversion stays lossless.
    """
    flops, nbytes, tokens, group, rest = np.nan, NO_INT, NO_INT, None, None
    for k, v in meta.items():
        if k == "flops" and isinstance(v, (int, float)) \
                and not isinstance(v, bool) and v:
            flops = float(v)
        elif k == "bytes" and isinstance(v, int) and not isinstance(v, bool) \
                and -_INT_MAX < v < _INT_MAX:
            nbytes = v
        elif k == "tokens" and isinstance(v, int) \
                and not isinstance(v, bool) and -_INT_MAX < v < _INT_MAX:
            tokens = v
        elif k == "group" and isinstance(v, str):
            group = v
        else:
            if rest is None:
                rest = {}
            rest[k] = v
    return flops, nbytes, tokens, group, rest


class EventBatch:
    """Immutable structure-of-arrays event store (build via the builder or
    the ``from_*`` constructors; never mutate columns in place)."""

    __slots__ = ("kind", "name_id", "rank", "issue_ts", "start_ts", "end_ts",
                 "step", "flops", "nbytes", "tokens", "group_id",
                 "names", "groups", "extra", "_step_index", "_ranks")

    def __init__(self, kind, name_id, rank, issue_ts, start_ts, end_ts, step,
                 flops, nbytes, tokens, group_id, names, groups, extra):
        self.kind = kind
        self.name_id = name_id
        self.rank = rank
        self.issue_ts = issue_ts
        self.start_ts = start_ts
        self.end_ts = end_ts
        self.step = step
        self.flops = flops
        self.nbytes = nbytes
        self.tokens = tokens
        self.group_id = group_id
        self.names: list[str] = names
        self.groups: list[str] = groups
        self.extra: dict[int, dict] = extra
        self._step_index = None
        self._ranks = None

    def __len__(self) -> int:
        return self.kind.size

    @classmethod
    def empty(cls) -> "EventBatch":
        return cls(np.empty(0, np.uint8), np.empty(0, np.int32),
                   np.empty(0, np.int32), np.empty(0, np.float64),
                   np.empty(0, np.float64), np.empty(0, np.float64),
                   np.empty(0, np.int32), np.empty(0, np.float64),
                   np.empty(0, np.int64), np.empty(0, np.int64),
                   np.empty(0, np.int16), [], [], {})

    # ------------------------------------------------------------------ #
    # indices
    # ------------------------------------------------------------------ #
    def step_index(self):
        """(order, steps, bounds): ``order`` is a stable permutation
        grouping rows by step; rows of step ``steps[i]`` are
        ``order[bounds[i]:bounds[i + 1]]`` in original insertion order."""
        if self._step_index is None:
            order = np.argsort(self.step, kind="stable")
            steps_sorted = self.step[order]
            uniq, starts = np.unique(steps_sorted, return_index=True)
            bounds = np.append(starts, order.size)
            self._step_index = (order, uniq, bounds)
        return self._step_index

    def steps(self) -> list[int]:
        _, uniq, _ = self.step_index()
        return [int(s) for s in uniq.tolist() if s >= 0]

    def ranks(self) -> np.ndarray:
        if self._ranks is None:
            self._ranks = np.unique(self.rank)
        return self._ranks

    def num_distinct_ranks(self) -> int:
        return int(self.ranks().size)

    def take(self, rows: np.ndarray) -> "EventBatch":
        """Row subset (copied columns) that SHARES the interning tables:
        ``names``/``groups`` are the same list objects, so ids stay
        comparable across slices.  The fleet store splits each arriving
        chunk into per-step slices this way and re-merges them through the
        shared-interning fast path of ``concat`` without any re-interning.
        ``rows`` must be unique row indices (e.g. a ``step_index`` slice).
        """
        rows = np.asarray(rows, np.int64)
        extra: dict[int, dict] = {}
        if self.extra and rows.size:
            if np.all(np.diff(rows) >= 0):
                er = np.fromiter(self.extra, np.int64, len(self.extra))
                pos = np.searchsorted(rows, er)
                pos_c = np.minimum(pos, rows.size - 1)
                ok = rows[pos_c] == er
                for r0, p0 in zip(er[ok].tolist(), pos_c[ok].tolist()):
                    extra[p0] = self.extra[r0]
            else:
                inv = {int(r): i for i, r in enumerate(rows.tolist())}
                for r0, d in self.extra.items():
                    i = inv.get(r0)
                    if i is not None:
                        extra[i] = d
        return EventBatch(
            self.kind[rows], self.name_id[rows], self.rank[rows],
            self.issue_ts[rows], self.start_ts[rows], self.end_ts[rows],
            self.step[rows], self.flops[rows], self.nbytes[rows],
            self.tokens[rows], self.group_id[rows],
            self.names, self.groups, extra)

    def slice_rows(self, lo: int, hi: int) -> "EventBatch":
        """Contiguous row range ``[lo, hi)`` as ZERO-COPY column views
        (numpy basic slicing) sharing the interning tables.  This is the
        replay fast path for step-sorted batches — an FCS segment decodes
        to memmap-backed columns, and its per-step slices reach the
        engine as views of the map instead of per-step ``take`` copies.
        Views keep the parent's buffers (and any backing memmap) alive.
        """
        extra: dict[int, dict] = {}
        if self.extra:
            for r, d in self.extra.items():
                if lo <= r < hi:
                    extra[r - lo] = d
        return EventBatch(
            self.kind[lo:hi], self.name_id[lo:hi], self.rank[lo:hi],
            self.issue_ts[lo:hi], self.start_ts[lo:hi], self.end_ts[lo:hi],
            self.step[lo:hi], self.flops[lo:hi], self.nbytes[lo:hi],
            self.tokens[lo:hi], self.group_id[lo:hi],
            self.names, self.groups, extra)

    def is_step_sorted(self) -> bool:
        """True if the step column is non-decreasing — then ``step_index``
        bounds are direct row offsets and per-step slices are contiguous
        (``slice_rows``), no permutation needed."""
        return len(self) < 2 or bool(np.all(self.step[:-1] <= self.step[1:]))

    # ------------------------------------------------------------------ #
    # conversion: TraceEvent lists
    # ------------------------------------------------------------------ #
    @classmethod
    def from_events(cls, events: Iterable[TraceEvent]) -> "EventBatch":
        b = EventBatchBuilder()
        for ev in events:
            b.append_event(ev)
        return b.build()

    @classmethod
    def from_events_by_rank(
            cls, events_by_rank: dict[int, list[TraceEvent]]) -> "EventBatch":
        """Rank-major flattening (matches the legacy dict iteration order)."""
        b = EventBatchBuilder()
        for r in sorted(events_by_rank):
            for ev in events_by_rank[r]:
                b.append_event(ev)
        return b.build()

    def _row_meta(self, row: int, *, fresh: bool = True) -> dict:
        m: dict = {}
        f = self.flops[row]
        if not np.isnan(f):
            m["flops"] = float(f)
        nb = self.nbytes[row]
        if nb != NO_INT:
            m["bytes"] = int(nb)
        g = self.group_id[row]
        if g >= 0:
            m["group"] = self.groups[g]
        tk = self.tokens[row]
        if tk != NO_INT:
            m["tokens"] = int(tk)
        if self.extra:
            rest = self.extra.get(row)
            if rest:
                m.update(rest)
        return m

    def to_events(self) -> list[TraceEvent]:
        kinds = [KINDS[c] for c in self.kind.tolist()]
        names = self.names
        nid = self.name_id.tolist()
        rk = self.rank.tolist()
        iss = self.issue_ts.tolist()
        st = self.start_ts.tolist()
        en = self.end_ts.tolist()
        sp = self.step.tolist()
        return [TraceEvent(kinds[i], names[nid[i]], rk[i], iss[i], st[i],
                           en[i], step=sp[i], meta=self._row_meta(i))
                for i in range(len(self))]

    def to_events_by_rank(self) -> dict[int, list[TraceEvent]]:
        out: dict[int, list[TraceEvent]] = {int(r): [] for r in self.ranks()}
        for ev in self.to_events():
            out[ev.rank].append(ev)
        return out

    # ------------------------------------------------------------------ #
    # conversion: JSONL (same compact schema as TraceEvent.to_json)
    # ------------------------------------------------------------------ #
    def to_jsonl_lines(self) -> Iterator[str]:
        names = self.names
        nid = self.name_id.tolist()
        kind_vals = [KINDS[c].value for c in self.kind.tolist()]
        rk = self.rank.tolist()
        iss = self.issue_ts.tolist()
        st = self.start_ts.tolist()
        en = self.end_ts.tolist()
        sp = self.step.tolist()
        dumps = json.dumps
        for i in range(len(self)):
            d = {"k": kind_vals[i], "n": names[nid[i]], "r": rk[i],
                 "i": round(iss[i], 6), "s": round(st[i], 6),
                 "e": round(en[i], 6), "t": sp[i]}
            m = self._row_meta(i)
            if m:
                d["m"] = {k: v for k, v in m.items() if k != "stack"}
                if "stack" in m:
                    d["m"]["stack"] = list(m["stack"])[-4:]
            yield dumps(d, separators=(",", ":"))

    def write_jsonl(self, path: str) -> int:
        """DEPRECATED shim — use ``repro.store.write_trace``.  Appends to
        ``path``; returns bytes written (Fig 9 accounting)."""
        return dump_jsonl(self, path)

    @classmethod
    def from_jsonl(cls, path: str, *, with_skip_count: bool = False):
        """DEPRECATED shim — use ``repro.store.read_jsonl`` (tolerant
        line-by-line decode; corrupt lines skipped with a counted
        warning)."""
        from repro.store.jsonl import read_jsonl
        return read_jsonl(path, with_skip_count=with_skip_count)

    @classmethod
    def from_jsonl_chunked(cls, path: str, *, chunk_bytes: int = 8 << 20,
                           max_workers: Optional[int] = None,
                           executor: str = "thread",
                           with_skip_count: bool = False):
        """DEPRECATED shim — use ``repro.store.read_jsonl_chunked`` (the
        chunked/parallel replay fast path; identical result to
        ``from_jsonl``)."""
        from repro.store.jsonl import read_jsonl_chunked
        return read_jsonl_chunked(path, chunk_bytes=chunk_bytes,
                                  max_workers=max_workers,
                                  executor=executor,
                                  with_skip_count=with_skip_count)

    # ------------------------------------------------------------------ #
    @classmethod
    def concat(cls, batches: Sequence["EventBatch"]) -> "EventBatch":
        batches = [b for b in batches if len(b)]
        if not batches:
            return cls.empty()
        if len(batches) == 1:
            return batches[0]
        first = batches[0]
        if all(b.names is first.names and b.groups is first.groups
               for b in batches[1:]):
            # shared interning (fleet store slices): ids are already
            # consistent — concatenate columns as-is, no LUT remap.
            extra: dict[int, dict] = {}
            off = 0
            for b in batches:
                for row, d in b.extra.items():
                    extra[off + row] = d
                off += len(b)
            return cls(
                np.concatenate([b.kind for b in batches]),
                np.concatenate([b.name_id for b in batches]),
                np.concatenate([b.rank for b in batches]),
                np.concatenate([b.issue_ts for b in batches]),
                np.concatenate([b.start_ts for b in batches]),
                np.concatenate([b.end_ts for b in batches]),
                np.concatenate([b.step for b in batches]),
                np.concatenate([b.flops for b in batches]),
                np.concatenate([b.nbytes for b in batches]),
                np.concatenate([b.tokens for b in batches]),
                np.concatenate([b.group_id for b in batches]),
                first.names, first.groups, extra)
        names: list[str] = []
        name_map: dict[str, int] = {}
        groups: list[str] = []
        group_map: dict[str, int] = {}
        nid_parts, gid_parts = [], []
        extra: dict[int, dict] = {}
        off = 0
        for b in batches:
            if b.names:
                lut = np.empty(len(b.names), np.int32)
                for i, nm in enumerate(b.names):
                    j = name_map.get(nm)
                    if j is None:
                        j = name_map[nm] = len(names)
                        names.append(nm)
                    lut[i] = j
                nid_parts.append(lut[b.name_id])
            else:
                nid_parts.append(b.name_id)
            if b.groups:
                glut = np.empty(len(b.groups) + 1, np.int16)
                glut[-1] = -1          # group_id -1 stays -1
                for i, gm in enumerate(b.groups):
                    j = group_map.get(gm)
                    if j is None:
                        j = group_map[gm] = len(groups)
                        groups.append(gm)
                    glut[i] = j
                gid_parts.append(glut[b.group_id])
            else:
                gid_parts.append(b.group_id)
            for row, d in b.extra.items():
                extra[off + row] = d
            off += len(b)
        return cls(
            np.concatenate([b.kind for b in batches]),
            np.concatenate(nid_parts).astype(np.int32),
            np.concatenate([b.rank for b in batches]),
            np.concatenate([b.issue_ts for b in batches]),
            np.concatenate([b.start_ts for b in batches]),
            np.concatenate([b.end_ts for b in batches]),
            np.concatenate([b.step for b in batches]),
            np.concatenate([b.flops for b in batches]),
            np.concatenate([b.nbytes for b in batches]),
            np.concatenate([b.tokens for b in batches]),
            np.concatenate(gid_parts).astype(np.int16),
            names, groups, extra)


# ----------------------------------------------------------------------- #
# builder
# ----------------------------------------------------------------------- #
class EventBatchBuilder:
    """Accumulates whole rank-vectors per op (the simulator hot path) or
    scalar rows (conversion paths) and concatenates once at ``build``."""

    def __init__(self):
        self._names: list[str] = []
        self._name_ids: dict[str, int] = {}
        self._groups: list[str] = []
        self._group_ids: dict[str, int] = {}
        self._kind: list[np.ndarray] = []
        self._nid: list[np.ndarray] = []
        self._rank: list[np.ndarray] = []
        self._issue: list[np.ndarray] = []
        self._start: list[np.ndarray] = []
        self._end: list[np.ndarray] = []
        self._step: list[np.ndarray] = []
        self._flops: list[np.ndarray] = []
        self._nbytes: list[np.ndarray] = []
        self._tokens: list[np.ndarray] = []
        self._gid: list[np.ndarray] = []
        self._extra: dict[int, dict] = {}
        self._count = 0
        # scalar-row staging (append_event / append_scalar)
        self._s_kind: list[int] = []
        self._s_nid: list[int] = []
        self._s_rank: list[int] = []
        self._s_issue: list[float] = []
        self._s_start: list[float] = []
        self._s_end: list[float] = []
        self._s_step: list[int] = []
        self._s_flops: list[float] = []
        self._s_nbytes: list[int] = []
        self._s_tokens: list[int] = []
        self._s_gid: list[int] = []

    def __len__(self) -> int:
        return self._count + len(self._s_kind)

    def _intern_name(self, name: str) -> int:
        i = self._name_ids.get(name)
        if i is None:
            i = self._name_ids[name] = len(self._names)
            self._names.append(name)
        return i

    def _intern_group(self, group: Optional[str]) -> int:
        if group is None:
            return -1
        i = self._group_ids.get(group)
        if i is None:
            i = self._group_ids[group] = len(self._groups)
            self._groups.append(group)
        return i

    def _drain_scalars(self):
        if not self._s_kind:
            return
        self._kind.append(np.asarray(self._s_kind, np.uint8))
        self._nid.append(np.asarray(self._s_nid, np.int32))
        self._rank.append(np.asarray(self._s_rank, np.int32))
        self._issue.append(np.asarray(self._s_issue, np.float64))
        self._start.append(np.asarray(self._s_start, np.float64))
        self._end.append(np.asarray(self._s_end, np.float64))
        self._step.append(np.asarray(self._s_step, np.int32))
        self._flops.append(np.asarray(self._s_flops, np.float64))
        self._nbytes.append(np.asarray(self._s_nbytes, np.int64))
        self._tokens.append(np.asarray(self._s_tokens, np.int64))
        self._gid.append(np.asarray(self._s_gid, np.int16))
        self._count += len(self._s_kind)
        for lst in (self._s_kind, self._s_nid, self._s_rank, self._s_issue,
                    self._s_start, self._s_end, self._s_step, self._s_flops,
                    self._s_nbytes, self._s_tokens, self._s_gid):
            lst.clear()

    # ------------------------------------------------------------------ #
    def append_block(self, kind: EventKind, name: str, rank: np.ndarray,
                     issue_ts, start_ts, end_ts, step: int, *,
                     flops: Optional[float] = None,
                     nbytes: Optional[int] = None,
                     tokens: Optional[int] = None,
                     group: Optional[str] = None,
                     extra=None):
        """Append one event per entry of ``rank`` (whole rank-vector).

        ``issue_ts``/``start_ts``/``end_ts`` may be scalars or arrays of
        the same length; values are copied, so callers may keep mutating
        their state vectors.  ``extra`` is either one dict shared by every
        row or a sequence of per-row dicts.
        """
        rank = np.asarray(rank, np.int32)
        m = rank.size
        if m == 0:
            return
        self._drain_scalars()
        self._kind.append(np.full(m, KIND_TO_CODE[kind], np.uint8))
        self._nid.append(np.full(m, self._intern_name(name), np.int32))
        self._rank.append(rank.copy())
        for dst, src in ((self._issue, issue_ts), (self._start, start_ts),
                         (self._end, end_ts)):
            a = np.asarray(src, np.float64)
            dst.append(np.full(m, float(a), np.float64) if a.ndim == 0
                       else a.astype(np.float64, copy=True))
        self._step.append(np.full(m, step, np.int32))
        self._flops.append(np.full(
            m, np.nan if flops is None or not flops else float(flops),
            np.float64))
        self._nbytes.append(np.full(
            m, NO_INT if nbytes is None else int(nbytes), np.int64))
        self._tokens.append(np.full(
            m, NO_INT if tokens is None else int(tokens), np.int64))
        self._gid.append(np.full(m, self._intern_group(group), np.int16))
        if extra is not None:
            base = self._count
            if isinstance(extra, dict):
                if extra:
                    for i in range(m):
                        self._extra[base + i] = extra
            else:
                for i, d in enumerate(extra):
                    if d:
                        self._extra[base + i] = d
        self._count += m

    def append_event(self, ev: TraceEvent):
        flops, nbytes, tokens, group, rest = _split_meta(ev.meta) \
            if ev.meta else (np.nan, NO_INT, NO_INT, None, None)
        self.append_scalar(KIND_TO_CODE[ev.kind], ev.name, ev.rank,
                           ev.issue_ts, ev.start_ts, ev.end_ts, ev.step,
                           None, _split=(flops, nbytes, tokens, group, rest))

    def append_scalar(self, kind_code: int, name: str, rank: int,
                      issue_ts: float, start_ts: float, end_ts: float,
                      step: int, meta: Optional[dict], _split=None):
        if _split is None:
            flops, nbytes, tokens, group, rest = _split_meta(meta or {})
        else:
            flops, nbytes, tokens, group, rest = _split
        self._s_kind.append(kind_code)
        self._s_nid.append(self._intern_name(name))
        self._s_rank.append(rank)
        self._s_issue.append(issue_ts)
        self._s_start.append(start_ts)
        self._s_end.append(end_ts)
        self._s_step.append(step)
        self._s_flops.append(flops)
        self._s_nbytes.append(nbytes)
        self._s_tokens.append(tokens)
        self._s_gid.append(self._intern_group(group))
        if rest:
            self._extra[self._count + len(self._s_kind) - 1] = rest

    # ------------------------------------------------------------------ #
    def build(self) -> EventBatch:
        self._drain_scalars()
        if not self._count:
            return EventBatch.empty()

        def cat(parts, dtype):
            return parts[0] if len(parts) == 1 \
                else np.concatenate(parts).astype(dtype, copy=False)

        return EventBatch(
            cat(self._kind, np.uint8), cat(self._nid, np.int32),
            cat(self._rank, np.int32), cat(self._issue, np.float64),
            cat(self._start, np.float64), cat(self._end, np.float64),
            cat(self._step, np.int32), cat(self._flops, np.float64),
            cat(self._nbytes, np.int64), cat(self._tokens, np.int64),
            cat(self._gid, np.int16), list(self._names), list(self._groups),
            dict(self._extra))


# ----------------------------------------------------------------------- #
# chunked JSONL decoding — moved to repro.store.jsonl
# ----------------------------------------------------------------------- #
def iter_jsonl_chunks(path: str, *, chunk_bytes: int = 8 << 20,
                      max_workers: Optional[int] = None,
                      executor: str = "thread",
                      ) -> Iterator[tuple[EventBatch, int]]:
    """DEPRECATED shim — use ``repro.store.iter_jsonl_chunks``."""
    from repro.store.jsonl import iter_jsonl_chunks as _impl
    return _impl(path, chunk_bytes=chunk_bytes, max_workers=max_workers,
                 executor=executor)


# ----------------------------------------------------------------------- #
# segmented query helpers (exact, fully vectorized)
# ----------------------------------------------------------------------- #
def prev_le(val_t: np.ndarray, val_seg: np.ndarray,
            q_t: np.ndarray, q_seg: np.ndarray) -> np.ndarray:
    """Per query, index of the value with the LARGEST t such that
    ``t <= q_t`` within the same segment; -1 if none.

    Works by merging values and queries into one (segment, t) order and
    running an integer prefix-max whose payload encodes (segment, sorted
    position) — segment boundaries reset for free because the segment term
    dominates the position term.
    """
    nv, nq = val_t.size, q_t.size
    if nq == 0:
        return np.empty(0, np.int64)
    if nv == 0:
        return np.full(nq, -1, np.int64)
    t = np.concatenate([val_t, q_t])
    seg = np.concatenate([val_seg, q_seg]).astype(np.int64)
    is_q = np.concatenate([np.zeros(nv, np.int8), np.ones(nq, np.int8)])
    # segment-major, time-minor; values sort before queries at equal t so
    # an exactly-equal value still qualifies (<= is inclusive)
    order = np.lexsort((is_q, t, seg))
    m = t.size
    seg_s = seg[order]
    isq_s = is_q[order]
    pos = np.where(isq_s == 0, np.arange(m, dtype=np.int64), -1)
    acc = np.maximum.accumulate(pos + seg_s * (m + 1))
    q_pos = np.nonzero(isq_s)[0]
    a = acc[q_pos]
    has = (a // (m + 1)) == seg_s[q_pos]
    val_sorted_pos = np.where(has, a % (m + 1), 0)
    res = np.where(has, order[val_sorted_pos], -1)
    out = np.empty(nq, np.int64)
    out[order[q_pos] - nv] = res
    return out


def next_ge(val_t: np.ndarray, val_seg: np.ndarray,
            q_t: np.ndarray, q_seg: np.ndarray) -> np.ndarray:
    """Per query, index of the value with the SMALLEST t such that
    ``t >= q_t`` within the same segment; -1 if none."""
    return prev_le(-np.asarray(val_t), val_seg, -np.asarray(q_t), q_seg)
