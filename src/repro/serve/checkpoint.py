"""Crash-safe checkpoint container for the resident fleet service.

``FleetService`` keeps months of diagnosis state purely in memory —
per-job frontier progress and watermarks, shared intern tables,
stateful detector instances, pending step buffers, tail offsets, the
departed-job set, telemetry counters.  This module is the durability
layer under it: a versioned, CRC-protected, atomically-written snapshot
file, plus a generation-numbered store that always restores the newest
snapshot that is actually *valid*.

File layout (``ckpt-NNNNNNNN.flc``)::

    magic   4s   b"FLC1"
    version u16  FORMAT_VERSION (little-endian, like the FLW wire header)
    flags   u16  reserved (0)
    length  u64  payload byte count
    crc     u32  crc32(payload)
    payload      one pickle of the whole state dict

The payload is deliberately ONE ``pickle.dumps`` call: the resident
state is a web of shared references (every pending ``EventBatch`` slice
points at the interner's live ``names``/``groups`` list objects), and
pickling it as a single object preserves that identity through the
memo — after restore, ``batch.names is interner.names`` still holds,
so the adopt fast path keeps working on the restored pipelines.

Write protocol (power-loss-safe): payload to ``<path>.tmp``, ``flush``
+ ``fsync``, ``os.replace`` onto the final name, then a best-effort
fsync of the directory so the rename itself is durable.  A torn write
can therefore only ever produce a torn ``.tmp`` (ignored) or a torn
final file — which the header length + CRC detect on read, and which
:meth:`CheckpointStore.load_latest` skips back past to the previous
generation.  A checkpoint written by a NEWER format version is refused
with :class:`CheckpointVersionError` (never skipped, never misparsed):
silently restoring a downgraded daemon from state it half-understands
is worse than making the operator pick a matching build.
"""
from __future__ import annotations

import os
import pickle
import re
import struct
import zlib
from typing import Optional

MAGIC = b"FLC1"
FORMAT_VERSION = 1

# magic | version | flags | payload length | crc32(payload)
_HEADER = struct.Struct("<4sHHQI")

_CKPT_RE = re.compile(r"^ckpt-(\d{8})\.flc$")

# Guard against absurd parses from corrupt headers: no service snapshot
# legitimately exceeds this (the resident state is bounded by watermark
# windows and ring sizes, not by stream length).
MAX_PAYLOAD = 1 << 32


class CheckpointError(Exception):
    """Torn, truncated or corrupt checkpoint file."""


class CheckpointVersionError(CheckpointError):
    """Checkpoint written by a NEWER format version — refuse loudly."""


def write_checkpoint(path: str, state: dict) -> int:
    """Atomically write ``state`` to ``path``; returns bytes written.
    Crash-safe: a reader either sees the previous file or the complete
    new one, never a torn intermediate under the final name."""
    payload = pickle.dumps(state, protocol=pickle.HIGHEST_PROTOCOL)
    header = _HEADER.pack(MAGIC, FORMAT_VERSION, 0, len(payload),
                          zlib.crc32(payload) & 0xFFFFFFFF)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(header)
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    _fsync_dir(os.path.dirname(path) or ".")
    return len(header) + len(payload)


def read_checkpoint(path: str) -> dict:
    """Read + verify one checkpoint file.  Raises
    :class:`CheckpointError` on any torn/corrupt shape and
    :class:`CheckpointVersionError` on a newer format version."""
    try:
        with open(path, "rb") as f:
            head = f.read(_HEADER.size)
            if len(head) < _HEADER.size:
                raise CheckpointError(f"{path}: truncated header "
                                      f"({len(head)} bytes)")
            magic, version, _flags, length, crc = _HEADER.unpack(head)
            if magic != MAGIC:
                raise CheckpointError(f"{path}: bad magic {magic!r}")
            if version > FORMAT_VERSION:
                raise CheckpointVersionError(
                    f"{path}: format version {version} is newer than this "
                    f"build understands (max {FORMAT_VERSION}); refusing "
                    "to guess — restore with a matching or newer build")
            if length > MAX_PAYLOAD:
                raise CheckpointError(f"{path}: implausible payload length "
                                      f"{length}")
            payload = f.read(length)
    except OSError as e:
        raise CheckpointError(f"{path}: unreadable ({e})") from e
    if len(payload) < length:
        raise CheckpointError(f"{path}: truncated payload "
                              f"({len(payload)}/{length} bytes)")
    if zlib.crc32(payload) & 0xFFFFFFFF != crc:
        raise CheckpointError(f"{path}: CRC mismatch")
    try:
        state = pickle.loads(payload)
    except Exception as e:
        raise CheckpointError(f"{path}: undecodable payload ({e})") from e
    if not isinstance(state, dict):
        raise CheckpointError(f"{path}: payload is not a state dict")
    return state


def _fsync_dir(directory: str) -> None:
    """Durable rename: fsync the directory entry (best effort — not
    every filesystem allows opening a directory for fsync)."""
    try:
        fd = os.open(directory, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


class CheckpointStore:
    """Generation-numbered checkpoint directory.

    ``save`` writes the next generation atomically and prunes old ones
    down to ``keep``; ``load_latest`` walks generations newest-first,
    skipping (and counting) torn/corrupt files until a valid one loads.
    A newer-format file still refuses — skipping past state a newer
    build wrote would silently restore an older view of the world."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = max(int(keep), 1)
        os.makedirs(directory, exist_ok=True)

    def _path(self, generation: int) -> str:
        return os.path.join(self.directory, f"ckpt-{generation:08d}.flc")

    def generations(self) -> list[int]:
        out = []
        try:
            names = os.listdir(self.directory)
        except OSError:
            return out
        for name in names:
            m = _CKPT_RE.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def save(self, state: dict) -> tuple[str, int, int]:
        """Write the next generation; returns ``(path, generation,
        bytes_written)``.  Prunes generations beyond ``keep`` (best
        effort; a failed unlink never fails the checkpoint)."""
        gens = self.generations()
        gen = (gens[-1] + 1) if gens else 1
        path = self._path(gen)
        nbytes = write_checkpoint(path, state)
        for old in gens[:max(len(gens) + 1 - self.keep, 0)]:
            try:
                os.unlink(self._path(old))
            except OSError:
                pass
        return path, gen, nbytes

    def load_latest(self) -> Optional[tuple[dict, str, int, list[str]]]:
        """Newest VALID checkpoint: ``(state, path, generation,
        skipped)`` where ``skipped`` lists the torn/corrupt files passed
        over on the way down, or ``None`` when no valid checkpoint
        exists (the caller falls back to a full replay).  Raises
        :class:`CheckpointVersionError` for newer-format files."""
        skipped: list[str] = []
        for gen in reversed(self.generations()):
            path = self._path(gen)
            try:
                state = read_checkpoint(path)
            except CheckpointVersionError:
                raise
            except CheckpointError as e:
                skipped.append(f"{os.path.basename(path)}: {e}")
                continue
            return state, path, gen, skipped
        return None
