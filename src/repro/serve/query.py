"""HTTP query plane for :class:`~repro.serve.service.FleetService`.

A minimal stdlib ``ThreadingHTTPServer`` over the service's live state —
the interactive surface an always-on diagnosis deployment needs next to
its ingest planes (ARGUS-style), with no dependency beyond the standard
library:

  ``GET /jobs``                  per-job engine stats + open/departed/queued
  ``GET /anomalies?n=100``       recent diagnosed anomalies (bounded ring)
  ``GET /weather``               cluster-weather rollup of the recent window
  ``GET /telemetry``             full pipeline self-telemetry snapshot
                                 (serve.* counters, per-job gauges, queue
                                 depths)
  ``GET /archive/events?job=...[&step_lo=&step_hi=&t_lo=&t_hi=&kind=
        &severity=&limit=&max_bytes=]``
  ``GET /archive/metrics?job=...[&metric=&step_lo=&step_hi=&bucket=
        &max_bytes=]``

Archive endpoints exist when the service was configured with
``archive_dir``; every archive query runs under a BYTE BUDGET
(``max_bytes`` query param, capped by ``ServiceConfig.archive_max_bytes``)
— a months-long archive answers from the prefix the budget affords and
says so (``"truncated": true``), instead of letting one dashboard query
decode the world.

All responses are JSON; numpy scalars/arrays in anomaly evidence coerce
through the same fallback the report module uses.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional
from urllib.parse import parse_qs, urlparse

from repro.core.report import _json_coerce


def fleet_anomaly_dict(fa) -> dict:
    """One JSON-ready record per diagnosed fleet anomaly."""
    a = fa.anomaly
    return {
        "job": fa.job_id, "ts": float(fa.ts), "origin": fa.origin,
        "route": fa.route, "kind": a.kind, "metric": a.metric,
        "team": a.team.value, "root_cause": a.root_cause,
        "step": int(a.step), "severity": float(a.severity),
        "ranks": list(a.ranks), "evidence": a.evidence,
    }


def _batch_rows(batch, limit: int) -> list[dict]:
    """First ``limit`` rows of an ``EventBatch`` as JSON-ready dicts."""
    n = min(len(batch), limit)
    names = batch.names
    out = []
    for i in range(n):
        out.append({
            "kind": int(batch.kind[i]),
            "name": names[int(batch.name_id[i])],
            "rank": int(batch.rank[i]),
            "step": int(batch.step[i]),
            "start_ts": float(batch.start_ts[i]),
            "end_ts": float(batch.end_ts[i]),
        })
    return out


class QueryServer:
    """Serves the endpoints above from daemon threads; ``close()`` stops
    accepting and joins.  Construction binds the port (readable at
    ``.port`` when configured as 0/ephemeral)."""

    def __init__(self, service, host: str = "127.0.0.1", port: int = 0):
        self.service = service
        self._archive = None
        self._archive_lock = threading.Lock()
        handler = self._make_handler()
        self._httpd = ThreadingHTTPServer((host, port), handler)
        self._httpd.daemon_threads = True
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, kwargs={"poll_interval": 0.2},
            daemon=True, name="flare-serve-query")
        self._thread.start()

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=10.0)

    # ------------------------------------------------------------------ #
    def archive(self):
        """Lazily opened :class:`~repro.archive.TraceArchive` over
        ``ServiceConfig.archive_dir`` (None when not configured)."""
        d = self.service.cfg.archive_dir
        if d is None:
            return None
        with self._archive_lock:
            if self._archive is None:
                from repro.archive import TraceArchive
                self._archive = TraceArchive(
                    d, telemetry=self.service.telemetry)
            return self._archive

    def _budget(self, q: dict) -> Optional[int]:
        cap = self.service.cfg.archive_max_bytes
        asked = q.get("max_bytes")
        if asked is None:
            return cap
        asked = int(asked[0])
        return min(asked, cap) if cap is not None else asked

    # ------------------------------------------------------------------ #
    def _route(self, path: str, q: dict):
        svc = self.service
        if path == "/jobs":
            return {"jobs": svc.job_stats()}
        if path == "/anomalies":
            n = int(q["n"][0]) if "n" in q else None
            return {"anomalies": [fleet_anomaly_dict(fa)
                                  for fa in svc.snapshot_recent(n)]}
        if path == "/weather":
            return svc.weather()
        if path == "/telemetry":
            return {"telemetry": svc.mux.telemetry_snapshot(),
                    "queues": svc.queue_depths()}
        if path == "/archive/events":
            arch = self.archive()
            if arch is None:
                return None
            job = q["job"][0]
            kw: dict = {}
            if "step_lo" in q or "step_hi" in q:
                kw["step_range"] = (int(q.get("step_lo", [0])[0]),
                                    int(q.get("step_hi", [1 << 60])[0]))
            if "t_lo" in q or "t_hi" in q:
                kw["time_range"] = (float(q.get("t_lo", [0])[0]),
                                    float(q.get("t_hi", [1e30])[0]))
            if "kind" in q:
                kw["kinds"] = [int(k) for k in q["kind"]]
            if "severity" in q:
                kw["severity"] = q["severity"][0]
            batch, scan = arch.query_events(
                job, with_scan=True, max_bytes=self._budget(q), **kw)
            limit = int(q.get("limit", [1000])[0])
            return {
                "job": job, "rows": len(batch),
                "truncated": scan.truncated,
                "scan": {"segments": scan.segments,
                         "segments_skipped": scan.segments_skipped,
                         "bytes_decoded": scan.bytes_decoded,
                         "bytes_skipped": scan.bytes_skipped},
                "events": _batch_rows(batch, limit),
            }
        if path == "/archive/metrics":
            arch = self.archive()
            if arch is None:
                return None
            job = q["job"][0]
            step_range = None
            if "step_lo" in q or "step_hi" in q:
                step_range = (int(q.get("step_lo", [0])[0]),
                              int(q.get("step_hi", [1 << 60])[0]))
            series, truncated = arch.query_metrics(
                job, step_range, q.get("metric", ["throughput"])[0],
                bucket=int(q.get("bucket", [1])[0]),
                max_bytes=self._budget(q), with_truncation=True)
            return {"job": job, "series": series, "truncated": truncated}
        return None

    def _make_handler(self):
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):               # noqa: N802 (stdlib API name)
                u = urlparse(self.path)
                try:
                    body = outer._route(u.path, parse_qs(u.query))
                except (KeyError, ValueError, IndexError) as e:
                    self._reply(400, {"error": str(e)})
                    return
                except Exception as e:      # noqa: BLE001 — a broken
                    # query must not take the query thread down
                    self._reply(500, {"error": f"{type(e).__name__}: {e}"})
                    return
                if body is None:
                    self._reply(404, {"error": f"unknown path {u.path}"})
                else:
                    self._reply(200, body)

            def _reply(self, code: int, body: dict) -> None:
                data = json.dumps(body, default=_json_coerce).encode()
                self.send_response(code)
                self.send_header("Content-Type", "application/json")
                self.send_header("Content-Length", str(len(data)))
                self.end_headers()
                self.wfile.write(data)

            def log_message(self, fmt, *args):   # quiet by default
                pass

        return Handler
