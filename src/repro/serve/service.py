"""FleetService — the always-on diagnosis daemon over the fleet engine.

``FleetReplayer.replay_dir`` is a one-shot drain over a finished
directory; this module keeps the same engine RESIDENT.  One service
instance owns a :class:`~repro.fleet.multiplexer.FleetMultiplexer` and
feeds it from two ingestion planes for as long as it lives:

  * **socket** — an FLW listener (``repro.serve.protocol``): training
    hosts ``HELLO`` a job (with topology for the fleet tier), stream
    ``BATCH`` frames (FCS-encoded ``EventBatch`` segments — the exact
    bytes the spill path writes), and ``BYE`` to leave gracefully;
  * **file tail** — a :class:`~repro.serve.tail.FileTailer` following
    the directory daemons spill into, feeding newly completed segments.

Both planes route into step-aligned ingest
(``FleetMultiplexer.ingest_step_aligned``) on one of two engines:

  * ``worker_kind="inline"`` — decode + diagnose on the service's own
    multiplexer (per-job locks already parallelize connection threads);
  * ``worker_kind="process"`` — frames cross *undecoded* into a
    resident :class:`~repro.fleet.ipc.ProcessWorkerPool` (each job
    pinned to a worker process holding its private engine), anomalies
    and keyed fleet-tier observations streaming back over bounded
    queues.  The parent buffers the observations and resolves its
    cross-job frontier incrementally (``resolve_fleet_ready``), so
    ``cross_job_failslow`` reclassifies LIVE in either mode.

Determinism contract (asserted in ``benchmarks/live.py`` and
``tests/test_serve.py``): streaming a recorded directory through either
plane, in either mode, then :meth:`finalize`, yields an anomaly
sequence byte-equivalent (after the stream's own ``(ts, job_id, seq)``
merge sort) to ``replay_dir`` + ``finalize`` on the same files — with
the documented caveats that the fleet frontier assumes the job set is
hello'd before its watermarks pass, and hang diagnosis (which fires on
flush granularity) needs hang-free scenarios for bit-exact gates.

A minimal HTTP query plane (``repro.serve.query``) serves
``/anomalies``, ``/weather``, ``/telemetry``, ``/jobs`` and byte-
budgeted archive queries over the same state.
"""
from __future__ import annotations

import dataclasses
import socket
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.engine import EngineConfig
from repro.fleet.multiplexer import FleetMultiplexer
from repro.fleet.replay import ReplayStats
from repro.fleet.stream import FleetAnomaly
from repro.serve.protocol import (FRAME_BATCH, FRAME_HELLO, ProtocolError,
                                  parse_hello, read_frame)
from repro.serve.tail import FileTailer
from repro.store import CodecError, decode_batch_bytes, encode_batch_bytes


@dataclass
class ServiceConfig:
    host: str = "127.0.0.1"
    # FLW ingest port: 0 = ephemeral (read it back from ``.port``),
    # None = no socket plane (tail-only service)
    port: Optional[int] = 0
    # HTTP query port: 0 = ephemeral, None = no query plane
    query_port: Optional[int] = None
    worker_kind: str = "inline"        # "inline" | "process"
    workers: Optional[int] = None      # process mode: None = cpu count
    tail_dir: Optional[str] = None     # follow this spill directory
    tail_poll_s: float = 0.05
    # socket recv timeout at frame boundaries — how often an idle
    # connection polls for service shutdown
    idle_poll_s: float = 0.2
    drain_interval_s: float = 0.05     # anomaly collector period
    max_recent_anomalies: int = 4096   # /anomalies ring size
    archive_dir: Optional[str] = None  # /archive/* query root
    archive_max_bytes: Optional[int] = 64 << 20   # per-query byte budget
    # engine template for jobs that HELLO without overrides
    default_engine: Optional[EngineConfig] = None


class FleetService:
    """Long-lived ingest + query service over one fleet multiplexer.

    ``on_anomaly(fa, arrival_monotonic)`` (optional) fires for every
    collected anomaly with its collection time — the hook the latency
    benchmark hangs off; the service itself keeps only a bounded ring
    (``recent_anomalies``), so memory stays flat over months."""

    def __init__(self, mux: Optional[FleetMultiplexer] = None,
                 config: Optional[ServiceConfig] = None,
                 *, on_anomaly: Optional[Callable] = None):
        self.cfg = config or ServiceConfig()
        if self.cfg.worker_kind not in ("inline", "process"):
            raise ValueError(f"worker_kind must be 'inline' or 'process', "
                             f"got {self.cfg.worker_kind!r}")
        self.mux = mux or FleetMultiplexer()
        self.telemetry = self.mux.telemetry
        self.on_anomaly = on_anomaly
        self.stats = ReplayStats(worker_kind=f"live-{self.cfg.worker_kind}")
        self.tailer: Optional[FileTailer] = None
        self._pool = None
        self._record_fleet = bool(self.mux.fleet_detectors)
        self._stop = threading.Event()
        self._started = False
        self._finalized = False
        self._reg_lock = threading.Lock()     # open-jobs registry
        self._merge_lock = threading.Lock()   # terminal-payload merges
        self._open: set[str] = set()
        self._departed: set[str] = set()
        self._job_cfg: dict[str, Optional[EngineConfig]] = {}
        self._errors: list[tuple[str, str]] = []
        self._rec_lock = threading.Lock()
        self.recent_anomalies: deque[FleetAnomaly] = deque(
            maxlen=self.cfg.max_recent_anomalies)
        self._inflight: dict[str, int] = {}   # process mode: frames queued
        self._lsock: Optional[socket.socket] = None
        self._threads: list[threading.Thread] = []
        self._conn_threads: list[threading.Thread] = []
        self._active_conns = 0
        self._query = None
        self.port: Optional[int] = None
        self.query_port: Optional[int] = None
        t = self.telemetry
        self._c_conns = t.counter("serve.connections")
        self._c_frames = t.counter("serve.frames")
        self._c_bytes = t.counter("serve.bytes_in")
        self._c_dropped = t.counter("serve.dropped_frames")
        self._g_active = t.gauge("serve.active_connections")
        self._g_jobs = t.gauge("serve.jobs")

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    def start(self) -> "FleetService":
        if self._started:
            return self
        self._started = True
        if self.cfg.worker_kind == "process":
            self._start_pool()
        if self.cfg.port is not None:
            self._lsock = socket.create_server(
                (self.cfg.host, self.cfg.port))
            self._lsock.settimeout(self.cfg.idle_poll_s)
            self.port = self._lsock.getsockname()[1]
            self._spawn(self._accept_loop, "flare-serve-accept")
        if self.cfg.tail_dir is not None:
            self.tailer = FileTailer(
                self.cfg.tail_dir, self._tail_sink,
                on_join=self.join_job, telemetry=self.telemetry)
            self._spawn(lambda: self.tailer.run(
                self._stop, self.cfg.tail_poll_s), "flare-serve-tail")
        self._spawn(self._collect_loop, "flare-serve-collect")
        if self.cfg.query_port is not None:
            from repro.serve.query import QueryServer
            self._query = QueryServer(self, self.cfg.host,
                                      self.cfg.query_port)
            self.query_port = self._query.port
        return self

    def _spawn(self, target, name: str) -> None:
        t = threading.Thread(target=target, daemon=True, name=name)
        t.start()
        self._threads.append(t)

    def _start_pool(self) -> None:
        import os

        from repro.fleet.ipc import ProcessWorkerPool
        mux = self.mux
        init = {
            "history": mux.history,
            "fleet": {"watermark_delay": mux.cfg.watermark_delay,
                      "backend": mux.cfg.backend,
                      "max_pending_rows": mux.cfg.max_pending_rows},
            "replay": {"chunk_bytes": 8 << 20, "max_workers": None,
                       "executor": "thread", "serial_below": None,
                       "prefetch": 2, "predicate": None},
        }
        workers = self.cfg.workers or os.cpu_count() or 1
        self._pool = ProcessWorkerPool(workers, init)
        self._pool.start(on_anomalies=self._on_worker_anomalies,
                         on_fleet=self._on_worker_fleet,
                         on_job=self._on_worker_job,
                         on_error=self._on_worker_error)

    def finalize(self, *, raise_errors: bool = True) -> list[FleetAnomaly]:
        """Graceful shutdown: stop accepting, drain the tail directory to
        its end (leftover partial tails become corruption counts), close
        every worker job, finalize the multiplexer.  Returns the final
        drain (everything not yet collected); the full stream was
        delivered incrementally via ``on_anomaly``/``recent_anomalies``.
        Idempotent."""
        if self._finalized:
            return []
        self._finalized = True
        self._stop.set()
        if self._lsock is not None:
            try:
                self._lsock.close()
            except OSError:
                pass
        for t in self._threads:
            t.join(timeout=30.0)
        for t in self._conn_threads:
            t.join(timeout=30.0)
        if self._pool is not None:
            # sentinel closes still-open jobs; terminal envelopes merge
            # through _on_worker_job before join returns
            self._pool.shutdown()
            self._pool.join(raise_errors=False)
            self._pool.close()
        if self.tailer is not None:
            self.tailer.finish()           # no-op if the run thread did
            with self._merge_lock:
                self.stats.merge(self.tailer.stats)
        final = self.mux.finalize()
        self._deliver(final)
        if self._query is not None:
            self._query.close()
        if raise_errors and self._errors:
            job_id, tb = self._errors[0]
            more = f" (+{len(self._errors) - 1} more)" \
                if len(self._errors) > 1 else ""
            raise RuntimeError(
                f"fleet service worker failed on job {job_id!r}{more}:\n{tb}")
        return final

    @property
    def errors(self) -> list[tuple[str, str]]:
        return list(self._errors)

    # ------------------------------------------------------------------ #
    # job lifecycle + ingest (both planes land here)
    # ------------------------------------------------------------------ #
    def _engine_cfg(self, overrides: Optional[dict]) -> Optional[EngineConfig]:
        if overrides:
            base = self.cfg.default_engine
            if base is not None:
                return dataclasses.replace(base, **overrides)
            return EngineConfig(**overrides)
        return self.cfg.default_engine

    def join_job(self, job_id: str, topology: Optional[dict] = None,
                 engine: Optional[dict] = None) -> None:
        """Register a job (idempotent; re-HELLO just merges topology).
        In process mode the job's resident pipeline opens eagerly, so
        its first frame pays no engine construction."""
        with self._reg_lock:
            if job_id in self._departed:
                return                 # departed jobs are never revived
            known = job_id in self._open
            if not known:
                self._open.add(job_id)
                self._job_cfg[job_id] = self._engine_cfg(engine)
            self._g_jobs.set(len(self._open))
        if topology:
            self.mux.set_topology(job_id, **topology)
        if known:
            return
        self.mux.add_job(job_id, self._job_cfg[job_id])
        if self._pool is not None:
            from repro.fleet.ipc import TASK_OPEN
            self._pool.submit((TASK_OPEN, job_id, None,
                               self._job_cfg[job_id], self._record_fleet))

    def leave_job(self, job_id: str) -> None:
        """Graceful leave (``BYE``): the job's pending steps close, its
        hang analysis and detector finalize run, its fleet-frontier
        contribution releases — other jobs' diagnosis is untouched."""
        with self._reg_lock:
            if job_id not in self._open:
                return
            self._open.discard(job_id)
            self._departed.add(job_id)
            self._g_jobs.set(len(self._open))
        if self._pool is not None:
            # the worker flushes + ships the terminal envelope; the
            # parent-side retire happens in _on_worker_job when it lands
            self._pool.close_job(job_id)
        else:
            self.mux.retire_job(job_id)

    def ingest_frame(self, job_id: str, payload: bytes) -> None:
        """One BATCH frame: an FCS-encoded ``EventBatch`` segment.
        Inline mode decodes here (a ``CodecError`` propagates — the
        connection handler counts it as a dropped frame); process mode
        forwards the bytes undecoded to the job's pinned worker."""
        with self._reg_lock:
            known = job_id in self._open
            departed = job_id in self._departed
        self._c_frames.inc()
        self._c_bytes.inc(len(payload))
        if departed:
            # graceful-leave contract: post-BYE stragglers are dropped
            # and counted, never revived — and never forwarded to a
            # worker, whose closed pipeline they would silently reopen
            # (in process mode the parent mux only marks the job
            # departed once the terminal envelope lands, so the mux
            # guard alone is racy; the service set is authoritative)
            n = len(decode_batch_bytes(bytes(payload)))
            self.telemetry.counter("fleet.departed_rows",
                                   job=job_id).inc(n)
            return
        if not known:
            self.join_job(job_id)
        if self._pool is not None:
            self._note_inflight(job_id, +1)
            self._pool.submit(("batches", job_id, [bytes(payload)],
                               self._job_cfg.get(job_id),
                               self._record_fleet))
            return
        batch = decode_batch_bytes(bytes(payload))
        self._count_events(job_id, len(batch))
        self.mux.ingest_step_aligned(job_id, batch)

    def _tail_sink(self, job_id: str, batch) -> None:
        """Tail plane: newly completed segments (already decoded for the
        boundary check) — process mode re-frames them as FCS bytes so
        the worker boundary stays zero-pickle."""
        with self._reg_lock:
            departed = job_id in self._departed
        if departed:
            self.telemetry.counter("fleet.departed_rows",
                                   job=job_id).inc(len(batch))
            return
        if self._pool is not None:
            self._note_inflight(job_id, +1)
            self._pool.submit(("batches", job_id,
                               [encode_batch_bytes(batch)],
                               self._job_cfg.get(job_id),
                               self._record_fleet))
            return
        self.mux.ingest_step_aligned(job_id, batch)

    def _count_events(self, job_id: str, n: int) -> None:
        with self._merge_lock:
            self.stats.events += n
            self.stats.per_job[job_id] = \
                self.stats.per_job.get(job_id, 0) + n

    def _note_inflight(self, job_id: str, d: int) -> None:
        with self._reg_lock:
            n = max(self._inflight.get(job_id, 0) + d, 0)
            self._inflight[job_id] = n
        self.telemetry.gauge("serve.inflight", job=job_id).set(n)

    def queue_depths(self) -> dict:
        """Per-job frames submitted but not yet acknowledged by their
        worker (process mode; empty inline) plus per-worker task-queue
        depths — the ``/telemetry`` queue view."""
        with self._reg_lock:
            per_job = dict(sorted(self._inflight.items()))
        workers = self._pool.task_depths() if self._pool is not None else []
        return {"per_job": per_job, "workers": workers}

    # ------------------------------------------------------------------ #
    # process-pool callbacks (drainer threads)
    # ------------------------------------------------------------------ #
    def _on_worker_anomalies(self, job_id: str, items) -> None:
        job = self.mux.job(job_id)
        for ts, a in items:
            self.mux.stream.push(job_id, a, ts)
            job.count_anomaly()

    def _on_worker_fleet(self, job_id: str, obs, progress: float) -> None:
        # one envelope per ingested frame: the ack that drives the
        # queue-depth gauge, the observations + progress that advance
        # the parent's cross-job frontier
        self.mux.buffer_fleet_observations(job_id, obs)
        self.mux.note_fleet_progress(job_id, progress)
        self.mux.resolve_fleet_ready()
        self._note_inflight(job_id, -1)

    def _on_worker_job(self, job_id: str, res: dict) -> None:
        with self._merge_lock:
            self.mux.interner.merge_tables(res["names"], res["groups"])
            self.mux.telemetry.absorb(res["telemetry"])
            self.mux.restore_job_state(job_id, res["state"])
            self.stats.merge(res["stats"])
            self.mux.buffer_fleet_observations(job_id, res["obs"])
        self.mux.retire_job(job_id)

    def _on_worker_error(self, job_id: str, tb: str) -> None:
        self._errors.append((job_id, tb))
        self._note_inflight(job_id, -1)

    # ------------------------------------------------------------------ #
    # socket plane
    # ------------------------------------------------------------------ #
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _addr = self._lsock.accept()
            except socket.timeout:
                continue
            except OSError:
                return                     # listener closed: shutting down
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 daemon=True, name="flare-serve-conn")
            t.start()
            self._conn_threads.append(t)

    def _serve_conn(self, conn: socket.socket) -> None:
        self._c_conns.inc()
        with self._reg_lock:
            self._active_conns += 1
            self._g_active.set(self._active_conns)
        conn.settimeout(self.cfg.idle_poll_s)
        try:
            while True:
                fr = read_frame(conn, stop=self._stop.is_set)
                if fr is None:
                    return                  # clean EOF / clean shutdown
                ftype, job_id, payload = fr
                if ftype == FRAME_HELLO:
                    body = parse_hello(payload)
                    self.join_job(str(body.get("job_id") or job_id),
                                  topology=body.get("topology"),
                                  engine=body.get("engine"))
                elif ftype == FRAME_BATCH:
                    try:
                        self.ingest_frame(job_id, payload)
                    except CodecError as e:
                        raise ProtocolError(
                            f"undecodable BATCH payload ({e})") from e
                else:
                    self.leave_job(job_id)
        except ProtocolError:
            # torn or corrupt input: count it and drop the connection —
            # resynchronizing a corrupt stream means guessing, and the
            # spill/tail plane is the recovery path
            self._c_dropped.inc()
        except OSError:
            pass
        finally:
            try:
                conn.close()
            except OSError:
                pass
            with self._reg_lock:
                self._active_conns -= 1
                self._g_active.set(self._active_conns)

    # ------------------------------------------------------------------ #
    # anomaly collection
    # ------------------------------------------------------------------ #
    def _deliver(self, fas: list[FleetAnomaly]) -> None:
        if not fas:
            return
        with self._rec_lock:
            self.recent_anomalies.extend(fas)
        if self.on_anomaly is not None:
            now = time.monotonic()
            for fa in fas:
                self.on_anomaly(fa, now)

    def collect(self) -> list[FleetAnomaly]:
        """Drain newly diagnosed anomalies into the recent ring (and the
        ``on_anomaly`` hook); the collector thread calls this every
        ``drain_interval_s``, tests may call it directly."""
        fas = self.mux.poll()
        self._deliver(fas)
        return fas

    def _collect_loop(self) -> None:
        while not self._stop.wait(self.cfg.drain_interval_s):
            self.collect()

    def snapshot_recent(self, n: Optional[int] = None) -> list[FleetAnomaly]:
        with self._rec_lock:
            out = list(self.recent_anomalies)
        return out[-n:] if n else out

    # ------------------------------------------------------------------ #
    # query-plane views
    # ------------------------------------------------------------------ #
    def job_stats(self) -> dict:
        """Per-job engine stats + live service view (open/departed,
        queued frames)."""
        stats = self.mux.stats()
        with self._reg_lock:
            open_jobs = set(self._open)
            inflight = dict(self._inflight)
        for job in self.mux.jobs:
            row = stats.setdefault(job.job_id, {})
            row["open"] = job.job_id in open_jobs
            row["departed"] = job.departed
            row["queued_frames"] = inflight.get(job.job_id, 0)
        return stats

    def weather(self) -> dict:
        """Cluster-weather summary over the recent ring: what the fleet
        looks like right now, one JSON object."""
        recent = self.snapshot_recent()
        by_kind: dict[str, int] = {}
        by_team: dict[str, int] = {}
        by_job: dict[str, int] = {}
        reclass = 0
        for fa in recent:
            k = getattr(fa.anomaly.kind, "value", str(fa.anomaly.kind))
            t = getattr(fa.anomaly.team, "value", str(fa.anomaly.team))
            by_kind[k] = by_kind.get(k, 0) + 1
            by_team[t] = by_team.get(t, 0) + 1
            by_job[fa.job_id] = by_job.get(fa.job_id, 0) + 1
        reclass = sum(1 for fa in recent if fa.origin == "fleet")
        with self._reg_lock:
            open_jobs = len(self._open)
        return {
            "jobs_open": open_jobs,
            "jobs_total": len(self.mux.jobs),
            "anomalies_recent": len(recent),
            "fleet_reclassified_recent": reclass,
            "by_kind": dict(sorted(by_kind.items())),
            "by_team": dict(sorted(by_team.items())),
            "by_job": dict(sorted(by_job.items())),
            "events_ingested": self.stats.events,
        }
