"""FleetService — the always-on diagnosis daemon over the fleet engine.

``FleetReplayer.replay_dir`` is a one-shot drain over a finished
directory; this module keeps the same engine RESIDENT.  One service
instance owns a :class:`~repro.fleet.multiplexer.FleetMultiplexer` and
feeds it from two ingestion planes for as long as it lives:

  * **socket** — an FLW listener (``repro.serve.protocol``): training
    hosts ``HELLO`` a job (with topology for the fleet tier), stream
    ``BATCH`` frames (FCS-encoded ``EventBatch`` segments — the exact
    bytes the spill path writes), and ``BYE`` to leave gracefully;
  * **file tail** — a :class:`~repro.serve.tail.FileTailer` following
    the directory daemons spill into, feeding newly completed segments.

Both planes route into step-aligned ingest
(``FleetMultiplexer.ingest_step_aligned``) on one of two engines:

  * ``worker_kind="inline"`` — decode + diagnose on the service's own
    multiplexer (per-job locks already parallelize connection threads);
  * ``worker_kind="process"`` — frames cross *undecoded* into a
    resident :class:`~repro.fleet.ipc.ProcessWorkerPool` (each job
    pinned to a worker process holding its private engine), anomalies
    and keyed fleet-tier observations streaming back over bounded
    queues.  The parent buffers the observations and resolves its
    cross-job frontier incrementally (``resolve_fleet_ready``), so
    ``cross_job_failslow`` reclassifies LIVE in either mode.

The service is CRASH-SAFE when given ``ServiceConfig.checkpoint_dir``:
:meth:`checkpoint` quiesces ingestion behind a readers-writer gate,
gathers every resident pipeline's full state (workers answer
``TASK_SNAPSHOT`` over the IPC envelope machinery), and writes one
atomic, CRC-protected, generation-numbered snapshot
(``repro.serve.checkpoint``) — periodically, at graceful shutdown, and
on demand.  :meth:`restore` (before :meth:`start`) loads the newest
VALID generation, rebuilds every pipeline, and resumes tailing at the
recorded byte offsets, so only the spill suffix past the checkpointed
frontier is ever replayed and the post-restart anomaly stream is
byte-equivalent to an uninterrupted run (hard-gated in
``benchmarks/live.py --chaos-quick``).  A worker process dying
mid-flight triggers the same restore in-process (pool rebuilt, already
delivered post-checkpoint anomalies suppressed by replay-order dedup).

Determinism contract (asserted in ``benchmarks/live.py`` and
``tests/test_serve.py``): streaming a recorded directory through either
plane, in either mode, then :meth:`finalize`, yields an anomaly
sequence byte-equivalent (after the stream's own ``(ts, job_id, seq)``
merge sort) to ``replay_dir`` + ``finalize`` on the same files — with
the documented caveats that the fleet frontier assumes the job set is
hello'd before its watermarks pass, and hang diagnosis (which fires on
flush granularity) needs hang-free scenarios for bit-exact gates.

A minimal HTTP query plane (``repro.serve.query``) serves
``/anomalies``, ``/weather``, ``/telemetry``, ``/jobs`` and byte-
budgeted archive queries over the same state.
"""
from __future__ import annotations

import contextlib
import dataclasses
import socket
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.engine import EngineConfig
from repro.fleet.multiplexer import FleetMultiplexer
from repro.fleet.replay import ReplayStats
from repro.fleet.stream import FleetAnomaly
from repro.serve.checkpoint import CheckpointError, CheckpointStore
from repro.serve.protocol import (FRAME_BATCH, FRAME_HELLO, ProtocolError,
                                  parse_hello, read_frame)
from repro.serve.tail import FileTailer
from repro.store import CodecError, decode_batch_bytes, encode_batch_bytes


@dataclass
class ServiceConfig:
    host: str = "127.0.0.1"
    # FLW ingest port: 0 = ephemeral (read it back from ``.port``),
    # None = no socket plane (tail-only service)
    port: Optional[int] = 0
    # HTTP query port: 0 = ephemeral, None = no query plane
    query_port: Optional[int] = None
    worker_kind: str = "inline"        # "inline" | "process"
    workers: Optional[int] = None      # process mode: None = cpu count
    tail_dir: Optional[str] = None     # follow this spill directory
    tail_poll_s: float = 0.05
    # socket recv timeout at frame boundaries — how often an idle
    # connection polls for service shutdown
    idle_poll_s: float = 0.2
    drain_interval_s: float = 0.05     # anomaly collector period
    max_recent_anomalies: int = 4096   # /anomalies ring size
    archive_dir: Optional[str] = None  # /archive/* query root
    archive_max_bytes: Optional[int] = 64 << 20   # per-query byte budget
    # engine template for jobs that HELLO without overrides
    default_engine: Optional[EngineConfig] = None
    # socket plane: concurrent-connection cap (None = unbounded).  Over
    # the cap, new connections get a clean immediate close and a
    # ``serve.rejected_connections`` count — never a hang, never an
    # unbounded thread pile-up.
    max_connections: Optional[int] = None
    # overload shedding (process mode, SOCKET plane only): per-job cap
    # on frames submitted but not yet acknowledged by the worker.  Over
    # the cap the newest frame for that job is dropped and counted
    # (``serve.shed_frames{job=}``) — per-job budgets keep one
    # backlogged job from starving the rest, drop-newest keeps the
    # consumed prefix contiguous, and the spill/tail plane remains the
    # lossless source of truth for whatever was shed.
    max_inflight_frames: Optional[int] = None
    # crash safety: generation-numbered checkpoint directory (None =
    # checkpoints off), optional periodic cadence, generations to keep,
    # and whether graceful finalize() snapshots first.
    checkpoint_dir: Optional[str] = None
    checkpoint_interval_s: Optional[float] = None
    checkpoint_keep: int = 3
    checkpoint_on_finalize: bool = True
    # how long checkpoint() waits for workers to drain + answer
    quiesce_timeout_s: float = 30.0


class _IngestGate:
    """Readers-writer gate around ingestion: frame handlers and the
    tail pump enter as READERS (concurrent, uncontended in steady
    state); :meth:`pause` is the WRITER — it blocks new ingestion and
    waits out in-flight handlers, giving checkpoint/recovery a
    consistent cut without stopping collector or query threads."""

    def __init__(self):
        self._cond = threading.Condition()
        self._active = 0
        self._paused = False

    @contextlib.contextmanager
    def ingest(self):
        with self._cond:
            while self._paused:
                self._cond.wait()
            self._active += 1
        try:
            yield
        finally:
            with self._cond:
                self._active -= 1
                if self._active == 0:
                    self._cond.notify_all()

    @contextlib.contextmanager
    def pause(self):
        with self._cond:
            while self._paused:
                self._cond.wait()
            self._paused = True
            while self._active:
                self._cond.wait()
        try:
            yield
        finally:
            with self._cond:
                self._paused = False
                self._cond.notify_all()


class FleetService:
    """Long-lived ingest + query service over one fleet multiplexer.

    ``on_anomaly(fa, arrival_monotonic)`` (optional) fires for every
    collected anomaly with its collection time — the hook the latency
    benchmark hangs off; the service itself keeps only a bounded ring
    (``recent_anomalies``), so memory stays flat over months."""

    def __init__(self, mux: Optional[FleetMultiplexer] = None,
                 config: Optional[ServiceConfig] = None,
                 *, on_anomaly: Optional[Callable] = None):
        self.cfg = config or ServiceConfig()
        if self.cfg.worker_kind not in ("inline", "process"):
            raise ValueError(f"worker_kind must be 'inline' or 'process', "
                             f"got {self.cfg.worker_kind!r}")
        self.mux = mux or FleetMultiplexer()
        self.telemetry = self.mux.telemetry
        self.on_anomaly = on_anomaly
        self.stats = ReplayStats(worker_kind=f"live-{self.cfg.worker_kind}")
        self.tailer: Optional[FileTailer] = None
        self._pool = None
        self._record_fleet = bool(self.mux.fleet_detectors)
        self._stop = threading.Event()
        self._started = False
        self._finalized = False
        self._abandoned = False               # kill(): skip drain/flush
        self._reg_lock = threading.Lock()     # open-jobs registry
        self._merge_lock = threading.Lock()   # terminal-payload merges
        self._open: set[str] = set()
        self._departed: set[str] = set()
        self._job_cfg: dict[str, Optional[EngineConfig]] = {}
        self._errors: list[tuple[str, str]] = []
        self._rec_lock = threading.Lock()
        self.recent_anomalies: deque[FleetAnomaly] = deque(
            maxlen=self.cfg.max_recent_anomalies)
        self._inflight: dict[str, int] = {}   # process mode: frames queued
        self._lsock: Optional[socket.socket] = None
        self._threads: list[threading.Thread] = []
        self._conn_threads: list[threading.Thread] = []
        self._active_conns = 0
        self._query = None
        self.port: Optional[int] = None
        self.query_port: Optional[int] = None
        # checkpoint/restore plumbing
        self._gate = _IngestGate()
        self._ckpt: Optional[CheckpointStore] = None
        if self.cfg.checkpoint_dir is not None:
            self._ckpt = CheckpointStore(self.cfg.checkpoint_dir,
                                         keep=self.cfg.checkpoint_keep)
        self._restore_worker_states: dict[str, dict] = {}
        self._tail_restore: Optional[dict] = None
        self._recover_lock = threading.Lock()
        self._snap_cond = threading.Condition()
        self._snap_pending: set[str] = set()
        self._snap_states: dict[str, Optional[dict]] = {}
        # worker-death dedup: anomalies delivered since the last
        # checkpoint (guarded by _rec_lock).  Only tracked when a warm
        # recovery could actually replay them.  ``_dup`` is a multiset
        # of delivery keys, not an ordered queue: re-derivation is
        # deterministic per (job, origin) stream, but the INTERLEAVE of
        # job-origin and fleet-origin anomalies across drain boundaries
        # is not, so suppression must not depend on delivery order.
        self._track_dups = (self.cfg.worker_kind == "process"
                            and self._ckpt is not None)
        self._dup_log: list = []
        self._dup: dict[tuple, int] = {}
        t = self.telemetry
        self._c_conns = t.counter("serve.connections")
        self._c_frames = t.counter("serve.frames")
        self._c_bytes = t.counter("serve.bytes_in")
        self._c_dropped = t.counter("serve.dropped_frames")
        self._c_rejected = t.counter("serve.rejected_connections")
        self._c_ckpts = t.counter("serve.checkpoints")
        self._c_respawns = t.counter("serve.worker_respawns")
        self._c_deduped = t.counter("serve.deduped_anomalies")
        self._g_active = t.gauge("serve.active_connections")
        self._g_jobs = t.gauge("serve.jobs")

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    def start(self) -> "FleetService":
        if self._started:
            return self
        self._started = True
        if self.cfg.worker_kind == "process":
            self._start_pool()
            if self._restore_worker_states:
                from repro.fleet.ipc import TASK_RESTORE
                for job_id in sorted(self._restore_worker_states):
                    self._pool.submit((
                        TASK_RESTORE, job_id,
                        self._restore_worker_states[job_id],
                        self._job_cfg.get(job_id), self._record_fleet))
                self._restore_worker_states = {}
        if self.cfg.port is not None:
            self._lsock = socket.create_server(
                (self.cfg.host, self.cfg.port))
            self._lsock.settimeout(self.cfg.idle_poll_s)
            self.port = self._lsock.getsockname()[1]
            self._spawn(self._accept_loop, "flare-serve-accept")
        if self.cfg.tail_dir is not None:
            self.tailer = FileTailer(
                self.cfg.tail_dir, self._tail_sink,
                on_join=self.join_job, telemetry=self.telemetry)
            if self._tail_restore is not None:
                self.tailer.load_state(self._tail_restore)
                self._tail_restore = None
            self._spawn(self._tail_loop, "flare-serve-tail")
        self._spawn(self._collect_loop, "flare-serve-collect")
        if self._ckpt is not None and self.cfg.checkpoint_interval_s:
            self._spawn(self._checkpoint_loop, "flare-serve-ckpt")
        if self.cfg.query_port is not None:
            from repro.serve.query import QueryServer
            self._query = QueryServer(self, self.cfg.host,
                                      self.cfg.query_port)
            self.query_port = self._query.port
        return self

    def _spawn(self, target, name: str) -> None:
        t = threading.Thread(target=target, daemon=True, name=name)
        t.start()
        self._threads.append(t)

    def _start_pool(self) -> None:
        import os

        from repro.fleet.ipc import ProcessWorkerPool
        mux = self.mux
        init = {
            "history": mux.history,
            "fleet": {"watermark_delay": mux.cfg.watermark_delay,
                      "backend": mux.cfg.backend,
                      "max_pending_rows": mux.cfg.max_pending_rows},
            "replay": {"chunk_bytes": 8 << 20, "max_workers": None,
                       "executor": "thread", "serial_below": None,
                       "prefetch": 2, "predicate": None},
        }
        workers = self.cfg.workers or os.cpu_count() or 1
        self._pool = ProcessWorkerPool(workers, init)
        self._pool.start(on_anomalies=self._on_worker_anomalies,
                         on_fleet=self._on_worker_fleet,
                         on_job=self._on_worker_job,
                         on_error=self._on_worker_error,
                         on_snapshot=self._on_worker_snapshot,
                         on_death=self._on_worker_death)

    def finalize(self, *, raise_errors: bool = True) -> list[FleetAnomaly]:
        """Graceful shutdown: checkpoint the resident state (when
        configured), stop accepting, drain the tail directory to its end
        (leftover partial tails become corruption counts), close every
        worker job, finalize the multiplexer.  Returns the final drain
        (everything not yet collected); the full stream was delivered
        incrementally via ``on_anomaly``/``recent_anomalies``.
        Idempotent."""
        if self._finalized:
            return []
        if (self._ckpt is not None and self.cfg.checkpoint_on_finalize
                and self._started):
            try:
                self.checkpoint()
            except Exception:
                self.telemetry.counter("serve.checkpoint_errors").inc()
        self._finalized = True
        self._stop.set()
        if self._lsock is not None:
            try:
                self._lsock.close()
            except OSError:
                pass
        for t in self._threads:
            t.join(timeout=30.0)
        for t in self._conn_threads:
            t.join(timeout=30.0)
        if self._pool is not None:
            # sentinel closes still-open jobs; terminal envelopes merge
            # through _on_worker_job before join returns
            self._pool.shutdown()
            self._pool.join(raise_errors=False)
            self._pool.close()
        if self.tailer is not None:
            self.tailer.finish()           # no-op if the run thread did
            with self._merge_lock:
                self.stats.merge(self.tailer.stats)
        final = self.mux.finalize()
        self._deliver(final)
        with self._rec_lock:
            leftover = sum(self._dup.values())
            self._dup = {}
        if leftover:
            # pre-death deliveries that never re-derived: the stitched
            # stream is missing them — make that loss visible
            self.telemetry.counter(
                "serve.recovery_dedup_mismatch").inc(leftover)
        if self._query is not None:
            self._query.close()
        if raise_errors and self._errors:
            job_id, tb = self._errors[0]
            more = f" (+{len(self._errors) - 1} more)" \
                if len(self._errors) > 1 else ""
            raise RuntimeError(
                f"fleet service worker failed on job {job_id!r}{more}:\n{tb}")
        return final

    def kill(self) -> None:
        """Abrupt crash-simulating stop (the chaos harness's SIGKILL):
        threads stopped, sockets closed, worker processes terminated —
        NO flush, NO finalize, NO farewell checkpoint.  Whatever state
        was not yet checkpointed is lost, exactly as in a real crash;
        :meth:`restore` on a fresh service is the other half."""
        if self._finalized:
            return
        self._finalized = True
        self._abandoned = True
        self._stop.set()
        if self._lsock is not None:
            try:
                self._lsock.close()
            except OSError:
                pass
        for t in self._threads:
            t.join(timeout=10.0)
        for t in self._conn_threads:
            t.join(timeout=10.0)
        if self._pool is not None:
            self._pool.stop()
        if self._query is not None:
            self._query.close()

    @property
    def errors(self) -> list[tuple[str, str]]:
        return list(self._errors)

    # ------------------------------------------------------------------ #
    # job lifecycle + ingest (both planes land here)
    # ------------------------------------------------------------------ #
    def _engine_cfg(self, overrides: Optional[dict]) -> Optional[EngineConfig]:
        if overrides:
            base = self.cfg.default_engine
            if base is not None:
                return dataclasses.replace(base, **overrides)
            return EngineConfig(**overrides)
        return self.cfg.default_engine

    def join_job(self, job_id: str, topology: Optional[dict] = None,
                 engine: Optional[dict] = None) -> None:
        """Register a job (idempotent; re-HELLO just merges topology).
        In process mode the job's resident pipeline opens eagerly, so
        its first frame pays no engine construction."""
        with self._reg_lock:
            if job_id in self._departed:
                return                 # departed jobs are never revived
            known = job_id in self._open
            if not known:
                self._open.add(job_id)
                self._job_cfg[job_id] = self._engine_cfg(engine)
            self._g_jobs.set(len(self._open))
        if topology:
            self.mux.set_topology(job_id, **topology)
        if known:
            return
        self.mux.add_job(job_id, self._job_cfg[job_id])
        if self._pool is not None:
            from repro.fleet.ipc import TASK_OPEN
            self._pool.submit((TASK_OPEN, job_id, None,
                               self._job_cfg[job_id], self._record_fleet))

    def leave_job(self, job_id: str) -> None:
        """Graceful leave (``BYE``): the job's pending steps close, its
        hang analysis and detector finalize run, its fleet-frontier
        contribution releases — other jobs' diagnosis is untouched."""
        with self._reg_lock:
            if job_id not in self._open:
                return
            self._open.discard(job_id)
            self._departed.add(job_id)
            self._g_jobs.set(len(self._open))
        if self._pool is not None:
            # the worker flushes + ships the terminal envelope; the
            # parent-side retire happens in _on_worker_job when it lands
            self._pool.close_job(job_id)
        else:
            self.mux.retire_job(job_id)

    def ingest_frame(self, job_id: str, payload: bytes) -> None:
        """One BATCH frame: an FCS-encoded ``EventBatch`` segment.
        Inline mode decodes here (a ``CodecError`` propagates — the
        connection handler counts it as a dropped frame); process mode
        forwards the bytes undecoded to the job's pinned worker."""
        with self._reg_lock:
            known = job_id in self._open
            departed = job_id in self._departed
        self._c_frames.inc()
        self._c_bytes.inc(len(payload))
        if departed:
            # graceful-leave contract: post-BYE stragglers are dropped
            # and counted, never revived — and never forwarded to a
            # worker, whose closed pipeline they would silently reopen
            # (in process mode the parent mux only marks the job
            # departed once the terminal envelope lands, so the mux
            # guard alone is racy; the service set is authoritative)
            n = len(decode_batch_bytes(bytes(payload)))
            self.telemetry.counter("fleet.departed_rows",
                                   job=job_id).inc(n)
            return
        if not known:
            self.join_job(job_id)
        if self._pool is not None:
            cap = self.cfg.max_inflight_frames
            if cap is not None:
                with self._reg_lock:
                    depth = self._inflight.get(job_id, 0)
                if depth >= cap:
                    # shed without decoding: the sender's spill is the
                    # lossless copy, the tail plane replays it later
                    self.telemetry.counter("serve.shed_frames",
                                           job=job_id).inc()
                    return
            self._note_inflight(job_id, +1)
            self._pool.submit(("batches", job_id, [bytes(payload)],
                               self._job_cfg.get(job_id),
                               self._record_fleet))
            return
        batch = decode_batch_bytes(bytes(payload))
        self._count_events(job_id, len(batch))
        self.mux.ingest_step_aligned(job_id, batch)

    def _tail_sink(self, job_id: str, batch) -> None:
        """Tail plane: newly completed segments (already decoded for the
        boundary check) — process mode re-frames them as FCS bytes so
        the worker boundary stays zero-pickle.  Never shed: the tail IS
        the recovery path, dropping here would lose data for good."""
        with self._reg_lock:
            departed = job_id in self._departed
        if departed:
            self.telemetry.counter("fleet.departed_rows",
                                   job=job_id).inc(len(batch))
            return
        if self._pool is not None:
            self._note_inflight(job_id, +1)
            self._pool.submit(("batches", job_id,
                               [encode_batch_bytes(batch)],
                               self._job_cfg.get(job_id),
                               self._record_fleet))
            return
        self.mux.ingest_step_aligned(job_id, batch)

    def _tail_loop(self) -> None:
        """Service-owned tail pump: each poll runs under the ingest
        gate, so a checkpoint's pause sees segment-aligned tail offsets
        — the consistency cut the checkpointed byte offsets rely on."""
        while not self._stop.is_set():
            with self._gate.ingest():
                self.tailer.poll_once()
            self._stop.wait(self.cfg.tail_poll_s)
        if not self._abandoned:
            with self._gate.ingest():
                self.tailer.finish()

    def _count_events(self, job_id: str, n: int) -> None:
        with self._merge_lock:
            self.stats.events += n
            self.stats.per_job[job_id] = \
                self.stats.per_job.get(job_id, 0) + n

    def _note_inflight(self, job_id: str, d: int) -> None:
        with self._reg_lock:
            n = max(self._inflight.get(job_id, 0) + d, 0)
            self._inflight[job_id] = n
        self.telemetry.gauge("serve.inflight", job=job_id).set(n)

    def queue_depths(self) -> dict:
        """Per-job frames submitted but not yet acknowledged by their
        worker (process mode; empty inline) plus per-worker task-queue
        depths — the ``/telemetry`` queue view."""
        with self._reg_lock:
            per_job = dict(sorted(self._inflight.items()))
        workers = self._pool.task_depths() if self._pool is not None else []
        return {"per_job": per_job, "workers": workers}

    # ------------------------------------------------------------------ #
    # checkpoint / restore
    # ------------------------------------------------------------------ #
    def checkpoint(self) -> dict:
        """Write one consistent snapshot of the full resident state and
        return its metadata (path, generation, anomalies emitted so far,
        tail progress).  The cut: ingestion pauses (readers drain),
        process workers finish their queued frames and answer
        ``TASK_SNAPSHOT`` (their pending anomalies/observations ship
        FIRST on the same FIFO queue), the stream drains through one
        final :meth:`collect` — then everything pickles as ONE payload,
        so interner-table/slice identity survives, and lands atomically
        as the next generation."""
        if self._ckpt is None:
            raise CheckpointError(
                "checkpoint() needs ServiceConfig.checkpoint_dir")
        if not self._started:
            raise CheckpointError("checkpoint() before start()")
        with self._gate.pause():
            self._await_quiesce()
            worker_states = self._gather_worker_states() \
                if self._pool is not None else {}
            self.collect()
            state = self._assemble_state(worker_states)
            path, gen, nbytes = self._ckpt.save(state)
            with self._rec_lock:
                # everything delivered so far is inside the snapshot:
                # a future warm recovery only needs to dedup deliveries
                # made AFTER this cut.  Suppressions still owed from a
                # PREVIOUS recovery that never re-derived are real
                # losses — surface them instead of carrying them over.
                self._dup_log = []
                leftover = sum(self._dup.values())
                self._dup = {}
            if leftover:
                self.telemetry.counter(
                    "serve.recovery_dedup_mismatch").inc(leftover)
        self._c_ckpts.inc()
        self.telemetry.gauge("serve.checkpoint_generation").set(gen)
        self.telemetry.gauge("serve.checkpoint_bytes").set(nbytes)
        return {
            "path": path, "generation": gen, "bytes": nbytes,
            "jobs": len(state["jobs"]),
            "anomalies_emitted": state["service"]["anomalies_emitted"],
            "tail_events": self.tailer.stats.events
            if self.tailer is not None else 0,
            "tail_bytes_decoded": self.tailer.stats.bytes_decoded
            if self.tailer is not None else 0,
        }

    def restore(self) -> Optional[dict]:
        """Load the newest VALID checkpoint generation (torn/corrupt
        files are counted and skipped back past; a newer-format file
        refuses) and rebuild the resident state from it — before
        :meth:`start`, which then resumes tailing at the recorded
        offsets and re-opens worker pipelines via ``TASK_RESTORE``.
        Returns restore metadata, or ``None`` when no valid checkpoint
        exists (the service simply starts cold: full replay)."""
        if self._started:
            raise CheckpointError("restore() must run before start()")
        if self._ckpt is None:
            return None
        loaded = self._ckpt.load_latest()
        if loaded is None:
            self.telemetry.counter("serve.restore_fallbacks").inc()
            return None
        state, path, gen, skipped = loaded
        if skipped:
            self.telemetry.counter("serve.checkpoints_skipped").inc(
                len(skipped))
        if state.get("worker_kind") != self.cfg.worker_kind:
            raise CheckpointError(
                f"{path} was written by a worker_kind="
                f"{state.get('worker_kind')!r} service; this one runs "
                f"{self.cfg.worker_kind!r} — restore with a matching "
                "engine (worker-local state does not translate)")
        self.mux.restore_fleet_state(state["fleet"])
        svc = state["service"]
        with self._reg_lock:
            self._open = set(svc["open"])
            self._departed = set(svc["departed"])
            self._job_cfg = dict(svc["job_cfg"])
        self.stats = svc["stats"]
        with self._rec_lock:
            self.recent_anomalies.extend(svc["recent"])
        for job_id in sorted(state["jobs"]):
            entry = state["jobs"][job_id]
            self.mux.add_job(job_id, self._job_cfg.get(job_id))
            self.mux.restore_job_pipeline(job_id, entry["parent"])
            if entry.get("worker") is not None:
                self._restore_worker_states[job_id] = entry["worker"]
        self.telemetry.absorb(state["telemetry"])
        if state.get("tail") is not None:
            self._tail_restore = state["tail"]
        self._g_jobs.set(len(self._open))
        return {"path": path, "generation": gen, "skipped": skipped,
                "jobs": len(state["jobs"]),
                "anomalies_emitted": svc["anomalies_emitted"]}

    def _await_quiesce(self) -> None:
        """Process mode: with ingestion paused, wait until the workers
        acknowledged every submitted frame (their ``fleet`` envelopes
        decrement the inflight counts) — after this the parent has seen
        every observation the snapshot must contain."""
        if self._pool is None:
            return
        deadline = time.monotonic() + self.cfg.quiesce_timeout_s
        while True:
            with self._reg_lock:
                busy = any(n > 0 for n in self._inflight.values())
            if not busy:
                return
            if time.monotonic() > deadline:
                with self._reg_lock:
                    stuck = {j: n for j, n in self._inflight.items() if n}
                raise CheckpointError(
                    f"quiesce timeout: workers never acknowledged "
                    f"{stuck} frames")
            time.sleep(0.005)

    def _gather_worker_states(self) -> dict[str, dict]:
        """Fan ``TASK_SNAPSHOT`` to every open job's pinned worker and
        collect the answers (each preceded, FIFO, by the job's final
        pending-output ship)."""
        with self._reg_lock:
            want = sorted(self._open)
        if not want:
            return {}
        from repro.fleet.ipc import TASK_SNAPSHOT
        with self._snap_cond:
            self._snap_pending = set(want)
            self._snap_states = {}
        for job_id in want:
            self._pool.submit((TASK_SNAPSHOT, job_id, None, None, None))
        deadline = time.monotonic() + self.cfg.quiesce_timeout_s
        with self._snap_cond:
            while self._snap_pending:
                left = deadline - time.monotonic()
                if left <= 0:
                    raise CheckpointError(
                        f"snapshot timeout: no worker answer for "
                        f"{sorted(self._snap_pending)}")
                self._snap_cond.wait(left)
            return {j: s for j, s in self._snap_states.items()
                    if s is not None}

    def _assemble_state(self, worker_states: dict[str, dict]) -> dict:
        """The full resident state as one picklable dict — see the
        checkpoint-format section of ``serve/README.md``."""
        with self._reg_lock:
            open_ = sorted(self._open)
            departed = sorted(self._departed)
            job_cfg = dict(self._job_cfg)
        jobs = {}
        for job in self.mux.jobs:
            jobs[job.job_id] = {
                "parent": self.mux.snapshot_job_state(job.job_id),
                "worker": worker_states.get(job.job_id),
            }
        with self._rec_lock:
            recent = list(self.recent_anomalies)
        return {
            "worker_kind": self.cfg.worker_kind,
            "service": {
                "open": open_, "departed": departed, "job_cfg": job_cfg,
                "stats": self.stats, "recent": recent,
                "anomalies_emitted": self.mux.stream.total,
            },
            "fleet": self.mux.snapshot_fleet_state(),
            "jobs": jobs,
            "telemetry": self.telemetry.snapshot(),
            "tail": self.tailer.state_dict()
            if self.tailer is not None else None,
        }

    def _checkpoint_loop(self) -> None:
        while not self._stop.wait(self.cfg.checkpoint_interval_s):
            try:
                self.checkpoint()
            except Exception:
                # a failed periodic snapshot must never take the
                # service down — the previous generation still stands
                self.telemetry.counter("serve.checkpoint_errors").inc()

    # ------------------------------------------------------------------ #
    # worker-death recovery (process mode)
    # ------------------------------------------------------------------ #
    def _on_worker_death(self, index: int) -> None:
        # drainer-thread context: recovery joins drainers, so it must
        # run on its own thread
        self.telemetry.counter("serve.worker_deaths").inc()
        t = threading.Thread(target=self._recover_from_death,
                             daemon=True, name="flare-serve-recover")
        t.start()
        self._threads.append(t)

    def _recover_from_death(self) -> None:
        """A worker died mid-flight: instead of poisoning the pool (or
        silently losing the dead worker's resident pipelines), pause
        ingestion, tear the whole pool down, and rewind the service to
        its newest on-disk checkpoint — pipelines restored into a fresh
        pool, tail offsets rewound so the suffix replays, and anomalies
        already delivered since that checkpoint suppressed by replay-
        order dedup (re-derivation is deterministic, so the re-derived
        per-job prefix matches the delivery log byte for byte)."""
        with self._recover_lock:
            if self._finalized or self._stop.is_set():
                return
            with self._gate.pause():
                old_pool = self._pool
                old_pool.stop()          # no callback fires past here
                self.collect()           # deliver (and log) stragglers
                loaded = self._ckpt.load_latest() \
                    if self._ckpt is not None else None
                if loaded is None:
                    self._recover_fresh()
                else:
                    self._recover_from_checkpoint(loaded[0])
                with self._reg_lock:
                    stale = list(self._inflight)
                    self._inflight = {}
                for job_id in stale:
                    self.telemetry.gauge("serve.inflight",
                                         job=job_id).set(0)
            self._c_respawns.inc()

    def _recover_fresh(self) -> None:
        """No checkpoint to rewind to: restart the pool with empty
        pipelines.  Jobs resume from whatever arrives next — counted,
        and explicitly OUTSIDE the byte-equivalence guarantee (that is
        what checkpoints are for)."""
        self.telemetry.counter("serve.recoveries_uncheckpointed").inc()
        self._start_new_pool()
        from repro.fleet.ipc import TASK_OPEN
        with self._reg_lock:
            open_ = sorted(self._open)
        for job_id in open_:
            self._pool.submit((TASK_OPEN, job_id, None,
                               self._job_cfg.get(job_id),
                               self._record_fleet))

    def _recover_from_checkpoint(self, state: dict) -> None:
        with self._rec_lock:
            # deliveries since the checkpoint become a suppression
            # multiset: the restored pipelines will re-derive exactly
            # these (ts, anomaly, origin) keys, once each
            self._dup = {}
            for key in self._dup_log:
                self._dup[key] = self._dup.get(key, 0) + 1
            self._dup_log = []
        old_mux = self.mux
        new_mux = FleetMultiplexer(
            dataclasses.replace(old_mux.cfg, telemetry=self.telemetry),
            history=old_mux.history)
        new_mux.restore_fleet_state(state["fleet"])
        for job_id, attrs in old_mux.topology.items():
            new_mux.set_topology(job_id, **attrs)   # post-snapshot HELLOs
        svc = state["service"]
        for job_id in sorted(state["jobs"]):
            cfg = self._job_cfg.get(job_id) or svc["job_cfg"].get(job_id)
            new_mux.add_job(job_id, cfg)
            new_mux.restore_job_pipeline(job_id,
                                         state["jobs"][job_id]["parent"])
        with self._reg_lock:
            extra = sorted(self._open - set(state["jobs"]))
        for job_id in extra:                        # joined post-snapshot
            new_mux.add_job(job_id, self._job_cfg.get(job_id))
        self.mux = new_mux
        self.stats = svc["stats"]
        self._start_new_pool()
        from repro.fleet.ipc import TASK_OPEN, TASK_RESTORE
        for job_id in sorted(state["jobs"]):
            wstate = state["jobs"][job_id]["worker"]
            if wstate is not None:
                self._pool.submit((TASK_RESTORE, job_id, wstate,
                                   self._job_cfg.get(job_id),
                                   self._record_fleet))
        for job_id in extra:
            self._pool.submit((TASK_OPEN, job_id, None,
                               self._job_cfg.get(job_id),
                               self._record_fleet))
        if self.tailer is not None and state.get("tail") is not None:
            # rewind the tail to the checkpointed offsets: the suffix
            # past the snapshot replays into the restored pipelines
            self.tailer.load_state(state["tail"])
        self.telemetry.counter("serve.jobs_recovered").inc(
            len(state["jobs"]))

    def _start_new_pool(self) -> None:
        self._pool = None
        self._start_pool()

    # ------------------------------------------------------------------ #
    # process-pool callbacks (drainer threads)
    # ------------------------------------------------------------------ #
    def _on_worker_anomalies(self, job_id: str, items) -> None:
        job = self.mux.job(job_id)
        for ts, a in items:
            self.mux.stream.push(job_id, a, ts)
            job.count_anomaly()

    def _on_worker_fleet(self, job_id: str, obs, progress: float) -> None:
        # one envelope per ingested frame: the ack that drives the
        # queue-depth gauge, the observations + progress that advance
        # the parent's cross-job frontier
        self.mux.buffer_fleet_observations(job_id, obs)
        self.mux.note_fleet_progress(job_id, progress)
        self.mux.resolve_fleet_ready()
        self._note_inflight(job_id, -1)

    def _on_worker_snapshot(self, job_id: str, state) -> None:
        with self._snap_cond:
            self._snap_states[job_id] = state
            self._snap_pending.discard(job_id)
            self._snap_cond.notify_all()

    def _on_worker_job(self, job_id: str, res: dict) -> None:
        with self._merge_lock:
            self.mux.interner.merge_tables(res["names"], res["groups"])
            self.mux.telemetry.absorb(res["telemetry"])
            self.mux.restore_job_state(job_id, res["state"])
            self.stats.merge(res["stats"])
            self.mux.buffer_fleet_observations(job_id, res["obs"])
        self.mux.retire_job(job_id)

    def _on_worker_error(self, job_id: str, tb: str) -> None:
        self._errors.append((job_id, tb))
        self._note_inflight(job_id, -1)

    # ------------------------------------------------------------------ #
    # socket plane
    # ------------------------------------------------------------------ #
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _addr = self._lsock.accept()
            except socket.timeout:
                continue
            except OSError:
                return                     # listener closed: shutting down
            maxc = self.cfg.max_connections
            if maxc is not None:
                with self._reg_lock:
                    over = self._active_conns >= maxc
                if over:
                    # clean immediate close, never a hang: the daemon's
                    # sink backs off and retries, its spill keeps the
                    # data; counted so operators see the pressure
                    self._c_rejected.inc()
                    try:
                        conn.close()
                    except OSError:
                        pass
                    continue
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 daemon=True, name="flare-serve-conn")
            t.start()
            self._conn_threads.append(t)

    def _serve_conn(self, conn: socket.socket) -> None:
        self._c_conns.inc()
        with self._reg_lock:
            self._active_conns += 1
            self._g_active.set(self._active_conns)
        conn.settimeout(self.cfg.idle_poll_s)
        try:
            while True:
                fr = read_frame(conn, stop=self._stop.is_set)
                if fr is None:
                    return                  # clean EOF / clean shutdown
                ftype, job_id, payload = fr
                # each frame lands under the ingest gate: a checkpoint's
                # pause happens BETWEEN frames, so the snapshot never
                # cuts a half-applied frame
                with self._gate.ingest():
                    if ftype == FRAME_HELLO:
                        body = parse_hello(payload)
                        self.join_job(str(body.get("job_id") or job_id),
                                      topology=body.get("topology"),
                                      engine=body.get("engine"))
                    elif ftype == FRAME_BATCH:
                        try:
                            self.ingest_frame(job_id, payload)
                        except CodecError as e:
                            raise ProtocolError(
                                f"undecodable BATCH payload ({e})") from e
                    else:
                        self.leave_job(job_id)
        except ProtocolError:
            # torn or corrupt input: count it and drop the connection —
            # resynchronizing a corrupt stream means guessing, and the
            # spill/tail plane is the recovery path
            self._c_dropped.inc()
        except OSError:
            pass
        finally:
            try:
                conn.close()
            except OSError:
                pass
            with self._reg_lock:
                self._active_conns -= 1
                self._g_active.set(self._active_conns)

    # ------------------------------------------------------------------ #
    # anomaly collection
    # ------------------------------------------------------------------ #
    def _deliver(self, fas: list[FleetAnomaly]) -> None:
        if not fas:
            return
        deliver = fas
        with self._rec_lock:
            if self._dup:
                # post-recovery replay: suppress anomalies the pre-death
                # service already delivered since the restored
                # checkpoint.  Re-derivation visits each key exactly
                # once, so a multiset decrement is sound; an unknown key
                # simply delivers (fail open — never swallow findings),
                # and keys left over at the next checkpoint are counted
                # as ``serve.recovery_dedup_mismatch``.
                deliver = []
                for fa in fas:
                    key = (fa.job_id, fa.ts, str(fa.anomaly), fa.origin)
                    n = self._dup.get(key, 0)
                    if n:
                        if n == 1:
                            del self._dup[key]
                        else:
                            self._dup[key] = n - 1
                        self._c_deduped.inc()
                        continue
                    deliver.append(fa)
            if self._track_dups:
                for fa in deliver:
                    self._dup_log.append(
                        (fa.job_id, fa.ts, str(fa.anomaly), fa.origin))
            self.recent_anomalies.extend(deliver)
        if self.on_anomaly is not None and deliver:
            now = time.monotonic()
            for fa in deliver:
                self.on_anomaly(fa, now)

    def collect(self) -> list[FleetAnomaly]:
        """Drain newly diagnosed anomalies into the recent ring (and the
        ``on_anomaly`` hook); the collector thread calls this every
        ``drain_interval_s``, tests may call it directly."""
        fas = self.mux.poll()
        self._deliver(fas)
        return fas

    def _collect_loop(self) -> None:
        while not self._stop.wait(self.cfg.drain_interval_s):
            self.collect()

    def snapshot_recent(self, n: Optional[int] = None) -> list[FleetAnomaly]:
        with self._rec_lock:
            out = list(self.recent_anomalies)
        return out[-n:] if n else out

    # ------------------------------------------------------------------ #
    # query-plane views
    # ------------------------------------------------------------------ #
    def job_stats(self) -> dict:
        """Per-job engine stats + live service view (open/departed,
        queued frames)."""
        stats = self.mux.stats()
        with self._reg_lock:
            open_jobs = set(self._open)
            inflight = dict(self._inflight)
        for job in self.mux.jobs:
            row = stats.setdefault(job.job_id, {})
            row["open"] = job.job_id in open_jobs
            row["departed"] = job.departed
            row["queued_frames"] = inflight.get(job.job_id, 0)
        return stats

    def weather(self) -> dict:
        """Cluster-weather summary over the recent ring: what the fleet
        looks like right now, one JSON object."""
        recent = self.snapshot_recent()
        by_kind: dict[str, int] = {}
        by_team: dict[str, int] = {}
        by_job: dict[str, int] = {}
        reclass = 0
        for fa in recent:
            k = getattr(fa.anomaly.kind, "value", str(fa.anomaly.kind))
            t = getattr(fa.anomaly.team, "value", str(fa.anomaly.team))
            by_kind[k] = by_kind.get(k, 0) + 1
            by_team[t] = by_team.get(t, 0) + 1
            by_job[fa.job_id] = by_job.get(fa.job_id, 0) + 1
        reclass = sum(1 for fa in recent if fa.origin == "fleet")
        with self._reg_lock:
            open_jobs = len(self._open)
        return {
            "jobs_open": open_jobs,
            "jobs_total": len(self.mux.jobs),
            "anomalies_recent": len(recent),
            "fleet_reclassified_recent": reclass,
            "by_kind": dict(sorted(by_kind.items())),
            "by_team": dict(sorted(by_team.items())),
            "by_job": dict(sorted(by_job.items())),
            "events_ingested": self.stats.events,
        }
