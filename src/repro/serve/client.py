"""Live-ingest clients for the FLW socket plane.

Two shapes for two callers:

  * :class:`LiveClient` — explicit control for simulators, benchmarks
    and tests: connect, ``hello`` jobs, ``send_batch`` frames, ``bye``,
    close.  Errors raise; nothing is dropped silently.
  * :class:`LiveBatchSink` — the resilient per-daemon sink behind
    ``DaemonConfig.live_endpoint``: a callable that frames one flushed
    :class:`~repro.core.columnar.EventBatch` per call.  Its contract is
    the TracingDaemon heartbeat's: NEVER block for long and NEVER
    raise.  A dead/slow service costs a counted drop
    (``daemon.live_dropped`` in the daemon's telemetry) and a
    reconnect-with-backoff attempt on a later flush — diagnosis
    telemetry must not be able to take training down.

Only ``repro.store`` and the wire protocol are imported here, so the
daemon side never pulls the service (with its fleet machinery) into the
training process.
"""
from __future__ import annotations

import socket
import time
from typing import Optional

from repro.serve.protocol import (batch_frame, bye_frame, hello_frame)
from repro.store import encode_batch_bytes


def parse_endpoint(endpoint: str) -> tuple[str, int]:
    """``"host:port"`` -> ``(host, port)`` (IPv4/hostname form)."""
    host, _, port = endpoint.rpartition(":")
    return host or "127.0.0.1", int(port)


class LiveClient:
    """Blocking, raising client — one socket, many jobs."""

    def __init__(self, host: str, port: int, *, timeout: float = 10.0):
        self.sock = socket.create_connection((host, port), timeout=timeout)

    def hello(self, job_id: str, topology: Optional[dict] = None,
              engine: Optional[dict] = None) -> None:
        self.sock.sendall(hello_frame(job_id, topology, engine))

    def send_batch(self, job_id: str, batch_or_bytes) -> int:
        """Frame + send one batch (an ``EventBatch`` is FCS-encoded
        first; raw ``bytes`` pass through — a spill segment already on
        hand costs no re-encode).  Returns wire bytes sent."""
        blob = batch_or_bytes if isinstance(batch_or_bytes, (bytes,
                                                             bytearray,
                                                             memoryview)) \
            else encode_batch_bytes(batch_or_bytes)
        frame = batch_frame(job_id, bytes(blob))
        self.sock.sendall(frame)
        return len(frame)

    def bye(self, job_id: str) -> None:
        self.sock.sendall(bye_frame(job_id))

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


class LiveBatchSink:
    """Never-blocking, never-raising batch sink for the daemon.

    On send failure the socket is torn down, the batch is counted as
    dropped, and reconnection is attempted no sooner than an
    exponential backoff allows (``backoff_s`` .. ``backoff_max_s``);
    batches arriving while disconnected are counted drops, not queued —
    the service's replay/tail planes exist precisely so lost live
    frames are recoverable from the spill, and an unbounded queue in
    the training process is the failure mode this sink exists to
    prevent."""

    def __init__(self, endpoint: str, job_id: str,
                 *, topology: Optional[dict] = None,
                 engine: Optional[dict] = None,
                 telemetry=None, timeout: float = 1.0,
                 backoff_s: float = 0.5, backoff_max_s: float = 30.0,
                 clock=time.monotonic):
        self.host, self.port = parse_endpoint(endpoint)
        self.job_id = job_id
        self.topology = topology
        self.engine = engine
        self.timeout = timeout
        self.backoff_s = backoff_s
        self.backoff_max_s = backoff_max_s
        self._clock = clock
        self._sock: Optional[socket.socket] = None
        self._next_try = 0.0
        self._fails = 0
        t = telemetry
        self._sent = t.counter("daemon.live_frames") if t else None
        self._bytes = t.counter("daemon.live_bytes") if t else None
        self._dropped = t.counter("daemon.live_dropped") if t else None
        self._reconnects = t.counter("daemon.live_reconnects") if t else None

    def _drop(self) -> None:
        if self._dropped is not None:
            self._dropped.inc()

    def _disconnect(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None
        self._fails += 1
        self._next_try = self._clock() + min(
            self.backoff_s * (2 ** min(self._fails - 1, 16)),
            self.backoff_max_s)

    def _ensure_connected(self) -> bool:
        if self._sock is not None:
            return True
        if self._clock() < self._next_try:
            return False
        try:
            sock = socket.create_connection((self.host, self.port),
                                            timeout=self.timeout)
            sock.settimeout(self.timeout)
            # HELLO rides EVERY fresh connection, not just the first:
            # a service restarted from a checkpoint (or rebooted cold)
            # learns this job's topology + engine overrides again on the
            # next backoff reconnect, with no daemon-side special case
            sock.sendall(hello_frame(self.job_id, self.topology,
                                     self.engine))
        except OSError:
            self._disconnect()
            return False
        self._sock = sock
        if self._fails and self._reconnects is not None:
            self._reconnects.inc()
        self._fails = 0
        return True

    def __call__(self, batch) -> bool:
        """Ship one flushed batch; ``True`` if it went out, ``False``
        for a counted drop.  Safe to call from the daemon's heartbeat
        thread: worst case is one connect/send timeout."""
        try:
            if not self._ensure_connected():
                self._drop()
                return False
            frame = batch_frame(self.job_id, encode_batch_bytes(batch))
            self._sock.sendall(frame)
        except Exception:
            # OSError/timeout from the socket, or anything unexpected
            # from encode: the heartbeat must survive all of it
            self._disconnect()
            self._drop()
            return False
        if self._sent is not None:
            self._sent.inc()
        if self._bytes is not None:
            self._bytes.inc(len(frame))
        return True

    def close(self) -> None:
        """Best-effort ``bye`` + socket close (idempotent)."""
        sock, self._sock = self._sock, None
        if sock is not None:
            try:
                sock.sendall(bye_frame(self.job_id))
            except OSError:
                pass
            try:
                sock.close()
            except OSError:
                pass
