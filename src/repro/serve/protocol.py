"""FLW wire protocol: length-prefixed FCS frames over a stream socket.

One daemon connection speaks three frame types:

  * ``HELLO`` — join a job: payload is a JSON object, at minimum
    ``{"job_id": ...}``, optionally ``topology`` (rack/switch attrs for
    the fleet tier) and ``engine`` (EngineConfig field overrides);
  * ``BATCH`` — one flushed :class:`~repro.core.columnar.EventBatch`,
    encoded with ``repro.store.encode_batch_bytes`` (an FCS v2 segment
    — the exact bytes the spill path writes, ~11.5 B/event);
  * ``BYE`` — graceful leave: the service retires the job (flush + hang
    check + detector finalize) without touching other jobs.

Frame layout (little-endian)::

    magic  b"FLW1"   4 bytes
    type   u8        1=HELLO 2=BATCH 3=BYE
    flags  u8        reserved, 0
    job    u16       job-id byte length
    len    u32       payload byte length
    crc    u32       crc32 of job-id bytes + payload
    job-id bytes, payload bytes

Integrity contract: a clean EOF lands exactly on a frame boundary.  EOF
mid-frame is a TORN frame; bad magic / unknown type / CRC mismatch is a
CORRUPT frame.  Both raise :class:`ProtocolError` — the service counts
them (``serve.dropped_frames``) and drops the connection rather than
guessing at resynchronization, exactly like a truncated FCS tail is
counted and never silently decoded.
"""
from __future__ import annotations

import json
import socket
import struct
import zlib
from typing import Optional

MAGIC = b"FLW1"
FRAME_HELLO = 1
FRAME_BATCH = 2
FRAME_BYE = 3

_HEADER = struct.Struct("<4sBBHII")

# sanity bound, not a protocol limit: one frame is one daemon flush
# (thousands of events, ~11.5 B each), so anything near this is garbage
# lengths from a corrupt header
MAX_PAYLOAD = 1 << 30


class ProtocolError(Exception):
    """Torn or corrupt frame on a live-ingest connection."""


def encode_frame(ftype: int, job_id: str, payload: bytes = b"") -> bytes:
    job = job_id.encode("utf-8")
    crc = zlib.crc32(payload, zlib.crc32(job)) & 0xFFFFFFFF
    return _HEADER.pack(MAGIC, ftype, 0, len(job), len(payload), crc) \
        + job + payload


def hello_frame(job_id: str, topology: Optional[dict] = None,
                engine: Optional[dict] = None) -> bytes:
    body: dict = {"job_id": job_id}
    if topology:
        body["topology"] = dict(topology)
    if engine:
        body["engine"] = dict(engine)
    return encode_frame(FRAME_HELLO, job_id,
                        json.dumps(body, sort_keys=True).encode("utf-8"))


def bye_frame(job_id: str) -> bytes:
    return encode_frame(FRAME_BYE, job_id)


def batch_frame(job_id: str, fcs_bytes: bytes) -> bytes:
    return encode_frame(FRAME_BATCH, job_id, fcs_bytes)


def _recv_exact(sock: socket.socket, n: int, *, at_boundary: bool,
                stop=None):
    """Read exactly ``n`` bytes; ``None`` on a clean EOF at a frame
    boundary, :class:`ProtocolError` on EOF mid-frame (torn).  With a
    socket timeout set, idle timeouts just poll ``stop()`` — a stall is
    tolerated indefinitely while the service runs, but stopping with a
    half-read frame is a torn frame (and a clean shutdown at a frame
    boundary returns ``None`` like EOF)."""
    buf = bytearray()
    while len(buf) < n:
        try:
            chunk = sock.recv(n - len(buf))
        except socket.timeout:
            if stop is not None and stop():
                if not buf and at_boundary:
                    return None
                raise ProtocolError(
                    "torn frame: connection stopped mid-frame")
            continue
        if not chunk:
            if not buf and at_boundary:
                return None
            raise ProtocolError(
                f"torn frame: EOF after {len(buf)}/{n} bytes")
        buf.extend(chunk)
    return bytes(buf)


def read_frame(sock: socket.socket, stop=None
               ) -> Optional[tuple[int, str, bytes]]:
    """Read one frame; returns ``(type, job_id, payload)`` or ``None``
    on clean EOF (or a ``stop()``-signalled shutdown at a frame
    boundary).  Raises :class:`ProtocolError` on torn or corrupt
    input."""
    head = _recv_exact(sock, _HEADER.size, at_boundary=True, stop=stop)
    if head is None:
        return None
    magic, ftype, _flags, job_len, payload_len, crc = _HEADER.unpack(head)
    if magic != MAGIC:
        raise ProtocolError(f"bad magic {magic!r} (expected {MAGIC!r})")
    if ftype not in (FRAME_HELLO, FRAME_BATCH, FRAME_BYE):
        raise ProtocolError(f"unknown frame type {ftype}")
    if payload_len > MAX_PAYLOAD:
        raise ProtocolError(f"implausible payload length {payload_len}")
    body = _recv_exact(sock, job_len + payload_len, at_boundary=False,
                       stop=stop) \
        if job_len + payload_len else b""
    if (zlib.crc32(body) & 0xFFFFFFFF) != crc:
        raise ProtocolError("frame CRC mismatch")
    job = body[:job_len].decode("utf-8", errors="replace")
    return ftype, job, body[job_len:]


def parse_hello(payload: bytes) -> dict:
    """Decode a HELLO payload; tolerant of an empty body (job id is in
    the frame header either way)."""
    if not payload:
        return {}
    try:
        body = json.loads(payload)
    except ValueError as e:
        raise ProtocolError(f"corrupt hello payload ({e})") from e
    if not isinstance(body, dict):
        raise ProtocolError("hello payload must be a JSON object")
    return body
