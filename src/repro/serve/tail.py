"""File-tailer ingestion: follow growing/rotating trace streams live.

The second ingestion plane of :class:`~repro.serve.service.FleetService`
(next to the socket listener): point it at the directory the tracing
daemons spill into and it feeds each job's NEWLY COMPLETED data to a
sink as it lands on disk —

  * FCS streams (``<stem>.fcs``/``.fcs2``/``.fcs3`` + rotated
    ``.segNNN.`` pieces from :class:`~repro.store.writer.
    SegmentedTraceWriter`) advance segment by segment: a segment is
    decoded only once its full ``seg_len`` is on disk
    (``store.tail_complete_segments``), so the tailer never races the
    writer's appends — segment boundaries are the commit points;
  * JSONL streams advance line by line (only up to the last complete
    ``\\n``), corrupt lines skipped and counted exactly like replay.

File progression mirrors ``replay_dir``'s rotation contract: a job's
files are ordered by ``seg_index``; file *N* is FINAL once file *N+1*
exists (the writer rotated away) or the tailer is told the stream ended
(:meth:`FileTailer.finish`).  A final file's leftover bytes — a torn
FCS tail from a killed writer, a partial trailing line — are counted
(``corrupt_files`` / ``skipped_lines``) with the same accounting rules
``FleetReplayer`` uses, so a tailed directory's stats are comparable to
a replayed one.

Drive it with :meth:`poll_once` (deterministic: jobs in sorted order,
files in rotation order — what the equivalence tests do) or hand
:meth:`run` a thread + stop event (what the service does).
"""
from __future__ import annotations

import glob
import os
import threading
from typing import Callable, Optional

from repro.fleet.replay import ReplayStats
from repro.store import (CodecError, codec_for_path, codecs,
                         decode_jsonl_lines, is_sidecar_path,
                         job_id_for_path, seg_index,
                         tail_complete_segments)

_FCS_CODECS = ("fcs", "fcs2", "fcs3")


class _TailFile:
    __slots__ = ("path", "kind", "offset", "events", "dead",
                 "corrupt_counted")

    def __init__(self, path: str, kind: str):
        self.path = path
        self.kind = kind                    # "fcs" | "jsonl" | "skip"
        self.offset = 0                     # consumed bytes
        self.events = 0
        self.dead = False                   # structural corruption: stop
        self.corrupt_counted = False


class _TailJob:
    __slots__ = ("files", "known", "idx")

    def __init__(self):
        self.files: list[_TailFile] = []
        self.known: set[str] = set()
        self.idx = 0                        # current (non-final) file


class FileTailer:
    """Follows every trace stream under ``directory``.

    ``sink(job_id, batch)`` receives each newly completed FCS segment /
    JSONL slab (the service routes it into step-aligned ingest);
    ``on_join(job_id)`` fires once when a job's first file appears.
    ``telemetry`` (optional registry) gets ``serve.tail_files``,
    ``serve.tail_segments``, ``serve.tail_corrupt_files`` and
    ``serve.tail_skipped_lines`` counters.  ``stats`` accumulates
    replay-comparable accounting."""

    def __init__(self, directory: str, sink: Callable,
                 *, on_join: Optional[Callable] = None,
                 telemetry=None, pattern: Optional[str] = None):
        self.directory = directory
        self.sink = sink
        self.on_join = on_join
        self.telemetry = telemetry
        self.pattern = pattern
        self.stats = ReplayStats(worker_kind="tail")
        self._jobs: dict[str, _TailJob] = {}
        self._finished = False

    # ------------------------------------------------------------------ #
    def _count(self, name: str, n: int = 1, **tags) -> None:
        if self.telemetry is not None and n:
            self.telemetry.counter(name, **tags).inc(n)

    def _patterns(self) -> tuple[str, ...]:
        if self.pattern is not None:
            return (self.pattern,)
        return tuple(f"*{ext}" for c in codecs().values()
                     for ext in c.extensions)

    def _classify(self, path: str) -> str:
        try:
            name = codec_for_path(path).name
        except (CodecError, KeyError, ValueError):
            return "skip"
        if name in _FCS_CODECS:
            return "fcs"
        if name == "jsonl":
            return "jsonl"
        return "skip"

    def _discover(self) -> None:
        """Pick up new files (and first-seen jobs).  Rotation only ever
        appends higher ``seg_index`` pieces, so known files keep their
        consumed offsets and new ones append in order."""
        paths = sorted({p for pat in self._patterns()
                        for p in glob.glob(
                            os.path.join(self.directory, pat))
                        if not is_sidecar_path(p)},
                       key=lambda p: (job_id_for_path(p), seg_index(p), p))
        for p in paths:
            job_id = job_id_for_path(p)
            tj = self._jobs.get(job_id)
            if tj is None:
                tj = self._jobs[job_id] = _TailJob()
                if self.on_join is not None:
                    self.on_join(job_id)
            if p not in tj.known:
                tj.known.add(p)
                tj.files.append(_TailFile(p, self._classify(p)))

    # ------------------------------------------------------------------ #
    def _pump(self, job_id: str, tf: _TailFile) -> int:
        """Feed the sink whatever newly completed data ``tf`` holds;
        returns the number of batches delivered."""
        if tf.dead or tf.kind == "skip":
            return 0
        try:
            if tf.kind == "fcs":
                return self._pump_fcs(job_id, tf)
            return self._pump_jsonl(job_id, tf)
        except FileNotFoundError:
            return 0       # vanished (restored state, file pruned): wait
        except CodecError:
            # structural corruption at a COMPLETED offset: count the
            # file once, stop consuming it (replay's skip-and-count)
            tf.dead = True
            if not tf.corrupt_counted:
                tf.corrupt_counted = True
                self.stats.corrupt_files += 1
                self._count("serve.tail_corrupt_files")
            return 0

    def _pump_fcs(self, job_id: str, tf: _TailFile) -> int:
        batches, new_off = tail_complete_segments(tf.path, tf.offset)
        # every byte of every completed segment is decoded exactly once
        # across tailer incarnations (offsets are checkpointed), so this
        # is the suffix-only-replay accounting the chaos gate asserts on
        self.stats.bytes_decoded += new_off - tf.offset
        tf.offset = new_off
        for b in batches:
            n = len(b)
            tf.events += n
            self.stats.events += n
            self._count("serve.tail_segments")
            self.sink(job_id, b)
        return len(batches)

    def _pump_jsonl(self, job_id: str, tf: _TailFile,
                    *, final: bool = False) -> int:
        try:
            size = os.path.getsize(tf.path)
        except OSError:
            return 0
        if size <= tf.offset:
            return 0
        with open(tf.path, "rb") as f:
            f.seek(tf.offset)
            data = f.read()
        if final:
            chunk = data           # trailing partial line: decode-or-count
        else:
            cut = data.rfind(b"\n")
            if cut < 0:
                return 0           # no complete line yet: wait
            chunk = data[:cut + 1]
        batch, skipped = decode_jsonl_lines(chunk.splitlines())
        tf.offset += len(chunk)
        self.stats.bytes_decoded += len(chunk)
        if skipped:
            self.stats.skipped_lines += skipped
            self._count("serve.tail_skipped_lines", skipped)
        n = len(batch)
        if n:
            tf.events += n
            self.stats.events += n
            self._count("serve.tail_segments")
            self.sink(job_id, batch)
        return 1 if (n or skipped) else 0

    def _finish_file(self, job_id: str, tf: _TailFile) -> None:
        """The file is FINAL (rotated away, or end of stream): resolve
        its leftover bytes and land replay-compatible accounting."""
        if tf.kind == "jsonl" and not tf.dead:
            self._pump_jsonl(job_id, tf, final=True)
        elif tf.kind == "fcs" and not tf.dead:
            try:
                size = os.path.getsize(tf.path)
            except OSError:
                size = tf.offset
            if size > tf.offset and not tf.corrupt_counted:
                # a tail that never completed: the killed-writer signal
                tf.corrupt_counted = True
                self.stats.corrupt_files += 1
                self._count("serve.tail_corrupt_files")
        if tf.kind == "skip":
            return
        if tf.events == 0 and tf.corrupt_counted:
            return                 # nothing usable before the corruption
        self.stats.files += 1
        self.stats.per_job[job_id] = \
            self.stats.per_job.get(job_id, 0) + tf.events
        self._count("serve.tail_files")

    # ------------------------------------------------------------------ #
    def poll_once(self) -> int:
        """One deterministic pass: discover files, pump every job's
        stream (sorted job order, rotation order within a job), finalize
        files that later rotation pieces prove complete.  Returns the
        number of batches delivered to the sink."""
        self._discover()
        delivered = 0
        for job_id in sorted(self._jobs):
            tj = self._jobs[job_id]
            while tj.idx < len(tj.files):
                tf = tj.files[tj.idx]
                delivered += self._pump(job_id, tf)
                if tj.idx < len(tj.files) - 1:
                    # a later piece exists: this one is final
                    self._finish_file(job_id, tf)
                    tj.idx += 1
                    continue
                break
        return delivered

    def finish(self) -> None:
        """End of stream: one last pump, then treat every job's current
        file as final (leftover tails become corruption counts, partial
        trailing lines decode-or-count).  Idempotent."""
        if self._finished:
            return
        self._finished = True
        self.poll_once()
        for job_id in sorted(self._jobs):
            tj = self._jobs[job_id]
            while tj.idx < len(tj.files):
                tf = tj.files[tj.idx]
                self._pump(job_id, tf)
                self._finish_file(job_id, tf)
                tj.idx += 1

    # ------------------------------------------------------------------ #
    # service checkpoints: byte/segment offsets + accounting
    # ------------------------------------------------------------------ #
    def state_dict(self) -> dict:
        """Picklable tail position: per-file consumed offsets (FCS
        offsets always sit on segment boundaries — the commit points —
        so a restored tailer resumes mid-file without re-decoding),
        per-job rotation cursors, and the replay-comparable stats."""
        return {
            "jobs": {
                job_id: {
                    "idx": tj.idx,
                    "files": [{
                        "path": tf.path, "kind": tf.kind,
                        "offset": tf.offset, "events": tf.events,
                        "dead": tf.dead,
                        "corrupt_counted": tf.corrupt_counted,
                    } for tf in tj.files],
                } for job_id, tj in self._jobs.items()
            },
            "stats": self.stats,
            "finished": self._finished,
        }

    def load_state(self, state: dict) -> None:
        """Inverse of :meth:`state_dict` on a fresh tailer over the same
        directory: tailing resumes exactly at the recorded offsets (only
        the suffix past them is ever decoded again), and the restored
        stats continue the uninterrupted run's accounting."""
        self._jobs = {}
        for job_id, js in state["jobs"].items():
            tj = self._jobs[job_id] = _TailJob()
            tj.idx = int(js["idx"])
            for fs in js["files"]:
                tf = _TailFile(fs["path"], fs["kind"])
                tf.offset = int(fs["offset"])
                tf.events = int(fs["events"])
                tf.dead = bool(fs["dead"])
                tf.corrupt_counted = bool(fs["corrupt_counted"])
                tj.known.add(tf.path)
                tj.files.append(tf)
        self.stats = state["stats"]
        self._finished = bool(state["finished"])

    def run(self, stop: threading.Event, poll_s: float = 0.05) -> None:
        """Thread body: poll until ``stop`` is set, then ``finish()``."""
        while not stop.is_set():
            self.poll_once()
            stop.wait(poll_s)
        self.finish()

    @property
    def jobs(self) -> list[str]:
        return sorted(self._jobs)
