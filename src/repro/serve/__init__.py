"""Live fleet service: long-lived ingest + query over the fleet engine.

  * :mod:`repro.serve.protocol` — FLW length-prefixed FCS frame format
    (HELLO / BATCH / BYE) with torn/corrupt-frame detection;
  * :mod:`repro.serve.client` — ``LiveClient`` (explicit, raising) and
    ``LiveBatchSink`` (the daemon's never-blocking counted-drop sink);
  * :mod:`repro.serve.tail` — ``FileTailer`` following growing/rotating
    spill directories, segment boundaries as commit points;
  * :mod:`repro.serve.service` — ``FleetService``: socket + tail
    ingestion planes over an inline or process-sharded engine, live
    cross-job frontier resolution, graceful join/leave;
  * :mod:`repro.serve.query` — the stdlib-HTTP query plane
    (``/anomalies``, ``/weather``, ``/telemetry``, ``/jobs``,
    byte-budgeted ``/archive/*``).

See ``src/repro/serve/README.md`` for the wire protocol and the
determinism contract.
"""
from repro.serve.client import LiveBatchSink, LiveClient, parse_endpoint
from repro.serve.protocol import (FRAME_BATCH, FRAME_BYE, FRAME_HELLO,
                                  ProtocolError, batch_frame, bye_frame,
                                  encode_frame, hello_frame, parse_hello,
                                  read_frame)
from repro.serve.query import QueryServer, fleet_anomaly_dict
from repro.serve.service import FleetService, ServiceConfig
from repro.serve.tail import FileTailer

__all__ = [
    "FleetService", "ServiceConfig", "FileTailer", "QueryServer",
    "LiveClient", "LiveBatchSink", "parse_endpoint", "ProtocolError",
    "FRAME_HELLO", "FRAME_BATCH", "FRAME_BYE", "encode_frame",
    "hello_frame", "batch_frame", "bye_frame", "read_frame",
    "parse_hello", "fleet_anomaly_dict",
]
