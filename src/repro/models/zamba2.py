"""Zamba2-style hybrid: Mamba2 backbone + ONE weight-shared attention block
applied after every ``attn_every`` SSM layers.

The shared block's weights are a single (non-scanned) copy; each application
keeps its own KV cache during serving.  Simplification vs. the released
Zamba2 (noted in DESIGN.md): we use the hidden state directly as the shared
block input rather than concat(hidden, embedding) + per-application LoRA.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models import mamba2 as M


def _stack_init(fn, rng, n):
    return jax.vmap(fn)(jax.random.split(rng, n))


@dataclass
class Zamba2LM:
    cfg: ModelConfig
    policy: L.Policy = field(default_factory=L.Policy)
    constrain: L.Constrain = L.null_constrain
    mesh: Any = None
    attn_impl: str = "auto"
    remat: str = "none"
    fold_depth: int = 4

    @property
    def n_groups(self) -> int:
        return self.cfg.num_layers // self.cfg.attn_every

    def init(self, rng) -> dict:
        cfg, pd = self.cfg, self.policy.param_dtype
        ks = jax.random.split(rng, 5)
        g, per = self.n_groups, cfg.attn_every

        def mamba_layer(k):
            return {"ln": L.rmsnorm_init(cfg.d_model, pd),
                    "mamba": M.mamba_init(k, cfg, pd)}

        params = {
            "embed": L.embed_init(ks[0], cfg.vocab_size, cfg.d_model, pd),
            "final_norm": L.rmsnorm_init(cfg.d_model, pd),
            "head": L.head_init(ks[1], cfg.d_model, cfg.vocab_size, pd),
            "layers": _stack_init(
                lambda k: _stack_init(mamba_layer, k, per), ks[2], g),
            "shared_attn": {
                "ln1": L.rmsnorm_init(cfg.d_model, pd),
                "ln2": L.rmsnorm_init(cfg.d_model, pd),
                "attn": attn_lib.attention_init(
                    ks[3], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                    cfg.head_dim, pd),
                "mlp": L.mlp_init(ks[4], cfg.d_model, cfg.d_ff, pd),
            },
        }
        return params

    def _maybe_remat(self, fn):
        if self.remat == "full":
            return jax.checkpoint(fn)
        if self.remat == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
        return fn

    def _shared_block(self, sp, x, positions, cache=None, pos=None):
        cfg = self.cfg
        h = L.rmsnorm(sp["ln1"], x, cfg.norm_eps)
        q, k, v = attn_lib.project_qkv(
            sp["attn"], h, positions=positions, rope_theta=cfg.rope_theta,
            constrain=self.constrain)
        if cache is None:
            o = attn_lib.attention(q, k, v, causal=True, impl=self.attn_impl,
                                   fold_depth=self.fold_depth)
            new_kv = (k, v)
        else:
            kc, vc = cache
            kc = jax.lax.dynamic_update_slice_in_dim(kc, k, pos, 1)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, v, pos, 1)
            o = attn_lib.decode_attention(q, kc, vc, pos)
            new_kv = (kc, vc)
        x = x + attn_lib.project_out(sp["attn"], o, self.constrain)
        h = L.rmsnorm(sp["ln2"], x, cfg.norm_eps)
        x = x + L.mlp_apply(sp["mlp"], h, self.constrain)
        return self.constrain(x, ("batch", "seq", "embed")), new_kv

    def _head_out(self, params, x):
        x = L.rmsnorm(params["final_norm"], x, self.cfg.norm_eps)
        return L.head_apply(params["head"], x)

    # ------------------------------------------------------------------ #
    def apply(self, params, tokens, vision_embeds=None, collect_kv=False,
              q_offset=0):
        cfg = self.cfg
        cd = self.policy.compute_dtype
        B, S = tokens.shape
        x = L.embed_apply(params["embed"], tokens, cd)
        x = self.constrain(x, ("batch", "seq", "embed"))
        positions = jnp.arange(S)[None, :] + q_offset
        sp = params["shared_attn"]

        def group(x, gp):
            def inner(x, lp):
                h = L.rmsnorm(lp["ln"], x, cfg.norm_eps)
                return x + M.mamba_apply(lp["mamba"], h, cfg, self.constrain), None
            x, _ = jax.lax.scan(inner, x, gp)
            x, kv = self._shared_block(sp, x, positions)
            return x, kv

        group = self._maybe_remat(group)
        x, kvs = jax.lax.scan(group, x, params["layers"])
        logits = self._head_out(params, x)
        logits = self.constrain(logits, ("batch", "seq", "vocab"))
        if collect_kv:
            return logits, {"shared": kvs}, jnp.zeros((), jnp.float32)
        return logits, jnp.zeros((), jnp.float32)

    def loss(self, params, batch, vision_embeds=None):
        logits, _ = self.apply(params, batch["tokens"])
        ce = L.cross_entropy(logits, batch["labels"])
        return ce, {"ce": ce}

    # ------------------------------------------------------------------ #
    def init_cache(self, batch: int, max_seq: int) -> dict:
        cfg = self.cfg
        cd = self.policy.compute_dtype
        g, per = self.n_groups, cfg.attn_every
        di, n = cfg.d_inner, cfg.ssm_state
        return {
            "state": jnp.zeros(
                (g, per, batch, cfg.ssm_heads, cfg.ssm_head_dim, n),
                jnp.float32),
            "conv": jnp.zeros(
                (g, per, batch, cfg.conv_width - 1, di + 2 * n), cd),
            "k": jnp.zeros((g, batch, max_seq, cfg.num_kv_heads,
                            cfg.head_dim), cd),
            "v": jnp.zeros((g, batch, max_seq, cfg.num_kv_heads,
                            cfg.head_dim), cd),
        }

    def prefill(self, params, tokens, cache, vision_embeds=None):
        cfg = self.cfg
        cd = self.policy.compute_dtype
        B, S = tokens.shape
        x = L.embed_apply(params["embed"], tokens, cd)
        positions = jnp.arange(S)[None, :]
        sp = params["shared_attn"]

        def group(x, gp):
            def inner(x, lp):
                h = L.rmsnorm(lp["ln"], x, cfg.norm_eps)
                out, c = M.mamba_apply(lp["mamba"], h, cfg, self.constrain,
                                       return_state=True)
                return x + out, c
            x, caches = jax.lax.scan(inner, x, gp)
            x, kv = self._shared_block(sp, x, positions)
            return x, (caches, kv)

        x, (mcaches, kvs) = jax.lax.scan(group, x, params["layers"])
        logits = self._head_out(params, x)
        k, v = kvs
        new_cache = dict(cache)
        new_cache["state"] = mcaches["state"]
        new_cache["conv"] = mcaches["conv"].astype(cd)
        new_cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cd), 0, 2)
        new_cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cd), 0, 2)
        return logits[:, -1], new_cache

    def decode_step(self, params, token, cache, pos):
        cfg = self.cfg
        cd = self.policy.compute_dtype
        x = L.embed_apply(params["embed"], token, cd)
        positions = jnp.full((token.shape[0], 1), pos, jnp.int32)
        sp = params["shared_attn"]

        def group(x, xs):
            gp, st, cv, kc, vc = xs

            def inner(x, ys):
                lp, sti, cvi = ys
                h = L.rmsnorm(lp["ln"], x, cfg.norm_eps)
                out, c = M.mamba_decode_step(
                    lp["mamba"], h, {"state": sti, "conv": cvi}, cfg,
                    self.constrain)
                return x + out, (c["state"], c["conv"])

            x, (st2, cv2) = jax.lax.scan(inner, x, (gp, st, cv))
            x, (k2, v2) = self._shared_block(sp, x, positions,
                                             cache=(kc, vc), pos=pos)
            return x, (st2, cv2, k2, v2)

        x, (st, cv, k2, v2) = jax.lax.scan(
            group, x, (params["layers"], cache["state"], cache["conv"],
                       cache["k"], cache["v"]))
        logits = self._head_out(params, x)
        return logits[:, 0], {"state": st, "conv": cv, "k": k2, "v": v2}
