"""Model registry: ModelConfig -> model instance, plus input-spec stubs."""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models.layers import Constrain, Policy, null_constrain


def build_model(cfg: ModelConfig, *, policy: Policy | None = None,
                constrain: Constrain = null_constrain, mesh: Any = None,
                attn_impl: str = "auto", remat: str = "none",
                fold_depth: int = 4):
    """Instantiate the right family for a config."""
    policy = policy or Policy()
    kw = dict(cfg=cfg, policy=policy, constrain=constrain, mesh=mesh,
              attn_impl=attn_impl, remat=remat, fold_depth=fold_depth)
    if cfg.family == "ssm":
        from repro.models.ssm_lm import MambaLM
        return MambaLM(**kw)
    if cfg.family == "hybrid":
        from repro.models.zamba2 import Zamba2LM
        return Zamba2LM(**kw)
    # dense / moe / audio / vlm all share TransformerLM
    from repro.models.transformer import TransformerLM
    return TransformerLM(**kw)


def modality_inputs(cfg: ModelConfig, batch: int, compute_dtype=jnp.bfloat16):
    """Shapes of stubbed modality-frontend inputs (assignment: frontends are
    stubs providing precomputed patch/frame embeddings)."""
    if cfg.family == "vlm":
        return {"vision_embeds": (batch, cfg.vision_tokens, cfg.vision_d)}
    return {}
