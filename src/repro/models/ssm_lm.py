"""Pure-SSM (Mamba2) language model: embed -> N x (norm + SSD block) -> head."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M


def _stack_init(fn, rng, n, *args):
    return jax.vmap(lambda k: fn(k, *args))(jax.random.split(rng, n))


@dataclass
class MambaLM:
    cfg: ModelConfig
    policy: L.Policy = field(default_factory=L.Policy)
    constrain: L.Constrain = L.null_constrain
    mesh: Any = None
    attn_impl: str = "auto"  # unused (attention-free)
    remat: str = "none"
    fold_depth: int = 4

    def init(self, rng) -> dict:
        cfg, pd = self.cfg, self.policy.param_dtype
        ks = jax.random.split(rng, 3)
        params = {
            "embed": L.embed_init(ks[0], cfg.vocab_size, cfg.d_model, pd),
            "final_norm": L.rmsnorm_init(cfg.d_model, pd),
            "layers": _stack_init(
                lambda k: {"ln": L.rmsnorm_init(cfg.d_model, pd),
                           "mamba": M.mamba_init(k, cfg, pd)},
                ks[1], cfg.num_layers),
        }
        if not cfg.tie_embeddings:
            params["head"] = L.head_init(ks[2], cfg.d_model, cfg.vocab_size, pd)
        return params

    def _maybe_remat(self, fn):
        if self.remat == "full":
            return jax.checkpoint(fn)
        if self.remat == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
        return fn

    def _head(self, params, x):
        x = L.rmsnorm(params["final_norm"], x, self.cfg.norm_eps)
        if self.cfg.tie_embeddings:
            return L.tied_head_apply(params["embed"], x)
        return L.head_apply(params["head"], x)

    def apply(self, params, tokens, vision_embeds=None, collect_kv=False,
              q_offset=0):
        cfg = self.cfg
        cd = self.policy.compute_dtype
        x = L.embed_apply(params["embed"], tokens, cd)
        x = self.constrain(x, ("batch", "seq", "embed"))

        def body(x, lp):
            h = L.rmsnorm(lp["ln"], x, cfg.norm_eps)
            x = x + M.mamba_apply(lp["mamba"], h, cfg, self.constrain)
            return x, None

        body = self._maybe_remat(body)
        x, _ = jax.lax.scan(body, x, params["layers"])
        logits = self._head(params, x)
        logits = self.constrain(logits, ("batch", "seq", "vocab"))
        if collect_kv:
            return logits, {}, jnp.zeros((), jnp.float32)
        return logits, jnp.zeros((), jnp.float32)

    def loss(self, params, batch, vision_embeds=None):
        logits, _ = self.apply(params, batch["tokens"])
        ce = L.cross_entropy(logits, batch["labels"])
        return ce, {"ce": ce}

    # ------------------------------------------------------------------ #
    def init_cache(self, batch: int, max_seq: int) -> dict:
        cfg = self.cfg
        cd = self.policy.compute_dtype
        di, n = cfg.d_inner, cfg.ssm_state
        return {
            "state": jnp.zeros(
                (cfg.num_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim, n),
                jnp.float32),
            "conv": jnp.zeros(
                (cfg.num_layers, batch, cfg.conv_width - 1, di + 2 * n), cd),
        }

    def prefill(self, params, tokens, cache, vision_embeds=None):
        cfg = self.cfg
        cd = self.policy.compute_dtype
        x = L.embed_apply(params["embed"], tokens, cd)

        def body(x, lp):
            h = L.rmsnorm(lp["ln"], x, cfg.norm_eps)
            out, c = M.mamba_apply(lp["mamba"], h, cfg, self.constrain,
                                   return_state=True)
            return x + out, c

        x, caches = jax.lax.scan(body, x, params["layers"])
        logits = self._head(params, x)
        new_cache = {"state": caches["state"],
                     "conv": caches["conv"].astype(cd)}
        return logits[:, -1], new_cache

    def decode_step(self, params, token, cache, pos):
        cfg = self.cfg
        cd = self.policy.compute_dtype
        x = L.embed_apply(params["embed"], token, cd)

        def body(x, xs):
            lp, st, cv = xs
            h = L.rmsnorm(lp["ln"], x, cfg.norm_eps)
            out, c = M.mamba_decode_step(lp["mamba"], h,
                                         {"state": st, "conv": cv},
                                         cfg, self.constrain)
            return x + out, (c["state"], c["conv"])

        x, (st, cv) = jax.lax.scan(
            body, x, (params["layers"], cache["state"], cache["conv"]))
        logits = self._head(params, x)
        return logits[:, 0], {"state": st, "conv": cv}
