"""Shared layers: norms, rotary embeddings, SwiGLU MLP, dtype policy."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

Constrain = Callable[[jax.Array, tuple], jax.Array]


def null_constrain(x: jax.Array, axes: tuple) -> jax.Array:  # noqa: ARG001
    return x


@dataclass(frozen=True)
class Policy:
    """Mixed-precision policy: storage vs compute dtype."""

    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.bfloat16

    def cast(self, tree):
        return jax.tree.map(
            lambda x: x.astype(self.compute_dtype)
            if isinstance(x, jax.Array) and jnp.issubdtype(x.dtype, jnp.floating)
            else x,
            tree,
        )


def normal_init(rng, shape, stddev, dtype):
    return (jax.random.normal(rng, shape, jnp.float32) * stddev).astype(dtype)


# --------------------------------------------------------------------------- #
# RMSNorm
# --------------------------------------------------------------------------- #
def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def gated_rmsnorm(params: dict, x: jax.Array, z: jax.Array, eps: float = 1e-5):
    """Mamba2-style norm: RMSNorm(x * silu(z))."""
    return rmsnorm(params, x * jax.nn.silu(z.astype(x.dtype)), eps)


# --------------------------------------------------------------------------- #
# Rotary position embeddings
# --------------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: [..., S] (int)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., :, None, :]  # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# SwiGLU MLP
# --------------------------------------------------------------------------- #
def mlp_init(rng, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    return {
        "wi_gate": normal_init(k1, (d_model, d_ff), s_in, dtype),
        "wi_up": normal_init(k2, (d_model, d_ff), s_in, dtype),
        "wo": normal_init(k3, (d_ff, d_model), s_out, dtype),
    }


def mlp_apply(params: dict, x: jax.Array, constrain: Constrain = null_constrain):
    # preferred_element_type pins dot OUTPUTS to the compute dtype so the
    # TP psum that follows moves bf16, not f32 (the MXU still accumulates
    # in f32 internally) — halves collective wire bytes at 405B scale.
    dt = x.dtype
    gate = jnp.einsum("...d,df->...f", x, params["wi_gate"].astype(dt),
                      preferred_element_type=dt)
    up = jnp.einsum("...d,df->...f", x, params["wi_up"].astype(dt),
                    preferred_element_type=dt)
    h = jax.nn.silu(gate) * up
    h = constrain(h, ("batch", "seq", "ff"))
    return jnp.einsum("...f,fd->...d", h, params["wo"].astype(dt),
                      preferred_element_type=dt)


# --------------------------------------------------------------------------- #
# Embedding / LM head
# --------------------------------------------------------------------------- #
def embed_init(rng, vocab: int, d_model: int, dtype) -> dict:
    return {"embedding": normal_init(rng, (vocab, d_model), 1.0, dtype)}


def embed_apply(params: dict, tokens: jax.Array, compute_dtype) -> jax.Array:
    return params["embedding"][tokens].astype(compute_dtype)


def head_init(rng, d_model: int, vocab: int, dtype) -> dict:
    return {"w": normal_init(rng, (d_model, vocab), d_model ** -0.5, dtype)}


def head_apply(params: dict, x: jax.Array) -> jax.Array:
    return jnp.einsum("...d,dv->...v", x, params["w"].astype(x.dtype))


def tied_head_apply(embed_params: dict, x: jax.Array) -> jax.Array:
    w = embed_params["embedding"].astype(x.dtype)
    return jnp.einsum("...d,vd->...v", x, w)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token CE. logits [..., V] fp32-upcast; labels int [...].

    The label pick uses a one-hot contraction, NOT take_along_axis: a gather
    along a model-sharded vocab axis makes GSPMD all-gather the full logits
    (hundreds of GiB/device at 405B scale); the one-hot contraction
    partitions cleanly."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    V = logits.shape[-1]
    onehot = labels[..., None] == jnp.arange(V, dtype=labels.dtype)
    ll = jnp.sum(logits * onehot, axis=-1)
    return jnp.mean(lse - ll)
