"""Mixture-of-Experts FF with sort-based (dropping) dispatch.

Design notes
------------
Dispatch is **sort-based** rather than GShard one-hot-einsum: the one-hot
dispatch matmul adds O(T*k*cf*S_g*D) fake FLOPs to the compiled HLO, which
would poison the roofline compute term (and real TPU time).  Sort+scatter
dispatch keeps HLO FLOPs ≈ active-expert FLOPs.

Expert parallelism: experts are sharded over the ``model`` mesh axis.  The
layer is wrapped in ``shard_map`` over that axis; each shard dispatches the
(model-replicated) token block to its local experts and the shard outputs
are combined with one ``psum`` — the same collective volume as a Megatron
TP FF.  (The all-to-all EP variant is a §Perf hillclimb option.)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.compat import shard_map

from repro.configs import ModelConfig
from repro.models.layers import Constrain, normal_init, null_constrain


def moe_init(rng, cfg: ModelConfig, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(rng, 4)
    s_in, s_out = d ** -0.5, f ** -0.5
    return {
        "router": normal_init(ks[0], (d, e), s_in, dtype),
        "wi_gate": normal_init(ks[1], (e, d, f), s_in, dtype),
        "wi_up": normal_init(ks[2], (e, d, f), s_in, dtype),
        "wo": normal_init(ks[3], (e, f, d), s_out, dtype),
    }


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    c = int(tokens * cfg.experts_per_token * cfg.capacity_factor
            // max(cfg.num_experts, 1)) + 1
    return max(c, 4)


def expert_ff_local(x_flat, eids, weights, wi_gate, wi_up, wo,
                    expert_offset: int, capacity: int):
    """Dispatch -> per-expert SwiGLU -> combine, for E_loc local experts.

    x_flat  [T, D]   tokens (model-replicated block)
    eids    [T, k]   global expert ids chosen per token
    weights [T, k]   router combine weights
    wi_*    [E_loc, D, F], wo [E_loc, F, D]
    """
    T, D = x_flat.shape
    k = eids.shape[1]
    E_loc = wi_gate.shape[0]
    C = capacity
    dt = x_flat.dtype

    flat_e = eids.reshape(-1) - expert_offset  # [T*k] local ids
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_w = weights.reshape(-1)
    local = (flat_e >= 0) & (flat_e < E_loc)
    key = jnp.where(local, flat_e, E_loc)  # junk bucket E_loc
    order = jnp.argsort(key, stable=True)
    se, st, sw = key[order], flat_t[order], flat_w[order]
    counts = jnp.bincount(key, length=E_loc + 1)
    offsets = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k) - offsets[se]
    keep = (se < E_loc) & (pos < C)
    dest = jnp.where(keep, se * C + pos, E_loc * C)  # overflow slot

    buf = jnp.zeros((E_loc * C + 1, D), dt)
    buf = buf.at[dest].add(x_flat[st] * keep[:, None].astype(dt))
    buf = buf[: E_loc * C].reshape(E_loc, C, D)

    g = jnp.einsum("ecd,edf->ecf", buf, wi_gate.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", buf, wi_up.astype(dt))
    h = jax.nn.silu(g) * u
    out = jnp.einsum("ecf,efd->ecd", h, wo.astype(dt)).reshape(E_loc * C, D)
    out = jnp.concatenate([out, jnp.zeros((1, D), dt)], axis=0)

    gathered = out[dest] * (sw * keep)[:, None].astype(dt)
    y = jnp.zeros((T, D), dt).at[st].add(gathered)
    return y


def route(params, x_flat, cfg: ModelConfig):
    """Router top-k. Returns (eids [T,k], weights [T,k], aux_loss scalar)."""
    dt = x_flat.dtype
    logits = jnp.einsum("td,de->te", x_flat, params["router"].astype(dt))
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, eids = jax.lax.top_k(probs, cfg.experts_per_token)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balancing aux loss
    E = cfg.num_experts
    frac_tokens = jnp.mean(
        jax.nn.one_hot(eids[:, 0], E, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return eids, w.astype(dt), aux


def moe_apply(params, x, cfg: ModelConfig, mesh=None, model_axis="model",
              constrain: Constrain = null_constrain):
    """x [B,S,D] -> ([B,S,D], aux_loss). Experts sharded over `model_axis`
    when a mesh is provided; pure local computation otherwise."""
    B, S, D = x.shape
    x_flat = x.reshape(B * S, D)
    eids, w, aux = route(params, x_flat, cfg)
    C = _capacity(B * S, cfg)

    if mesh is None or model_axis not in getattr(mesh, "axis_names", ()):
        y = expert_ff_local(x_flat, eids, w, params["wi_gate"],
                            params["wi_up"], params["wo"], 0, C)
        return y.reshape(B, S, D), aux

    from jax.sharding import PartitionSpec as P

    n_shards = mesh.shape[model_axis]
    E_loc = cfg.num_experts // n_shards
    dp_axes = tuple(a for a in mesh.axis_names if a != model_axis)
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    C = _capacity((B * S) // dp, cfg)  # capacity per data shard

    def shard_fn(xf, ei, wi, wg, wu, wo):
        shard = jax.lax.axis_index(model_axis)
        y = expert_ff_local(xf, ei, wi, wg, wu, wo, shard * E_loc, C)
        return jax.lax.psum(y, model_axis)

    y = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(dp_axes), P(dp_axes), P(dp_axes),
                  P(model_axis), P(model_axis), P(model_axis)),
        out_specs=P(dp_axes),
        check_vma=False,
    )(x_flat, eids, w, params["wi_gate"], params["wi_up"], params["wo"])
    return y.reshape(B, S, D), aux
