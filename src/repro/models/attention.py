"""GQA attention: direct, chunked (flash-style in XLA), folded-causal, decode.

Layouts: q [B,S,H,hd], k/v [B,T,KV,hd].  GQA groups G = H // KV.
``chunked_attention`` is the memory-bounded train/prefill path (online
softmax over KV chunks, optional Q chunking).  ``folded_causal_attention``
is the beyond-paper FLOP-reduction path (recursive causality folding: the
upper-triangular blocks are never materialized, cutting HLO FLOPs toward the
causal-optimal S^2/2).  ``decode_attention`` is the single-token path.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.parallel.compat import shard_map

from repro.models.layers import Constrain, apply_rope, normal_init, null_constrain

NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# Parameter init / projections
# --------------------------------------------------------------------------- #
def attention_init(rng, d_model, num_heads, num_kv_heads, head_dim, dtype,
                   qkv_bias=False, with_gate=False) -> dict:
    ks = jax.random.split(rng, 5)
    s = d_model ** -0.5
    p = {
        "wq": normal_init(ks[0], (d_model, num_heads, head_dim), s, dtype),
        "wk": normal_init(ks[1], (d_model, num_kv_heads, head_dim), s, dtype),
        "wv": normal_init(ks[2], (d_model, num_kv_heads, head_dim), s, dtype),
        "wo": normal_init(ks[3], (num_heads, head_dim, d_model),
                          (num_heads * head_dim) ** -0.5, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((num_heads, head_dim), dtype)
        p["bk"] = jnp.zeros((num_kv_heads, head_dim), dtype)
        p["bv"] = jnp.zeros((num_kv_heads, head_dim), dtype)
    if with_gate:  # llama3.2-vision cross-attn tanh gate
        p["gate"] = jnp.zeros((), dtype)
    return p


def project_qkv(params, x, kv_x=None, positions=None, rope_theta=None,
                constrain: Constrain = null_constrain):
    """Returns q [B,S,H,hd], k/v [B,T,KV,hd]; applies RoPE if positions given."""
    dt = x.dtype
    kv_x = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("btd,dhk->bthk", kv_x, params["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", kv_x, params["wv"].astype(dt))
    if "bq" in params:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    if positions is not None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    # q keeps the "seq" axis: with sequence parallelism and non-16-divisible
    # head counts (e.g. arctic's 56) the model axis lands on q's sequence
    # dim -> context-parallel attention (each shard owns 1/16 of the rows).
    # k/v must NEVER shard on seq: every q row needs every k/v row, and a
    # seq-sharded K under a heads-sharded Q forces GSPMD into involuntary
    # full rematerialization (measured: 17 TB/step of all-gathers at 405B).
    q = constrain(q, ("batch", "seq", "heads", None))
    k = constrain(k, ("batch", None, "kv_heads", None))
    v = constrain(v, ("batch", None, "kv_heads", None))
    return q, k, v


def project_out(params, o, constrain: Constrain = null_constrain):
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(o.dtype),
                     preferred_element_type=o.dtype)
    return constrain(out, ("batch", "seq", "embed"))


# --------------------------------------------------------------------------- #
# Direct attention (small shapes / oracle)
# --------------------------------------------------------------------------- #
def direct_attention(q, k, v, causal=True, q_offset=0):
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    if causal:
        qpos = jnp.arange(S) + q_offset
        mask = qpos[:, None] >= jnp.arange(T)[None, :]
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return o.reshape(B, S, H, hd)


# --------------------------------------------------------------------------- #
# Chunked (flash-style) attention — the XLA train/prefill workhorse
# --------------------------------------------------------------------------- #
def _chunk_scan(q, k, v, causal, qpos, kv_chunk, return_stats=False):
    """Online-softmax scan over KV chunks for one q-block.

    q: [B,Sq,KV,G,hd]; qpos: f32 [Sq] global row positions (an ARRAY so it
    stays valid when traced, e.g. under shard_map context parallelism)."""
    B, Sq, KV, G, hd = q.shape
    T = k.shape[1]
    n_chunks = T // kv_chunk
    kc = k.reshape(B, n_chunks, kv_chunk, KV, hd)
    vc = v.reshape(B, n_chunks, kv_chunk, KV, hd)
    scale = hd ** -0.5

    def body(carry, inputs):
        o, m, l = carry
        j, kj, vj = inputs
        s = jnp.einsum("bskgh,btkh->bkgst", q, kj).astype(jnp.float32) * scale
        if causal:
            kpos = (jnp.arange(kv_chunk) + j * kv_chunk).astype(jnp.float32)
            mask = qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkh->bkgsh", p.astype(q.dtype), vj)
        o_new = o * alpha[..., None].astype(o.dtype) + pv
        return (o_new, m_new, l_new), None

    o0 = jnp.zeros((B, KV, G, Sq, hd), q.dtype)
    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    (o, m, l), _ = jax.lax.scan(
        body, (o0, m0, l0), (jnp.arange(n_chunks), kc.swapaxes(0, 1), vc.swapaxes(0, 1)))
    o = o / jnp.maximum(l, 1e-30)[..., None].astype(o.dtype)
    o = o.transpose(0, 3, 1, 2, 4)  # [B,Sq,KV,G,hd]
    if return_stats:
        lse = m + jnp.log(jnp.maximum(l, 1e-30))  # [B,KV,G,Sq]
        return o, lse
    return o


def _flash_fwd(qg, k, v, qpos, causal, q_chunk, kv_chunk):
    B, S, KV, G, hd = qg.shape
    nq = max(S // q_chunk, 1)
    if S % q_chunk:
        nq, q_chunk = 1, S
    qs = qg.reshape(B, nq, q_chunk, KV, G, hd).swapaxes(0, 1)
    qps = qpos.reshape(nq, q_chunk)

    def one_q(args):
        qb, qp = args
        return _chunk_scan(qb, k, v, causal, qp, kv_chunk,
                           return_stats=True)

    o, lse = jax.lax.map(one_q, (qs, qps))
    # o: [nq, B, bq, KV, G, hd]; lse: [nq, B, KV, G, bq]
    o = o.swapaxes(0, 1).reshape(B, S, KV, G, hd)
    lse = lse.transpose(1, 2, 3, 0, 4).reshape(B, KV, G, S)
    return o, lse


def _flash_bwd_body(q, k, v, o, do, lse, qpos, causal, kv_chunk):
    """Recompute-based backward for one q block. Shapes:
    q/o/do [B,bq,KV,G,hd]; lse [B,KV,G,bq]; k/v [B,T,KV,hd]; qpos [bq]."""
    B, bq, KV, G, hd = q.shape
    T = k.shape[1]
    scale = hd ** -0.5
    nkv = T // kv_chunk
    kc = k.reshape(B, nkv, kv_chunk, KV, hd).swapaxes(0, 1)
    vc = v.reshape(B, nkv, kv_chunk, KV, hd).swapaxes(0, 1)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1)  # [B,bq,KV,G]
    delta = delta.transpose(0, 2, 3, 1)  # [B,KV,G,bq]

    def body(dq, xs):
        j, kj, vj = xs
        s = jnp.einsum("bskgh,btkh->bkgst", q, kj).astype(jnp.float32) * scale
        if causal:
            kpos = (jnp.arange(kv_chunk) + j * kv_chunk).astype(jnp.float32)
            mask = qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])  # [B,KV,G,bq,bk]
        dp = jnp.einsum("bskgh,btkh->bkgst",
                        do, vj).astype(jnp.float32)
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bkgst,btkh->bskgh", ds.astype(q.dtype), kj)
        dkj = jnp.einsum("bkgst,bskgh->btkh", ds.astype(q.dtype), q)
        dvj = jnp.einsum("bkgst,bskgh->btkh", p.astype(q.dtype), do)
        return dq, (dkj, dvj)

    dq0 = jnp.zeros_like(q)
    dq, (dk_c, dv_c) = jax.lax.scan(body, dq0, (jnp.arange(nkv), kc, vc))
    dk = dk_c.swapaxes(0, 1).reshape(B, T, KV, hd)
    dv = dv_c.swapaxes(0, 1).reshape(B, T, KV, hd)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _flash_attention_xla(q, k, v, qpos, causal, q_chunk, kv_chunk):
    o, _ = _flash_fwd(q, k, v, qpos, causal, q_chunk, kv_chunk)
    return o


def _flash_attention_xla_fwd(q, k, v, qpos, causal, q_chunk, kv_chunk):
    o, lse = _flash_fwd(q, k, v, qpos, causal, q_chunk, kv_chunk)
    return o, (q, k, v, qpos, o, lse)


def _flash_attention_xla_bwd(causal, q_chunk, kv_chunk, res, do_):
    q, k, v, qpos, o, lse = res  # q/o/do_ [B,S,KV,G,hd]; lse [B,KV,G,S]
    B, S, KV, G, hd = q.shape
    nq = max(S // q_chunk, 1)
    if S % q_chunk:
        nq = 1
    bq = S // nq
    qs = q.reshape(B, nq, bq, KV, G, hd).swapaxes(0, 1)
    os_ = o.reshape(B, nq, bq, KV, G, hd).swapaxes(0, 1)
    dos = do_.reshape(B, nq, bq, KV, G, hd).swapaxes(0, 1)
    lses = lse.reshape(B, KV, G, nq, bq).transpose(3, 0, 1, 2, 4)
    qps = qpos.reshape(nq, bq)

    def one_q(args):
        qb, ob, dob, lseb, qp = args
        return _flash_bwd_body(qb, k, v, ob, dob, lseb, qp, causal, kv_chunk)

    dq, dk, dv = jax.lax.map(one_q, (qs, os_, dos, lses, qps))
    dq = dq.swapaxes(0, 1).reshape(B, S, KV, G, hd)
    dk = jnp.sum(dk, axis=0)
    dv = jnp.sum(dv, axis=0)
    return dq, dk, dv, jnp.zeros_like(qpos)


_flash_attention_xla.defvjp(_flash_attention_xla_fwd, _flash_attention_xla_bwd)


def chunked_attention(q, k, v, causal=True, q_offset=0,
                      q_chunk=1024, kv_chunk=512):
    """Memory-bounded flash-style attention with a recompute backward.

    Residuals are only (q, k, v, o, lse) — scores are recomputed per chunk
    in the VJP, so train-time memory is O(S) not O(S^2) (the XLA analogue
    of the flash-attention backward; see kernels/flash_attention for the
    Pallas TPU version).  q_offset may be a traced scalar (context
    parallelism passes the per-shard row offset)."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    kv_chunk = min(kv_chunk, T)
    if T % kv_chunk:
        kv_chunk = T
    q_chunk = min(q_chunk, S)
    qpos = (jnp.arange(S) + q_offset).astype(jnp.float32)
    og = _flash_attention_xla(q.reshape(B, S, KV, G, hd), k, v, qpos,
                              causal, q_chunk, kv_chunk)
    return og.reshape(B, S, H, hd)


# --------------------------------------------------------------------------- #
# Folded-causal attention (beyond-paper perf path)
# --------------------------------------------------------------------------- #
# Causal attention over S splits as:
#   Q_lo  ->  causal(K_lo)                       (recurse)
#   Q_hi  ->  full(K_lo)  merged with  causal(K_hi)  (recurse)
# Each fold level removes the strictly-upper quadrant from the compiled HLO,
# converging to the causal-optimal S^2/2 FLOPs with `depth` levels.
def _merge_partials(o1, m1, l1, o2, m2, l2):
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)
    a2 = jnp.exp(m2 - m)
    l = l1 * a1 + l2 * a2
    o = o1 * a1[..., None].astype(o1.dtype) + o2 * a2[..., None].astype(o2.dtype)
    return o, m, l


def _full_partial(q, k, v):
    """Unmasked attention partials. q [B,S,KV,G,hd] -> (o, m, l) unnormalized."""
    hd = q.shape[-1]
    s = jnp.einsum("bskgh,btkh->bkgst", q, k).astype(jnp.float32) * (hd ** -0.5)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgst,btkh->bkgsh", p.astype(q.dtype), v)
    return o, m, l


def _causal_partial(q, k, v, depth):
    B, S, KV, G, hd = q.shape
    if depth <= 0 or S % 2 or S < 256:
        s = jnp.einsum("bskgh,btkh->bkgst", q, k).astype(jnp.float32) * (hd ** -0.5)
        mask = jnp.arange(S)[:, None] >= jnp.arange(S)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m = jnp.max(s, axis=-1)
        p = jnp.exp(s - m[..., None])
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bkgst,btkh->bkgsh", p.astype(q.dtype), v)
        return o, m, l
    h = S // 2
    q_lo, q_hi = q[:, :h], q[:, h:]
    k_lo, k_hi = k[:, :h], k[:, h:]
    v_lo, v_hi = v[:, :h], v[:, h:]
    o_lo, m_lo, l_lo = _causal_partial(q_lo, k_lo, v_lo, depth - 1)
    o_f, m_f, l_f = _full_partial(q_hi, k_lo, v_lo)
    o_c, m_c, l_c = _causal_partial(q_hi, k_hi, v_hi, depth - 1)
    o_hi, m_hi, l_hi = _merge_partials(o_f, m_f, l_f, o_c, m_c, l_c)
    o = jnp.concatenate([o_lo, o_hi], axis=3)  # seq axis of [B,KV,G,S,hd]
    m = jnp.concatenate([m_lo, m_hi], axis=3)
    l = jnp.concatenate([l_lo, l_hi], axis=3)
    return o, m, l


def folded_causal_attention(q, k, v, depth=4):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    qg = q.reshape(B, S, KV, H // KV, hd)
    o, _, l = _causal_partial(qg, k, v, depth)
    o = o / jnp.maximum(l, 1e-30)[..., None].astype(o.dtype)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd)


# --------------------------------------------------------------------------- #
# Context-parallel attention (shard_map over the model axis)
# --------------------------------------------------------------------------- #
def context_parallel_attention(q, k, v, mesh, *, causal=True, q_offset=0,
                               q_chunk=1024, kv_chunk=512,
                               model_axis="model"):
    """Shard q ROWS over the model axis; k/v replicated per shard.

    An lax.map over a seq-sharded block axis SERIALIZES under SPMD (every
    device executes every block), so context parallelism must be expressed
    manually: each model shard computes attention for its 1/M of the query
    rows against the full K/V.  Causality is preserved via per-shard
    q_offset.  Differentiating through shard_map psums the replicated
    k/v cotangents automatically.  Scores memory/traffic drop by M — the
    fix for heads that don't divide the model axis (arctic's 56).
    """
    from jax.sharding import PartitionSpec as P

    M = mesh.shape[model_axis]
    B, S, H, hd = q.shape
    if S % M or (S // M) % 16:
        return chunked_attention(q, k, v, causal, q_offset,
                                 q_chunk=q_chunk, kv_chunk=kv_chunk)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = dp if B % max(
        1, __import__("math").prod(mesh.shape[a] for a in dp)) == 0 else None
    s_loc = S // M

    def body(qb, kb, vb):
        m = jax.lax.axis_index(model_axis)
        off = q_offset + m * s_loc
        return chunked_attention(qb, kb, vb, causal=causal, q_offset=off,
                                 q_chunk=min(q_chunk, s_loc),
                                 kv_chunk=kv_chunk)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, model_axis), P(bspec), P(bspec)),
        out_specs=P(bspec, model_axis),
        check_vma=False,
    )(q, k, v)


# --------------------------------------------------------------------------- #
# Decode (single new token against a KV cache)
# --------------------------------------------------------------------------- #
def decode_attention(q, k_cache, v_cache, pos):
    """q [B,1,H,hd]; caches [B,T,KV,hd]; pos scalar = #valid tokens."""
    B, _, H, hd = q.shape
    T, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,btkh->bkgt", qg, k_cache).astype(jnp.float32)
    s = s * (hd ** -0.5)
    valid = jnp.arange(T)[None, None, None, :] <= pos
    s = jnp.where(valid, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgt,btkh->bkgh", w, v_cache)
    return o.reshape(B, 1, H, hd)


# --------------------------------------------------------------------------- #
# Dispatcher
# --------------------------------------------------------------------------- #
def attention(q, k, v, *, causal=True, q_offset=0, impl="auto", fold_depth=4,
              q_chunk=1024, kv_chunk=512):
    """impl: auto | direct | chunked | folded."""
    S, T = q.shape[1], k.shape[1]
    if impl == "auto":
        if S * T <= 1024 * 1024:
            impl = "direct"
        else:
            impl = "chunked"
    if impl == "direct":
        return direct_attention(q, k, v, causal, q_offset)
    if impl == "folded" and causal and S == T:
        return folded_causal_attention(q, k, v, fold_depth)
    return chunked_attention(q, k, v, causal, q_offset,
                             q_chunk=q_chunk, kv_chunk=kv_chunk)
