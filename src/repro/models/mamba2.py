"""Mamba2 / SSD (state-space duality) blocks.

Chunked SSD algorithm (arXiv:2405.21060): within-chunk quadratic term +
inter-chunk state recurrence, both expressed with einsums + one lax.scan so
the compiled HLO is compact and TPU-friendly.  ``ssd_sequential`` is the
step-by-step recurrence oracle used by tests and the decode path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models.layers import (
    Constrain, gated_rmsnorm, normal_init, null_constrain, rmsnorm_init,
)


# --------------------------------------------------------------------------- #
# Core SSD math (head-dim P, state N). All fp32 internally.
# --------------------------------------------------------------------------- #
def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, initial_state=None):
    """Chunked SSD scan.

    x  [B,L,H,P]   inputs (already head-split)
    dt [B,L,H]     positive step sizes
    A  [H]         negative decay rates
    Bm [B,L,N]     input projections (shared across heads, ngroups=1)
    Cm [B,L,N]     output projections
    Returns (y [B,L,H,P], final_state [B,H,P,N]).
    """
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    if L % Q:
        Q = L
    nc = L // Q
    f32 = jnp.float32

    xc = x.reshape(Bsz, nc, Q, H, P).astype(f32)
    dtc = dt.reshape(Bsz, nc, Q, H).astype(f32)
    Bc = Bm.reshape(Bsz, nc, Q, N).astype(f32)
    Cc = Cm.reshape(Bsz, nc, Q, N).astype(f32)

    dA = dtc * A.astype(f32)[None, None, None, :]  # [B,nc,Q,H], <= 0
    cum = jnp.cumsum(dA, axis=2)  # inclusive within-chunk cumulative decay

    # ---- intra-chunk (quadratic in Q) ---------------------------------- #
    # scores[t,s] = (C_t . B_s) * exp(cum_t - cum_s) * dt_s   for s <= t
    cb = jnp.einsum("bctn,bcsn->bcts", Cc, Bc)  # [B,nc,Q,Q]
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # [B,nc,Q,Q,H]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    scores = cb[..., None] * jnp.where(tri[None, None, :, :, None], decay, 0.0)
    scores = scores * dtc[:, :, None, :, :]  # weight by dt_s
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", scores, xc)

    # ---- chunk states + inter-chunk recurrence -------------------------- #
    # S_c = sum_s exp(cum_last - cum_s) * dt_s * (B_s ⊗ x_s)   [B,H,P,N]
    last = cum[:, :, -1:, :]  # [B,nc,1,H]
    w = jnp.exp(last - cum) * dtc  # [B,nc,Q,H]
    S_c = jnp.einsum("bcsh,bcsn,bcshp->bchpn", w, Bc, xc)
    chunk_decay = jnp.exp(last[:, :, 0, :])  # [B,nc,H]

    def body(S_prev, inputs):
        S_chunk, decay_c = inputs  # [B,H,P,N], [B,H]
        S_next = S_prev * decay_c[:, :, None, None] + S_chunk
        return S_next, S_prev

    S0 = (jnp.zeros((Bsz, H, P, N), f32) if initial_state is None
          else initial_state.astype(f32))
    S_final, S_prevs = jax.lax.scan(
        body, S0, (S_c.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    S_prevs = S_prevs.swapaxes(0, 1)  # [B,nc,H,P,N] state at chunk start

    # y_inter[t] = exp(cum_t) * C_t . S_prev
    y_inter = jnp.einsum("bcth,bctn,bchpn->bcthp", jnp.exp(cum), Cc, S_prevs)

    y = (y_intra + y_inter).reshape(Bsz, L, H, P)
    return y.astype(x.dtype), S_final


def ssd_sequential(x, dt, A, Bm, Cm, initial_state=None):
    """Step-recurrence oracle: S_t = exp(dt_t A) S_{t-1} + dt_t B_t ⊗ x_t."""
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32
    S0 = (jnp.zeros((Bsz, H, P, N), f32) if initial_state is None
          else initial_state.astype(f32))

    def body(S, inputs):
        xt, dtt, Bt, Ct = inputs  # [B,H,P],[B,H],[B,N],[B,N]
        decay = jnp.exp(dtt * A[None, :])  # [B,H]
        S = S * decay[:, :, None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dtt, Bt, xt)
        y = jnp.einsum("bn,bhpn->bhp", Ct, S)
        return S, y

    xs = (x.swapaxes(0, 1).astype(f32), dt.swapaxes(0, 1).astype(f32),
          Bm.swapaxes(0, 1).astype(f32), Cm.swapaxes(0, 1).astype(f32))
    S, ys = jax.lax.scan(body, S0, xs)
    return ys.swapaxes(0, 1).astype(x.dtype), S


def ssd_decode_step(state, xt, dtt, A, Bt, Ct):
    """One-token recurrence. state [B,H,P,N]; returns (y [B,H,P], state)."""
    f32 = jnp.float32
    decay = jnp.exp(dtt.astype(f32) * A.astype(f32)[None, :])
    state = state * decay[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dtt.astype(f32), Bt.astype(f32), xt.astype(f32))
    y = jnp.einsum("bn,bhpn->bhp", Ct.astype(f32), state)
    return y.astype(xt.dtype), state


# --------------------------------------------------------------------------- #
# Depthwise causal conv (width W, small) via shifts
# --------------------------------------------------------------------------- #
def causal_conv(x, w, b, history=None):
    """x [B,L,C]; w [W,C]; b [C]; history [B,W-1,C] or None (zeros)."""
    W = w.shape[0]
    if history is None:
        history = jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([history.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(W))
    return jax.nn.silu(y + b.astype(x.dtype))


# --------------------------------------------------------------------------- #
# Full Mamba2 block
# --------------------------------------------------------------------------- #
def mamba_init(rng, cfg: ModelConfig, dtype) -> dict:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(rng, 8)
    s = d ** -0.5
    conv_dim = di + 2 * n
    return {
        "in_z": normal_init(ks[0], (d, di), s, dtype),
        "in_x": normal_init(ks[1], (d, di), s, dtype),
        "in_B": normal_init(ks[2], (d, n), s, dtype),
        "in_C": normal_init(ks[3], (d, n), s, dtype),
        "in_dt": normal_init(ks[4], (d, h), s, dtype),
        "conv_w": normal_init(ks[5], (cfg.conv_width, conv_dim),
                              cfg.conv_width ** -0.5, dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.linspace(1e-3, 1e-1, h))).astype(dtype),  # softplus^-1 of dt
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(dtype),
        "D": jnp.ones((h,), dtype),
        "norm": rmsnorm_init(di, dtype),
        "out": normal_init(ks[6], (di, d), di ** -0.5, dtype),
    }


def _mamba_project(params, u, constrain: Constrain):
    dt_ = u.dtype
    z = jnp.einsum("bld,dk->blk", u, params["in_z"].astype(dt_))
    xp = jnp.einsum("bld,dk->blk", u, params["in_x"].astype(dt_))
    Bp = jnp.einsum("bld,dn->bln", u, params["in_B"].astype(dt_))
    Cp = jnp.einsum("bld,dn->bln", u, params["in_C"].astype(dt_))
    dt = jnp.einsum("bld,dh->blh", u, params["in_dt"].astype(dt_))
    z = constrain(z, ("batch", "seq", "ff"))
    xp = constrain(xp, ("batch", "seq", "ff"))
    return z, xp, Bp, Cp, dt


def mamba_apply(params, u, cfg: ModelConfig, constrain: Constrain = null_constrain,
                initial_state=None, conv_history=None, return_state=False):
    """u [B,L,D] -> [B,L,D]. Full-sequence (train/prefill) path."""
    B_, L, _ = u.shape
    di, n, h, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xp, Bp, Cp, dt = _mamba_project(params, u, constrain)
    xBC_pre = jnp.concatenate([xp, Bp, Cp], axis=-1)
    xBC = causal_conv(xBC_pre, params["conv_w"], params["conv_b"], conv_history)
    xp, Bp, Cp = jnp.split(xBC, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xp.reshape(B_, L, h, P)
    y, state = ssd_chunked(xh, dt, A, Bp, Cp, cfg.ssm_chunk, initial_state)
    y = y + xh * params["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B_, L, di)
    y = gated_rmsnorm(params["norm"], y, z, cfg.norm_eps)
    out = jnp.einsum("blk,kd->bld", y, params["out"].astype(y.dtype))
    out = constrain(out, ("batch", "seq", "embed"))
    if return_state:
        # conv history is the last W-1 PRE-activation xBC columns
        new_cache = {"state": state, "conv": xBC_pre[:, L - (cfg.conv_width - 1):]}
        return out, new_cache
    return out


def mamba_init_cache(cfg: ModelConfig, batch: int, dtype):
    di, n, h, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    return {
        "state": jnp.zeros((batch, h, P, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di + 2 * n), dtype),
    }


def mamba_decode_step(params, u, cache, cfg: ModelConfig,
                      constrain: Constrain = null_constrain):
    """u [B,1,D]; cache {'state','conv'} -> ([B,1,D], cache)."""
    B_ = u.shape[0]
    di, n, h, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xp, Bp, Cp, dt = _mamba_project(params, u, constrain)
    xBC = jnp.concatenate([xp, Bp, Cp], axis=-1)  # [B,1,conv_dim]
    hist = cache["conv"]
    window = jnp.concatenate([hist, xBC], axis=1)  # [B,W,conv_dim]
    w = params["conv_w"].astype(u.dtype)
    conv_out = jnp.einsum("bwc,wc->bc", window, w) + params["conv_b"].astype(u.dtype)
    conv_out = jax.nn.silu(conv_out)[:, None, :]
    xp, Bp, Cp = jnp.split(conv_out, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xp.reshape(B_, h, P)
    y, state = ssd_decode_step(cache["state"], xh, dt[:, 0], A, Bp[:, 0], Cp[:, 0])
    y = y + xh * params["D"].astype(y.dtype)[None, :, None]
    y = y.reshape(B_, 1, di)
    y = gated_rmsnorm(params["norm"], y, z, cfg.norm_eps)
    out = jnp.einsum("blk,kd->bld", y, params["out"].astype(y.dtype))
    new_cache = {"state": state, "conv": window[:, 1:]}
    return out, new_cache
