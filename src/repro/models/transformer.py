"""Decoder-only transformer LM covering dense / MoE / audio / VLM families.

Functional, scan-over-layers (compact HLO), KV-cache prefill/decode, optional
cross-attention groups (VLM) and MoE FF (dbrx/arctic).  Parameters are plain
nested dicts; layer params carry a leading stacked dimension consumed by
``lax.scan``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models import moe as moe_lib


def _stack_init(fn, rng, n, *args):
    return jax.vmap(lambda k: fn(k, *args))(jax.random.split(rng, n))


@dataclass
class TransformerLM:
    cfg: ModelConfig
    policy: L.Policy = field(default_factory=L.Policy)
    constrain: L.Constrain = L.null_constrain
    mesh: Any = None  # for MoE expert sharding
    attn_impl: str = "auto"  # auto | direct | chunked | folded
    remat: str = "none"  # none | full | dots
    fold_depth: int = 4
    q_chunk: int = 1024
    kv_chunk: int = 512

    # ------------------------------------------------------------------ #
    @property
    def is_moe(self) -> bool:
        return self.cfg.num_experts > 0

    @property
    def n_cross(self) -> int:
        c = self.cfg.cross_attn_every
        return self.cfg.num_layers // (c + 1) if c else 0

    @property
    def n_self(self) -> int:
        return self.cfg.num_layers - self.n_cross

    # ------------------------------------------------------------------ #
    # Init
    # ------------------------------------------------------------------ #
    def _layer_init(self, rng) -> dict:
        cfg, pd = self.cfg, self.policy.param_dtype
        k1, k2, k3, k4 = jax.random.split(rng, 4)
        p = {
            "ln1": L.rmsnorm_init(cfg.d_model, pd),
            "ln2": L.rmsnorm_init(cfg.d_model, pd),
            "attn": attn_lib.attention_init(
                k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.head_dim, pd, qkv_bias=cfg.qkv_bias),
        }
        if self.is_moe:
            p["moe"] = moe_lib.moe_init(k2, cfg, pd)
            if cfg.moe_dense_residual:
                p["mlp"] = L.mlp_init(k3, cfg.d_model, cfg.d_ff, pd)
        else:
            p["mlp"] = L.mlp_init(k3, cfg.d_model, cfg.d_ff, pd)
        return p

    def _cross_layer_init(self, rng) -> dict:
        cfg, pd = self.cfg, self.policy.param_dtype
        k1, k2 = jax.random.split(rng, 2)
        return {
            "ln1": L.rmsnorm_init(cfg.d_model, pd),
            "ln2": L.rmsnorm_init(cfg.d_model, pd),
            "attn": attn_lib.attention_init(
                k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.head_dim, pd, with_gate=True),
            "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, pd),
            "gate_mlp": jnp.zeros((), pd),
            "kv_proj": L.normal_init(
                k2, (cfg.vision_d, cfg.d_model), cfg.vision_d ** -0.5, pd),
        }

    def init(self, rng) -> dict:
        cfg, pd = self.cfg, self.policy.param_dtype
        ks = jax.random.split(rng, 4)
        params = {
            "embed": L.embed_init(ks[0], cfg.vocab_size, cfg.d_model, pd),
            "final_norm": L.rmsnorm_init(cfg.d_model, pd),
        }
        if not cfg.tie_embeddings:
            params["head"] = L.head_init(ks[1], cfg.d_model, cfg.vocab_size, pd)
        if self.n_cross:
            g = self.n_cross
            per = cfg.cross_attn_every
            params["layers"] = _stack_init(
                lambda k: _stack_init(self._layer_init, k, per), ks[2], g)
            params["cross"] = _stack_init(self._cross_layer_init, ks[3], g)
        else:
            params["layers"] = _stack_init(
                self._layer_init, ks[2], cfg.num_layers)
        return params

    # ------------------------------------------------------------------ #
    # Blocks
    # ------------------------------------------------------------------ #
    def _self_block(self, p, x, positions, cache=None, pos=None):
        """Pre-norm block. Returns (x, new_kv or (k,v))."""
        cfg = self.cfg
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        if cache is None:
            q, k, v = attn_lib.project_qkv(
                p["attn"], h, positions=positions, rope_theta=cfg.rope_theta,
                constrain=self.constrain)
            if self.attn_impl == "cp" and self.mesh is not None:
                o = attn_lib.context_parallel_attention(
                    q, k, v, self.mesh, causal=True,
                    q_chunk=self.q_chunk, kv_chunk=self.kv_chunk)
            else:
                o = attn_lib.attention(
                    q, k, v, causal=True, impl=self.attn_impl,
                    fold_depth=self.fold_depth, q_chunk=self.q_chunk,
                    kv_chunk=self.kv_chunk)
            new_kv = (k, v)
        else:
            k_cache, v_cache = cache
            q, k, v = attn_lib.project_qkv(
                p["attn"], h, positions=positions, rope_theta=cfg.rope_theta,
                constrain=self.constrain)
            k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, pos, 1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, pos, 1)
            o = attn_lib.decode_attention(q, k_cache, v_cache, pos)
            new_kv = (k_cache, v_cache)
        x = x + attn_lib.project_out(p["attn"], o, self.constrain)
        x = self.constrain(x, ("batch", "seq", "embed"))

        h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        aux = jnp.zeros((), jnp.float32)
        if self.is_moe:
            y, aux = moe_lib.moe_apply(
                p["moe"], h, cfg, mesh=self.mesh, constrain=self.constrain)
            if cfg.moe_dense_residual:
                y = y + L.mlp_apply(p["mlp"], h, self.constrain)
        else:
            y = L.mlp_apply(p["mlp"], h, self.constrain)
        x = x + y
        return self.constrain(x, ("batch", "seq", "embed")), new_kv, aux

    def _cross_block(self, p, x, vis_kv, cache=None):
        """Gated cross-attention block (vision). vis_kv [B,Tv,D_model]."""
        cfg = self.cfg
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        q, k, v = attn_lib.project_qkv(p["attn"], h, kv_x=vis_kv,
                                       constrain=self.constrain)
        if cache is not None:  # decode: reuse cached cross K/V
            k, v = cache
        o = attn_lib.attention(q, k, v, causal=False, impl="direct"
                               if q.shape[1] * k.shape[1] <= 1 << 22 else "chunked")
        gate = jnp.tanh(p["attn"]["gate"].astype(x.dtype))
        x = x + gate * attn_lib.project_out(p["attn"], o, self.constrain)
        h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        gate2 = jnp.tanh(p["gate_mlp"].astype(x.dtype))
        x = x + gate2 * L.mlp_apply(p["mlp"], h, self.constrain)
        return x, (k, v)

    def _maybe_remat(self, fn):
        if self.remat == "full":
            return jax.checkpoint(fn)
        if self.remat == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
        return fn

    # ------------------------------------------------------------------ #
    # Forward (train / prefill)
    # ------------------------------------------------------------------ #
    def apply(self, params, tokens, vision_embeds=None, collect_kv=False,
              q_offset=0):
        """tokens [B,S] -> logits [B,S,V].  collect_kv returns per-layer K/V."""
        cfg = self.cfg
        cd = self.policy.compute_dtype
        B, S = tokens.shape
        x = L.embed_apply(params["embed"], tokens, cd)
        x = self.constrain(x, ("batch", "seq", "embed"))
        positions = jnp.arange(S)[None, :] + q_offset

        vis = None
        if self.n_cross:
            assert vision_embeds is not None, "VLM requires vision embeddings"
            vis = vision_embeds.astype(cd)

        aux_total = jnp.zeros((), jnp.float32)

        if self.n_cross:
            def group(x, gp):
                def inner(x, lp):
                    x, kv, aux = self._self_block(lp, x, positions)
                    return x, (kv, aux)
                inner = self._maybe_remat(inner)
                x, (kvs, auxs) = jax.lax.scan(inner, x, gp["layers"])
                vkv = jnp.einsum("btd,dm->btm", vis,
                                 gp["cross"]["kv_proj"].astype(cd))
                x, cross_kv = self._cross_block(gp["cross"], x, vkv)
                return x, (kvs, cross_kv, jnp.sum(auxs))

            group = self._maybe_remat(group)
            stacked = {"layers": params["layers"], "cross": params["cross"]}
            x, (kvs, cross_kvs, auxs) = jax.lax.scan(group, x, stacked)
            aux_total = jnp.sum(auxs)
            kv_out = {"self": kvs, "cross": cross_kvs}
        else:
            def body(x, lp):
                x, kv, aux = self._self_block(lp, x, positions)
                return x, (kv, aux)
            body = self._maybe_remat(body)
            x, (kvs, auxs) = jax.lax.scan(body, x, params["layers"])
            aux_total = jnp.sum(auxs)
            kv_out = {"self": kvs}

        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = L.tied_head_apply(params["embed"], x)
        else:
            logits = L.head_apply(params["head"], x)
        logits = self.constrain(logits, ("batch", "seq", "vocab"))
        if collect_kv:
            return logits, kv_out, aux_total
        return logits, aux_total

    def loss(self, params, batch, vision_embeds=None):
        logits, aux = self.apply(params, batch["tokens"],
                                 vision_embeds=vision_embeds)
        ce = L.cross_entropy(logits, batch["labels"])
        loss = ce + 0.01 * aux if self.is_moe else ce
        return loss, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------------ #
    # KV cache serving
    # ------------------------------------------------------------------ #
    def init_cache(self, batch: int, max_seq: int) -> dict:
        cfg = self.cfg
        cd = self.policy.compute_dtype
        kv_shape = (cfg.num_layers if not self.n_cross else None)
        cache = {}
        if self.n_cross:
            g, per = self.n_cross, cfg.cross_attn_every
            cache["k"] = jnp.zeros(
                (g, per, batch, max_seq, cfg.num_kv_heads, cfg.head_dim), cd)
            cache["v"] = jnp.zeros_like(cache["k"])
            cache["cross_k"] = jnp.zeros(
                (g, batch, cfg.vision_tokens, cfg.num_kv_heads, cfg.head_dim), cd)
            cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
        else:
            cache["k"] = jnp.zeros(
                (cfg.num_layers, batch, max_seq, cfg.num_kv_heads,
                 cfg.head_dim), cd)
            cache["v"] = jnp.zeros_like(cache["k"])
        return cache

    def prefill(self, params, tokens, cache, vision_embeds=None):
        """Run full-sequence forward, fill cache. Returns (last_logits, cache)."""
        S = tokens.shape[1]
        logits, kv, _ = self.apply(params, tokens, vision_embeds=vision_embeds,
                                   collect_kv=True)
        k, v = kv["self"]
        if self.n_cross:
            cache = dict(cache)
            cache["k"] = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), 0, 3)
            cache["v"] = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), 0, 3)
            ck, cv = kv["cross"]
            cache["cross_k"], cache["cross_v"] = ck, cv
        else:
            cache = dict(cache)
            cache["k"] = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), 0, 2)
            cache["v"] = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), 0, 2)
        return logits[:, -1], cache

    def decode_step(self, params, token, cache, pos):
        """token [B,1]; pos: scalar int32 index of the new token."""
        cfg = self.cfg
        cd = self.policy.compute_dtype
        x = L.embed_apply(params["embed"], token, cd)
        positions = jnp.full((token.shape[0], 1), pos, jnp.int32)

        if self.n_cross:
            def group(x, gp):
                lp, kc, vc, cp, ck, cv = gp

                def inner(x, xs):
                    lpi, kci, vci = xs
                    x, (knew, vnew), _ = self._self_block(
                        lpi, x, positions, cache=(kci, vci), pos=pos)
                    return x, (knew, vnew)

                x, (kn, vn) = jax.lax.scan(inner, x, (lp, kc, vc))
                x, _ = self._cross_block(cp, x, None, cache=(ck, cv))
                return x, (kn, vn)

            x, (kn, vn) = jax.lax.scan(
                group, x,
                (params["layers"], cache["k"], cache["v"], params["cross"],
                 cache["cross_k"], cache["cross_v"]))
            new_cache = dict(cache, k=kn, v=vn)
        else:
            def body(x, xs):
                lp, kc, vc = xs
                x, (kn, vn), _ = self._self_block(
                    lp, x, positions, cache=(kc, vc), pos=pos)
                return x, (kn, vn)
            x, (kn, vn) = jax.lax.scan(body, x, (params["layers"],
                                                 cache["k"], cache["v"]))
            new_cache = dict(cache, k=kn, v=vn)

        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = L.tied_head_apply(params["embed"], x)
        else:
            logits = L.head_apply(params["head"], x)
        return logits[:, 0], new_cache
