from repro.runtime.train import RunConfig, Trainer, make_train_step  # noqa: F401
