"""Fault-tolerant supervisor: restart-from-checkpoint, elastic re-shard,
straggler mitigation driven by FLARE diagnoses.

On a real fleet this process runs alongside the job scheduler: FLARE routes
(hang -> isolate machines -> restart; fail-slow underclock -> drain host).
Here the control loop is identical; machine actions are pluggable (the
cluster simulator implements them for tests/benchmarks, logging what a
scheduler would do).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.engine import Anomaly, Team


class SimulatedFault(RuntimeError):
    """Raised by fault hooks to simulate a mid-training crash."""


@dataclass
class ClusterAction:
    kind: str            # isolate | drain | restart | rescale
    ranks: list = field(default_factory=list)
    note: str = ""
    ts: float = field(default_factory=time.time)


@dataclass
class Supervisor:
    max_restarts: int = 3
    actions: list = field(default_factory=list)
    restarts: int = 0

    # ------------------------------------------------------------------ #
    def run(self, make_trainer: Callable[[], "object"],
            steps: int) -> list[dict]:
        """Run training with restart-on-fault.  `make_trainer()` must build
        a fresh Trainer that restores from the shared checkpoint dir."""
        history: list[dict] = []
        while True:
            trainer = make_trainer()
            try:
                history.extend(trainer.train(steps))
                return history
            except SimulatedFault as e:
                # keep the partial progress made before the crash — the
                # checkpoint already persisted it, this is just bookkeeping
                history.extend(trainer.history)
                self.restarts += 1
                self.actions.append(ClusterAction(
                    kind="restart", note=f"fault: {e}; restoring from "
                    "latest checkpoint"))
                if self.restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded {self.max_restarts} restarts") from e

    # ------------------------------------------------------------------ #
    def apply_diagnosis(self, anomalies: list[Anomaly]) -> list[ClusterAction]:
        """Translate FLARE anomalies into cluster actions (ops runbook)."""
        out = []
        for a in anomalies:
            if a.team != Team.OPERATIONS:
                continue  # algorithm/infrastructure findings are tickets
            if a.kind == "hang":
                out.append(ClusterAction(
                    kind="isolate", ranks=list(a.ranks),
                    note=f"hang ({a.metric}): {a.root_cause}"))
                out.append(ClusterAction(
                    kind="restart", note="restart excluding isolated hosts"))
            elif a.kind == "fail_slow" and a.ranks:
                out.append(ClusterAction(
                    kind="drain", ranks=list(a.ranks),
                    note=f"straggler mitigation: {a.root_cause}"))
            elif a.kind == "fail_slow":
                out.append(ClusterAction(
                    kind="rescale", note="network fail-slow: reroute/probe "
                    "per attached binary-search plan"))
        self.actions.extend(out)
        return out
