"""Serving runtime: batched prefill + decode with KV caches, FLARE hooks."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.models.layers import Policy
from repro.models.registry import build_model


@dataclass
class ServeConfig:
    model: ModelConfig
    batch: int = 4
    max_seq: int = 256
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    seed: int = 0
    flare: bool = True

    def policy(self) -> Policy:
        return Policy(jnp.dtype(self.param_dtype),
                      jnp.dtype(self.compute_dtype))


class Server:
    def __init__(self, cfg: ServeConfig, params=None):
        self.cfg = cfg
        self.model = build_model(cfg.model, policy=cfg.policy())
        self.params = params if params is not None else self.model.init(
            jax.random.PRNGKey(cfg.seed))
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(2,))
        self.daemon = None
        if cfg.flare:
            from repro.core.daemon import DaemonConfig, TracingDaemon
            self.daemon = TracingDaemon(DaemonConfig(
                rank=0, backend=f"{cfg.model.family}-serve",
                hang_timeout=300.0)).attach()

    def close(self):
        if self.daemon:
            self.daemon.detach()

    # ------------------------------------------------------------------ #
    def generate(self, prompts: np.ndarray, new_tokens: int = 16,
                 vision_embeds=None) -> np.ndarray:
        """prompts [B, S0] int32 -> [B, S0+new_tokens]."""
        cfg = self.cfg
        B, S0 = prompts.shape
        cache = self.model.init_cache(B, cfg.max_seq)
        kw = {}
        if cfg.model.family == "vlm":
            kw["vision_embeds"] = (vision_embeds if vision_embeds is not None
                                   else jnp.ones((B, cfg.model.vision_tokens,
                                                  cfg.model.vision_d),
                                                 jnp.bfloat16))
        if self.daemon:
            self.daemon.step_begin(0)
        logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                      cache, **kw)
        out = [np.asarray(prompts)]
        tok = jnp.argmax(logits, axis=-1)[:, None]
        for i in range(new_tokens):
            if self.daemon:
                self.daemon.step_begin(i + 1)
            out.append(np.asarray(tok))
            logits, cache = self._decode(self.params, tok, cache,
                                         jnp.int32(S0 + i))
            tok = jnp.argmax(logits, axis=-1)[:, None]
            if self.daemon:
                self.daemon.step_end(tokens=B)
        return np.concatenate(out, axis=1)
