"""Training runtime: jitted train step + the FLARE-instrumented driver loop.

``make_train_step`` builds the pure step (microbatched grad accumulation,
AdamW with compressed state, LR schedule).  ``Trainer`` is the driver: it
owns the dataloader, attaches the FLARE daemon, emits step/dataloader
events, checkpoints, and exposes fault hooks for the supervisor.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.data import DataConfig, ShardedLoader
from repro.models.layers import Policy
from repro.models.registry import build_model
from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               opt_state_specs)
from repro.optim.schedule import warmup_cosine


@dataclass
class RunConfig:
    model: ModelConfig
    global_batch: int = 8
    seq_len: int = 128
    num_microbatches: int = 1
    steps: int = 50
    warmup_steps: int = 20
    peak_lr: float = 3e-4
    remat: str = "none"
    attn_impl: str = "auto"
    grad_accum_dtype: str = "float32"  # float32 | bfloat16 (microbatching)
    opt: AdamWConfig = field(default_factory=AdamWConfig)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    seed: int = 0
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 25
    flare: bool = True
    flare_log: Optional[str] = None
    mask_mode: str = "none"   # none | naive | fast (Case-3)
    data_prefetch: bool = True  # False = synchronous dataloader (Case-3)

    def policy(self) -> Policy:
        return Policy(jnp.dtype(self.param_dtype), jnp.dtype(self.compute_dtype))


def make_train_step(model, cfg: RunConfig, mesh=None):
    """Returns step_fn(params, opt_state, batch, step) -> (p, o, metrics)."""
    opt_cfg = cfg.opt
    M = cfg.num_microbatches

    def _constrain_micro(x):
        # keep the microbatch split sharded over the dp axes (avoids GSPMD
        # "involuntary full rematerialization" on the reshape)
        if mesh is None:
            return x
        from jax.sharding import NamedSharding, PartitionSpec as P
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        spec = P(None, dp, *([None] * (x.ndim - 2)))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    def loss_fn(params, batch):
        loss, aux = model.loss(params, batch,
                               vision_embeds=batch.get("vision_embeds"))
        return loss, aux

    def step_fn(params, opt_state, batch, step):
        lr = warmup_cosine(step, peak_lr=cfg.peak_lr,
                           warmup_steps=cfg.warmup_steps,
                           total_steps=cfg.steps)
        if M <= 1:
            (loss, aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            acc_dt = jnp.dtype(cfg.grad_accum_dtype)

            def micro(carry, mb):
                gacc, lacc = carry
                (l, _), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dt), gacc, g)
                return (gacc, lacc + l), None

            mbs = jax.tree.map(
                lambda x: _constrain_micro(
                    x.reshape((M, x.shape[0] // M) + x.shape[1:])),
                batch)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            (grads, loss), _ = jax.lax.scan(micro, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / M, grads)
            loss = loss / M
        params, opt_state, om = adamw_update(
            grads, opt_state, params, opt_cfg, lr)
        metrics = {"loss": loss, "lr": lr, **om}
        return params, opt_state, metrics

    return step_fn


class Trainer:
    """FLARE-instrumented training driver with checkpoint/restart support."""

    def __init__(self, cfg: RunConfig, fault_hook: Optional[Callable] = None):
        self.cfg = cfg
        self.model = build_model(cfg.model, policy=cfg.policy(),
                                 attn_impl=cfg.attn_impl, remat=cfg.remat)
        self.step_fn = jax.jit(make_train_step(self.model, cfg),
                               donate_argnums=(0, 1))
        self.fault_hook = fault_hook
        self.daemon = None
        self.ckpt = None
        if cfg.checkpoint_dir:
            from repro.checkpoint import CheckpointManager
            self.ckpt = CheckpointManager(cfg.checkpoint_dir)
        self.history: list[dict] = []

    # ------------------------------------------------------------------ #
    def init_state(self):
        rng = jax.random.PRNGKey(self.cfg.seed)
        params = self.model.init(rng)
        opt_state = adamw_init(params, self.cfg.opt)
        return params, opt_state, 0

    def restore_or_init(self):
        params, opt_state, start = self.init_state()
        if self.ckpt and self.ckpt.latest_step() is not None:
            tree = {"params": params, "opt": opt_state}
            restored = self.ckpt.restore(tree)
            params, opt_state = restored["params"], restored["opt"]
            start = self.ckpt.latest_step() + 1
        return params, opt_state, start

    def _loader(self) -> ShardedLoader:
        c = self.cfg
        return ShardedLoader(DataConfig(
            vocab_size=c.model.vocab_size, batch=c.global_batch,
            seq_len=c.seq_len, seed=c.seed, mask_mode=c.mask_mode))

    def _vision_stub(self):
        c = self.cfg.model
        if c.family != "vlm":
            return None
        return jnp.ones((self.cfg.global_batch, c.vision_tokens, c.vision_d),
                        jnp.dtype(self.cfg.compute_dtype))

    # ------------------------------------------------------------------ #
    def train(self, steps: Optional[int] = None) -> list[dict]:
        cfg = self.cfg
        steps = steps if steps is not None else cfg.steps
        if cfg.flare:
            from repro.core.daemon import DaemonConfig, TracingDaemon
            self.daemon = TracingDaemon(DaemonConfig(
                rank=0, backend=f"{cfg.model.family}-train",
                log_path=cfg.flare_log, hang_timeout=300.0))
            self.daemon.attach()
        loader = self._loader()
        if cfg.data_prefetch:
            loader.start()
        params, opt_state, start = self.restore_or_init()
        vis = self._vision_stub()
        tokens_per_step = cfg.global_batch * cfg.seq_len
        try:
            for step in range(start, steps):
                if self.daemon:
                    self.daemon.step_begin(step)
                    self.daemon.set_stack(["Trainer.train", "next_batch"])
                t0 = time.perf_counter()
                batch = loader.next_batch()
                t_data = time.perf_counter()
                if self.daemon:
                    from repro.core.events import EventKind
                    self.daemon.record_span(
                        EventKind.DATALOADER, "dataloader.next_batch",
                        t0, t_data, tokens=tokens_per_step)
                    self.daemon.set_stack(["Trainer.train", "train_step"])
                jb = {"tokens": jnp.asarray(batch["tokens"]),
                      "labels": jnp.asarray(batch["labels"])}
                if vis is not None:
                    jb["vision_embeds"] = vis
                if self.fault_hook:
                    self.fault_hook(step)
                t_dispatch = time.perf_counter()
                params, opt_state, metrics = self.step_fn(
                    params, opt_state, jb, jnp.int32(step))
                loss = float(metrics["loss"])  # sync point
                t_done = time.perf_counter()
                if self.daemon:
                    from repro.core.events import EventKind
                    # whole-step device occupancy (the jitted step is one
                    # fused XLA program on this backend)
                    self.daemon.record_span(
                        EventKind.KERNEL_COMPUTE, "train_step_exec",
                        t_dispatch, t_done,
                        flops=6.0 * cfg.model.active_param_count()
                        * tokens_per_step)
                    self.daemon.step_end(tokens=tokens_per_step, loss=loss)
                rec = {"step": step, "loss": loss,
                       "lr": float(metrics["lr"]),
                       "grad_norm": float(metrics["grad_norm"]),
                       "step_time_s": time.perf_counter() - t0,
                       "tokens_per_s": tokens_per_step
                       / max(time.perf_counter() - t0, 1e-9)}
                self.history.append(rec)
                if self.ckpt and (step + 1) % cfg.checkpoint_every == 0:
                    self.ckpt.save(step, {"params": params, "opt": opt_state},
                                   {"loss": loss})
        finally:
            loader.stop()
            if self.daemon:
                self.daemon.detach()
        self.final_state = (params, opt_state)
        return self.history
