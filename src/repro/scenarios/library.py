"""The scenario library: every fault the matrix scores, with ground truth.

Five-plus legacy kinds (the paper's cases) and the five L4 production
faults, each labelled with the detector key(s) that constitute a correct
catch, the team that must be paged, culprit ranks and onset step.  All
absolute stall durations are fractions of the program's healthy step
time, so the same scenario transfers across the model zoo — a 0.5 B
config with 0.3 s steps and a 405 B config with minute steps inject
proportionally identical faults.

``allowed`` keys document real secondary symptoms (a checkpoint storm
also dents throughput; heavy serving interference also depresses
achieved FLOPS uniformly) — they are not scored as false positives, but
anything else firing is.
"""
from __future__ import annotations

from repro.core.injectors import Injection
from repro.scenarios.base import GroundTruth, Scenario

# Detector keys (kind:metric) the suite can emit
FS_TPUT = "fail_slow:throughput"
FS_BW = "fail_slow:bandwidth"
RG_ISSUE = "regression:issue_latency"
RG_VINTER = "regression:v_inter"
RG_VMIN = "regression:v_minority"
RG_FLOPS = "regression:flops"
RG_BW = "regression:bandwidth"
HANG_INSPECT = "hang:intra_kernel_inspecting"
HANG_STACK = "hang:call_stack_analysis"


SCENARIOS: tuple[Scenario, ...] = (
    # ------------------------------------------------------------------ #
    # baseline: a healthy job — ANY anomaly is a false positive
    # ------------------------------------------------------------------ #
    Scenario(
        name="healthy",
        description="clean run; the suite must stay silent",
        inject=lambda step_s, n: [],
        truth=None,
        tags=("baseline",)),
    # ------------------------------------------------------------------ #
    # legacy taxonomy (paper cases)
    # ------------------------------------------------------------------ #
    Scenario(
        name="gc_stall",
        description="periodic Python GC pauses compress issue latencies",
        inject=lambda step_s, n: [Injection(
            kind="gc", duration=0.002 * step_s, period_ops=5)],
        truth=GroundTruth(kind="regression", team="algorithm",
                          expect=(RG_ISSUE,)),
        tags=("legacy", "host")),
    Scenario(
        name="pyapi_package_check",
        description="a package/version check stalls the dispatch thread",
        inject=lambda step_s, n: [Injection(
            kind="pyapi_stall", duration=0.0025 * step_s, period_ops=7,
            api_name="importlib.metadata.version")],
        truth=GroundTruth(kind="regression", team="algorithm",
                          expect=(RG_ISSUE,)),
        tags=("legacy", "host")),
    Scenario(
        name="sync_after_comm",
        description="Case-1: needless block_until_ready after collectives",
        inject=lambda step_s, n: [Injection(kind="sync_after_comm")],
        truth=GroundTruth(kind="regression", team="algorithm",
                          expect=(RG_ISSUE,)),
        tags=("legacy", "host")),
    Scenario(
        name="gpu_underclock",
        description="one rank's GPU drops clocks mid-job",
        inject=lambda step_s, n: [Injection(
            kind="underclock", ranks=(5,), factor=2.5, start_step=3)],
        truth=GroundTruth(kind="fail_slow", team="operations",
                          expect=(FS_TPUT,), culprit_ranks=(5,),
                          onset_step=3),
        tags=("legacy", "hardware")),
    Scenario(
        name="network_jitter",
        description="persistent noisy collective slowdown mid-job",
        inject=lambda step_s, n: [Injection(
            kind="network_jitter", factor=3.0, start_step=3)],
        truth=GroundTruth(kind="fail_slow", team="operations",
                          expect=(FS_BW,), allowed=(FS_TPUT,),
                          onset_step=3),
        tags=("legacy", "network")),
    Scenario(
        name="slow_dataloader",
        description="Case-3: host dataloader starves the device",
        inject=lambda step_s, n: [Injection(
            kind="slow_dataloader", factor=1.0, duration=0.2 * step_s)],
        truth=GroundTruth(kind="regression", team="algorithm",
                          expect=(RG_VINTER,)),
        tags=("legacy", "host")),
    Scenario(
        name="minority_kernels",
        description="Table-5: un-instrumented kernels inflate V_minority",
        inject=lambda step_s, n: [Injection(
            kind="minority_kernels", factor=0.35)],
        truth=GroundTruth(kind="regression", team="infrastructure",
                          expect=(RG_VMIN,)),
        tags=("legacy", "coverage")),
    Scenario(
        name="misaligned_matmul",
        description="Case-2: a layout change halves ffn matmul FLOPS",
        inject=lambda step_s, n: [Injection(
            kind="slow_compute", op_match="ffn_matmul", factor=2.88)],
        truth=GroundTruth(kind="regression", team="infrastructure",
                          expect=(RG_FLOPS,)),
        tags=("legacy", "software")),
    Scenario(
        name="comm_hang",
        description="one rank freezes inside a collective",
        inject=lambda step_s, n: [Injection(
            kind="hang", ranks=(11 % n,), at_step=2)],
        truth=GroundTruth(kind="hang", team="operations",
                          expect=(HANG_INSPECT,), allowed=(HANG_STACK,),
                          culprit_ranks=(11,), onset_step=2),
        steps=6,
        tags=("legacy", "hang")),
    # ------------------------------------------------------------------ #
    # L4 production taxonomy (PAPERS.md)
    # ------------------------------------------------------------------ #
    Scenario(
        name="checkpoint_write_storm",
        description="periodic multi-second blocking checkpoint flushes",
        inject=lambda step_s, n: [Injection(
            kind="checkpoint_write_storm", duration=0.25 * step_s,
            period_ops=6, start_step=2,
            meta={"period_steps": 6, "storm_steps": 3})],
        truth=GroundTruth(kind="regression", team="infrastructure",
                          expect=(RG_ISSUE,), allowed=(FS_TPUT,),
                          onset_step=2),
        tags=("l4", "storage")),
    Scenario(
        name="ecc_throttle",
        description="ECC storm / thermal throttle ramping on two ranks",
        inject=lambda step_s, n: [Injection(
            kind="ecc_throttle", ranks=(4, 5), factor=2.5, start_step=4,
            meta={"ramp_steps": 3})],
        truth=GroundTruth(kind="fail_slow", team="operations",
                          expect=(FS_TPUT,), culprit_ranks=(4, 5),
                          onset_step=4),
        tags=("l4", "hardware")),
    Scenario(
        name="network_flap",
        description="a link flaps: collectives degrade on a duty cycle",
        inject=lambda step_s, n: [Injection(
            kind="network_flap", factor=3.0, start_step=4,
            meta={"on_steps": 2, "off_steps": 2})],
        truth=GroundTruth(kind="fail_slow", team="operations",
                          expect=(FS_BW,), allowed=(FS_TPUT,),
                          onset_step=4),
        tags=("l4", "network")),
    Scenario(
        name="moe_straggler",
        description="one hot MoE expert runs 3x slow on its FFN kernels",
        inject=lambda step_s, n: [Injection(
            kind="moe_straggler", op_match="moe_ffn", factor=3.0,
            meta={"hot_expert": 2})],
        truth=GroundTruth(kind="regression", team="infrastructure",
                          expect=(RG_FLOPS,)),
        families=("moe",), moe_experts=4,
        tags=("l4", "moe")),
    Scenario(
        name="serving_interference",
        description="co-located serving burst steals compute on a duty "
                    "cycle (uniform, transient, no rank/network culprit)",
        inject=lambda step_s, n: [Injection(
            kind="serving_interference", factor=1.3, start_step=4,
            meta={"on_steps": 2, "off_steps": 2})],
        truth=GroundTruth(kind="fail_slow", team="operations",
                          expect=(FS_TPUT,), allowed=(RG_FLOPS,),
                          onset_step=4),
        tags=("l4", "multitenant")),
)

SCENARIOS_BY_NAME: dict[str, Scenario] = {s.name: s for s in SCENARIOS}

#: distinct fault kinds covered (hang + healthy included)
FAULT_KINDS: tuple[str, ...] = tuple(sorted(
    {inj.kind for s in SCENARIOS for inj in s.inject(1.0, 32)}))


def scenarios_for(cfg) -> list[Scenario]:
    """Scenarios applicable to one model-zoo config."""
    return [s for s in SCENARIOS if s.applies_to(cfg)]
