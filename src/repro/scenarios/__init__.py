"""Scenario matrix: labelled fault cases scored against the detectors.

``SCENARIOS`` is the library (legacy paper cases + L4 production faults,
each with machine-readable ground truth); ``run_matrix``/``score_matrix``
sweep them over model-zoo configs and fold results into per-detector
precision/recall.  ``benchmarks/scenarios.py`` is the CI entry point.
"""
from repro.scenarios.base import (GroundTruth, Scenario,  # noqa: F401
                                  anomaly_key)
from repro.scenarios.library import (FAULT_KINDS, SCENARIOS,  # noqa: F401
                                     SCENARIOS_BY_NAME, scenarios_for)
from repro.scenarios.runner import (DEFAULT_NUM_RANKS, CellResult,  # noqa: F401
                                    run_cell, run_matrix, score_matrix)

__all__ = [
    "GroundTruth", "Scenario", "anomaly_key",
    "SCENARIOS", "SCENARIOS_BY_NAME", "FAULT_KINDS", "scenarios_for",
    "CellResult", "run_cell", "run_matrix", "score_matrix",
    "DEFAULT_NUM_RANKS",
]
