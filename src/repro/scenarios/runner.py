"""Matrix runner + scorer: scenarios x model-zoo configs -> P/R per detector.

One *cell* = one scenario run against one config: learn a healthy profile
for that config (clean sims), run the injected sim, diagnose, and grade
the anomalies against the scenario's :class:`GroundTruth`.  The scorer
folds cells into per-detector precision/recall:

  * TP  — an expected key fired on a faulty cell
  * FN  — no expected key fired (charged to ``expect[0]``)
  * FP  — a key fired that is neither expected nor allowed; on a healthy
          cell EVERY firing is a false positive

A cell also grades *attribution*: team routing, culprit-rank coverage and
onset ordering on the catching anomaly.  ``benchmarks/scenarios.py``
asserts hard floors over these results in CI.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass

from repro.configs import get_config
from repro.core.engine import DiagnosticEngine, EngineConfig
from repro.core.history import HistoryStore
from repro.core.timeline import ClusterSimulator, program_from_config
from repro.scenarios.base import Scenario, anomaly_key
from repro.scenarios.library import SCENARIOS_BY_NAME, scenarios_for

DEFAULT_NUM_RANKS = 32
PROFILE_SEEDS = 3
PROFILE_STEPS = 4


@dataclass(frozen=True)
class CellResult:
    """Graded outcome of one (scenario, config) cell."""

    scenario: str
    config: str
    healthy: bool
    fired: tuple[str, ...]       # distinct detector keys, first-fire order
    false_keys: tuple[str, ...]  # fired but neither expected nor allowed
    caught: bool                 # an expected key fired (healthy: True)
    team_ok: bool                # catching anomaly routed to truth.team
    ranks_ok: bool               # culprit ranks covered by its ``ranks``
    onset_ok: bool               # nothing expected fired before onset
    first_step: int              # step of first expected firing (-1: none)
    anomalies: int

    @property
    def ok(self) -> bool:
        if self.healthy:
            return self.anomalies == 0
        return (self.caught and self.team_ok and self.ranks_ok
                and self.onset_ok)


def run_cell(scn: Scenario, config_name: str,
             num_ranks: int = DEFAULT_NUM_RANKS) -> CellResult:
    """Run one scenario against one model-zoo config and grade it."""
    cfg = get_config(config_name)
    prog = program_from_config(cfg, num_chips=num_ranks,
                               moe_experts=scn.moe_experts)
    step_s = float(sum(op.duration for op in prog))

    store = HistoryStore()
    learner = DiagnosticEngine(
        EngineConfig(backend="dense-train", num_ranks=num_ranks), store)
    for seed in range(PROFILE_SEEDS):
        sim = ClusterSimulator(num_ranks, prog, seed=seed)
        learner.ingest_all(sim.run(PROFILE_STEPS))
    learner.learn_healthy()

    eng = DiagnosticEngine(
        EngineConfig(backend="dense-train", num_ranks=num_ranks), store)
    sim = ClusterSimulator(num_ranks, prog, seed=scn.seed,
                           injections=scn.inject(step_s, num_ranks))
    eng.ingest_all(sim.run(scn.steps))
    if sim.hang:
        anomalies = [eng.diagnose_hang(sim.hang.stacks,
                                       sim.hang.ring_progress)]
        anomalies = [a for a in anomalies if a is not None]
    else:
        anomalies = eng.evaluate_all()

    return _grade(scn, config_name, anomalies)


def _grade(scn: Scenario, config_name: str, anomalies) -> CellResult:
    fired: list[str] = []
    for a in anomalies:
        k = anomaly_key(a)
        if k not in fired:
            fired.append(k)

    t = scn.truth
    if t is None:
        return CellResult(
            scenario=scn.name, config=config_name, healthy=True,
            fired=tuple(fired), false_keys=tuple(fired), caught=True,
            team_ok=True, ranks_ok=True, onset_ok=True, first_step=-1,
            anomalies=len(anomalies))

    matching = [a for a in anomalies if anomaly_key(a) in t.expect]
    caught = bool(matching)
    team_ok = any(a.team.value == t.team for a in matching)
    ranks_ok = (not t.culprit_ranks) or any(
        all(r in a.ranks for r in t.culprit_ranks) for a in matching)
    # hang anomalies carry step=-1 (diagnosed post-mortem, not per-step)
    onset_ok = not any(0 <= a.step < t.onset_step for a in matching)
    steps = [a.step for a in matching if a.step >= 0]
    first_step = min(steps) if steps else -1
    ok_keys = set(t.expect) | set(t.allowed)
    false_keys = tuple(k for k in fired if k not in ok_keys)
    return CellResult(
        scenario=scn.name, config=config_name, healthy=False,
        fired=tuple(fired), false_keys=false_keys, caught=caught,
        team_ok=team_ok, ranks_ok=ranks_ok, onset_ok=onset_ok,
        first_step=first_step, anomalies=len(anomalies))


def run_matrix(config_names: list[str],
               num_ranks: int = DEFAULT_NUM_RANKS,
               scenario_names=None) -> list[CellResult]:
    """Sweep applicable scenarios over ``config_names`` (skips cells whose
    scenario doesn't apply to the config, e.g. MoE-only faults)."""
    cells = []
    for config_name in config_names:
        cfg = get_config(config_name)
        for scn in scenarios_for(cfg):
            if scenario_names and scn.name not in scenario_names:
                continue
            cells.append(run_cell(scn, config_name, num_ranks=num_ranks))
    return cells


def score_matrix(cells: list[CellResult]) -> dict:
    """Per-detector precision/recall + matrix-level attribution summary."""
    det: dict[str, dict[str, int]] = defaultdict(
        lambda: {"tp": 0, "fp": 0, "fn": 0})
    missed, misrouted, false_cells = [], [], []
    for c in cells:
        cell_id = f"{c.scenario}@{c.config}"
        if c.healthy:
            for k in c.false_keys:
                det[k]["fp"] += 1
            if c.false_keys:
                false_cells.append(cell_id)
            continue
        t = SCENARIOS_BY_NAME[c.scenario].truth
        hit = [k for k in t.expect if k in c.fired]
        for k in hit:
            det[k]["tp"] += 1
        if not hit:
            det[t.expect[0]]["fn"] += 1
            missed.append(cell_id)
        elif not (c.team_ok and c.ranks_ok and c.onset_ok):
            misrouted.append(cell_id)
        for k in c.false_keys:
            det[k]["fp"] += 1
        if c.false_keys:
            false_cells.append(cell_id)

    detectors = {}
    for key in sorted(det):
        s = det[key]
        tp, fp, fn = s["tp"], s["fp"], s["fn"]
        detectors[key] = {
            "tp": tp, "fp": fp, "fn": fn,
            "precision": tp / (tp + fp) if tp + fp else 1.0,
            "recall": tp / (tp + fn) if tp + fn else 1.0,
        }
    tp = sum(s["tp"] for s in det.values())
    fp = sum(s["fp"] for s in det.values())
    fn = sum(s["fn"] for s in det.values())
    return {
        "detectors": detectors,
        "micro_precision": tp / (tp + fp) if tp + fp else 1.0,
        "micro_recall": tp / (tp + fn) if tp + fn else 1.0,
        "cells": len(cells),
        "faulty_cells": sum(1 for c in cells if not c.healthy),
        "missed": missed,
        "misrouted": misrouted,
        "false_positive_cells": sorted(set(false_cells)),
    }
