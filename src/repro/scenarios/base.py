"""Scenario model: a fault + its machine-readable ground truth.

A :class:`Scenario` bundles everything needed to *score* diagnosis, not
just run it: the injections (built per-config, scaled to the program's
healthy step time so one scenario transfers across the model zoo), and a
:class:`GroundTruth` naming the anomaly the detector suite MUST report —
expected detector key(s), team attribution, culprit ranks, onset step.
``truth=None`` marks the healthy baseline: any anomaly at all is a false
positive.

Detector keys are ``"<anomaly.kind>:<anomaly.metric>"`` (e.g.
``"fail_slow:throughput"``, ``"regression:issue_latency"``,
``"hang:intra_kernel_inspecting"``) — :func:`anomaly_key` builds them.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.configs import ModelConfig
from repro.core.anomaly import Anomaly
from repro.core.injectors import Injection


def anomaly_key(a: Anomaly) -> str:
    """The scoring identity of a detector firing."""
    return f"{a.kind}:{a.metric}"


@dataclass(frozen=True)
class GroundTruth:
    """What correct diagnosis looks like for one scenario.

    ``expect`` is any-of: the scenario is *caught* when at least one
    expected key fires.  ``allowed`` keys may legitimately fire alongside
    (secondary symptoms of the same fault) and are not penalized; any
    other key is a false positive against that detector's precision.
    ``team`` must match on an expected-key anomaly (else the catch is a
    mis-attribution); ``culprit_ranks``, when set, must all appear in
    that anomaly's ``ranks``.  ``onset_step`` is the injection onset —
    no matching anomaly may fire before it."""

    kind: str                          # fail_slow | regression | hang
    team: str                          # Team value ("operations", ...)
    expect: tuple[str, ...]            # any-of detector keys
    allowed: tuple[str, ...] = ()      # unpenalized secondary keys
    culprit_ranks: tuple[int, ...] = ()
    onset_step: int = 0


@dataclass(frozen=True)
class Scenario:
    """One parameterized fault case, runnable against any model-zoo
    config.  ``inject(step_s, num_ranks)`` builds the injection list;
    ``step_s`` is the program's healthy per-step device+host seconds, so
    absolute stall durations scale with the model instead of being tuned
    to one architecture.  ``families`` restricts the scenario to config
    families that can express it (e.g. ``moe_straggler`` needs experts);
    ``moe_experts`` asks the program builder for per-expert kernels."""

    name: str
    description: str
    inject: Callable[[float, int], list[Injection]]
    truth: Optional[GroundTruth]       # None = healthy baseline
    steps: int = 10
    seed: int = 7
    families: tuple[str, ...] = ()
    moe_experts: int = 0
    tags: tuple[str, ...] = field(default=())

    def applies_to(self, cfg: ModelConfig) -> bool:
        return not self.families or cfg.family in self.families

    @property
    def healthy(self) -> bool:
        return self.truth is None
