from repro.data.loader import DataConfig, ShardedLoader  # noqa: F401
