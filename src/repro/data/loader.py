"""Sharded, prefetching dataloader with FLARE instrumentation seams.

``next_batch`` is the exact seam the paper instruments for metric ①
(training throughput) and where Case-3's quadratic mask generation lives
when ``mask_mode='naive'``.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass, field
from typing import Iterator, Optional

import numpy as np

from repro.data import masks as mask_lib
from repro.data.synthetic import SyntheticCorpus


@dataclass
class DataConfig:
    vocab_size: int
    batch: int  # per-host batch
    seq_len: int
    seed: int = 0
    shard: int = 0
    num_shards: int = 1
    prefetch: int = 2
    mask_mode: str = "none"  # none | naive | fast  (Case-3 reproduction)
    docs_per_seq: int = 4


class ShardedLoader:
    """Background-prefetching loader over the synthetic corpus."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.corpus = SyntheticCorpus(cfg.vocab_size, cfg.seed)
        self._iter = self.corpus.batch_iter(
            cfg.batch, cfg.seq_len, cfg.shard, cfg.num_shards)
        self._q: queue.Queue = queue.Queue(maxsize=max(cfg.prefetch, 1))
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._rng = np.random.default_rng(cfg.seed + 1)

    # ------------------------------------------------------------------ #
    def _make_batch(self) -> dict:
        batch = next(self._iter)
        cfg = self.cfg
        if cfg.mask_mode != "none":
            L = cfg.seq_len
            lens = self._doc_lengths(L, cfg.docs_per_seq)
            seg = mask_lib.segment_ids_from_docs(lens, L)
            if cfg.mask_mode == "naive":
                batch["mask"] = mask_lib.mask_naive_quadratic(seg)
            else:
                batch["seg_starts"] = mask_lib.mask_fast_linear(seg)
        return batch

    def _doc_lengths(self, L: int, n: int) -> list[int]:
        cuts = np.sort(self._rng.choice(np.arange(1, L), n - 1, replace=False))
        edges = np.concatenate([[0], cuts, [L]])
        return list(np.diff(edges))

    # ------------------------------------------------------------------ #
    def start(self):
        if self._thread is not None:
            return
        def worker():
            while not self._stop.is_set():
                try:
                    self._q.put(self._make_batch(), timeout=0.2)
                except queue.Full:
                    continue
        self._thread = threading.Thread(target=worker, daemon=True,
                                        name="flare-dataloader")
        self._thread.start()

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None

    def next_batch(self) -> dict:
        """THE instrumented seam (FLARE metric ①: throughput; Case-3 V_inter)."""
        if self._thread is None:
            return self._make_batch()  # synchronous mode
        return self._q.get()

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()
