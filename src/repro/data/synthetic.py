"""Deterministic synthetic token corpus (no external data gate).

A seeded Zipf-ish unigram stream with injected local structure (bigram
coupling) so that a ~100M model trained for a few hundred steps shows a
clearly decreasing loss — enough signal for the end-to-end example.
"""
from __future__ import annotations

import numpy as np


class SyntheticCorpus:
    def __init__(self, vocab_size: int, seed: int = 0, zipf_a: float = 1.1):
        self.vocab_size = vocab_size
        self.seed = seed
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = 1.0 / np.power(ranks, zipf_a)
        self.p = p / p.sum()

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        base = rng.choice(self.vocab_size, size=n, p=self.p)
        # bigram coupling: token[i] often determined by token[i-1]
        couple = rng.random(n) < 0.5
        shifted = (np.roll(base, 1) * 31 + 7) % self.vocab_size
        out = np.where(couple, shifted, base)
        return out.astype(np.int32)

    def batch_iter(self, batch: int, seq_len: int, shard: int = 0,
                   num_shards: int = 1, seed_offset: int = 0):
        """Yields {tokens [b,s], labels [b,s]} for this data shard forever."""
        step = 0
        while True:
            rng = np.random.default_rng(
                (self.seed + seed_offset, shard, step))
            toks = self.sample(rng, batch * (seq_len + 1))
            toks = toks.reshape(batch, seq_len + 1)
            yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
            step += 1
