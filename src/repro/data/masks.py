"""Attention-mask generation — the paper's Case-3 dataloader regression.

The paper (§7.3.3): an algorithm team reused a 4k training script at 64k
sequence length; the dataloader's O(L^2) attention-mask generation became
the bottleneck (41% MFU drop, detected via V_inter).  We provide both the
naive quadratic generator (to reproduce the regression) and the O(L)
fixed version (what the routed team ships after FLARE's diagnosis).
"""
from __future__ import annotations

import numpy as np


def segment_ids_from_docs(doc_lengths: list[int], seq_len: int) -> np.ndarray:
    seg = np.zeros(seq_len, np.int32)
    pos = 0
    for i, ln in enumerate(doc_lengths):
        seg[pos:pos + ln] = i
        pos += ln
        if pos >= seq_len:
            break
    seg[pos:] = len(doc_lengths)
    return seg


def mask_naive_quadratic(segment_ids: np.ndarray) -> np.ndarray:
    """O(L^2) dense causal+segment mask — the regression-inducing path."""
    L = segment_ids.shape[0]
    mask = np.zeros((L, L), dtype=bool)
    for i in range(L):          # noqa: B007 — intentionally quadratic
        for j in range(i + 1):
            mask[i, j] = segment_ids[i] == segment_ids[j]
    return mask


def mask_fast_linear(segment_ids: np.ndarray) -> np.ndarray:
    """O(L) metadata: per-token segment start offset.  Equivalent mask is
    (j >= start[i]) & (j <= i); materialization is deferred to the kernel."""
    L = segment_ids.shape[0]
    start = np.zeros(L, np.int32)
    cur = 0
    for i in range(1, L):
        if segment_ids[i] != segment_ids[i - 1]:
            cur = i
        start[i] = cur
    return start


def materialize_from_starts(start: np.ndarray) -> np.ndarray:
    L = start.shape[0]
    j = np.arange(L)
    return (j[None, :] >= start[:, None]) & (j[None, :] <= np.arange(L)[:, None])
