"""Oracle for fused residual+RMSNorm."""
import jax
import jax.numpy as jnp


def fused_ref(x, res, scale, eps=1e-5):
    h = x.astype(jnp.float32) + res.astype(jnp.float32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    y = h * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)[None, :]
    return y.astype(x.dtype), h.astype(x.dtype)
