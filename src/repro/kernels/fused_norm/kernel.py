"""Fused residual-add + RMSNorm (the Table-5 'minority kernel' fusion).

Unfused, this is 3 HBM round trips (add, mean-square, scale); fused it is
one read + two writes.  FLARE's V_minority metric is exactly what flags the
unfused version (paper §7.3.3) — this kernel is the infra team's response.

Grid: (rows // block_r,).  One row tile [block_r, D] in VMEM per program.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_kernel(x_ref, res_ref, scale_ref, y_ref, res_out_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    r = res_ref[...].astype(jnp.float32)
    s = scale_ref[...].astype(jnp.float32)
    h = x + r
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    y = h * jax.lax.rsqrt(var + eps) * s[None, :]
    res_out_ref[...] = h.astype(res_out_ref.dtype)
    y_ref[...] = y.astype(y_ref.dtype)


def fused_residual_rmsnorm_fwd(x, res, scale, *, eps=1e-5, block_r=256,
                               interpret=False):
    """x,res [R,D]; scale [D] -> (normed [R,D], new_residual [R,D])."""
    R, D = x.shape
    block_r = min(block_r, R)
    assert R % block_r == 0
    kernel = functools.partial(_fused_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(R // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, D), lambda i: (i, 0)),
            pl.BlockSpec((block_r, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_r, D), lambda i: (i, 0)),
            pl.BlockSpec((block_r, D), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((R, D), x.dtype),
                   jax.ShapeDtypeStruct((R, D), x.dtype)],
        interpret=interpret,
    )(x, res, scale)
