from repro.kernels.fused_norm.ops import fused_residual_rmsnorm  # noqa: F401
