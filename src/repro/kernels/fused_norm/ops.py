"""jit'd entry point for the fused residual+RMSNorm kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels import interpret_default, traced_op
from repro.kernels.fused_norm.kernel import fused_residual_rmsnorm_fwd


def _meta(x, res, scale, **kw):
    return {"flops": 6.0 * x.size, "bytes": 4 * x.size * x.dtype.itemsize,
            "shape": list(x.shape)}


@traced_op("fused_residual_rmsnorm", "compute", _meta)
@functools.partial(jax.jit, static_argnames=("eps", "block_r", "interpret"))
def fused_residual_rmsnorm(x, res, scale, eps=1e-5, block_r=256,
                           interpret=None):
    if interpret is None:
        interpret = interpret_default()
    return fused_residual_rmsnorm_fwd(x, res, scale, eps=eps,
                                      block_r=block_r, interpret=interpret)
