"""Mamba2 chunked SSD scan (Pallas TPU).

Grid: (batch, heads, L // chunk) — the chunk axis is innermost/sequential;
the inter-chunk SSM state [N, P] lives in VMEM scratch and persists across
grid steps for a fixed (b, h), reset at chunk 0.  Within a chunk the
quadratic intra-term runs on the MXU; the state update is two small
matmuls.  This is the TPU-native shape of the SSD algorithm: HBM traffic
is O(L·(P+N)) while compute stays MXU-dense.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
                chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _reset():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[...].astype(jnp.float32)          # [Q, P]
    dt = dt_ref[...].astype(jnp.float32)        # [Q]
    A = a_ref[0].astype(jnp.float32)            # scalar (this head)
    Bm = b_ref[...].astype(jnp.float32)         # [Q, N]
    Cm = c_ref[...].astype(jnp.float32)         # [Q, N]

    dA = dt * A                                  # [Q], <= 0
    cum = jnp.cumsum(dA)                         # inclusive decay
    # ---- intra-chunk (quadratic) ---------------------------------------- #
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # [Q, Q]
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    scores = jnp.where(ti >= si, cb * decay, 0.0) * dt[None, :]
    y = jax.lax.dot(scores, x)                   # [Q, P]
    # ---- inter-chunk (state) -------------------------------------------- #
    S = state_ref[...]                           # [N, P]
    y += jnp.exp(cum)[:, None] * jax.lax.dot(Cm, S)
    w = jnp.exp(cum[-1] - cum) * dt              # [Q]
    S_new = jnp.exp(cum[-1]) * S + jax.lax.dot_general(
        Bm, w[:, None] * x, (((0,), (0,)), ((), ())))  # [N, P]
    state_ref[...] = S_new
    y_ref[...] = y.astype(y_ref.dtype)


def ssd_scan_fwd(x, dt, A, Bm, Cm, *, chunk=128, interpret=False):
    """x [B,L,H,P]; dt [B,L,H]; A [H]; Bm/Cm [B,L,N] -> y [B,L,H,P]."""
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, L)
    assert L % chunk == 0
    grid = (B, H, L // chunk)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, None, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((None, chunk, None), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((None, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((None, chunk, None, P),
                               lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
