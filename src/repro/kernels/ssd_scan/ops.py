"""jit'd entry point for the SSD scan kernel (+ FLARE registration)."""
from __future__ import annotations

import functools

import jax

from repro.kernels import interpret_default, traced_op
from repro.kernels.ssd_scan.kernel import ssd_scan_fwd


def _meta(x, dt, A, Bm, Cm, **kw):
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    chunk = kw.get("chunk", 128)
    flops = 2.0 * B * L * H * (chunk * (N + P) + N * P * 2)
    return {"flops": flops, "shape": list(x.shape)}


@traced_op("ssd_scan", "compute", _meta)
@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, chunk=128, interpret=None):
    if interpret is None:
        interpret = interpret_default()
    return ssd_scan_fwd(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
