"""Oracle: the sequential SSD recurrence (models/mamba2.ssd_sequential)."""
from repro.models.mamba2 import ssd_sequential


def ssd_ref(x, dt, A, Bm, Cm):
    y, _ = ssd_sequential(x, dt, A, Bm, Cm)
    return y
