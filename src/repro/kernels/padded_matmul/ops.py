"""Case-2 entry point: pad misaligned dims to the 128 tile, run the tiled
kernel, slice back.  ``padded_matmul(a, b)`` accepts ANY (M,K)x(K,N)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import interpret_default, traced_op
from repro.kernels.padded_matmul.kernel import matmul_tiled

TILE = 128


def _pad_to(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def _meta(a, b, **kw):
    M, K = a.shape
    N = b.shape[1]
    return {"flops": 2.0 * M * K * N, "shape": [M, K, N]}


@traced_op("padded_matmul", "compute", _meta)
@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def padded_matmul(a, b, block=TILE, interpret=None):
    if interpret is None:
        interpret = interpret_default()
    M, K = a.shape
    N = b.shape[1]
    ap = _pad_to(a, block, block)
    bp = _pad_to(b, block, block)
    out = matmul_tiled(ap, bp, block_m=block, block_n=block, block_k=block,
                       interpret=interpret)
    return out[:M, :N]
