from repro.kernels.padded_matmul.ops import padded_matmul  # noqa: F401
