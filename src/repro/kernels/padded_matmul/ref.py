"""Oracle for padded_matmul."""
import jax.numpy as jnp


def matmul_ref(a, b):
    return jnp.dot(a.astype(jnp.float32),
                   b.astype(jnp.float32)).astype(a.dtype)
