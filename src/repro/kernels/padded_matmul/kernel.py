"""Tiled matmul with in-wrapper MXU-alignment padding (paper Case-2, Fig 12).

The FSDP->Megatron migration shrank the FFN weight dim 33936 -> 8484, which
is not 128-byte aligned; FLOPS dropped 65.3%.  The infra team's fix (per
FLARE's layout advice) pads N up to the next 128 multiple so every MXU tile
is full, then slices the result.  Padding happens in ops.py; this kernel is
a classic 3-D-grid tiled matmul with a VMEM fp32 accumulator that requires
aligned shapes.

Grid: (M/bm, N/bn, K/bk) with the K dimension innermost ("arbitrary"
semantics — the accumulator tile is revisited across k steps).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        a_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_tiled(a, b, *, block_m=128, block_n=128, block_k=128,
                 interpret=False):
    """a [M,K] @ b [K,N]; all dims must be tile-aligned (ops.py pads)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, \
        (M, N, K, "padded_matmul requires aligned shapes — use ops.padded_matmul")
    k_steps = K // block_k
    grid = (M // block_m, N // block_n, k_steps)
    kernel = functools.partial(_mm_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(a, b)
