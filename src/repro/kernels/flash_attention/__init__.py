from repro.kernels.flash_attention.ops import flash_attention  # noqa: F401
