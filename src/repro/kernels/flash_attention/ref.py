"""Pure-jnp oracle for flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, causal=True):
    """q [B,S,H,hd]; k/v [B,T,KV,hd] -> [B,S,H,hd]; fp32 softmax."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bskgh,btkh->bkgst", qg, kf) * (hd ** -0.5)
    if causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(T)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkh->bskgh", w, vf)
    return o.reshape(B, S, H, hd).astype(q.dtype)
