"""jit'd entry point for the flash-attention kernel (+ FLARE registration)."""
from __future__ import annotations

import functools

import jax

from repro.kernels import interpret_default, traced_op
from repro.kernels.flash_attention.kernel import flash_attention_fwd


def _meta(q, k, v, **kw):
    B, S, H, hd = q.shape
    causal = kw.get("causal", True)
    factor = 0.5 if causal else 1.0
    return {"flops": 4.0 * B * S * S * H * hd * factor,
            "shape": list(q.shape)}


@traced_op("flash_attention", "compute", _meta)
@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                    interpret=None):
    if interpret is None:
        interpret = interpret_default()
    return flash_attention_fwd(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=interpret)
