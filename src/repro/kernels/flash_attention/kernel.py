"""Blocked causal GQA flash-attention forward (Pallas TPU).

Grid: (batch, q_heads, S // block_q).  Each program holds one q tile in
VMEM and streams k/v tiles; the kv loop runs only to the causal frontier,
so the compiled kernel does the ~S^2/2 work a full-mask XLA attention
cannot (cf. §Perf hillclimb H1).  Online softmax carries (o, m, l) in
registers; all matmul tiles are 128-aligned for the MXU.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, sm_scale,
                 causal, seq_len):
    qi = pl.program_id(2)
    q = q_ref[...].astype(jnp.float32) * sm_scale  # [block_q, hd]
    hd = q.shape[-1]

    q_base = qi * block_q
    if causal:
        hi = (q_base + block_q + block_k - 1) // block_k
    else:
        hi = seq_len // block_k

    def body(j, carry):
        o, m, l = carry
        k = pl.load(k_ref, (pl.dslice(j * block_k, block_k),
                            slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(j * block_k, block_k),
                            slice(None))).astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bk]
        if causal:
            qpos = q_base + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=1)
        o_new = o * alpha[:, None] + jax.lax.dot(p, v)
        return o_new, m_new, l_new

    o0 = jnp.zeros((block_q, hd), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    o, m, l = jax.lax.fori_loop(0, hi, body, (o0, m0, l0))
    o = o / jnp.maximum(l, 1e-30)[:, None]
    o_ref[...] = o.astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal=True, block_q=128, block_k=128,
                        interpret=False):
    """q [B,S,H,hd]; k/v [B,S,KV,hd] -> [B,S,H,hd]."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    sm_scale = hd ** -0.5
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0

    grid = (B, H, S // block_q)
    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k, sm_scale=sm_scale,
        causal=causal, seq_len=S)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, None, hd),
                         lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((None, S, None, hd),
                         lambda b, h, i, G=G: (b, 0, h // G, 0)),
            pl.BlockSpec((None, S, None, hd),
                         lambda b, h, i, G=G: (b, 0, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, None, hd),
                               lambda b, h, i: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out
