"""Pallas TPU kernels for the compute hot-spots + FLARE tracing seams.

Kernels (each: kernel.py = pl.pallas_call + BlockSpec, ops.py = jit'd
wrapper, ref.py = pure-jnp oracle):
  flash_attention  — blocked online-softmax causal GQA attention
  padded_matmul    — Case-2: MXU-alignment padding inside the tile
  ssd_scan         — Mamba2 chunked state-space scan
  fused_norm       — residual+RMSNorm fusion (Table-5 minority kernels)
  ring_reduce      — ring-combine step with progress export (intra-kernel
                     inspecting seam)

``interpret_default()`` is True off-TPU so kernels validate on CPU.
Every ops.py entry point self-registers with an attached FLARE daemon —
this is the paper's explicit "C++ interface" registration (§4.1).
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax


def interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def traced_op(name: str, kind: str = "compute",
              meta_fn: Optional[Callable] = None):
    """Wrap an op entry point with FLARE kernel tracing when attached."""
    from repro.core.daemon import get_daemon
    from repro.core.events import EventKind

    ekind = (EventKind.KERNEL_COMPUTE if kind == "compute"
             else EventKind.KERNEL_COMM)

    def deco(fn):
        def wrapped(*args, **kwargs):
            daemon = get_daemon()
            if daemon is None:
                return fn(*args, **kwargs)
            issue = time.perf_counter()
            out = fn(*args, **kwargs)
            meta = meta_fn(*args, **kwargs) if meta_fn else {}
            daemon._pending.put((name, ekind, issue, daemon._step, out, meta))
            return out
        wrapped.__name__ = getattr(fn, "__name__", name)
        wrapped.__wrapped__ = fn
        return wrapped
    return deco
