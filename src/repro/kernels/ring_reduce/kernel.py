"""Ring-combine step with progress export (the intra-kernel-inspecting seam).

One ring step of reduce-scatter is: acc_chunk += incoming_chunk.  This
kernel performs the chunked combine AND writes a per-block progress counter
to a dedicated output buffer — the TPU-native equivalent of the ring-step
registers FLARE reads out of a hung NCCL kernel with CUDA-GDB (paper Fig 6).
On hardware the progress buffer lives in HBM and is host-visible mid-kernel
via async copies; under a hang its frozen values feed
repro.core.inspecting.diagnose_ring directly.

Grid: (chunk_elems // block,) — progress[i] = i+1 after block i combines.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(acc_ref, in_ref, o_ref, prog_ref):
    i = pl.program_id(0)
    o_ref[...] = acc_ref[...] + in_ref[...]
    prog_ref[0] = i + 1  # SASS step-counter analogue, host-readable


def ring_combine_step(acc, incoming, *, block=1024, interpret=False):
    """acc, incoming [C] -> (combined [C], progress [C//block] int32)."""
    (C,) = acc.shape
    block = min(block, C)
    assert C % block == 0
    n_blocks = C // block
    out, prog = pl.pallas_call(
        _combine_kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((C,), acc.dtype),
                   jax.ShapeDtypeStruct((n_blocks,), jnp.int32)],
        interpret=interpret,
    )(acc, incoming)
    return out, prog
