"""jit'd entry point for the ring combine kernel (+ FLARE registration)."""
from __future__ import annotations

import functools

import jax

from repro.kernels import interpret_default, traced_op
from repro.kernels.ring_reduce.kernel import ring_combine_step


def _meta(acc, incoming, **kw):
    return {"bytes": 3 * acc.size * acc.dtype.itemsize,
            "shape": list(acc.shape)}


@traced_op("ring_combine", "comm", _meta)
@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def ring_combine(acc, incoming, block=1024, interpret=None):
    if interpret is None:
        interpret = interpret_default()
    return ring_combine_step(acc, incoming, block=block, interpret=interpret)
