from repro.kernels.ring_reduce.ops import ring_combine  # noqa: F401
