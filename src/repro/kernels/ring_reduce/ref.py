"""Oracle for the ring combine step."""
import jax.numpy as jnp


def combine_ref(acc, incoming):
    return acc + incoming


def progress_ref(C, block):
    return jnp.arange(1, C // block + 1, dtype=jnp.int32)
