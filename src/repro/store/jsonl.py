"""JSONL trace codec — line-per-event JSON (the historical daemon format).

Machinery extracted from ``repro.core.columnar`` behind the
:class:`~repro.store.base.TraceCodec` API: tolerant line-by-line decode,
the slab-wise array-parse fast path, and chunked/parallel file decode.
``EventBatch.from_jsonl*`` remain as thin deprecated shims over this
module.

Chunk decoding supports two executors:

  ``thread``   default — fine when json array-parsing releases enough of
               the GIL between slabs and for warm-cache replay;
  ``process``  a ``ProcessPoolExecutor``: ``json.loads`` is GIL-bound, and
               ``EventBatch`` pickles cheaply (numpy columns), so process
               workers scale decode with cores on multi-GB logs.
"""
from __future__ import annotations

import json
import os
import warnings
from typing import Iterator, Optional

import numpy as np

from repro.core.columnar import (NO_INT, _VALUE_TO_CODE, _split_meta,
                                 EventBatch, EventBatchBuilder)
from repro.core.events import dump_jsonl

_DECODE_SLAB = 65536          # lines array-parsed per json.loads call

_NO_META = (np.nan, NO_INT, NO_INT, None, None)


def _append_dicts(b: EventBatchBuilder, ds: list) -> None:
    """Append parsed JSONL row dicts to the builder with local bindings —
    the per-row ``append_scalar`` call was a third of decode time."""
    code = _VALUE_TO_CODE
    intern = b._intern_name
    igroup = b._intern_group
    sk, sn, sr = b._s_kind, b._s_nid, b._s_rank
    si, ss, se = b._s_issue, b._s_start, b._s_end
    st, sf, sb = b._s_step, b._s_flops, b._s_nbytes
    stk, sg = b._s_tokens, b._s_gid
    extra = b._extra
    base = b._count + len(sk)
    for n, d in enumerate(ds):
        m = d.get("m")
        flops, nbytes, tokens, group, rest = \
            _split_meta(m) if m else _NO_META
        sk.append(code[d["k"]])
        sn.append(intern(d["n"]))
        sr.append(d["r"])
        si.append(d["i"])
        ss.append(d["s"])
        se.append(d["e"])
        st.append(d.get("t", -1))
        sf.append(flops)
        sb.append(nbytes)
        stk.append(tokens)
        sg.append(igroup(group))
        if rest:
            extra[base + n] = rest


def _rollback_slab(b: EventBatchBuilder, n_rows: int, n_extra_base: int):
    """Drop scalar rows staged past ``n_rows`` (a slab whose array parse
    half-applied before hitting a malformed dict)."""
    for lst in (b._s_kind, b._s_nid, b._s_rank, b._s_issue, b._s_start,
                b._s_end, b._s_step, b._s_flops, b._s_nbytes, b._s_tokens,
                b._s_gid):
        del lst[n_rows:]
    for k in [k for k in b._extra if k >= n_extra_base]:
        del b._extra[k]


def decode_jsonl_lines(lines) -> tuple[EventBatch, int]:
    """Decode an iterable of JSONL lines (str or bytes) into one batch,
    skipping (and counting) undecodable lines.  Consumes the iterable
    slab-wise, so a multi-GB file is never materialized as a line list.

    Fast path: each slab is joined into one JSON array and parsed with a
    single ``json.loads`` (~2x a per-line loop).  Only a slab containing a
    corrupt/truncated line (common at the tail of killed jobs' logs) is
    rolled back and re-decoded tolerantly line by line — the intact rest
    of the file keeps the fast path."""
    from itertools import islice
    b = EventBatchBuilder()
    skipped = 0
    it = iter(lines)
    while True:
        raw = list(islice(it, _DECODE_SLAB))
        if not raw:
            break
        slab = [ln for ln in (line.strip() for line in raw) if ln]
        if not slab:
            continue
        lb, sep, rb = (b"[", b",", b"]") if isinstance(slab[0], bytes) \
            else ("[", ",", "]")
        n_rows = len(b._s_kind)
        try:
            _append_dicts(b, json.loads(lb + sep.join(slab) + rb))
            continue
        except (KeyError, TypeError, AttributeError, ValueError):
            _rollback_slab(b, n_rows, b._count + n_rows)
        for line in slab:
            try:
                d = json.loads(line)
                b.append_scalar(_VALUE_TO_CODE[d["k"]], d["n"], d["r"],
                                d["i"], d["s"], d["e"], d.get("t", -1),
                                d.get("m") or {})
            except (KeyError, TypeError, AttributeError, ValueError):
                skipped += 1
    return b.build(), skipped


def _chunk_spans(path: str, chunk_bytes: int) -> list[tuple[int, int]]:
    """Split ``path`` into ~chunk_bytes (lo, hi) byte spans on line
    boundaries: each span ends just after a newline (or at EOF)."""
    size = os.path.getsize(path)
    spans: list[tuple[int, int]] = []
    with open(path, "rb") as f:
        lo = 0
        while lo < size:
            hi = min(lo + chunk_bytes, size)
            if hi < size:
                f.seek(hi)
                f.readline()           # advance to the end of this line
                hi = min(f.tell(), size)
            spans.append((lo, hi))
            lo = hi
    return spans


def _decode_file_span(path: str, lo: int, hi: int) -> tuple[EventBatch, int]:
    with open(path, "rb") as f:
        f.seek(lo)
        data = f.read(hi - lo)
    return decode_jsonl_lines(data.split(b"\n"))


def _make_executor(executor: str, workers: int):
    """``executor`` is pre-validated by :func:`iter_jsonl_chunks`."""
    if executor == "process":
        from concurrent.futures import ProcessPoolExecutor
        try:
            return ProcessPoolExecutor(workers)
        except (OSError, ValueError) as e:   # no fork/spawn available
            warnings.warn(f"process executor unavailable ({e}); falling "
                          "back to threads", stacklevel=3)
    from concurrent.futures import ThreadPoolExecutor
    return ThreadPoolExecutor(workers)


# Below this file size, concurrent chunk decode LOSES to one serial pass:
# per-chunk executor overhead plus GIL contention (thread) or worker
# spawn + pickle cost (process) outweigh the parallel decode of a file
# that one json pass clears in well under a second.  Measured on the
# 256-rank fleet bench (~7 MB logs) where chunked decode ran 0.9x and
# process-pool 0.7x the plain line decoder.
SERIAL_DECODE_BYTES = 24 << 20


def _default_workers(executor: str) -> int:
    """Thread decode contends on the GIL between array-parse slabs, so
    more than a few threads just adds switching; process workers scale
    with cores until pickle traffic dominates."""
    cores = os.cpu_count() or 1
    return min(4, cores) if executor == "thread" else min(8, cores)


def iter_jsonl_chunks(path: str, *, chunk_bytes: int = 8 << 20,
                      max_workers: Optional[int] = None,
                      executor: str = "thread",
                      serial_below: Optional[int] = None,
                      ) -> Iterator[tuple[EventBatch, int]]:
    """Yield ``(EventBatch, skipped_lines)`` per line-aligned chunk of
    ``path``, decoding chunks concurrently but yielding in file order (so
    streaming consumers see events in log order).  In-flight decodes are
    capped at ``workers + 2`` so a slow consumer (e.g. replay driving
    diagnosis) bounds memory instead of buffering the whole decoded file.

    Files below ``serial_below`` bytes (default
    :data:`SERIAL_DECODE_BYTES`; pass ``0`` to force chunking) are
    decoded inline in one pass with no executor: on small-to-mid logs
    the parallel machinery is pure overhead and was measurably SLOWER
    than the line decoder.

    ``executor="process"`` decodes chunks in worker processes —
    ``json.loads`` holds the GIL, so threads cannot scale decode past one
    core, while batches cross the process boundary as cheap numpy-column
    pickles."""
    if executor not in ("thread", "process"):
        raise ValueError(f"executor must be 'thread' or 'process', "
                         f"got {executor!r}")
    threshold = SERIAL_DECODE_BYTES if serial_below is None else serial_below
    size = os.path.getsize(path)
    if size == 0:
        return
    if size < max(threshold, chunk_bytes + 1):
        yield _decode_file_span(path, 0, size)
        return
    spans = _chunk_spans(path, chunk_bytes)
    if len(spans) <= 1:
        if spans:
            yield _decode_file_span(path, *spans[0])
        return
    from collections import deque
    workers = max_workers or _default_workers(executor)
    with _make_executor(executor, workers) as ex:
        window = workers + 2
        futs = deque(ex.submit(_decode_file_span, path, *sp)
                     for sp in spans[:window])
        nxt = window
        while futs:
            yield futs.popleft().result()
            if nxt < len(spans):
                futs.append(ex.submit(_decode_file_span, path, *spans[nxt]))
                nxt += 1


def read_jsonl(path: str, *, with_skip_count: bool = False):
    """Line-by-line decode of a whole file.  Truncated/corrupt lines
    (common in logs of killed jobs) are SKIPPED with one counted warning
    instead of raising; ``with_skip_count=True`` returns
    ``(batch, skipped)``."""
    with open(path) as f:
        batch, skipped = decode_jsonl_lines(f)
    if skipped:
        warnings.warn(f"{path}: skipped {skipped} corrupt/truncated "
                      "JSONL line(s)", stacklevel=2)
    return (batch, skipped) if with_skip_count else batch


def read_jsonl_chunked(path: str, *, chunk_bytes: int = 8 << 20,
                       max_workers: Optional[int] = None,
                       executor: str = "thread",
                       serial_below: Optional[int] = None,
                       with_skip_count: bool = False):
    """Chunked/parallel decode of a whole file (identical result to
    :func:`read_jsonl` — interning order is first appearance in file
    order either way).  This is the replay fast path for multi-GB logs;
    small files auto-fall back to one serial pass (``serial_below``)."""
    parts: list[EventBatch] = []
    skipped = 0
    for b, sk in iter_jsonl_chunks(path, chunk_bytes=chunk_bytes,
                                   max_workers=max_workers,
                                   executor=executor,
                                   serial_below=serial_below):
        parts.append(b)
        skipped += sk
    batch = EventBatch.concat(parts)
    if skipped:
        warnings.warn(f"{path}: skipped {skipped} corrupt/truncated "
                      "JSONL line(s)", stacklevel=2)
    return (batch, skipped) if with_skip_count else batch


class JsonlCodec:
    """``TraceCodec`` facade over the module functions."""

    name = "jsonl"
    extensions = (".jsonl", ".json")

    def write(self, batch: EventBatch, path: str) -> int:
        return dump_jsonl(batch, path)

    def read(self, path: str, *, with_skip_count: bool = False):
        return read_jsonl(path, with_skip_count=with_skip_count)

    def iter_chunks(self, path: str, *, chunk_bytes: int = 8 << 20,
                    max_workers: Optional[int] = None,
                    executor: str = "thread",
                    serial_below: Optional[int] = None, **_ignored
                    ) -> Iterator[tuple[EventBatch, int]]:
        return iter_jsonl_chunks(path, chunk_bytes=chunk_bytes,
                                 max_workers=max_workers, executor=executor,
                                 serial_below=serial_below)
