"""FCS — Flare Columnar Segment: numpy-native binary trace storage.

JSONL replay is json-parse-bound (~0.1 Mev/s/core); a fleet that records
for months needs a format whose decode cost is ~zero.  FCS writes the
``EventBatch`` columns themselves: each ``write`` call appends one
self-contained *segment* — a small header, interning tables, and raw
little-endian column slabs — so reading is a header parse plus
``np.frombuffer`` views straight off an ``np.memmap`` (timestamp slabs
are zero-copy; narrowed columns pay one vectorized ``astype``).  No
per-row work, ever.

Compactness comes from per-column encodings picked at write time, all
lossless:

  ABSENT  column is all-null (0 bytes)
  CONST   all rows equal (one value)
  RAW     narrowest integer dtype that fits the value range
  DICT    value table + per-row codes (flops/bytes/tokens carry a handful
          of distinct per-op values across millions of rows; float tables
          are stored as raw u64 bit patterns so NaN round-trips exactly)
  SAMEAS  column is bit-identical to another (CPU spans: issue == start)

``extra`` meta dicts are dict-encoded too: a table of unique dicts
(Python-literal ``repr`` when it round-trips — preserving tuples exactly,
which JSON cannot — else JSON) plus sparse (row, code) index columns.

Three segment versions share the header and reader (dispatch is on the
header version field, so one file may even mix them — e.g. a daemon
restarted with a different spill config):

  v1  column slabs stored raw; decoding is zero-copy ``np.memmap`` views
      (the online / hot-replay format);
  v2  each column slab individually compressed (zstd when available,
      stdlib zlib otherwise; RAW slabs byte-shuffled first) — the
      archival format, ~2-3x smaller again, trading the memmap fast path
      for a per-slab inflate.  Header, interning blobs, and the column
      directory stay uncompressed so magic sniffing, segment skipping,
      and per-column tooling keep working.  Write it via the ``fcs2``
      codec (:class:`FcsV2Codec`) or ``write_fcs(..., version=2)``.
  v3  v2 plus a CRC-protected **statistics block** between the column
      directory and the payloads (step/time/rank ranges, an event-kind
      presence bitmask, per-column min/max — see ``repro.store.stats``):
      the queryable-archive format.  Readers prune whole segments on a
      :class:`~repro.store.stats.Predicate` without inflating a single
      slab (``iter_segments(path, predicate=...)``), and
      :func:`segment_stats` iterates the stats directory alone.  Write
      it via the ``fcs3`` codec (:class:`FcsV3Codec`) or
      ``write_fcs(..., version=3)``.

The exact byte layout is documented in ``src/repro/store/README.md``.
Corruption (bad magic, unknown version, a truncated tail from a killed
writer) raises :class:`~repro.store.base.CodecError` with file + byte
offset; ``iter_chunks`` yields every intact leading segment first so
replay can skip-and-count the broken tail.
"""
from __future__ import annotations

import ast
import json
import mmap
import os
import struct
from typing import Iterator, Optional

import numpy as np

from repro.core.columnar import NO_INT, EventBatch
from repro.store import compress as _comp
from repro.store.base import CodecError
from repro.store.stats import (Predicate, ScanStats, SegmentStats,
                               decode_stats_block, encode_stats_block,
                               stats_size)

MAGIC = b"FCS1"
VERSION = 1                              # default (raw-slab) segment version
VERSION_V2 = 2                           # compressed-slab segment version
VERSION_V3 = 3                           # v2 + per-segment stats block
_VERSIONS = (VERSION, VERSION_V2, VERSION_V3)

# header: magic, version, ncols, n_rows, seg_len, names_len, groups_len,
# extra_len — 48 bytes, so the blob region after it stays 8-aligned.
# Identical for v1 and v2 (seg_len is always the on-disk byte count).
_HEADER = struct.Struct("<4sHHQQQQQ")
_DIRENT = struct.Struct("<BBBBI")        # v1: col_id, enc, dtype/src, 0, len
# v2: col_id, enc, dtype/src, comp (backend | FLAG_SHUFFLE),
#     compressed len, raw len
_DIRENT2 = struct.Struct("<BBBBII")

# slabs below this stay uncompressed in v2: backend framing would only
# grow them, and they are noise next to the timestamp slabs anyway
_MIN_COMPRESS_BYTES = 128

# encodings
ENC_ABSENT, ENC_CONST, ENC_RAW, ENC_DICT, ENC_SAMEAS = range(5)

# storage dtypes (little-endian), ordered by itemsize for narrowing
_DTYPES = ("<u1", "<i1", "<u2", "<i2", "<u4", "<i4", "<i8", "<f8")
_DT_CODE = {dt: i for i, dt in enumerate(_DTYPES)}
_U64 = np.dtype("<u8")

# column table: (slot, runtime dtype, null value, wide storage dtype)
# the two trailing pseudo-columns hold the sparse extra-dict index.
_COLUMNS = (
    ("kind",     np.uint8,   0,       "<u1"),
    ("name_id",  np.int32,   0,       "<i4"),
    ("rank",     np.int32,   0,       "<i4"),
    ("issue_ts", np.float64, 0.0,     "<f8"),
    ("start_ts", np.float64, 0.0,     "<f8"),
    ("end_ts",   np.float64, 0.0,     "<f8"),
    ("step",     np.int32,   -1,      "<i4"),
    ("flops",    np.float64, np.nan,  "<f8"),
    ("nbytes",   np.int64,   NO_INT,  "<i8"),
    ("tokens",   np.int64,   NO_INT,  "<i8"),
    ("group_id", np.int16,   -1,      "<i2"),
    ("_extra_rows",  np.int64, 0, "<i8"),
    ("_extra_codes", np.int64, 0, "<i8"),
)
NCOLS = len(_COLUMNS)
_TS_COLS = (3, 4, 5)
_VALUE_COLS = (7, 8, 9)       # sparse numeric meta: DICT-friendly


def _pad8(n: int) -> int:
    return -n % 8


def _narrowest(mn: int, mx: int) -> str:
    for dt in ("<u1", "<i1", "<u2", "<i2", "<u4", "<i4", "<i8"):
        info = np.iinfo(dt)
        if info.min <= mn and mx <= info.max:
            return dt
    return "<i8"


def _code_dtype(n_values: int) -> str:
    return "<u1" if n_values <= 0xFF else \
           "<u2" if n_values <= 0xFFFF else "<u4"


# --------------------------------------------------------------------- #
# encode
# --------------------------------------------------------------------- #
def _encode_int_col(arr: np.ndarray, *, allow_const: bool = True
                    ) -> tuple[int, str, bytes]:
    """(enc, storage dtype, payload) for an integer column.  The sparse
    extra index columns pass ``allow_const=False``: their length is not
    ``n_rows``, so the decoder must be able to derive it from the payload
    size (RAW only)."""
    if arr.size == 0:
        return ENC_ABSENT, "<u1", b""
    mn, mx = int(arr.min()), int(arr.max())
    dt = _narrowest(mn, mx)
    if mn == mx and allow_const:
        return ENC_CONST, dt, arr[:1].astype(dt).tobytes()
    return ENC_RAW, dt, arr.astype(dt).tobytes()


def _encode_value_col(arr: np.ndarray, null, wide: str
                      ) -> tuple[int, str, bytes]:
    """flops/nbytes/tokens: ABSENT / CONST / DICT / RAW over full-width
    values.  Floats are dict-encoded as u64 bit patterns so NaN behaves
    like any other value (bit-exact, one table slot)."""
    n = arr.size
    is_f = arr.dtype.kind == "f"
    if n == 0:
        return ENC_ABSENT, "<u1", b""
    if is_f:
        if bool(np.isnan(arr).all()):
            return ENC_ABSENT, "<u1", b""
    elif bool((arr == null).all()):
        return ENC_ABSENT, "<u1", b""
    bits = arr.view(_U64) if is_f else arr
    table, codes = np.unique(bits, return_inverse=True)
    if table.size == 1:
        return ENC_CONST, wide, arr[:1].astype(wide).tobytes()
    cdt = _code_dtype(table.size)
    dict_size = 4 + table.size * 8 + n * np.dtype(cdt).itemsize
    if dict_size < n * 8:
        payload = (struct.pack("<I", table.size)
                   + table.astype("<u8" if is_f else "<i8").tobytes()
                   + codes.astype(cdt).tobytes())
        return ENC_DICT, cdt, payload
    return ENC_RAW, wide, arr.astype(wide).tobytes()


def _encode_ts_col(arr: np.ndarray, col_id: int, batch: EventBatch
                   ) -> tuple[int, str, bytes]:
    if arr.size == 0:
        return ENC_ABSENT, "<u1", b""
    # start_ts (col 4) is the canonical timeline; issue/end frequently
    # alias it bit-for-bit (CPU spans, hang markers)
    if col_id != 4 and np.array_equal(arr, batch.start_ts):
        return ENC_SAMEAS, "<f8", b""
    if bool((arr == arr[0]).all()):
        return ENC_CONST, "<f8", arr[:1].astype("<f8").tobytes()
    return ENC_RAW, "<f8", arr.astype("<f8").tobytes()


def _encode_extra(batch: EventBatch
                  ) -> tuple[bytes, np.ndarray, np.ndarray]:
    """Dedupe the row->dict table: returns (json table blob, rows, codes).

    Unique dicts (by identity first — the daemon shares one meta dict
    across a whole rank-vector — then by serialized form) are stored once
    as ``p:<repr>`` when ``ast.literal_eval`` round-trips (tuples survive)
    or ``j:<json>`` otherwise."""
    if not batch.extra:
        return b"", np.empty(0, np.int64), np.empty(0, np.int64)
    table: list[str] = []
    code_by_key: dict[str, int] = {}
    code_by_id: dict[int, int] = {}
    rows = np.fromiter(sorted(batch.extra), np.int64, len(batch.extra))
    codes = np.empty(rows.size, np.int64)
    for i, row in enumerate(rows.tolist()):
        d = batch.extra[row]
        c = code_by_id.get(id(d))
        if c is None:
            key = _serialize_meta(d)
            c = code_by_key.get(key)
            if c is None:
                c = code_by_key[key] = len(table)
                table.append(key)
            code_by_id[id(d)] = c
        codes[i] = c
    return json.dumps(table, separators=(",", ":")).encode(), rows, codes


def _serialize_meta(d: dict) -> str:
    r = repr(d)
    try:
        if ast.literal_eval(r) == d:
            return "p:" + r
    except (ValueError, SyntaxError, MemoryError):
        pass
    try:
        return "j:" + json.dumps(d)
    except (TypeError, ValueError) as e:
        raise CodecError(f"meta dict not serializable for FCS: {d!r} "
                         f"({e})") from e


def _deserialize_meta(s: str) -> dict:
    if s.startswith("p:"):
        return ast.literal_eval(s[2:])
    return json.loads(s[2:])


def _compress_slab(payload: bytes, enc: int, dt_byte: int, backend: int,
                   level: Optional[int]) -> tuple[int, bytes]:
    """(comp byte, on-disk bytes) for one v2 slab.  RAW slabs of multi-
    byte values are byte-shuffled first (timestamps dominate segment
    size and shuffle is what makes them compress); a slab that would not
    shrink is stored verbatim so v2 never exceeds v1 + directory."""
    if backend == _comp.COMP_STORED or len(payload) < _MIN_COMPRESS_BYTES:
        return _comp.COMP_STORED, payload
    flags = 0
    data = payload
    if enc == ENC_RAW:
        itemsize = np.dtype(_DTYPES[dt_byte]).itemsize
        if itemsize > 1:
            data = _comp.shuffle(payload, itemsize)
            flags = _comp.FLAG_SHUFFLE
    cdata = _comp.compress(data, backend, level)
    if len(cdata) >= len(payload):
        return _comp.COMP_STORED, payload
    return backend | flags, cdata


def encode_segment(batch: EventBatch, *, version: int = VERSION,
                   compression: Optional[str] = None,
                   level: Optional[int] = None) -> bytes:
    """One self-contained segment for ``batch`` (appendable bytes).

    ``version=2`` compresses each column slab (``compression`` names the
    backend — ``"zstd"``/``"zlib"``/``None`` = best available — and
    ``level`` its setting); header, interning blobs, and the column
    directory stay plain.  ``version=3`` additionally writes the stats
    block (pruning directory) between the directory and the payloads."""
    if version not in _VERSIONS:
        raise ValueError(f"unsupported FCS segment version {version}")
    n = len(batch)
    names_blob = json.dumps(batch.names, separators=(",", ":")).encode() \
        if batch.names else b""
    groups_blob = json.dumps(batch.groups, separators=(",", ":")).encode() \
        if batch.groups else b""
    extra_blob, extra_rows, extra_codes = _encode_extra(batch)
    backend = _comp.resolve_backend(compression) if version != VERSION \
        else None

    entries: list[bytes] = []
    payloads: list[bytes] = []
    cols = (batch.kind, batch.name_id, batch.rank, batch.issue_ts,
            batch.start_ts, batch.end_ts, batch.step, batch.flops,
            batch.nbytes, batch.tokens, batch.group_id,
            extra_rows, extra_codes)
    for col_id, ((_, _, null, wide), arr) in enumerate(zip(_COLUMNS, cols)):
        if col_id in _TS_COLS:
            enc, dt, payload = _encode_ts_col(arr, col_id, batch)
        elif col_id in _VALUE_COLS:
            enc, dt, payload = _encode_value_col(arr, null, wide)
        else:
            enc, dt, payload = _encode_int_col(arr, allow_const=col_id < 11)
        # SAMEAS stores the source column id (always start_ts) in the
        # dtype slot
        dt_byte = 4 if enc == ENC_SAMEAS else _DT_CODE[dt]
        if version != VERSION:
            comp, disk = _compress_slab(payload, enc, dt_byte, backend,
                                        level)
            entries.append(_DIRENT2.pack(col_id, enc, dt_byte, comp,
                                         len(disk), len(payload)))
        else:
            disk = payload
            entries.append(_DIRENT.pack(col_id, enc, dt_byte, 0,
                                        len(payload)))
        payloads.append(disk + b"\0" * _pad8(len(disk)))

    directory = b"".join(entries)
    stats = encode_stats_block(cols) if version == VERSION_V3 else b""
    blob = names_blob + groups_blob + extra_blob
    body = blob + b"\0" * _pad8(len(blob)) + directory \
        + b"\0" * _pad8(len(directory)) + stats + b"".join(payloads)
    seg_len = _HEADER.size + len(body)
    header = _HEADER.pack(MAGIC, version, NCOLS, n, seg_len,
                          len(names_blob), len(groups_blob),
                          len(extra_blob))
    return header + body


# --------------------------------------------------------------------- #
# decode
# --------------------------------------------------------------------- #
def _view(buf, dtype: str, count: int, offset: int,
          path: Optional[str] = None) -> np.ndarray:
    try:
        return np.frombuffer(buf, dtype, count, offset)
    except ValueError as e:
        raise CodecError(f"column slab out of bounds ({e})",
                         path=path, offset=offset) from e


def _decode_col(arrays, sameas, col_id: int, enc: int, dt_byte: int,
                buf, pos: int, plen: int, n: int, path: str) -> None:
    """Decode one column slab (``plen`` raw bytes of ``buf`` at ``pos``)
    into ``arrays[col_id]``.  Shared by v1 (slab = file view) and v2
    (slab = inflated bytes)."""
    _, rdtype, null, _wide = _COLUMNS[col_id]

    def _need(expected: int):
        # a corrupted length field must fail loudly here: frombuffer
        # reads from `pos` regardless of plen while the cursor advances
        # BY plen, so a mismatch would silently shift every later column
        if plen != expected:
            raise CodecError(
                f"column {col_id} slab length {plen} != expected "
                f"{expected} for encoding {enc}", path=path, offset=pos)

    if enc == ENC_ABSENT:
        _need(0)
        # the sparse extra index columns (11, 12) carry their own
        # length; every real column has n_rows entries
        arrays[col_id] = np.empty(0, np.int64) if col_id >= 11 \
            else np.full(n, null, rdtype)
    elif enc == ENC_SAMEAS:
        _need(0)
        sameas.append((col_id, dt_byte))
    elif enc == ENC_CONST:
        dt = _DTYPES[dt_byte]
        _need(np.dtype(dt).itemsize)
        arrays[col_id] = np.full(n, _view(buf, dt, 1, pos, path)[0],
                                 rdtype)
    elif enc == ENC_RAW:
        dt = _DTYPES[dt_byte]
        isz = np.dtype(dt).itemsize
        if col_id < 11:
            _need(n * isz)
            cnt = n
        else:
            if plen % isz:
                raise CodecError(f"column {col_id} slab length {plen} "
                                 f"not a multiple of itemsize {isz}",
                                 path=path, offset=pos)
            cnt = plen // isz
        a = _view(buf, dt, cnt, pos, path)
        arrays[col_id] = a if a.dtype == np.dtype(rdtype) \
            else a.astype(rdtype)
    elif enc == ENC_DICT:
        cdt = _DTYPES[dt_byte]
        if plen < 4:
            raise CodecError(f"column {col_id} DICT payload too short",
                             path=path, offset=pos)
        (ntab,) = struct.unpack_from("<I", buf, pos)
        _need(4 + ntab * 8 + n * np.dtype(cdt).itemsize)
        is_f = np.dtype(rdtype).kind == "f"
        table = _view(buf, "<u8" if is_f else "<i8", ntab, pos + 4, path)
        codes = _view(buf, cdt, n, pos + 4 + ntab * 8, path)
        if codes.size and int(codes.max()) >= ntab:
            raise CodecError(f"column {col_id} DICT code "
                             f"{int(codes.max())} out of table range "
                             f"{ntab}", path=path, offset=pos)
        out = table[codes]
        arrays[col_id] = out.view(np.float64) if is_f \
            else out.astype(rdtype, copy=False)
    else:
        raise CodecError(f"unknown encoding {enc} for column {col_id}",
                         path=path, offset=pos)


def _inflate_slab(buf, pay: int, clen: int, rlen: int, comp: int,
                  dt_byte: int, path: str) -> bytes:
    """v2 slab -> raw bytes: decompress with the per-slab backend, then
    undo the byte shuffle when the writer applied one."""
    backend = comp & _comp.COMP_MASK
    if backend == _comp.COMP_STORED:
        data = bytes(buf[pay:pay + clen])
        if len(data) != rlen:
            raise CodecError(f"stored slab is {len(data)} bytes, "
                             f"directory declares {rlen}",
                             path=path, offset=pay)
    else:
        data = _comp.decompress(buf[pay:pay + clen], backend, rlen,
                                path=path, offset=pay)
    if comp & _comp.FLAG_SHUFFLE:
        if dt_byte >= len(_DTYPES):
            raise CodecError(f"shuffled slab with bad dtype byte {dt_byte}",
                             path=path, offset=pay)
        isz = np.dtype(_DTYPES[dt_byte]).itemsize
        if isz <= 1 or len(data) % isz:
            raise CodecError("shuffled slab length inconsistent with "
                             f"dtype itemsize {isz}", path=path, offset=pay)
        data = _comp.unshuffle(data, isz)
    return data


def _parse_header(buf, off: int, path: str):
    """Validate + unpack one segment header; returns ``(version, ncols,
    n_rows, seg_len, names_len, groups_len, extra_len)``."""
    size = len(buf)
    if off + _HEADER.size > size:
        raise CodecError("truncated segment header "
                         f"({size - off} bytes left, need {_HEADER.size})",
                         path=path, offset=off)
    magic, version, ncols, n, seg_len, names_len, groups_len, extra_len = \
        _HEADER.unpack_from(buf, off)
    if magic != MAGIC:
        raise CodecError(f"bad magic {magic!r} (expected {MAGIC!r})",
                         path=path, offset=off)
    if version not in _VERSIONS:
        raise CodecError(f"unsupported FCS version {version}",
                         path=path, offset=off)
    if seg_len < _HEADER.size:
        raise CodecError(f"implausible segment length {seg_len}",
                         path=path, offset=off)
    if off + seg_len > size:
        raise CodecError("truncated segment: partial slab "
                         f"(need {seg_len} bytes, {size - off} left)",
                         path=path, offset=off)
    return version, ncols, n, seg_len, names_len, groups_len, extra_len


def _stats_offset(off: int, ncols: int, names_len: int, groups_len: int,
                  extra_len: int, dirent_size: int) -> int:
    """Byte offset of a v3 segment's stats block (right after the padded
    column directory)."""
    blob = names_len + groups_len + extra_len
    dir_bytes = ncols * dirent_size
    return off + _HEADER.size + blob + _pad8(blob) \
        + dir_bytes + _pad8(dir_bytes)


def decode_segment(buf, off: int, path: str) -> tuple[EventBatch, int]:
    """Decode one segment of ``buf`` starting at byte ``off``; returns
    ``(batch, next_offset)``.  Dispatches on the header version field
    (v1 raw slabs / v2 compressed slabs / v3 compressed slabs + stats
    block, whose CRC is verified here so corruption never goes quiet).
    Raises :class:`CodecError` on a bad magic, unsupported version, or a
    slab truncated by a killed writer."""
    version, ncols, n, seg_len, names_len, groups_len, extra_len = \
        _parse_header(buf, off, path)
    if ncols < NCOLS:
        raise CodecError(f"segment declares {ncols} columns, need {NCOLS}",
                         path=path, offset=off)

    p = off + _HEADER.size
    try:
        names = json.loads(bytes(buf[p:p + names_len]) or b"[]")
        groups = json.loads(
            bytes(buf[p + names_len:p + names_len + groups_len]) or b"[]")
        eb = bytes(buf[p + names_len + groups_len:
                       p + names_len + groups_len + extra_len])
        extra_table = [_deserialize_meta(s) for s in json.loads(eb)] \
            if eb else []
    except (ValueError, SyntaxError) as e:
        raise CodecError(f"corrupt interning/meta tables ({e})",
                         path=path, offset=p) from e
    blob = names_len + groups_len + extra_len
    p += blob + _pad8(blob)
    dirent = _DIRENT if version == VERSION else _DIRENT2
    dir_bytes = ncols * dirent.size
    if p + dir_bytes > off + seg_len:
        raise CodecError("column directory overruns segment "
                         "(corrupt blob lengths)", path=path, offset=p)

    arrays: list[Optional[np.ndarray]] = [None] * NCOLS
    sameas: list[tuple[int, int]] = []
    pay = p + dir_bytes + _pad8(dir_bytes)
    if version == VERSION_V3:
        # verify the stats block even on a full decode: a bit-flipped
        # stats entry must fail loudly here, not mis-prune a later scan
        decode_stats_block(buf, pay, ncols, off, seg_len, n, version,
                           path=path)
        pay += stats_size(ncols)
    for i in range(ncols):
        ent = p + i * dirent.size
        if version == VERSION:
            col_id, enc, dt_byte, _, disk_len = _DIRENT.unpack_from(buf, ent)
        else:
            col_id, enc, dt_byte, comp, disk_len, raw_len = \
                _DIRENT2.unpack_from(buf, ent)
        if pay + disk_len > off + seg_len:
            raise CodecError(f"column {col_id} slab overruns segment",
                             path=path, offset=pay)
        if col_id >= NCOLS:      # forward-compat: ignore unknown columns
            pay += disk_len + _pad8(disk_len)
            continue
        if version == VERSION:
            # raw slab decoded in place: memmap views stay zero-copy
            _decode_col(arrays, sameas, col_id, enc, dt_byte,
                        buf, pay, disk_len, n, path)
        else:
            slab = _inflate_slab(buf, pay, disk_len, raw_len, comp,
                                 dt_byte, path)
            _decode_col(arrays, sameas, col_id, enc, dt_byte,
                        slab, 0, raw_len, n, path)
        pay += disk_len + _pad8(disk_len)
    for col_id, src in sameas:
        if arrays[src] is None:
            raise CodecError(f"SAMEAS column {col_id} references "
                             f"unresolved column {src}", path=path, offset=off)
        arrays[col_id] = arrays[src]

    extra: dict[int, dict] = {}
    rows_a, codes_a = arrays[11], arrays[12]
    if rows_a is not None and rows_a.size:
        for r, c in zip(rows_a.tolist(), codes_a.tolist()):
            try:
                extra[int(r)] = extra_table[int(c)]
            except IndexError:
                raise CodecError(f"extra code {c} out of table range",
                                 path=path, offset=off) from None
    batch = EventBatch(arrays[0], arrays[1], arrays[2], arrays[3],
                       arrays[4], arrays[5], arrays[6], arrays[7],
                       arrays[8], arrays[9], arrays[10],
                       list(names), list(groups), extra)
    return batch, off + seg_len


def _open_buffer(path: str, use_mmap: bool):
    """Map (or read) the file; a memory-map keeps decoded column views
    zero-copy, and the views hold a reference to the map so they stay
    valid after every file handle is closed."""
    with open(path, "rb") as f:
        if not use_mmap:
            return f.read()
        size = os.fstat(f.fileno()).st_size
        if size == 0:
            return b""
        return mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ)


def _segment_stats_at(buf, off: int, path: str) -> SegmentStats:
    """Stats for the segment at ``off`` without touching any slab: v3
    parses + CRC-checks the stats block; v1/v2 return header-only facts
    with ``has_stats=False`` (meaning "cannot prune")."""
    version, ncols, n, seg_len, names_len, groups_len, extra_len = \
        _parse_header(buf, off, path)
    if version != VERSION_V3:
        return SegmentStats(offset=off, seg_len=seg_len, n_rows=n,
                            version=version)
    spos = _stats_offset(off, ncols, names_len, groups_len, extra_len,
                         _DIRENT2.size)
    return decode_stats_block(buf, spos, ncols, off, seg_len, n, version,
                              path=path)


def segment_stats(path: str, *, use_mmap: bool = True
                  ) -> Iterator[SegmentStats]:
    """Iterate the file's stats directory alone — header + stats block
    per segment, hopping by ``seg_len`` — never inflating a column slab.
    v1/v2 segments yield header-only entries (``has_stats=False``);
    corrupt stats blocks raise :class:`CodecError`."""
    buf = _open_buffer(path, use_mmap)
    off = 0
    size = len(buf)
    while off < size:
        try:
            st = _segment_stats_at(buf, off, path)
        except CodecError:
            raise
        except (struct.error, IndexError, ValueError, KeyError) as e:
            raise CodecError(f"corrupt segment ({type(e).__name__}: {e})",
                             path=path, offset=off) from e
        yield st
        off += st.seg_len


def iter_segments(path: str, *, use_mmap: bool = True,
                  predicate: Optional[Predicate] = None,
                  scan: Optional[ScanStats] = None
                  ) -> Iterator[EventBatch]:
    """Yield each intact segment in file order; raises
    :class:`CodecError` at the first corrupt one (after yielding every
    good segment before it).  Bit-rot that slips past the structural
    checks (e.g. a flipped dtype byte making a slab misparse) is
    rewrapped so replay's skip-and-count contract holds.

    With a ``predicate``, v3 segments whose stats prove no row can match
    are skipped on the stats block alone — no slab is inflated, the scan
    just hops ``seg_len`` bytes.  Pruning is segment-granular and
    conservative: yielded segments may still contain non-matching rows
    (callers wanting exact rows apply ``predicate.filter``), and v1/v2
    segments always decode.  Pass a :class:`ScanStats` as ``scan`` to
    account decoded vs skipped bytes."""
    buf = _open_buffer(path, use_mmap)
    off = 0
    size = len(buf)
    prune = predicate is not None and not predicate.empty
    while off < size:
        try:
            if prune:
                st = _segment_stats_at(buf, off, path)
                if st.version == VERSION_V3 and not predicate.may_match(st):
                    if scan is not None:
                        scan.segments += 1
                        scan.segments_skipped += 1
                        scan.bytes_skipped += st.seg_len
                    off += st.seg_len
                    continue
            batch, next_off = decode_segment(buf, off, path)
        except CodecError:
            raise
        except (struct.error, IndexError, ValueError, KeyError) as e:
            raise CodecError(f"corrupt segment ({type(e).__name__}: {e})",
                             path=path, offset=off) from e
        if scan is not None:
            scan.segments += 1
            scan.bytes_decoded += next_off - off
            scan.rows += len(batch)
        off = next_off
        yield batch


def read_fcs(path: str, *, with_skip_count: bool = False,
             use_mmap: bool = True):
    """Decode a whole (possibly multi-segment) file into one batch."""
    parts = list(iter_segments(path, use_mmap=use_mmap))
    batch = parts[0] if len(parts) == 1 else EventBatch.concat(parts)
    return (batch, 0) if with_skip_count else batch


def write_fcs(batch: EventBatch, path: str, *, version: int = VERSION,
              compression: Optional[str] = None,
              level: Optional[int] = None) -> int:
    """Append one segment; returns bytes written.  ``version=2`` writes a
    compressed archival segment, ``version=3`` adds the stats block
    (see :func:`encode_segment`)."""
    seg = encode_segment(batch, version=version, compression=compression,
                         level=level)
    with open(path, "ab") as f:
        f.write(seg)
    return len(seg)


def encode_batch_bytes(batch: EventBatch, *, version: int = VERSION_V2,
                       compression: Optional[str] = None,
                       level: Optional[int] = None) -> bytes:
    """One in-memory FCS segment for ``batch`` — the fleet IPC wire
    format.  Identical bytes to what :func:`write_fcs` appends to disk,
    so a batch shipped across a process boundary costs the same ~11.5
    B/event as the archival spill (v2 compressed slabs by default)
    instead of a numpy pickle.  Round-trips through
    :func:`decode_batch_bytes`."""
    return encode_segment(batch, version=version, compression=compression,
                          level=level)


def tail_complete_segments(path: str, offset: int = 0
                           ) -> tuple[list[EventBatch], int]:
    """Tail a GROWING FCS stream: decode every segment that is complete
    on disk at/after byte ``offset`` and return ``(batches,
    new_offset)``, leaving a partial trailing segment (a write in
    flight, or fewer bytes than a header) for the next call — resume by
    passing ``new_offset`` back in.  This is how a live tailer follows a
    :class:`~repro.store.writer.SegmentedTraceWriter` file without ever
    racing the writer's appends: segment boundaries are the commit
    points.  Structural corruption at a completed offset (bad magic,
    bad version, CRC) raises :class:`CodecError` exactly like
    :func:`iter_segments` — a torn tail that never completes is the
    CALLER's corruption signal at end of stream."""
    with open(path, "rb") as f:
        f.seek(offset)
        data = f.read()
    out: list[EventBatch] = []
    off = 0
    size = len(data)
    while size - off >= _HEADER.size:
        magic, _version, _ncols, _n, seg_len = \
            _HEADER.unpack_from(data, off)[:5]
        if magic != MAGIC:
            raise CodecError(f"bad magic {magic!r} (expected {MAGIC!r})",
                             path=path, offset=offset + off)
        if seg_len < _HEADER.size:
            raise CodecError(f"implausible segment length {seg_len}",
                             path=path, offset=offset + off)
        if off + seg_len > size:
            break                    # incomplete tail: write in flight
        try:
            batch, off = decode_segment(data, off, path)
        except CodecError:
            raise
        except (struct.error, IndexError, ValueError, KeyError) as e:
            raise CodecError(f"corrupt segment ({type(e).__name__}: {e})",
                             path=path, offset=offset + off) from e
        out.append(batch)
    return out, offset + off


def decode_batch_bytes(buf) -> EventBatch:
    """Decode one or more concatenated FCS segments from an in-memory
    buffer (bytes/memoryview) into a single batch.  The inverse of
    :func:`encode_batch_bytes`; multi-segment buffers concat in order."""
    parts: list[EventBatch] = []
    off = 0
    size = len(buf)
    while off < size:
        batch, off = decode_segment(buf, off, "<memory>")
        parts.append(batch)
    if not parts:
        return EventBatch.empty()
    return parts[0] if len(parts) == 1 else EventBatch.concat(parts)


class FcsCodec:
    """v1 (raw-slab) writer; the read side handles both versions, so one
    file may mix v1 and v2 segments and still decode in one pass."""

    name = "fcs"
    extensions = (".fcs",)
    version = VERSION
    compression: Optional[str] = None
    level: Optional[int] = None

    def write(self, batch: EventBatch, path: str) -> int:
        return write_fcs(batch, path, version=self.version,
                         compression=self.compression, level=self.level)

    def read(self, path: str, *, with_skip_count: bool = False):
        return read_fcs(path, with_skip_count=with_skip_count)

    def iter_chunks(self, path: str, *,
                    predicate: Optional[Predicate] = None,
                    scan: Optional[ScanStats] = None, **_ignored
                    ) -> Iterator[tuple[EventBatch, int]]:
        for batch in iter_segments(path, predicate=predicate, scan=scan):
            yield batch, 0


class FcsV2Codec(FcsCodec):
    """Archival FCS: zstd/zlib-compressed column slabs (~2-3x smaller on
    long-horizon logs), same reader, same replay path.  Registered as
    ``"fcs2"`` — select it with ``DaemonConfig(log_codec="fcs2")``, a
    ``.fcs2`` spill extension, or instantiate with an explicit backend
    and level for custom ratio/speed trade-offs."""

    name = "fcs2"
    extensions = (".fcs2",)
    version = VERSION_V2

    def __init__(self, compression: Optional[str] = None,
                 level: Optional[int] = None):
        self.compression = compression
        self.level = level


class FcsV3Codec(FcsV2Codec):
    """Queryable-archive FCS: v2's compressed slabs plus the per-segment
    stats block, so readers prune segments on (step, time, rank,
    severity) predicates without inflating slabs.  ~272 bytes/segment of
    overhead — noise next to any real slab.  Registered as ``"fcs3"`` —
    select it with ``DaemonConfig(log_codec="fcs3")`` or a ``.fcs3``
    spill extension; this is what :class:`repro.archive.TraceArchive`
    expects rotated segments to be written in (though it reads all
    three versions)."""

    name = "fcs3"
    extensions = (".fcs3",)
    version = VERSION_V3
