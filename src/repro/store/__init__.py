"""Pluggable trace storage: codec registry + on-disk formats.

Usage::

    from repro import store

    store.get_codec("fcs").write(batch, "job-a.fcs")     # append a segment
    batch = store.read_trace("logs/job-a.fcs")           # format-detected
    for chunk, skipped in store.iter_trace_chunks(path): ...

See ``src/repro/store/README.md`` for the FCS on-disk layout.
"""
from repro.store.base import (CodecError, TraceCodec, codec_for_path,
                              codecs, get_codec, register_codec,
                              sniff_format)
from repro.store.compress import have_zstd
from repro.store.fcs import (FcsCodec, FcsV2Codec, FcsV3Codec,
                             decode_batch_bytes, encode_batch_bytes,
                             read_fcs, segment_stats,
                             tail_complete_segments, write_fcs)
from repro.store.jsonl import (JsonlCodec, decode_jsonl_lines,
                               iter_jsonl_chunks, read_jsonl,
                               read_jsonl_chunked)
from repro.store.stats import (SEVERITY_KINDS, STAT_COLUMNS, Predicate,
                               ScanStats, SegmentStats)
from repro.store.writer import (ROLLUP_SUFFIX, SegmentedTraceWriter,
                                is_sidecar_path, job_id_for_path,
                                seg_index, seg_path)

JSONL = register_codec(JsonlCodec())
FCS = register_codec(FcsCodec())
FCS2 = register_codec(FcsV2Codec())
FCS3 = register_codec(FcsV3Codec())


def read_trace(path: str, *, codec: str | None = None,
               with_skip_count: bool = False):
    """Decode a whole trace file with an explicit or auto-detected codec."""
    c = get_codec(codec) if codec else codec_for_path(path)
    return c.read(path, with_skip_count=with_skip_count)


def write_trace(batch, path: str, *, codec: str | None = None) -> int:
    """Append ``batch`` to ``path``; returns bytes written."""
    c = get_codec(codec) if codec else codec_for_path(path, default="jsonl")
    return c.write(batch, path)


def iter_trace_chunks(path: str, *, codec: str | None = None, **opts):
    """Stream ``(EventBatch, skipped)`` chunks in file order."""
    c = get_codec(codec) if codec else codec_for_path(path)
    return c.iter_chunks(path, **opts)


__all__ = [
    "CodecError", "TraceCodec", "JsonlCodec", "FcsCodec", "FcsV2Codec",
    "FcsV3Codec", "JSONL", "FCS", "FCS2", "FCS3", "have_zstd",
    "register_codec", "get_codec", "codecs", "codec_for_path",
    "sniff_format", "read_trace", "write_trace", "iter_trace_chunks",
    "read_jsonl", "read_jsonl_chunked", "iter_jsonl_chunks",
    "decode_jsonl_lines", "read_fcs",
    "write_fcs", "encode_batch_bytes", "decode_batch_bytes",
    "segment_stats", "tail_complete_segments",
    "Predicate", "ScanStats", "SegmentStats",
    "SEVERITY_KINDS", "STAT_COLUMNS", "SegmentedTraceWriter", "seg_path",
    "seg_index", "job_id_for_path", "is_sidecar_path", "ROLLUP_SUFFIX",
]
