"""Segmented trace writer: codec-agnostic spill with size-based rotation.

The daemon spill path appends one batch per drain for the lifetime of a
job — months, for the fleet's long-runners.  ``SegmentedTraceWriter``
owns the on-disk layout of that stream: every ``write`` appends through
the configured codec, and once the current file passes ``rotate_bytes``
the writer rolls to ``<stem>.seg001<ext>``, ``<stem>.seg002<ext>``, …
so any single file stays cheap to ship, replay, or delete.  The replayer
(:func:`job_id_for_path`) strips the ``.segNNN`` infix, so every rotated
piece replays under the same job id, in order (plain lexicographic sort:
the bare base file sorts before its ``.segNNN`` siblings).
"""
from __future__ import annotations

import os
import re
from typing import Optional, Union

from repro.store.base import TraceCodec, codec_for_path, get_codec

_SEG_RE = re.compile(r"\.seg(\d{3,})$")

# non-trace companions that live next to trace files and can collide
# with codec extension globs (JSONL claims ``*.json``): the archive's
# persistent rollup cache (``<trace>.rollup.json``) and its telemetry
# exports (``telemetry-NNN.json``)
_TELEMETRY_RE = re.compile(r"^telemetry-\d+\.json$")

ROLLUP_SUFFIX = ".rollup.json"


def is_sidecar_path(path: str) -> bool:
    """True for archive sidecar files (rollup caches, telemetry exports,
    service checkpoints) that must not be treated as trace logs even
    when a codec's extension glob matches them."""
    base = os.path.basename(path)
    return (base.endswith(ROLLUP_SUFFIX)
            or base.endswith(".flc") or base.endswith(".flc.tmp")
            or bool(_TELEMETRY_RE.match(base)))


def seg_path(base_path: str, index: int) -> str:
    """Path of rotation segment ``index`` (0 = the base path itself)."""
    if index == 0:
        return base_path
    stem, ext = os.path.splitext(base_path)
    return f"{stem}.seg{index:03d}{ext}"


def job_id_for_path(path: str) -> str:
    """Job id for a log file: the stem with any ``.segNNN`` rotation
    infix removed, so ``job-a.fcs`` and ``job-a.seg002.fcs`` replay into
    the same job."""
    stem = os.path.splitext(os.path.basename(path))[0]
    return _SEG_RE.sub("", stem)


def seg_index(path: str) -> int:
    """Rotation index of a log file (0 for the base file).  Replay sorts
    a job's pieces by this NUMERICALLY — lexicographic order breaks past
    ``seg999`` (``seg1000`` < ``seg999`` as strings)."""
    m = _SEG_RE.search(os.path.splitext(os.path.basename(path))[0])
    return int(m.group(1)) if m else 0


class SegmentedTraceWriter:
    """Append batches through a codec, rotating files by size.

    On construction the writer RESUMES an existing rotated stream: it
    scans for the highest ``.segNNN`` piece already on disk and appends
    after it, so a restarted daemon keeps the stream append-only in time
    order instead of interleaving new batches into old segments."""

    def __init__(self, path: str, *, codec: Union[TraceCodec, str, None] = None,
                 rotate_bytes: Optional[int] = None):
        if isinstance(codec, str):
            codec = get_codec(codec)
        self.codec = codec or codec_for_path(path, default="jsonl")
        self.base_path = path
        self.rotate_bytes = rotate_bytes
        self.paths: list[str] = [path]
        self._index = 0
        while os.path.exists(seg_path(path, self._index + 1)):
            self._index += 1
            self.paths.append(seg_path(path, self._index))
        self._current_bytes = os.path.getsize(self.current_path) \
            if os.path.exists(self.current_path) else 0
        self.bytes_written = 0

    @property
    def current_path(self) -> str:
        return self.paths[-1]

    def write(self, batch) -> int:
        """Append one batch; returns bytes written (spill accounting)."""
        if not len(batch):
            return 0
        if (self.rotate_bytes is not None
                and self._current_bytes >= self.rotate_bytes):
            self._index += 1
            nxt = seg_path(self.base_path, self._index)
            self.paths.append(nxt)
            self._current_bytes = os.path.getsize(nxt) \
                if os.path.exists(nxt) else 0
        n = self.codec.write(batch, self.current_path)
        self._current_bytes += n
        self.bytes_written += n
        return n

    def close(self) -> None:
        """Nothing buffered — every ``write`` is a complete append — but
        kept so callers can treat writers uniformly."""
