"""Trace-storage codec protocol + registry.

A :class:`TraceCodec` is the single seam between the in-memory
``EventBatch`` and its on-disk representation.  Every producer (daemon
spill, benchmarks) and consumer (fleet replay, offline analysis) goes
through a codec looked up here, so adding a format is one module that
calls :func:`register_codec` — no call-site changes.

Two codecs ship in-tree:

  ``jsonl``  line-per-event JSON (human-greppable, appendable, tolerant
             of truncated tails — the historical daemon format);
  ``fcs``    Flare Columnar Segment — numpy-native binary segments,
             ~5x smaller and 50x+ faster to replay (see ``fcs.py`` and
             ``src/repro/store/README.md``).

Format resolution order for a path: explicit codec name > file
extension > content sniff (:func:`sniff_format` reads the magic bytes),
so mixed-format log directories replay without configuration.
"""
from __future__ import annotations

import os
from typing import TYPE_CHECKING, Iterator, Optional, Protocol, runtime_checkable

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (columnar is heavy)
    from repro.core.columnar import EventBatch


class CodecError(ValueError):
    """A trace file (or one segment of it) cannot be decoded.

    Carries ``path`` and ``offset`` (byte position of the broken
    structure) so operators can locate corruption in multi-GB logs."""

    def __init__(self, message: str, *, path: Optional[str] = None,
                 offset: Optional[int] = None):
        loc = ""
        if path is not None:
            loc = f" [{path}" + (f" @ byte {offset}" if offset is not None
                                 else "") + "]"
        super().__init__(message + loc)
        self.path = path
        self.offset = offset


@runtime_checkable
class TraceCodec(Protocol):
    """On-disk trace format.  ``write`` APPENDS one batch (a daemon calls
    it once per drain); ``read`` decodes a whole file; ``iter_chunks``
    streams ``(EventBatch, skipped)`` pieces in file order for replay."""

    name: str
    extensions: tuple[str, ...]

    def write(self, batch: "EventBatch", path: str) -> int:
        """Append ``batch`` to ``path``; returns bytes written."""
        ...

    def read(self, path: str, *, with_skip_count: bool = False):
        """Decode the whole file into one ``EventBatch`` (optionally with
        the count of skipped corrupt lines/segments)."""
        ...

    def iter_chunks(self, path: str, **opts
                    ) -> Iterator[tuple["EventBatch", int]]:
        """Yield ``(EventBatch, skipped)`` per chunk in file order."""
        ...


_REGISTRY: dict[str, TraceCodec] = {}
_BY_EXTENSION: dict[str, TraceCodec] = {}


def register_codec(codec: TraceCodec) -> TraceCodec:
    _REGISTRY[codec.name] = codec
    for ext in codec.extensions:
        _BY_EXTENSION[ext] = codec
    return codec


def get_codec(name: str) -> TraceCodec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown trace codec {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def codecs() -> dict[str, TraceCodec]:
    return dict(_REGISTRY)


def sniff_format(path: str) -> Optional[str]:
    """Look at the leading bytes: FCS files start with the segment magic;
    JSONL files with ``{`` (possibly after whitespace).  Returns a codec
    name or None."""
    from repro.store.fcs import MAGIC
    try:
        with open(path, "rb") as f:
            head = f.read(len(MAGIC) + 16)
    except OSError:
        return None
    if head.startswith(MAGIC):
        return "fcs"
    if head.lstrip()[:1] == b"{" or head.strip() == b"":
        return "jsonl"
    return None


def codec_for_path(path: str, *, default: Optional[str] = None) -> TraceCodec:
    """Resolve the codec for ``path`` by extension, then by content
    sniff, then by ``default``."""
    ext = os.path.splitext(path)[1].lower()
    codec = _BY_EXTENSION.get(ext)
    if codec is not None:
        return codec
    if os.path.exists(path):
        name = sniff_format(path)
        if name is not None:
            return get_codec(name)
    if default is not None:
        return get_codec(default)
    raise CodecError(f"cannot determine trace codec for {path!r} "
                     f"(extension {ext!r} unknown, content sniff failed)",
                     path=path)
