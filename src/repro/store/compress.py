"""Slab compression backends for FCS version-2 segments.

FCS v1 already removes per-row redundancy (dict/const/narrowed columns),
but the raw f8 timestamp slabs — the bulk of every archival segment —
still carry ~8 high-entropy-looking bytes per value.  They are not
actually high entropy: within a segment the timestamps are near-sorted,
so their high bytes barely change.  A byte-transpose ("shuffle", the
Blosc trick) groups byte 0 of every value, then byte 1, … — after which
a general-purpose compressor folds the nearly-constant high-byte runs.

Backends (one byte in the v2 column directory, per slab):

  ``stored``  (0)  slab kept verbatim — tiny slabs, or when compression
                   would not shrink it;
  ``zstd``    (1)  the ``zstandard`` package when importable — the
                   intended archival backend (fast decode);
  ``zlib``    (2)  stdlib fallback so v2 never needs a new dependency.

``zstandard`` is an OPTIONAL dependency: when it is absent, writers fall
back to zlib (an explicit ``compression="zstd"`` request warns once and
is counted in :data:`zstd_fallbacks`), and readers raise a clear
:class:`~repro.store.base.CodecError` only if they meet a slab that was
actually written with zstd.
"""
from __future__ import annotations

import warnings
import zlib
from typing import Optional

import numpy as np

from repro.store.base import CodecError

try:                                    # optional: stdlib zlib is the floor
    import zstandard as _zstd
except ImportError:                     # pragma: no cover - env-dependent
    _zstd = None

COMP_STORED, COMP_ZSTD, COMP_ZLIB = 0, 1, 2
FLAG_SHUFFLE = 0x80                     # high bit of the dirent comp byte
COMP_MASK = 0x7F

_BACKEND_NAMES = {"stored": COMP_STORED, "zstd": COMP_ZSTD,
                  "zlib": COMP_ZLIB}
_NAME_BY_CODE = {v: k for k, v in _BACKEND_NAMES.items()}
_DEFAULT_LEVEL = {COMP_ZSTD: 3, COMP_ZLIB: 6}

# explicit "zstd" requests served by zlib because the package is absent
# (observability for the CI / requirements-dev story)
zstd_fallbacks = 0


def have_zstd() -> bool:
    return _zstd is not None


def resolve_backend(name: Optional[str]) -> int:
    """Backend code for a writer: ``None``/``"auto"`` picks zstd when the
    package is importable, else zlib.  An explicit ``"zstd"`` without the
    package falls back to zlib with one counted warning instead of
    failing the spill path at runtime."""
    global zstd_fallbacks
    if name is None or name == "auto":
        return COMP_ZSTD if _zstd is not None else COMP_ZLIB
    try:
        code = _BACKEND_NAMES[name]
    except KeyError:
        raise ValueError(f"unknown FCS compression backend {name!r}; "
                         f"known: {sorted(_BACKEND_NAMES)}") from None
    if code == COMP_ZSTD and _zstd is None:
        zstd_fallbacks += 1
        if zstd_fallbacks == 1:
            warnings.warn("zstandard is not installed; FCS v2 segments "
                          "will use the stdlib zlib backend instead",
                          stacklevel=2)
        return COMP_ZLIB
    return code


def shuffle(data: bytes, itemsize: int) -> bytes:
    """Byte-transpose a fixed-width slab: all byte-0s, then all byte-1s…
    Lossless for any ``len(data) % itemsize == 0`` buffer."""
    a = np.frombuffer(data, np.uint8).reshape(-1, itemsize)
    return a.T.tobytes()


def unshuffle(data: bytes, itemsize: int) -> bytes:
    a = np.frombuffer(data, np.uint8).reshape(itemsize, -1)
    return a.T.tobytes()


def compress(data: bytes, backend: int, level: Optional[int] = None) -> bytes:
    lvl = _DEFAULT_LEVEL[backend] if level is None else level
    if backend == COMP_ZLIB:
        # clamp: a level tuned for zstd (1..22) must keep working after
        # the zlib fallback — zlib.error on every encode would silently
        # kill the daemon spill path for the job's whole lifetime
        return zlib.compress(data, max(-1, min(lvl, 9)))
    if backend == COMP_ZSTD:
        return _zstd.ZstdCompressor(level=lvl).compress(data)
    raise ValueError(f"cannot compress with backend code {backend}")


def decompress(data, backend: int, raw_len: int, *,
               path: Optional[str] = None,
               offset: Optional[int] = None) -> bytes:
    """Inflate one slab; every failure mode (bit-rot, unknown backend,
    missing zstandard) surfaces as :class:`CodecError` so the replay
    skip-and-count contract holds for v2 exactly as for v1."""
    if backend == COMP_ZLIB:
        try:
            out = zlib.decompress(bytes(data))
        except zlib.error as e:
            raise CodecError(f"corrupt zlib slab ({e})", path=path,
                             offset=offset) from e
    elif backend == COMP_ZSTD:
        if _zstd is None:
            raise CodecError(
                "segment slab is zstd-compressed but the zstandard "
                "package is not installed (pip install zstandard)",
                path=path, offset=offset)
        try:
            out = _zstd.ZstdDecompressor().decompress(
                bytes(data), max_output_size=raw_len)
        except _zstd.ZstdError as e:
            raise CodecError(f"corrupt zstd slab ({e})", path=path,
                             offset=offset) from e
    else:
        raise CodecError(f"unknown slab compression backend {backend}",
                         path=path, offset=offset)
    if len(out) != raw_len:
        raise CodecError(f"slab inflated to {len(out)} bytes, directory "
                         f"declares {raw_len}", path=path, offset=offset)
    return out
