"""FCS v3 segment statistics: the per-segment pruning directory.

A v3 segment carries a fixed-size **stats block** between the column
directory and the column payloads, written at segment close from the
already-encoded batch.  It holds everything a reader needs to decide
"can any row of this segment match my predicate?" WITHOUT inflating a
single column slab:

  * the segment's step range (over attributed rows, ``step >= 0``),
    event-time range (``min(start_ts) .. max(end_ts)``) and rank range;
  * a presence bitmask over event kinds — HANG_SUSPECT, GC, … — which
    doubles as the *severity* index (:data:`SEVERITY_KINDS` maps named
    severity classes to kind sets, so "any critical event in this
    window?" prunes on bits);
  * per-column min/max for every real column (floats as f8, ints as
    i64), for tooling that filters on e.g. ``flops`` or ``bytes``;
  * a CRC32 over the block, so a truncated or bit-flipped stats entry
    is a loud :class:`~repro.store.base.CodecError` instead of a wrong
    pruning decision.

:class:`Predicate` is the query half: the conservative segment test
(:meth:`Predicate.may_match`) plus the exact row filter
(:meth:`Predicate.filter`) that makes pruned reads byte-equivalent to
full reads — a segment is skipped only when the stats PROVE no row can
match, and segments without stats (v1/v2) always decode.
:class:`ScanStats` counts what a pruned scan actually decoded vs
skipped (the bytes-read accounting ``benchmarks/archive.py`` asserts).
"""
from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass, field
from typing import Iterable, Optional, Sequence

import numpy as np

from repro.core.columnar import KIND_TO_CODE, NO_INT
from repro.core.events import EventKind

# --------------------------------------------------------------------- #
# severity classes over event kinds
# --------------------------------------------------------------------- #
# Cumulative severity ladder: "critical" is the daemon screaming (hang
# suspects), "warning" adds interference events (GC pauses, forced
# syncs), "info" is everything.  A severity predicate is sugar for a
# kind-set predicate, which is what the stats bitmask prunes on.
SEVERITY_KINDS: dict[str, tuple[EventKind, ...]] = {
    "critical": (EventKind.HANG_SUSPECT,),
    "warning": (EventKind.HANG_SUSPECT, EventKind.GC, EventKind.SYNC),
    "info": tuple(EventKind),
}


def kind_mask(kinds: Iterable) -> int:
    """Bitmask over kind codes; accepts EventKind members, their string
    values, or raw integer codes."""
    mask = 0
    for k in kinds:
        if isinstance(k, EventKind):
            code = KIND_TO_CODE[k]
        elif isinstance(k, str):
            code = KIND_TO_CODE[EventKind(k)]
        else:
            code = int(k)
        mask |= 1 << code
    return mask


# --------------------------------------------------------------------- #
# on-disk stats block
# --------------------------------------------------------------------- #
# fixed header:  crc32 (over everything after this field), kind_bits,
# step_min/max (i64, over step >= 0 rows; -1 = none), ts_min/max (f8,
# min start_ts / max end_ts), rank_min/max (i64), col_present bitmask,
# 4 pad bytes — 64 bytes, followed by ncols × (min, max) 8-byte pairs
# (floats as <d, ints as <q), so the whole block stays 8-aligned.
STATS_HDR = struct.Struct("<IIqqddqqI4x")
_PAIR_F = struct.Struct("<dd")
_PAIR_I = struct.Struct("<qq")

# column ids whose min/max pair is stored as f8 (mirrors fcs._COLUMNS:
# issue_ts / start_ts / end_ts / flops)
FLOAT_STAT_COLS = frozenset((3, 4, 5, 7))

# batch attribute name -> fcs column id, for value-predicate pushdown
# (``Predicate(columns={"flops": (lo, hi)})``).  Mirrors the real-column
# prefix of fcs._COLUMNS; the sparse extra index columns are internal.
STAT_COLUMNS: dict[str, int] = {
    "kind": 0, "name_id": 1, "rank": 2, "issue_ts": 3, "start_ts": 4,
    "end_ts": 5, "step": 6, "flops": 7, "nbytes": 8, "tokens": 9,
    "group_id": 10,
}

# null sentinels per value column: rows holding the sentinel carry no
# value, so they can never satisfy a bound (mirrors the exclusions
# compute_stats applies when building the per-column min/max)
_NAN_NULL_COLS = frozenset(("flops",))
_INT_NULL_COLS = frozenset(("nbytes", "tokens"))


def stats_size(ncols: int) -> int:
    return STATS_HDR.size + ncols * 16


@dataclass
class SegmentStats:
    """Decoded stats for one segment — or the header-only facts (offset,
    length, row count, version) for a v1/v2 segment, with
    ``has_stats=False`` meaning "cannot prune, must decode"."""
    offset: int
    seg_len: int
    n_rows: int
    version: int
    has_stats: bool = False
    kind_bits: int = 0
    step_min: int = -1          # over attributed rows only; -1 = none
    step_max: int = -1
    ts_min: float = 0.0         # min start_ts
    ts_max: float = 0.0         # max end_ts
    rank_min: int = 0
    rank_max: int = 0
    col_present: int = 0        # bit i: column i min/max is meaningful
    col_min: tuple = ()
    col_max: tuple = ()

    def column_range(self, col_id: int):
        """(min, max) for a column, or None when absent/all-null."""
        if not self.has_stats or not (self.col_present >> col_id) & 1:
            return None
        return self.col_min[col_id], self.col_max[col_id]

    def kinds(self) -> list[EventKind]:
        ks = tuple(EventKind)
        return [ks[i] for i in range(len(ks)) if (self.kind_bits >> i) & 1]


@dataclass
class ScanStats:
    """Accounting for one pruned scan: how much the pushdown actually
    saved.  ``bytes_decoded`` counts the on-disk bytes of segments that
    were decoded; ``bytes_skipped`` those hopped over on stats alone.
    ``truncated`` flags a scan that stopped early because it hit a
    caller-imposed byte budget — the result is an honest PREFIX of the
    full answer, not the full answer."""
    segments: int = 0
    segments_skipped: int = 0
    bytes_decoded: int = 0
    bytes_skipped: int = 0
    rows: int = 0
    truncated: bool = False

    def merge(self, other: "ScanStats") -> None:
        self.segments += other.segments
        self.segments_skipped += other.segments_skipped
        self.bytes_decoded += other.bytes_decoded
        self.bytes_skipped += other.bytes_skipped
        self.rows += other.rows
        self.truncated = self.truncated or other.truncated


def compute_stats(arrays: Sequence[np.ndarray], float_nulls_nan: bool = True
                  ) -> tuple[int, list, list]:
    """(col_present, mins, maxs) over the real columns.  ``arrays`` is
    the fcs column tuple (index = col_id); sparse columns exclude their
    null sentinel (NaN for flops, INT64_MIN for bytes/tokens, -1 for
    group_id stays included — it is a real code)."""
    present = 0
    mins: list = []
    maxs: list = []
    for col_id, arr in enumerate(arrays):
        a = arr
        if a.size and a.dtype.kind == "f" and col_id not in (3, 4, 5):
            a = a[~np.isnan(a)]
        elif a.size and col_id in (8, 9):
            a = a[a != NO_INT]
        if a.size == 0:
            mins.append(0.0 if col_id in FLOAT_STAT_COLS else 0)
            maxs.append(0.0 if col_id in FLOAT_STAT_COLS else 0)
            continue
        present |= 1 << col_id
        if col_id in FLOAT_STAT_COLS:
            mins.append(float(a.min()))
            maxs.append(float(a.max()))
        else:
            mins.append(int(a.min()))
            maxs.append(int(a.max()))
    return present, mins, maxs


def encode_stats_block(arrays: Sequence[np.ndarray]) -> bytes:
    """Serialize the stats block for one segment from its column arrays
    (the same tuple ``encode_segment`` encodes; sparse extra index
    columns get empty stats)."""
    kind_arr, rank_arr = arrays[0], arrays[2]
    step_arr = arrays[6]
    start_arr, end_arr = arrays[4], arrays[5]
    kbits = 0
    if kind_arr.size:
        for code in np.unique(kind_arr).tolist():
            kbits |= 1 << int(code)
    attributed = step_arr[step_arr >= 0] if step_arr.size \
        else np.empty(0, np.int64)
    step_min = int(attributed.min()) if attributed.size else -1
    step_max = int(attributed.max()) if attributed.size else -1
    ts_min = float(start_arr.min()) if start_arr.size else 0.0
    ts_max = float(end_arr.max()) if end_arr.size else 0.0
    rank_min = int(rank_arr.min()) if rank_arr.size else 0
    rank_max = int(rank_arr.max()) if rank_arr.size else 0
    present, mins, maxs = compute_stats(arrays)
    body = STATS_HDR.pack(0, kbits, step_min, step_max, ts_min, ts_max,
                          rank_min, rank_max, present)[4:]
    pairs = []
    for col_id in range(len(arrays)):
        pair = _PAIR_F if col_id in FLOAT_STAT_COLS else _PAIR_I
        pairs.append(pair.pack(mins[col_id], maxs[col_id]))
    tail = b"".join(pairs)
    crc = zlib.crc32(body + tail)
    return struct.pack("<I", crc) + body + tail


def decode_stats_block(buf, pos: int, ncols: int, offset: int,
                       seg_len: int, n_rows: int, version: int,
                       path: Optional[str] = None) -> SegmentStats:
    """Parse + CRC-validate one stats block at ``pos``; raises
    :class:`CodecError` on truncation or bit-rot so a corrupt entry can
    never silently mis-prune."""
    from repro.store.base import CodecError
    size = stats_size(ncols)
    if pos + size > offset + seg_len or pos + size > len(buf):
        raise CodecError(
            f"truncated stats block (need {size} bytes)", path=path,
            offset=pos)
    raw = bytes(buf[pos:pos + size])
    (crc, kbits, step_min, step_max, ts_min, ts_max, rank_min, rank_max,
     present) = STATS_HDR.unpack_from(raw, 0)
    if zlib.crc32(raw[4:]) != crc:
        raise CodecError("stats block CRC mismatch (bit-flipped or "
                         "corrupt stats entry)", path=path, offset=pos)
    mins: list = []
    maxs: list = []
    for col_id in range(ncols):
        pair = _PAIR_F if col_id in FLOAT_STAT_COLS else _PAIR_I
        lo, hi = pair.unpack_from(raw, STATS_HDR.size + col_id * 16)
        mins.append(lo)
        maxs.append(hi)
    return SegmentStats(
        offset=offset, seg_len=seg_len, n_rows=n_rows, version=version,
        has_stats=True, kind_bits=kbits, step_min=step_min,
        step_max=step_max, ts_min=ts_min, ts_max=ts_max,
        rank_min=rank_min, rank_max=rank_max, col_present=present,
        col_min=tuple(mins), col_max=tuple(maxs))


# --------------------------------------------------------------------- #
# predicates
# --------------------------------------------------------------------- #
@dataclass
class Predicate:
    """A conjunctive trace predicate: every given clause must hold.

    ``step_range``/``time_range`` are INCLUSIVE ``(lo, hi)`` bounds; a
    row matches ``time_range`` when its ``[start_ts, end_ts]`` span
    intersects the window.  ``ranks`` is an explicit rank set;
    ``kinds`` an event-kind set; ``severity`` names a class from
    :data:`SEVERITY_KINDS` and unions into ``kinds``.

    ``columns`` adds per-column VALUE bounds keyed by batch attribute
    name (see :data:`STAT_COLUMNS`), e.g. ``{"flops": (1e12, None)}`` —
    inclusive ``(lo, hi)``, either end ``None`` for open.  Rows holding
    a column's null sentinel (NaN flops, missing bytes/tokens) never
    match a bound on it, mirroring the null exclusion the v3 per-column
    min/max already applies — which is what makes the segment-level
    prune sound: a column absent from ``col_present`` has no non-null
    row, so the whole segment is skipped.

    Two faces, kept consistent by construction: :meth:`may_match` is
    the CONSERVATIVE segment test over a stats block (false only when
    no row can possibly match), :meth:`row_mask`/:meth:`filter` the
    exact row-level filter — so pruned scans return byte-identical rows
    to full scans."""
    step_range: Optional[tuple[int, int]] = None
    time_range: Optional[tuple[float, float]] = None
    ranks: Optional[Sequence[int]] = None
    kinds: Optional[Sequence] = None
    severity: Optional[str] = None
    columns: Optional[dict] = None
    _kind_mask: int = field(init=False, default=0, repr=False)
    _rank_set: Optional[np.ndarray] = field(init=False, default=None,
                                            repr=False)
    _col_bounds: dict = field(init=False, default_factory=dict, repr=False)

    def __post_init__(self):
        if self.columns:
            for name, bounds in self.columns.items():
                if name not in STAT_COLUMNS:
                    raise ValueError(
                        f"unknown predicate column {name!r}; known: "
                        f"{sorted(STAT_COLUMNS)}")
                lo, hi = bounds
                if lo is None and hi is None:
                    continue
                self._col_bounds[name] = (lo, hi)
        ks = list(self.kinds) if self.kinds else []
        if self.severity is not None:
            try:
                ks.extend(SEVERITY_KINDS[self.severity])
            except KeyError:
                raise ValueError(
                    f"unknown severity {self.severity!r}; known: "
                    f"{sorted(SEVERITY_KINDS)}") from None
        self._kind_mask = kind_mask(ks) if ks else 0
        if self.ranks is not None:
            self._rank_set = np.unique(np.asarray(list(self.ranks),
                                                  np.int64))

    @property
    def empty(self) -> bool:
        return (self.step_range is None and self.time_range is None
                and self._rank_set is None and self._kind_mask == 0
                and not self._col_bounds)

    # ------------------------- segment test -------------------------- #
    def may_match(self, stats: Optional[SegmentStats]) -> bool:
        """False only when the stats PROVE no row matches.  Segments
        without stats (v1/v2, or ``stats=None``) always decode."""
        if stats is None or not stats.has_stats:
            return True
        if stats.n_rows == 0:
            return False
        if self.step_range is not None:
            lo, hi = self.step_range
            if stats.step_max < 0:          # no attributed rows at all
                return False
            if stats.step_max < lo or stats.step_min > hi:
                return False
        if self.time_range is not None:
            t0, t1 = self.time_range
            if stats.ts_max < t0 or stats.ts_min > t1:
                return False
        if self._rank_set is not None:
            rs = self._rank_set
            if not bool(((rs >= stats.rank_min)
                         & (rs <= stats.rank_max)).any()):
                return False
        if self._kind_mask and not (stats.kind_bits & self._kind_mask):
            return False
        for name, (lo, hi) in self._col_bounds.items():
            cr = stats.column_range(STAT_COLUMNS[name])
            if cr is None:          # no non-null value in any row
                return False
            if lo is not None and cr[1] < lo:
                return False
            if hi is not None and cr[0] > hi:
                return False
        return True

    # --------------------------- row filter --------------------------- #
    def row_mask(self, batch) -> np.ndarray:
        m = np.ones(len(batch), bool)
        if self.step_range is not None:
            lo, hi = self.step_range
            m &= (batch.step >= lo) & (batch.step <= hi)
        if self.time_range is not None:
            t0, t1 = self.time_range
            m &= (batch.end_ts >= t0) & (batch.start_ts <= t1)
        if self._rank_set is not None:
            m &= np.isin(batch.rank, self._rank_set)
        if self._kind_mask:
            codes = [c for c in range(len(EventKind))
                     if (self._kind_mask >> c) & 1]
            m &= np.isin(batch.kind, np.asarray(codes, batch.kind.dtype))
        for name, (lo, hi) in self._col_bounds.items():
            vals = getattr(batch, name)
            if name in _NAN_NULL_COLS:
                valid = ~np.isnan(vals)
            elif name in _INT_NULL_COLS:
                valid = vals != NO_INT
            else:
                valid = None
            cm = np.ones(len(batch), bool) if valid is None else valid
            if lo is not None:
                cm = cm & (vals >= lo)
            if hi is not None:
                cm = cm & (vals <= hi)
            m &= cm
        return m

    def filter(self, batch):
        """Row-filtered batch (shares interning tables via ``take``)."""
        if self.empty:
            return batch
        mask = self.row_mask(batch)
        if bool(mask.all()):
            return batch
        return batch.take(np.nonzero(mask)[0])
