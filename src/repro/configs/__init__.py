"""Architecture configs and input-shape registry.

Every assigned architecture has one module in this package exporting
``CONFIG`` (exact published shape) and ``REDUCED`` (same family, tiny — used
by CPU smoke tests).  ``get_config(name)`` / ``get_reduced(name)`` look them
up; ``SHAPES`` defines the four assigned input-shape cells.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Any


@dataclass(frozen=True)
class ModelConfig:
    """Static architecture description (model shape only, no run knobs)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int  # query heads; 0 for attention-free archs
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    qkv_bias: bool = False
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_dense_residual: bool = False  # arctic: dense FF in parallel with MoE
    capacity_factor: float = 1.25
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    conv_width: int = 4
    ssm_chunk: int = 256
    # --- hybrid (zamba2): one weight-shared attention block every k SSM layers
    attn_every: int = 0
    # --- VLM: a cross-attention layer after every k self-attention layers ---
    cross_attn_every: int = 0
    vision_tokens: int = 0
    vision_d: int = 0
    # --- misc ---
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    notes: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ------------------------------------------------------------------ #
    @property
    def attention_free(self) -> bool:
        return self.num_heads == 0

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports 500k-token decode (SSM/hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    # ------------------------------------------------------------------ #
    def param_count(self) -> int:
        """Analytic parameter count (used for 6*N*D roofline MODEL_FLOPS)."""
        d, v, L = self.d_model, self.vocab_size, self.num_layers
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d  # lm head
        n += d  # final norm
        hd = self.head_dim
        attn = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) \
            + (self.num_heads * hd) * d if self.num_heads else 0
        if self.qkv_bias and self.num_heads:
            attn += (self.num_heads + 2 * self.num_kv_heads) * hd
        ff_dense = 3 * d * self.d_ff  # SwiGLU: gate, up, down
        per_layer_norms = 2 * d
        if self.family in ("dense", "audio"):
            n += L * (attn + ff_dense + per_layer_norms)
        elif self.family == "moe":
            moe = self.num_experts * 3 * d * self.d_ff + d * self.num_experts
            dense_res = ff_dense if self.moe_dense_residual else 0
            n += L * (attn + moe + dense_res + per_layer_norms)
        elif self.family == "ssm":
            n += L * (self._mamba_block_params() + d)
        elif self.family == "hybrid":
            # L mamba layers + ONE shared attention block (+ its ff)
            n += L * (self._mamba_block_params() + d)
            n += attn + ff_dense + per_layer_norms
        elif self.family == "vlm":
            n_self = L - L // (self.cross_attn_every + 1) if self.cross_attn_every else L
            n_cross = L - n_self
            cross = attn + d  # extra gate + kv from vision (same shapes)
            n += n_self * (attn + ff_dense + per_layer_norms)
            n += n_cross * (cross + ff_dense + per_layer_norms)
        return n

    def _mamba_block_params(self) -> int:
        d, di = self.d_model, self.d_inner
        h = self.ssm_heads
        n = d * (2 * di + 2 * self.ssm_state + h) + di  # in_proj(z,x,B,C,dt)
        n += self.conv_width * (di + 2 * self.ssm_state)  # conv over x,B,C
        n += h + h  # A_log, D
        n += di * d  # out_proj
        n += di  # gate norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts) — for 6*N_active*D."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.num_layers
        full = self.param_count()
        all_experts = L * self.num_experts * 3 * d * self.d_ff
        active = L * self.experts_per_token * 3 * d * self.d_ff
        return full - all_experts + active


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        # decode processes ONE new token per sequence in the batch
        n = 1 if self.kind == "decode" else self.seq_len
        return n * self.global_batch


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

ARCH_MODULES: dict[str, str] = {
    "zamba2-2.7b": "zamba2_2p7b",
    "dbrx-132b": "dbrx_132b",
    "arctic-480b": "arctic_480b",
    "llama3-405b": "llama3_405b",
    "llama3.2-1b": "llama3p2_1b",
    "qwen2-0.5b": "qwen2_0p5b",
    "qwen2-72b": "qwen2_72b",
    "musicgen-large": "musicgen_large",
    "mamba2-780m": "mamba2_780m",
    "llama-3.2-vision-11b": "llama3p2_vision_11b",
    # the paper's own workhorse model (§6.4, Case-1)
    "llama-20b-paper": "llama_20b_paper",
}

ASSIGNED_ARCHS = [k for k in ARCH_MODULES if k != "llama-20b-paper"]


def _load(name: str):
    if name not in ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{ARCH_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _load(name).CONFIG


def get_reduced(name: str) -> ModelConfig:
    return _load(name).REDUCED


def list_archs() -> list[str]:
    return list(ARCH_MODULES)


def cells(include_skipped: bool = False):
    """Yield every assigned (arch, shape) cell; honours the long_500k skip rule."""
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            skipped = shape.name == "long_500k" and not cfg.sub_quadratic
            if skipped and not include_skipped:
                continue
            yield arch, shape.name, skipped


def scale(cfg: ModelConfig, **overrides: Any) -> ModelConfig:
    return dataclasses.replace(cfg, **overrides)
