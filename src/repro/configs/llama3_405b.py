"""llama3-405b — dense GQA transformer, 128k vocab.

[arXiv:2407.21783; unverified]  126L d_model=16384 128H (GQA kv=8)
d_ff=53248 vocab=128256.
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    rope_theta=500000.0,
    notes="long_500k SKIPPED: pure full attention (see DESIGN.md)",
)

REDUCED = ModelConfig(
    name="llama3-405b-reduced",
    family="dense",
    num_layers=3,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    d_ff=192,
    vocab_size=256,
    rope_theta=500000.0,
)
