"""zamba2-2.7b — Mamba2 backbone + weight-shared attention blocks.

[arXiv:2411.15242; hf]  54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64.  One shared (weight-tied) full-attention transformer block is
applied after every 6 Mamba2 layers.
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
    rope_theta=10000.0,
    notes="hybrid: Mamba2 + shared attn; long_500k RUNS (sub-quadratic)",
)

REDUCED = ModelConfig(
    name="zamba2-reduced",
    family="hybrid",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_chunk=16,
    attn_every=2,
    rope_theta=10000.0,
)
