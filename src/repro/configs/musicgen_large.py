"""musicgen-large — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf]  48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048.
Backbone only per assignment: the EnCodec modality frontend is a stub —
``input_specs()`` provides precomputed frame embeddings / token ids.
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    rope_theta=10000.0,
    notes="long_500k SKIPPED: pure full attention (see DESIGN.md); "
    "audio frontend stubbed (assignment)",
)

REDUCED = ModelConfig(
    name="musicgen-reduced",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=128,
)
