"""qwen2-72b — GQA with QKV bias.

[arXiv:2407.10671; hf]  80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064.
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1000000.0,
    notes="long_500k SKIPPED: pure full attention (see DESIGN.md)",
)

REDUCED = ModelConfig(
    name="qwen2-72b-reduced",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    d_ff=192,
    vocab_size=256,
    qkv_bias=True,
)
