"""mamba2-780m — pure SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified]  48L d_model=1536 vocab=50280 ssm_state=128.
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    notes="attention-free; long_500k RUNS; issue-latency healthy profile "
    "keyed to the ssm backend family (paper §8.2)",
)

REDUCED = ModelConfig(
    name="mamba2-reduced",
    family="ssm",
    num_layers=3,
    d_model=64,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=256,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_chunk=16,
)
