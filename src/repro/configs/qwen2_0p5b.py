"""qwen2-0.5b — GQA with QKV bias.

[arXiv:2407.10671; hf]  24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936.
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
    notes="long_500k SKIPPED: pure full attention (see DESIGN.md)",
)

REDUCED = ModelConfig(
    name="qwen2-0.5b-reduced",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    qkv_bias=True,
    tie_embeddings=True,
)
