"""llama-20b-paper — the paper's own workhorse model (Fig 11, Case-1).

Not in the assigned pool; used by the reproduction benchmarks so that the
issue-latency-distribution and kernel-issue-stall experiments run on the
same model family/scale the paper used (Llama-20B on 256 H800s).
Shape chosen as a standard ~20B llama: 62L d_model=5120 40H kv=8 d_ff=13824.
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="llama-20b-paper",
    family="dense",
    num_layers=62,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=13824,
    vocab_size=32000,
    rope_theta=10000.0,
)

REDUCED = ModelConfig(
    name="llama-20b-reduced",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=192,
    vocab_size=256,
)
