"""llama3.2-1b — small dense llama3.

[hf:meta-llama/Llama-3.2-1B; unverified]  16L d_model=2048 32H (GQA kv=8)
d_ff=8192 vocab=128256.
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500000.0,
    tie_embeddings=True,
    notes="long_500k SKIPPED: pure full attention (see DESIGN.md)",
)

REDUCED = ModelConfig(
    name="llama3.2-1b-reduced",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    d_ff=256,
    vocab_size=256,
    tie_embeddings=True,
)
