"""dbrx-132b — fine-grained MoE, 16 experts top-4.

[hf:databricks/dbrx-base; unverified]  40L d_model=6144 48H (GQA kv=8)
d_ff=10752 vocab=100352.
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    num_experts=16,
    experts_per_token=4,
    rope_theta=500000.0,
)

REDUCED = ModelConfig(
    name="dbrx-reduced",
    capacity_factor=8.0,  # no token drops at smoke-test scale
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    num_experts=4,
    experts_per_token=2,
)
