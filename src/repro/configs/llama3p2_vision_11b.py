"""llama-3.2-vision-11b — decoder with cross-attention image layers.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified]  40L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=128256.  A cross-attention layer follows every
4 self-attention layers (8 of 40 layers are cross-attn).  The vision
frontend is a stub: ``input_specs()`` provides precomputed patch embeddings
of shape (batch, vision_tokens, vision_d).
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    cross_attn_every=4,
    vision_tokens=1600,
    vision_d=4096,
    rope_theta=500000.0,
    notes="long_500k SKIPPED: pure full attention; vision frontend stubbed",
)

REDUCED = ModelConfig(
    name="llama-vision-reduced",
    family="vlm",
    num_layers=5,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    cross_attn_every=4,
    vision_tokens=16,
    vision_d=64,
)
