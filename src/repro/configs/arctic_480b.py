"""arctic-480b — 128-expert top-2 MoE with a dense residual path.

[hf:Snowflake/snowflake-arctic-base; hf]  35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000.
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    num_experts=128,
    experts_per_token=2,
    moe_dense_residual=True,
    rope_theta=10000.0,
)

REDUCED = ModelConfig(
    name="arctic-reduced",
    capacity_factor=8.0,  # no token drops at smoke-test scale
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=96,
    vocab_size=256,
    num_experts=8,
    experts_per_token=2,
    moe_dense_residual=True,
)
