"""Sharded AdamW with optional compressed optimizer state.

``state_dtype``:
  float32  — classic m/v
  bfloat16 — halves optimizer HBM (negligible quality delta at LLM scale)
  int8     — block-wise absmax-quantized m/v (8-bit-Adam style); required to
             fit the ≥100B assigned archs on 16GB v5e chips (DESIGN.md §7)

State tensors inherit the parameter PartitionSpec plus ZeRO sharding over the
data axes (see parallel.sharding.zero_spec).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

QBLOCK = 256


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: str = "float32"  # float32 | bfloat16 | int8
    grad_clip: float = 1.0


# ---------------------------------------------------------------- int8 state
# Shape-preserving, last-axis-block quantization: the int8 payload keeps the
# parameter's exact shape (and therefore its PartitionSpec); scales live on
# a [..., n_blocks] tail.  A flat [N/256, 256] layout would force GSPMD to
# re-shard (replicate!) the decoded fp32 moments of every scan-stacked
# parameter — hundreds of GiB/device at 405B scale.
def _nblocks(last: int) -> int:
    return max((last + QBLOCK - 1) // QBLOCK, 1)


def _q_init(x):
    last = x.shape[-1] if x.ndim else 1
    lead = x.shape[:-1] if x.ndim else ()
    return {"q": jnp.zeros(x.shape if x.ndim else (1,), jnp.int8),
            "scale": jnp.zeros(lead + (_nblocks(last),), jnp.float32)}


def _q_enc(x):
    if x.ndim == 0:
        x = x[None]
    last = x.shape[-1]
    nb = _nblocks(last)
    pad = nb * QBLOCK - last
    xp = jnp.pad(x.astype(jnp.float32), [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    blocks = xp.reshape(x.shape[:-1] + (nb, QBLOCK))
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=-1) / 127.0, 1e-20)
    q = jnp.clip(jnp.round(blocks / scale[..., None]), -127, 127)
    q = q.reshape(xp.shape)[..., :last].astype(jnp.int8)
    return {"q": q, "scale": scale}


def _q_dec(s, shape):
    q = s["q"]
    last = q.shape[-1]
    nb = s["scale"].shape[-1]
    pad = nb * QBLOCK - last
    qp = jnp.pad(q.astype(jnp.float32),
                 [(0, 0)] * (q.ndim - 1) + [(0, pad)])
    blocks = qp.reshape(q.shape[:-1] + (nb, QBLOCK))
    x = (blocks * s["scale"][..., None]).reshape(qp.shape)[..., :last]
    return x.reshape(shape)


# --------------------------------------------------------------------- AdamW
def adamw_init(params, cfg: AdamWConfig):
    def one(p):
        if cfg.state_dtype == "int8":
            return {"m": _q_init(p), "v": _q_init(p)}
        dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
        return {"m": jnp.zeros(p.shape, dt), "v": jnp.zeros(p.shape, dt)}

    return {"mu_nu": jax.tree.map(one, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state, params, cfg: AdamWConfig, lr):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def one(g, s, p):
        g = g.astype(jnp.float32) * clip
        if cfg.state_dtype == "int8":
            m = _q_dec(s["m"], p.shape)
            v = _q_dec(s["v"], p.shape)
        else:
            m = s["m"].astype(jnp.float32)
            v = s["v"].astype(jnp.float32)
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        new_p = pf - lr * (upd + cfg.weight_decay * pf)
        if cfg.state_dtype == "int8":
            new_s = {"m": _q_enc(m), "v": _q_enc(v)}
        else:
            dt = s["m"].dtype
            new_s = {"m": m.astype(dt), "v": v.astype(dt)}
        return new_p.astype(p.dtype), new_s

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_s = tdef.flatten_up_to(state["mu_nu"])
    new_p, new_s = [], []
    for g, s, p in zip(flat_g, flat_s, flat_p):
        np_, ns_ = one(g, s, p)
        new_p.append(np_)
        new_s.append(ns_)
    new_params = jax.tree_util.tree_unflatten(tdef, new_p)
    new_state = {"mu_nu": jax.tree_util.tree_unflatten(tdef, new_s),
                 "count": count}
    return new_params, new_state, {"grad_norm": gnorm}


def opt_state_specs(p_specs, params, mesh, cfg: AdamWConfig,
                    zero: bool = True):
    """PartitionSpecs for the optimizer state (ZeRO over data axes)."""
    from jax.sharding import PartitionSpec as P
    from repro.parallel.sharding import zero_spec

    def one(spec, p):
        base = zero_spec(spec, p.shape, mesh) if zero else spec
        if cfg.state_dtype == "int8":
            from repro.parallel.sharding import sanitize_spec
            last = p.shape[-1] if p.ndim else 1
            scale_shape = (p.shape[:-1] if p.ndim else ()) + (
                (last + QBLOCK - 1) // QBLOCK,)
            return {"q": base,
                    "scale": sanitize_spec(base, scale_shape, mesh)}
        return base

    def per_param(spec, p):
        s = one(spec, p)
        return {"m": s, "v": s}

    mu_nu = jax.tree.map(per_param, p_specs, params,
                         is_leaf=lambda x: isinstance(x, P))
    return {"mu_nu": mu_nu, "count": P()}
