"""Fault-tolerant checkpointing: atomic manifests, elastic re-shard on load.

Layout:  <dir>/step_<N>/   arrays as .npy keyed by flattened tree path,
         manifest.json with tree structure, dtypes, logical PartitionSpecs
         and the mesh shape they were saved under.  A checkpoint directory
         is written under a ``.tmp`` name and atomically renamed, so a
         crash mid-save never corrupts the latest checkpoint (restart
         safety — the supervisor always restores the newest *complete*
         manifest).

Elastic restore: arrays are loaded in full and re-placed under the *new*
mesh/specs (``jax.device_put``), so a job can restart with a different DP
degree after FLARE routes a faulty machine out (single-host container; on a
real fleet each host would read only its shard slices — the manifest
already records per-array specs to support that).
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", "?"))) for p in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree: Any, metadata: Optional[dict] = None):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(tree)
        manifest = {"step": step, "time": time.time(),
                    "metadata": metadata or {}, "arrays": {}}
        for key, leaf in flat.items():
            arr = np.asarray(leaf)
            fname = key.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["arrays"][key] = {
                "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: max(len(steps) - self.keep, 0)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------ #
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of `tree_like`; optionally re-place
        under new `shardings` (elastic re-shard)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        keys = _flatten(tree_like)
        flat_sh = _flatten(shardings) if shardings is not None else {}
        out = {}
        for key in keys:
            fname = os.path.join(d, key.replace("/", "__") + ".npy")
            arr = np.load(fname)
            if key in flat_sh:
                out[key] = jax.device_put(arr, flat_sh[key])
            else:
                out[key] = jax.numpy.asarray(arr)
        # rebuild tree
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
        leaves = []
        for path, _ in flat:
            key = "/".join(
                str(getattr(p, "key", getattr(p, "idx", "?"))) for p in path)
            leaves.append(out[key])
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def metadata(self, step: Optional[int] = None) -> dict:
        step = step if step is not None else self.latest_step()
        with open(os.path.join(self.dir, f"step_{step:08d}",
                               "manifest.json")) as f:
            return json.load(f)
