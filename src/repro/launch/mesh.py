"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state.  The dry-run entrypoint
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` BEFORE any
jax import; nothing else in the repo does.
"""
from __future__ import annotations

import jax


def _mk(shape, axes):
    # AxisType landed in jax 0.4.35+; older installs use the default kind
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                shape, axes, axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:   # make_mesh without the axis_types kwarg
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CI-scale sharding tests (requires fake devices)."""
    if pod:
        return _mk((pod, data, model), ("pod", "data", "model"))
    return _mk((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
