"""Training launcher CLI.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --steps 50 --batch 8 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --steps 30 --mask-mode naive   # Case-3 regression reproduction
"""
from __future__ import annotations

import argparse
import json

from repro.configs import get_config, get_reduced, list_archs
from repro.optim.adamw import AdamWConfig
from repro.runtime.train import RunConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--opt-dtype", default="float32",
                    choices=["float32", "bfloat16", "int8"])
    ap.add_argument("--remat", default="none",
                    choices=["none", "dots", "full"])
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--mask-mode", default="none",
                    choices=["none", "naive", "fast"])
    ap.add_argument("--no-flare", action="store_true")
    ap.add_argument("--flare-log", default=None)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    run = RunConfig(
        model=cfg, global_batch=args.batch, seq_len=args.seq,
        steps=args.steps, peak_lr=args.lr,
        num_microbatches=args.microbatches,
        opt=AdamWConfig(lr=args.lr, state_dtype=args.opt_dtype),
        remat=args.remat, checkpoint_dir=args.checkpoint_dir,
        flare=not args.no_flare, flare_log=args.flare_log,
        mask_mode=args.mask_mode)
    trainer = Trainer(run)
    hist = trainer.train()
    for rec in hist[:: max(len(hist) // 10, 1)]:
        print(json.dumps(rec))
    print(f"final loss: {hist[-1]['loss']:.4f} "
          f"({hist[-1]['tokens_per_s']:.0f} tok/s)")


if __name__ == "__main__":
    main()
