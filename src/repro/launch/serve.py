"""Serving launcher CLI (batched prefill + greedy decode)."""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config, get_reduced, list_archs
from repro.runtime.serve import ServeConfig, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    server = Server(ServeConfig(model=cfg, batch=args.batch,
                                max_seq=args.max_seq))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    out = server.generate(prompts, new_tokens=args.new_tokens)
    print(f"generated {out.shape} tokens; sample row: {out[0, -8:]}")
    server.close()


if __name__ == "__main__":
    main()
