"""Scan-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, so any
scan-over-layers model under-reports FLOPs / bytes / collective payloads by
the trip count.  This module parses the post-optimization HLO text, builds
per-computation summaries, and multiplies through ``while`` loops using the
trip count recovered from the loop condition's integer constant (scan
lowering always compares the induction variable against a constant).

Traffic model (TPU-oriented): a top-level fusion/dot/collective reads its
operands from HBM and writes its result once; fusion-internal ops are free.
That approximates TPU HBM traffic far better than the CPU backend's
"bytes accessed".
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from functools import lru_cache

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

# ops whose operands+result count as HBM traffic at top level
_TRAFFIC_OPS = frozenset([
    "fusion", "dot", "convolution", "custom-call", "copy", "gather",
    "scatter", "dynamic-slice", "dynamic-update-slice", "reduce",
    "reduce-window", "sort", "select-and-scatter", "transpose", "reverse",
    "concatenate", "pad", "slice", "cholesky", "triangular-solve",
    *COLLECTIVES,
    *[c + "-start" for c in COLLECTIVES],
])


def _type_bytes_and_shapes(type_str: str):
    total = 0
    shapes = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d)
        n = math.prod(shape) if shape else 1
        total += n * _DTYPE_BYTES[dt]
        shapes.append(shape)
    return total, shapes


@dataclass
class Instr:
    name: str
    op: str
    result_bytes: int
    result_shapes: list
    operands: list
    line: str


@dataclass
class CompSummary:
    flops: float = 0.0
    traffic: float = 0.0
    coll: dict = field(default_factory=dict)  # op -> [count, result_b, wire_b]
    # trip-weighted attributions for perf debugging:
    traffic_by_op: dict = field(default_factory=dict)    # opcode -> bytes
    coll_by_shape: dict = field(default_factory=dict)    # (op, result_b) -> wire


class HloAnalysis:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, list[Instr]] = {}
        self.entry: str | None = None
        self._parse(hlo_text)
        self._summaries: dict[str, CompSummary] = {}

    # ------------------------------------------------------------------ #
    def _parse(self, text: str):
        cur: list[Instr] | None = None
        cur_name = None
        for line in text.splitlines():
            mc = _COMP_RE.match(line)
            if mc and line.rstrip().endswith("{"):
                cur_name = mc.group(1)
                cur = []
                self.comps[cur_name] = cur
                if line.lstrip().startswith("ENTRY"):
                    self.entry = cur_name
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            mi = _INSTR_RE.match(line)
            if not mi:
                continue
            name, restype, op = mi.groups()
            rb, shapes = _type_bytes_and_shapes(restype)
            rest = line[mi.end():]
            args_part = rest.split(")", 1)[0]
            operands = _OPERAND_RE.findall(args_part)
            cur.append(Instr(name, op, rb, shapes, operands, line))

    # ------------------------------------------------------------------ #
    def _table(self, comp: str) -> dict[str, Instr]:
        return {i.name: i for i in self.comps.get(comp, [])}

    def _attr_comp(self, line: str, key: str) -> str | None:
        m = re.search(key + r"=%?([\w.\-]+)", line)
        return m.group(1) if m else None

    def _called(self, line: str) -> list[str]:
        m = re.search(r"calls=%?([\w.\-]+)", line)
        if m:
            return [m.group(1)]
        m = re.search(r"to_apply=%?([\w.\-]+)", line)
        if m:
            return [m.group(1)]
        return []

    def trip_count(self, cond_comp: str) -> int:
        best = 1
        for instr in self.comps.get(cond_comp, []):
            m = re.search(r"constant\((\d+)\)", instr.line)
            if m:
                best = max(best, int(m.group(1)))
        return best

    # ------------------------------------------------------------------ #
    def summary(self, comp: str | None = None) -> CompSummary:
        comp = comp or self.entry
        if comp in self._summaries:
            return self._summaries[comp]
        s = CompSummary()
        self._summaries[comp] = s  # pre-insert (cycle safety)
        table = self._table(comp)
        for instr in self.comps.get(comp, []):
            op = instr.op
            if op == "while":
                body = self._attr_comp(instr.line, "body")
                cond = self._attr_comp(instr.line, "condition")
                if body:
                    inner = self.summary(body)
                    trip = self.trip_count(cond) if cond else 1
                    s.flops += trip * inner.flops
                    s.traffic += trip * inner.traffic
                    _merge(s.coll, inner.coll, trip)
                    for k, v in inner.traffic_by_op.items():
                        s.traffic_by_op[k] = s.traffic_by_op.get(k, 0) \
                            + v * trip
                    for k, v in inner.coll_by_shape.items():
                        s.coll_by_shape[k] = s.coll_by_shape.get(k, 0) \
                            + v * trip
                continue
            if op == "conditional":
                branches = re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                      r"(?:true|false)_computation=%?([\w.\-]+))",
                                      instr.line)
                names = []
                for grp, single in branches:
                    if grp:
                        names += _OPERAND_RE.findall(grp)
                    if single:
                        names.append(single)
                if names:
                    inners = [self.summary(n) for n in names]
                    worst = max(inners, key=lambda x: x.flops + x.traffic)
                    s.flops += worst.flops
                    s.traffic += worst.traffic
                    _merge(s.coll, worst.coll, 1)
                continue
            if op in ("call", "async-start"):
                for c in self._called(instr.line):
                    inner = self.summary(c)
                    s.flops += inner.flops
                    s.traffic += inner.traffic
                    _merge(s.coll, inner.coll, 1)
                    for k, v in inner.traffic_by_op.items():
                        s.traffic_by_op[k] = s.traffic_by_op.get(k, 0) + v
                    for k, v in inner.coll_by_shape.items():
                        s.coll_by_shape[k] = s.coll_by_shape.get(k, 0) + v
                continue
            if op == "fusion":
                # fusion = one kernel: HBM traffic at the boundary; count
                # any dots hidden inside for flops
                for c in self._called(instr.line):
                    inner = self.summary(c)
                    s.flops += inner.flops
                    _merge(s.coll, inner.coll, 1)
            if op == "dot":
                s.flops += self._dot_flops(instr, table)
            base = op.replace("-start", "")
            if base in COLLECTIVES and not op.endswith("-done"):
                rb = instr.result_bytes
                n = self._group_size(instr.line)
                wire = _wire_bytes(base, rb, n)
                c = s.coll.setdefault(base, [0, 0, 0])
                c[0] += 1
                c[1] += rb
                c[2] += wire
                key = (base, rb)
                s.coll_by_shape[key] = s.coll_by_shape.get(key, 0) + wire
            if op in _TRAFFIC_OPS:
                t = self._traffic_for(instr, table)
                s.traffic += t
                s.traffic_by_op[op] = s.traffic_by_op.get(op, 0) + t
        return s

    def _traffic_for(self, instr: Instr, table: dict) -> float:
        """HBM traffic model per top-level op.  In-place-updatable ops
        (dynamic-update-slice at a scan buffer) move only the slice, not
        the whole buffer — XLA aliases the big operand."""
        op = instr.op
        if op == "dynamic-update-slice":
            upd = (table[instr.operands[1]].result_bytes
                   if len(instr.operands) > 1 and instr.operands[1] in table
                   else instr.result_bytes)
            return 2.0 * upd  # read-modify-write of the slice only
        if op in ("dynamic-slice", "slice", "pad", "copy", "transpose",
                  "reverse", "broadcast"):
            return 2.0 * instr.result_bytes  # read + write of the slice
        if op == "gather":
            return 2.0 * instr.result_bytes
        if op == "scatter":
            upd = (table[instr.operands[2]].result_bytes
                   if len(instr.operands) > 2 and instr.operands[2] in table
                   else instr.result_bytes)
            return 2.0 * upd
        opb = sum(table[o].result_bytes for o in instr.operands
                  if o in table)
        return opb + instr.result_bytes

    def _dot_flops(self, instr: Instr, table: dict) -> float:
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
        cdims = [int(x) for x in m.group(1).split(",") if x] if m else []
        lhs = table.get(instr.operands[0]) if instr.operands else None
        if lhs is None or not lhs.result_shapes:
            out_elems = sum(math.prod(s) if s else 1
                            for s in instr.result_shapes)
            return 2.0 * out_elems  # degenerate fallback
        lshape = lhs.result_shapes[0]
        k = math.prod(lshape[d] for d in cdims) if cdims else 1
        out_elems = sum(math.prod(s) if s else 1 for s in instr.result_shapes)
        return 2.0 * out_elems * k

    @staticmethod
    def _group_size(line: str) -> int:
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
        if m:
            return int(m.group(2))
        m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)
        if m:
            return len(m.group(1).split(","))
        return 2


def _wire_bytes(op: str, result_bytes: int, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * result_bytes * (n - 1) / n
    if op == "all-gather":
        return result_bytes * (n - 1) / n
    if op == "reduce-scatter":
        return float(result_bytes * (n - 1))
    if op == "all-to-all":
        return result_bytes * (n - 1) / n
    return float(result_bytes)


def _merge(dst: dict, src: dict, factor: int):
    for k, v in src.items():
        c = dst.setdefault(k, [0, 0, 0])
        c[0] += v[0] * factor
        c[1] += v[1] * factor
        c[2] += v[2] * factor


def analyze_hlo(hlo_text: str) -> dict:
    h = HloAnalysis(hlo_text)
    s = h.summary()
    coll = {k: {"count": v[0], "result_bytes": v[1], "wire_bytes": v[2]}
            for k, v in s.coll.items()}
    total_wire = sum(v["wire_bytes"] for v in coll.values())
    return {"flops": s.flops, "traffic_bytes": s.traffic,
            "collectives": coll, "total_wire_bytes": total_wire}
