"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes, print memory/cost analysis, extract roofline terms.

MUST be the very first lines — jax locks the device count on first init:
"""
import os  # noqa: E402
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
from dataclasses import dataclass  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, get_config, cells  # noqa: E402
from repro.launch.mesh import dp_axes, make_production_mesh  # noqa: E402
from repro.models.layers import Policy  # noqa: E402
from repro.models.registry import build_model  # noqa: E402
from repro.optim.adamw import AdamWConfig, adamw_init, opt_state_specs  # noqa: E402
from repro.parallel.sharding import (MeshRules, param_specs,  # noqa: E402
                                     sanitize_specs)
from repro.runtime.train import RunConfig, make_train_step  # noqa: E402

# ---------------------------------------------------------------- hardware
CHIP_PEAK_FLOPS = 197e12     # TPU v5e bf16
CHIP_HBM_BW = 819e9          # B/s
LINK_BW = 50e9               # B/s per ICI link (conservative single link)

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


# ---------------------------------------------------------------- policies
@dataclass
class DryrunPolicy:
    param_dtype: str
    opt_dtype: str
    microbatches: int
    remat: str
    attn_impl: str = "chunked"
    fsdp: bool = False               # shard params over data axes too
    sequence_parallel: bool = True   # SP for the residual stream (train)
    sp_prefill: bool = False         # context-parallel prefill (perf knob)
    q_chunk: int = 1024
    kv_chunk: int = 512
    grad_accum_dtype: str = "float32"
    fold_depth: int = 4

    def policy(self) -> Policy:
        return Policy(jnp.dtype(self.param_dtype), jnp.bfloat16)


BIG = {"llama3-405b", "arctic-480b", "dbrx-132b", "qwen2-72b"}
MID = {"llama-3.2-vision-11b", "musicgen-large", "zamba2-2.7b",
       "llama-20b-paper"}


def dryrun_policy(arch: str, overrides: dict | None = None) -> DryrunPolicy:
    if arch in BIG:
        p = DryrunPolicy("bfloat16", "int8", 16, "full", fsdp=True)
    elif arch in MID:
        p = DryrunPolicy("float32", "bfloat16", 4, "full", fsdp=True)
    else:
        p = DryrunPolicy("float32", "float32", 4, "none")
    for k, v in (overrides or {}).items():
        setattr(p, k, v)
    return p


# ---------------------------------------------------------------- specs
def _sds(shapes_tree, specs_tree, mesh):
    specs_tree = sanitize_specs(specs_tree, shapes_tree, mesh)

    def mk(s, p):
        return jax.ShapeDtypeStruct(s.shape, s.dtype,
                                    sharding=NamedSharding(mesh, p))
    return jax.tree.map(mk, shapes_tree, specs_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _param_specs_tree(model, mesh):
    pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = param_specs(pshapes)
    return pshapes, pspecs


def cache_specs(cfg, mesh, batch: int, max_seq: int, policy: Policy,
                model) -> tuple:
    """(cache_shapes, cache_specs) for serve_step lowering."""
    dp = dp_axes(mesh)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    batch_ok = batch % n_dp == 0 and batch >= n_dp
    bspec = dp if batch_ok else None
    sspec = None if batch_ok else dp  # batch=1 long-context: shard the seq
    shapes = jax.eval_shape(lambda: model.init_cache(batch, max_seq))
    fam = cfg.family

    def spec_for(path_key: str, ndim: int) -> P:
        if fam in ("dense", "moe", "audio"):
            # k/v [L,B,T,KV,hd]
            return P(None, bspec, sspec, "model", None)
        if fam == "vlm":
            if path_key.startswith("cross"):
                return P(None, bspec, None, "model", None)
            return P(None, None, bspec, sspec, "model", None)
        if fam == "ssm":
            if path_key == "state":
                return P(None, bspec, "model", None, None)
            return P(None, bspec, None, "model")
        if fam == "hybrid":
            if path_key == "state":
                return P(None, None, bspec, "model", None, None)
            if path_key == "conv":
                return P(None, None, bspec, None, "model")
            return P(None, bspec, sspec, "model", None)
        raise ValueError(fam)

    specs = {k: spec_for(k, v.ndim) for k, v in shapes.items()}
    return shapes, specs


# ---------------------------------------------------------------- builders
def build_cell(arch: str, shape_name: str, mesh, overrides=None):
    """Returns (fn, arg_specs, info) ready for jax.jit(fn).lower(*specs)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    pol = dryrun_policy(arch, overrides)
    sp = (bool(pol.sequence_parallel) and shape.kind == "train") or \
        (bool(pol.sp_prefill) and shape.kind == "prefill")
    rules = MeshRules(mesh, sequence_parallel=sp)
    model = build_model(cfg, policy=pol.policy(), constrain=rules, mesh=mesh,
                        attn_impl=pol.attn_impl, remat=pol.remat,
                        fold_depth=pol.fold_depth)
    if hasattr(model, "q_chunk"):
        model.q_chunk = pol.q_chunk
        model.kv_chunk = pol.kv_chunk
    dp = dp_axes(mesh)
    pshapes, pspecs = _param_specs_tree(model, mesh)
    if pol.fsdp:
        from repro.parallel.sharding import zero_spec
        pspecs = jax.tree.map(
            lambda s, sh: zero_spec(s, sh.shape, mesh, axes=dp),
            pspecs, pshapes, is_leaf=lambda x: isinstance(x, P))
    params_sds = _sds(pshapes, pspecs, mesh)
    B, S = shape.global_batch, shape.seq_len
    info = {"arch": arch, "shape": shape_name, "kind": shape.kind,
            "family": cfg.family, "tokens": shape.tokens,
            "param_count": cfg.param_count(),
            "active_param_count": cfg.active_param_count(),
            "policy": vars(pol).copy()}

    def tok_sds(b, s):
        return jax.ShapeDtypeStruct(
            (b, s), jnp.int32, sharding=NamedSharding(
                mesh, P(dp if b % _n(mesh, dp) == 0 else None, None)))

    vis_sds = None
    if cfg.family == "vlm":
        vis_sds = jax.ShapeDtypeStruct(
            (B, cfg.vision_tokens, cfg.vision_d), jnp.bfloat16,
            sharding=NamedSharding(mesh, P(dp, None, None)))

    if shape.kind == "train":
        run = RunConfig(model=cfg, global_batch=B, seq_len=S,
                        num_microbatches=pol.microbatches,
                        opt=AdamWConfig(state_dtype=pol.opt_dtype),
                        param_dtype=pol.param_dtype, remat=pol.remat,
                        attn_impl=pol.attn_impl,
                        grad_accum_dtype=pol.grad_accum_dtype)
        step_fn = make_train_step(model, run, mesh=mesh)
        oshapes = jax.eval_shape(
            lambda p: adamw_init(p, run.opt), pshapes)
        ospecs = opt_state_specs(pspecs, pshapes, mesh, run.opt)
        opt_sds = _sds(oshapes, ospecs, mesh)
        batch = {"tokens": tok_sds(B, S), "labels": tok_sds(B, S)}
        if vis_sds is not None:
            batch["vision_embeds"] = vis_sds
        step_sds = jax.ShapeDtypeStruct((), jnp.int32)
        return step_fn, (params_sds, opt_sds, batch, step_sds), info

    if shape.kind == "prefill":
        def prefill_fn(params, tokens, vision_embeds=None):
            cache = model.init_cache(B, S)
            kw = ({"vision_embeds": vision_embeds}
                  if vision_embeds is not None else {})
            return model.prefill(params, tokens, cache, **kw)
        args = (params_sds, tok_sds(B, S))
        if vis_sds is not None:
            args = args + (vis_sds,)
        return prefill_fn, args, info

    # decode: one new token against a full cache
    cshapes, cspecs = cache_specs(cfg, mesh, B, S, pol.policy(), model)
    cache_sds = _sds(cshapes, cspecs, mesh)
    tok = jax.ShapeDtypeStruct(
        (B, 1), jnp.int32, sharding=NamedSharding(
            mesh, P(dp if B % _n(mesh, dp) == 0 else None, None)))
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def decode_fn(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos)

    return decode_fn, (params_sds, tok, cache_sds, pos), info


def _n(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return max(n, 1)


# ---------------------------------------------------------------- analysis
def parse_collective_bytes(hlo: str) -> dict:
    """Per-device collective payloads from the (SPMD-partitioned) HLO."""
    out = {k: {"count": 0, "result_bytes": 0, "wire_bytes": 0}
           for k in COLLECTIVES}
    type_re = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
    for line in hlo.splitlines():
        m = re.search(r"=\s*(.+?)\s+(" + "|".join(COLLECTIVES) +
                      r")(?:-start|-done)?\(", line)
        if not m:
            continue
        restype, op = m.group(1), m.group(2)
        if "-done(" in line:
            continue  # avoid double count of async pairs
        nbytes = 0
        for dt, dims in type_re.findall(restype):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        gsize = _group_size(line)
        wire = _wire_bytes(op, nbytes, gsize)
        out[op]["count"] += 1
        out[op]["result_bytes"] += nbytes
        out[op]["wire_bytes"] += wire
    out["total_wire_bytes"] = sum(
        v["wire_bytes"] for k, v in out.items() if isinstance(v, dict))
    out["total_result_bytes"] = sum(
        v["result_bytes"] for k, v in out.items() if isinstance(v, dict))
    return out


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return 2


def _wire_bytes(op: str, result_bytes: int, n: int) -> int:
    """Ring-schedule wire traffic per device, from the RESULT size."""
    if n <= 1:
        return 0
    if op == "all-reduce":
        return int(2 * result_bytes * (n - 1) / n)
    if op == "all-gather":
        return int(result_bytes * (n - 1) / n)
    if op == "reduce-scatter":
        return int(result_bytes * (n - 1))  # result is the 1/n shard
    if op == "all-to-all":
        return int(result_bytes * (n - 1) / n)
    return result_bytes  # collective-permute


def analyze(compiled, lowered, info, chips: int) -> dict:
    from repro.launch.hlo_analysis import analyze_hlo

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # older jax: one dict per device
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    scan_aware = analyze_hlo(hlo)  # multiplies through while-loop trip counts
    flops = float(scan_aware["flops"])            # per-device
    bytes_acc = float(scan_aware["traffic_bytes"])
    wire = float(scan_aware["total_wire_bytes"])
    # train = fwd+bwd (6·N·D); prefill/decode = forward only (2·N·D)
    flops_per_param = 6.0 if info["kind"] == "train" else 2.0
    model_flops = flops_per_param * info["active_param_count"] * info["tokens"]
    t_compute = flops / CHIP_PEAK_FLOPS
    t_memory = bytes_acc / CHIP_HBM_BW
    t_coll = wire / LINK_BW
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    return {
        **info,
        "chips": chips,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": (mem.argument_size_in_bytes
                           + mem.output_size_in_bytes
                           + mem.temp_size_in_bytes),
        },
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_acc,
        "cost_analysis_flops_once": float(ca.get("flops", 0.0)),
        "cost_analysis_bytes_once": float(ca.get("bytes accessed", 0.0)),
        "collectives": scan_aware["collectives"],
        "total_wire_bytes": wire,
        "model_flops_global": model_flops,
        "model_flops_per_device": model_flops / chips,
        "useful_flops_ratio": (model_flops / chips) / flops if flops else 0.0,
        "roofline_s": {"compute": t_compute, "memory": t_memory,
                       "collective": t_coll},
        "dominant": dominant,
    }


# ---------------------------------------------------------------- driver
def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str | None = None, overrides=None,
             tag: str = "") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 512 if multi_pod else 256
    t0 = time.time()
    fn, specs, info = build_cell(arch, shape_name, mesh, overrides)
    with mesh:
        lowered = jax.jit(fn).lower(*specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    res = analyze(compiled, lowered, info, chips)
    res["mesh"] = "2x16x16" if multi_pod else "16x16"
    res["lower_s"] = round(t_lower, 1)
    res["compile_s"] = round(t_compile, 1)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        fname = (f"{arch}_{shape_name}_{res['mesh'].replace('x', '-')}"
                 f"{suffix}.json")
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(res, f, indent=1)
    return res


def main():
    ap = argparse.ArgumentParser(description="FLARE repro multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every assigned cell on this mesh (in-process)")
    ap.add_argument("--out", default="dryrun_out")
    ap.add_argument("--override", default="",
                    help="k=v,k=v policy overrides (e.g. attn_impl=folded)")
    ap.add_argument("--tag", default="", help="suffix for output json")
    args = ap.parse_args()

    overrides = {}
    for kv in args.override.split(","):
        if "=" in kv:
            k, v = kv.split("=", 1)
            overrides[k] = int(v) if v.isdigit() else v

    todo = []
    if args.all:
        todo = [(a, s) for a, s, _ in cells()]
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        todo = [(args.arch, args.shape)]

    failures = []
    for arch, shape_name in todo:
        try:
            r = run_cell(arch, shape_name, args.multi_pod, args.out,
                         overrides, args.tag)
            mem_gb = r["memory"]["peak_bytes"] / 2 ** 30
            roof = r["roofline_s"]
            print(f"OK   {arch:22s} {shape_name:12s} {r['mesh']:8s} "
                  f"peak/dev={mem_gb:6.2f}GiB "
                  f"compute={roof['compute'] * 1e3:8.2f}ms "
                  f"memory={roof['memory'] * 1e3:8.2f}ms "
                  f"coll={roof['collective'] * 1e3:8.2f}ms "
                  f"dom={r['dominant']:10s} "
                  f"useful={r['useful_flops_ratio']:.2f} "
                  f"[compile {r['compile_s']}s]",
                  flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append((arch, shape_name, repr(e)[:300]))
            print(f"FAIL {arch:22s} {shape_name:12s}: {e!r}"[:240],
                  flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
