"""Shared-interning, step-partitioned columnar storage for fleet ingest.

A fleet multiplexes many jobs' daemon streams into one process.  Keeping a
separate name table per job would re-intern the same op names (the fleet
runs a handful of model families, so jobs overlap heavily) and make any
cross-job work re-hash strings; instead one :class:`SharedInterner` owns
the fleet-wide ``names``/``groups`` tables and every arriving chunk is
*adopted* — its id columns remapped once, after which all slices of all
jobs speak the same ids and ``EventBatch.concat`` merges them with plain
column concatenation (the shared-interning fast path, no LUTs).

:class:`StepPartitionedStore` is the per-job buffer between ingest and the
incremental evaluator: chunks are split into per-step slices on arrival
(one stable argsort per chunk), a step's slices are merged only when the
watermark closes it, and the slice memory is released right after the
engine consumed it — fleet memory stays proportional to the watermark
window, not to job length.  Hang-suspect stacks are extracted at append
time into a tiny side table so dropping diagnosed steps never loses the
hang path.
"""
from __future__ import annotations

import threading
from typing import Optional

import numpy as np

from repro.core.columnar import KIND_TO_CODE, EventBatch
from repro.core.events import EventKind

_C_HANG = KIND_TO_CODE[EventKind.HANG_SUSPECT]


class SharedInterner:
    """Fleet-wide name/group tables; ``adopt`` remaps a batch onto them.

    Adopted batches reference the SAME list objects, so the tables growing
    later never invalidates earlier slices (ids are append-only)."""

    def __init__(self):
        self.names: list[str] = []
        self._name_ids: dict[str, int] = {}
        self.groups: list[str] = []
        self._group_ids: dict[str, int] = {}
        self._lock = threading.Lock()   # jobs adopt from their own threads

    def intern_name(self, name: str) -> int:
        i = self._name_ids.get(name)
        if i is None:
            i = self._name_ids[name] = len(self.names)
            self.names.append(name)
        return i

    def intern_group(self, group: str) -> int:
        i = self._group_ids.get(group)
        if i is None:
            i = self._group_ids[group] = len(self.groups)
            self.groups.append(group)
        return i

    def restore_tables(self, names: list, groups: list) -> None:
        """Adopt checkpointed tables — the VERY list objects, not copies.
        Restored ``EventBatch`` slices from the same checkpoint pickle
        reference these exact objects (single-pickle identity memo), so
        adopting them keeps the ``batch.names is self.names`` fast path
        valid after restore.  Only legal on an empty interner: merging
        into live tables would break that identity."""
        with self._lock:
            if self.names or self.groups:
                raise ValueError("restore_tables on a non-empty interner")
            self.names = names
            self.groups = groups
            self._name_ids = {nm: i for i, nm in enumerate(names)}
            self._group_ids = {gm: i for i, gm in enumerate(groups)}

    def merge_tables(self, names, groups) -> None:
        """Fold another interner's tables in (a replay worker process
        built its own; the parent adopts every name/group it saw).  Ids
        are NOT preserved — merging interns each string in table order,
        which is deterministic as long as workers' tables are merged in
        a deterministic job order (the replayer merges in sorted-path
        group order), so repeated runs produce identical fleet tables."""
        with self._lock:
            for nm in names:
                self.intern_name(nm)
            for gm in groups:
                self.intern_group(gm)

    def adopt(self, batch: EventBatch) -> EventBatch:
        if batch.names is self.names and batch.groups is self.groups:
            return batch
        with self._lock:
            return self._adopt_locked(batch)

    def _adopt_locked(self, batch: EventBatch) -> EventBatch:
        if batch.names:
            lut = np.empty(len(batch.names), np.int32)
            for i, nm in enumerate(batch.names):
                lut[i] = self.intern_name(nm)
            nid = lut[batch.name_id]
        else:
            nid = batch.name_id
        if batch.groups:
            glut = np.empty(len(batch.groups) + 1, np.int16)
            glut[-1] = -1                     # group_id -1 stays -1
            for i, gm in enumerate(batch.groups):
                glut[i] = self.intern_group(gm)
            gid = glut[batch.group_id]
        else:
            gid = batch.group_id
        # rows are unchanged, so the extra dict is shared, not copied
        # (EventBatch is immutable by convention)
        return EventBatch(
            batch.kind, nid.astype(np.int32, copy=False), batch.rank,
            batch.issue_ts, batch.start_ts, batch.end_ts, batch.step,
            batch.flops, batch.nbytes, batch.tokens,
            gid.astype(np.int16, copy=False),
            self.names, self.groups, batch.extra)


class StepPartitionedStore:
    """Per-job buffer: arriving chunks split into per-step slices (shared
    interning), merged per step on demand, dropped once diagnosed."""

    def __init__(self, interner: Optional[SharedInterner] = None):
        self.interner = interner or SharedInterner()
        self._by_step: dict[int, list[EventBatch]] = {}
        self._step_rows: dict[int, int] = {}  # step -> rows buffered
        self.buffered_rows = 0          # total rows currently held
        self._rank_seen = np.zeros(0, bool)   # scatter beats np.unique here
        self._num_ranks = 0
        self._ranks_floor = 0           # restored summary floor (see below)
        self._ranks_dirty = False
        self.max_step_seen = -1
        self.last_ts = 0.0              # max end_ts observed (event time)
        self.events_total = 0
        self.nostep_events = 0          # rows with no step attribution
        self.hang_stacks: dict[int, list] = {}   # rank -> last stack

    @property
    def num_ranks(self) -> int:
        if self._ranks_dirty:
            self._num_ranks = int(np.count_nonzero(self._rank_seen))
            self._ranks_dirty = False
        return max(self._num_ranks, self._ranks_floor)

    def append(self, batch: EventBatch) -> dict[int, int]:
        """Adopt + split one chunk; returns ``step -> rows buffered`` so
        the caller can spot rows for steps it already evaluated."""
        if not len(batch):
            return {}
        b = self.interner.adopt(batch)
        self.events_total += len(b)
        mx = int(b.rank.max())
        if mx >= self._rank_seen.size:
            grown = np.zeros(max(mx + 1, 2 * self._rank_seen.size), bool)
            grown[:self._rank_seen.size] = self._rank_seen
            self._rank_seen = grown
        self._rank_seen[b.rank] = True
        self._ranks_dirty = True
        self.last_ts = max(self.last_ts, float(b.end_ts.max()))
        hang_rows = np.nonzero(b.kind == _C_HANG)[0]
        for row in hang_rows.tolist():
            self.hang_stacks[int(b.rank[row])] = \
                (b.extra.get(row) or {}).get("stack", [])
        touched: dict[int, int] = {}
        s0 = int(b.step[0])
        if b.step[0] == b.step[-1] and bool((b.step == s0).all()):
            # single-step chunk (daemon drained within one step, or an
            # already-split slice): no argsort, no row copies
            if s0 < 0:
                self.nostep_events += len(b)
            else:
                self._by_step.setdefault(s0, []).append(b)
                self._step_rows[s0] = self._step_rows.get(s0, 0) + len(b)
                self.buffered_rows += len(b)
                touched[s0] = len(b)
                if s0 > self.max_step_seen:
                    self.max_step_seen = s0
            return touched
        order, uniq, bounds = b.step_index()
        for i, s in enumerate(uniq.tolist()):
            rows = order[bounds[i]:bounds[i + 1]]
            if s < 0:
                self.nostep_events += rows.size
                continue
            self._by_step.setdefault(s, []).append(b.take(rows))
            self._step_rows[s] = self._step_rows.get(s, 0) + rows.size
            self.buffered_rows += rows.size
            touched[s] = rows.size
            if s > self.max_step_seen:
                self.max_step_seen = s
        return touched

    def pending_steps(self) -> list[int]:
        return sorted(self._by_step)

    def step_batch(self, step: int) -> EventBatch:
        """Merged slice for one step (shared-interning concat, no remap)."""
        return EventBatch.concat(self._by_step[step])

    def pop_step(self, step: int) -> EventBatch:
        """``step_batch`` + release the buffered slices."""
        out = self.step_batch(step)
        del self._by_step[step]
        self.buffered_rows -= self._step_rows.pop(step, 0)
        return out

    def drop_step(self, step: int) -> None:
        self._by_step.pop(step, None)
        self.buffered_rows -= self._step_rows.pop(step, 0)

    # ------------------------------------------------------------------ #
    # process-sharded replay: mirror a worker store's summary facts
    # ------------------------------------------------------------------ #
    def summary(self) -> dict:
        """Picklable facts a replay worker ships back so the parent's
        store mirror answers ``stats()``/hang/flush questions exactly as
        the worker's would.  Buffered slices are NOT shipped — the
        worker flushed before summarizing, so there are none."""
        return {
            "events_total": self.events_total,
            "nostep_events": self.nostep_events,
            "num_ranks": self.num_ranks,
            "max_step_seen": self.max_step_seen,
            "last_ts": self.last_ts,
            "hang_stacks": dict(self.hang_stacks),
        }

    def restore_summary(self, s: dict) -> None:
        """Fold a worker's :meth:`summary` into this (parent-side) store.
        Rank identities don't cross the boundary, so the count lands as a
        floor that later direct ingest can only raise."""
        self.events_total += int(s["events_total"])
        self.nostep_events += int(s["nostep_events"])
        self._ranks_floor = max(self._ranks_floor, int(s["num_ranks"]))
        self.max_step_seen = max(self.max_step_seen, int(s["max_step_seen"]))
        self.last_ts = max(self.last_ts, float(s["last_ts"]))
        self.hang_stacks.update(s["hang_stacks"])

    # ------------------------------------------------------------------ #
    # service checkpoints: FULL state transfer (summary() is lossy — it
    # drops pending slices and rank identities, which a mid-stream
    # restore needs intact)
    # ------------------------------------------------------------------ #
    def snapshot_state(self) -> dict:
        """Complete picklable state, pending ``EventBatch`` slices
        included.  Slices reference the interner's live list objects;
        pickled together with the interner tables (one checkpoint
        pickle) the shared identity survives the round trip."""
        return {
            "by_step": {s: list(v) for s, v in self._by_step.items()},
            "step_rows": dict(self._step_rows),
            "buffered_rows": self.buffered_rows,
            "rank_seen": self._rank_seen.copy(),
            "ranks_floor": self._ranks_floor,
            "max_step_seen": self.max_step_seen,
            "last_ts": self.last_ts,
            "events_total": self.events_total,
            "nostep_events": self.nostep_events,
            "hang_stacks": dict(self.hang_stacks),
        }

    def restore_state(self, s: dict) -> None:
        """Inverse of :meth:`snapshot_state` on a fresh store whose
        interner already adopted the checkpointed tables."""
        self._by_step = {int(k): list(v) for k, v in s["by_step"].items()}
        self._step_rows = {int(k): int(v)
                           for k, v in s["step_rows"].items()}
        self.buffered_rows = int(s["buffered_rows"])
        self._rank_seen = s["rank_seen"]
        self._ranks_floor = int(s["ranks_floor"])
        self._num_ranks = 0
        self._ranks_dirty = True
        self.max_step_seen = int(s["max_step_seen"])
        self.last_ts = float(s["last_ts"])
        self.events_total = int(s["events_total"])
        self.nostep_events = int(s["nostep_events"])
        self.hang_stacks = dict(s["hang_stacks"])
