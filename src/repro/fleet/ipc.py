"""FCS-over-IPC process workers: the fleet engine past the GIL.

Thread-per-job replay (``FleetReplayer.replay_dir``) is byte-equivalent
to serial but GIL-bound — per-step diagnosis interleaves short Python
sections with numpy windows, so two worker threads on two cores buy
~1.08x, not 2x.  This module ships each job's whole pipeline — decode ->
step-aligned ingest -> ``evaluate_step_batch`` on a private
:class:`~repro.core.engine.DiagnosticEngine` — into a worker *process*,
and moves data across the boundary in the cheapest shapes the codebase
already has:

  * **inputs**: replay workers read trace files straight from disk (no
    event rows cross at all); live-streaming callers ship
    :class:`~repro.core.columnar.EventBatch` chunks as FCS-encoded
    segments (``repro.store.encode_batch_bytes`` — the archival spill
    format, ~11.5 B/event at 256 ranks) instead of numpy pickles;
  * **outputs**: anomalies stream back incrementally on a BOUNDED
    result queue (backpressure: a slow parent stalls its workers, not
    the box's memory), ``"fleet"`` envelopes carry each job's keyed
    fleet-tier observations + frontier progress as they accrue
    (``FleetMultiplexer.record_fleet_observations``), and one terminal
    envelope per job ships the compact serialized end state — job-local
    ``ReplayStats``, any post-flush observations, the job's intern
    tables, a telemetry snapshot, and the store/engine summary the
    parent mirrors back onto its own ``FleetJob``.

The pool is RESIDENT: workers hold their open jobs' multiplexers
between tasks, so a long-lived service (``repro.serve``) streams
``TASK_BATCHES`` frames at a job for hours and closes it with
``TASK_CLOSE`` when it leaves the fleet.  Each job is pinned to one
worker at first submission (per-worker task queues keep a job's tasks
in order); one-shot replay callers just ``submit`` everything and
``drain`` once — the shutdown sentinel closes whatever is still open.

Determinism contract: a worker owns a job exclusively and ships its
anomalies in push order; the parent re-pushes on ITS stream (per-job
order preserved; the stream's ``(ts, job_id, seq)`` drain sort already
makes cross-job interleave scheduling-independent), merges intern
tables and stats in sorted-path group order, and buffers the shipped
fleet observations — whose per-job cummax KEYS the worker computed over
the full stream — for the parent's frontier resolution
(``resolve_fleet_ready`` live, ``resolve_fleet_all`` at end of drain).
Diagnosis output is therefore byte-equivalent to serial by construction
— asserted end to end in ``benchmarks/fleet.py``, ``benchmarks/
live.py`` and ``tests/test_fleet.py``.

Worker entry points are top-level functions with picklable arguments,
so the pool works under both ``fork`` (Linux default) and ``spawn``.
"""
from __future__ import annotations

import multiprocessing as mp
import queue as _queue
import threading
import traceback
from typing import Callable, Optional

# task envelope: (kind, job_id, payload, engine_cfg, record_fleet)
#   ("replay", job_id, [paths], engine_cfg, record_fleet)
#   ("batches", job_id, [fcs_bytes], engine_cfg, record_fleet)
#   ("open", job_id, None, engine_cfg, record_fleet)   explicit join
#   ("close", job_id, None, None, None)                graceful leave
#   ("snapshot", job_id, None, None, None)   ship pending + full job state
#   ("restore", job_id, state, engine_cfg, record_fleet)  rebuild from it
#   None (shutdown sentinel: close every open job, then exit)
TASK_REPLAY = "replay"
TASK_BATCHES = "batches"
TASK_OPEN = "open"
TASK_CLOSE = "close"
TASK_SNAPSHOT = "snapshot"
TASK_RESTORE = "restore"

# result envelopes, on the owning worker's bounded queue:
#   ("anomalies", job_id, [(ts, Anomaly), ...])     incremental
#   ("fleet", job_id, [(key, step, anoms, ts)], progress)  incremental
#   ("snapshot", job_id, state_dict_or_None)        checkpoint answer
#   ("job", job_id, payload_dict)                   terminal, per job
#   ("error", job_id, traceback_str)
#   ("exit",)                                       worker is done
_EXIT = ("exit",)


class _WorkerJob:
    """One open job resident in a worker process: a private single-job
    multiplexer (its own engine + intern tables, so terminal payloads
    keep the exact per-job shape the parent merges deterministically),
    the replayer that drives it, and job-local stats."""

    __slots__ = ("mux", "rep", "stats", "record_fleet")

    def __init__(self, job_id: str, engine_cfg, record_fleet: bool,
                 init: dict):
        from repro.fleet.multiplexer import FleetConfig, FleetMultiplexer
        from repro.fleet.replay import FleetReplayer, ReplayStats
        self.mux = FleetMultiplexer(FleetConfig(**init["fleet"]),
                                    history=init["history"])
        self.mux.add_job(job_id, engine_cfg)
        # record the fleet-tier observation sequence for the parent
        # (which owns the actual cross-job detectors) — skipped when it
        # has none
        if record_fleet:
            self.mux.record_fleet_observations(True)
        self.rep = FleetReplayer(self.mux, job_workers=1, **init["replay"])
        self.stats = ReplayStats(worker_kind="process")
        self.record_fleet = record_fleet


def _ship(result_q, job_id: str, wj: _WorkerJob) -> None:
    """Flush a job's pending outputs to the parent: anomalies in push
    order, then (in record mode) the keyed fleet observations gathered
    since the last ship plus the job's frontier progress — even with no
    new observations, progress is what lets the parent's frontier
    advance past this job's healthy stretches."""
    pend = wj.mux.stream.drain_raw()
    if pend:
        result_q.put(("anomalies", job_id,
                      [(fa.ts, fa.anomaly) for fa in pend]))
    obs = wj.mux.drain_fleet_observations().get(job_id, []) \
        if wj.record_fleet else []
    # shipped even with nothing to say: the envelope count is the
    # parent's per-job acknowledgement (queue-depth gauges), and the
    # progress float is what advances the parent's fleet frontier
    result_q.put(("fleet", job_id, obs, wj.mux.fleet_progress(job_id)))


def _close_job(result_q, job_id: str, wj: _WorkerJob) -> None:
    """Flush + terminal envelope: the job's end state crosses once, in
    the compact summary shape ``FleetMultiplexer.restore_job_state``
    mirrors back."""
    wj.mux.flush(job_id)
    _ship(result_q, job_id, wj)
    obs = wj.mux.drain_fleet_observations().get(job_id, []) \
        if wj.record_fleet else []
    job = wj.mux.job(job_id)
    result_q.put(("job", job_id, {
        "stats": wj.stats,
        "obs": obs,
        "state": {
            "store": job.store.summary(),
            "last_closed": job.last_closed,
            "hang_reported": job.hang_reported,
            "evaluated_steps": sorted(job.engine.evaluated_steps),
        },
        "names": list(wj.mux.interner.names),
        "groups": list(wj.mux.interner.groups),
        "telemetry": wj.mux.telemetry.snapshot(),
    }))


def _snapshot_job(result_q, job_id: str, wj: Optional[_WorkerJob]) -> None:
    """Checkpoint answer for one resident job: flush pending outputs
    first (``_ship`` — so the parent buffers every observation BEFORE
    the snapshot envelope lands; the result queue is FIFO), then ship
    the job's complete pipeline state.  The worker's intern tables ride
    along — restored slices reference them, and pickling state + tables
    as one envelope keeps that identity across the IPC boundary."""
    if wj is None:
        result_q.put(("snapshot", job_id, None))
        return
    _ship(result_q, job_id, wj)
    result_q.put(("snapshot", job_id, {
        "pipeline": wj.mux.snapshot_job_state(job_id),
        "names": wj.mux.interner.names,
        "groups": wj.mux.interner.groups,
        "stats": wj.stats,
        "telemetry": wj.mux.telemetry.snapshot(),
    }))


def _restore_job(job_id: str, state: dict, engine_cfg, record_fleet: bool,
                 init: dict) -> _WorkerJob:
    """Rebuild a resident job from its :func:`_snapshot_job` state: a
    fresh pipeline, then tables + full pipeline state + job-local stats
    + telemetry loaded back in."""
    wj = _WorkerJob(job_id, engine_cfg, bool(record_fleet), init)
    wj.mux.interner.restore_tables(state["names"], state["groups"])
    wj.mux.restore_job_pipeline(job_id, state["pipeline"])
    wj.stats = state["stats"]
    wj.mux.telemetry.absorb(state["telemetry"])
    return wj


def _worker_main(task_q, result_q, init: dict) -> None:
    """Resident worker loop: pull tasks until the shutdown sentinel,
    holding every open job's pipeline between tasks.  An exception in
    one task is shipped as an ``error`` envelope and the worker moves
    on — partial fleet progress is never thrown away by one bad job.
    The sentinel closes still-open jobs in sorted order (deterministic
    terminal-envelope order for one-shot replay callers)."""
    from repro.store import decode_batch_bytes

    jobs: dict[str, _WorkerJob] = {}
    while True:
        task = task_q.get()
        if task is None:
            break
        kind, job_id, payload, engine_cfg, record_fleet = task
        try:
            if kind == TASK_CLOSE:
                wj = jobs.pop(job_id, None)
                if wj is None:
                    wj = _WorkerJob(job_id, engine_cfg, False, init)
                _close_job(result_q, job_id, wj)
                continue
            if kind == TASK_SNAPSHOT:
                _snapshot_job(result_q, job_id, jobs.get(job_id))
                continue
            if kind == TASK_RESTORE:
                jobs[job_id] = _restore_job(job_id, payload, engine_cfg,
                                            bool(record_fleet), init)
                continue
            if kind not in (TASK_OPEN, TASK_REPLAY, TASK_BATCHES):
                raise ValueError(f"unknown worker task kind {kind!r}")
            wj = jobs.get(job_id)
            if wj is None:
                wj = jobs[job_id] = _WorkerJob(job_id, engine_cfg,
                                               bool(record_fleet), init)
            if kind == TASK_REPLAY:
                wj.rep._replay_job(
                    job_id, payload, wj.stats,
                    on_file=lambda: _ship(result_q, job_id, wj))
            elif kind == TASK_BATCHES:
                for blob in payload:
                    batch = decode_batch_bytes(blob)
                    wj.stats.events += len(batch)
                    wj.stats.per_job[job_id] = \
                        wj.stats.per_job.get(job_id, 0) + len(batch)
                    wj.mux.ingest_step_aligned(job_id, batch)
                    _ship(result_q, job_id, wj)
        except BaseException:
            try:
                result_q.put(("error", job_id, traceback.format_exc()))
            except Exception:
                break
    for job_id in sorted(jobs):
        try:
            _close_job(result_q, job_id, jobs[job_id])
        except BaseException:
            try:
                result_q.put(("error", job_id, traceback.format_exc()))
            except Exception:
                break
    result_q.put(_EXIT)


class ProcessWorkerPool:
    """Fixed pool of resident job-pipeline worker processes.

    Each worker has its OWN task queue; a job is pinned to one worker at
    first submission (round-robin over workers), so a job's tasks always
    execute in order on the engine that holds its state.  One BOUNDED
    result queue per worker gives backpressure: a parent that falls
    behind consuming anomalies stalls the producing worker instead of
    buffering unboundedly.

    Two driving styles:

    * **one-shot** (``FleetReplayer._replay_dir_process``): ``submit``
      every task, then ``drain`` exactly once — it starts the drainer
      threads, enqueues one shutdown sentinel per worker (closing every
      still-open job), consumes every result, joins, and raises if any
      worker errored or died.
    * **resident** (``repro.serve.FleetService``): ``start`` the drainer
      threads up front with callbacks, ``submit`` tasks for as long as
      the service lives (``TASK_CLOSE`` retires one job), and finally
      ``shutdown`` + ``join``.

    ``close`` is the unconditional cleanup (safe after a drain/join;
    terminates stragglers otherwise)."""

    def __init__(self, workers: int, init: dict, *, result_depth: int = 8,
                 mp_context=None):
        ctx = mp_context or mp.get_context()
        self._task_qs = []
        self._procs = []
        self._result_qs = []
        self._results: dict[str, dict] = {}
        self._errors: list[tuple[str, str]] = []
        self._route: dict[str, int] = {}
        self._next_worker = 0
        self._drainers: list[threading.Thread] = []
        self._shutdown_sent = False
        self._closing = False        # intentional teardown: deaths expected
        self._obs_lock = threading.Lock()
        # job -> [(key, step, anoms, ts)] in ship order, accumulated by
        # the drainers when no on_fleet callback consumes them instead
        self.fleet_observations: dict[str, list] = {}
        self.fleet_progress: dict[str, float] = {}
        self._on_anomalies: Optional[Callable] = None
        self._on_fleet: Optional[Callable] = None
        self._on_job: Optional[Callable] = None
        self._on_error: Optional[Callable] = None
        self._on_snapshot: Optional[Callable] = None
        self._on_death: Optional[Callable] = None
        for i in range(workers):
            tq = ctx.Queue()
            rq = ctx.Queue(maxsize=max(result_depth, 2))
            p = ctx.Process(target=_worker_main, args=(tq, rq, init),
                            daemon=True, name=f"flare-fleet-worker-{i}")
            p.start()
            self._task_qs.append(tq)
            self._procs.append(p)
            self._result_qs.append(rq)

    # ------------------------------------------------------------------ #
    # submission / routing
    # ------------------------------------------------------------------ #
    def worker_for(self, job_id: str) -> int:
        """The worker index a job is (or will be) pinned to."""
        w = self._route.get(job_id)
        if w is None:
            w = self._route[job_id] = self._next_worker
            self._next_worker = (self._next_worker + 1) % len(self._procs)
        return w

    def submit(self, task) -> None:
        """Enqueue one task on its job's pinned worker (pinning the job
        round-robin on first sight)."""
        self._task_qs[self.worker_for(task[1])].put(task)

    def close_job(self, job_id: str) -> None:
        """Graceful per-job leave: the worker flushes the job and ships
        its terminal envelope (surfaced via ``on_job`` / ``results``)."""
        self.submit((TASK_CLOSE, job_id, None, None, None))

    def task_depths(self) -> list[int]:
        """Approximate per-worker task-queue depths (-1 where the
        platform can't say)."""
        out = []
        for q in self._task_qs:
            try:
                out.append(q.qsize())
            except (NotImplementedError, OSError):
                out.append(-1)
        return out

    @property
    def results(self) -> dict[str, dict]:
        """Terminal payloads received so far (job_id -> payload)."""
        return self._results

    # ------------------------------------------------------------------ #
    # draining
    # ------------------------------------------------------------------ #
    def start(self, *, on_anomalies: Optional[Callable] = None,
              on_fleet: Optional[Callable] = None,
              on_job: Optional[Callable] = None,
              on_error: Optional[Callable] = None,
              on_snapshot: Optional[Callable] = None,
              on_death: Optional[Callable] = None) -> None:
        """Start one drainer thread per worker (idempotent).  Callbacks
        may fire from several drainer threads at once — one per worker —
        so they must only touch internally-locked state:

        * ``on_anomalies(job_id, [(ts, Anomaly), ...])`` — incremental,
          in the worker's push order;
        * ``on_fleet(job_id, obs, progress)`` — keyed fleet observations
          plus frontier progress (when absent, both accumulate on
          ``fleet_observations`` / ``fleet_progress`` instead);
        * ``on_snapshot(job_id, state_or_None)`` — ``TASK_SNAPSHOT``
          answer (the job's full pipeline state for a checkpoint);
        * ``on_job(job_id, payload)`` — terminal envelope (always also
          recorded in ``results``);
        * ``on_error(job_id, tb)`` — when absent, errors collect and
          ``join`` raises;
        * ``on_death(worker_index)`` — a worker died WITHOUT its exit
          envelope and the pool is not closing: the recovery hook (when
          absent, an error records instead).  Fires from that worker's
          drainer thread, which returns right after — recovery must run
          elsewhere (never join drainers from it)."""
        if self._drainers:
            return
        self._on_anomalies = on_anomalies
        self._on_fleet = on_fleet
        self._on_job = on_job
        self._on_error = on_error
        self._on_snapshot = on_snapshot
        self._on_death = on_death
        self._drainers = [threading.Thread(
            target=self._drain_one, args=(i, p, rq),
            daemon=True, name=f"flare-fleet-drain-{i}")
            for i, (p, rq) in enumerate(zip(self._procs, self._result_qs))]
        for t in self._drainers:
            t.start()

    def shutdown(self) -> None:
        """Send every worker its shutdown sentinel (idempotent): each
        closes its still-open jobs (terminal envelopes flow to the
        drainers) and exits."""
        if not self._shutdown_sent:
            self._shutdown_sent = True
            self._closing = True
            for q in self._task_qs:
                q.put(None)

    def join(self, *, raise_errors: bool = True) -> dict[str, dict]:
        """Wait for the drainers and workers after ``shutdown``; raises
        the first collected worker error (unless routed to ``on_error``
        or ``raise_errors=False``); returns the terminal payloads."""
        for t in self._drainers:
            t.join()
        for p in self._procs:
            p.join(timeout=10.0)
        if raise_errors and self._errors:
            job_id, tb = self._errors[0]
            more = f" (+{len(self._errors) - 1} more)" \
                if len(self._errors) > 1 else ""
            raise RuntimeError(
                f"fleet replay worker failed on job {job_id!r}{more}:\n{tb}")
        return self._results

    def drain(self, on_anomalies: Optional[Callable] = None
              ) -> dict[str, dict]:
        """One-shot drive: shutdown + consume everything + join; returns
        ``job_id -> terminal payload``.  Shipped fleet observations and
        progress accumulate on ``fleet_observations``/``fleet_progress``
        for the caller to buffer afterwards."""
        self.start(on_anomalies=on_anomalies)
        self.shutdown()
        return self.join()

    def _drain_one(self, index: int, proc, rq) -> None:
        dead_polls = 0
        while True:
            try:
                env = rq.get(timeout=0.2)
            except _queue.Empty:
                if not proc.is_alive():
                    # grace polls: the feeder pipe may still hold data
                    # written just before an abnormal death
                    dead_polls += 1
                    if dead_polls >= 3:
                        if self._closing:
                            return     # intentional teardown, not a death
                        if self._on_death is not None:
                            self._on_death(index)
                            return
                        self._record_error(
                            "<unknown>",
                            f"worker {proc.name} died without an exit "
                            f"envelope (exitcode {proc.exitcode})")
                        return
                continue
            dead_polls = 0
            kind = env[0]
            if kind == "exit":
                return
            if kind == "anomalies":
                if self._on_anomalies is not None:
                    self._on_anomalies(env[1], env[2])
            elif kind == "snapshot":
                if self._on_snapshot is not None:
                    self._on_snapshot(env[1], env[2])
            elif kind == "fleet":
                if self._on_fleet is not None:
                    self._on_fleet(env[1], env[2], env[3])
                else:
                    with self._obs_lock:
                        if env[2]:
                            self.fleet_observations.setdefault(
                                env[1], []).extend(env[2])
                        self.fleet_progress[env[1]] = env[3]
            elif kind == "job":
                self._results[env[1]] = env[2]
                if self._on_job is not None:
                    self._on_job(env[1], env[2])
            elif kind == "error":
                self._record_error(env[1], env[2])

    def _record_error(self, job_id: str, tb: str) -> None:
        if self._on_error is not None:
            self._on_error(job_id, tb)
        else:
            self._errors.append((job_id, tb))

    def kill_worker(self, index: int) -> None:
        """Chaos hook: SIGKILL one worker process mid-flight (its open
        jobs' in-memory state is lost — exactly the failure the service's
        checkpoint recovery exists for)."""
        self._procs[index].kill()

    def stop(self, *, drainer_timeout: float = 10.0) -> None:
        """Abrupt teardown for recovery paths: mark the pool closing
        (so the terminations below don't read as worker deaths), kill
        the processes, and JOIN the drainer threads — after this no
        callback fires again, so the caller can safely rebuild shared
        state the callbacks touch.  Must not be called from a drainer
        thread (a drainer cannot join itself)."""
        self._closing = True
        for p in self._procs:
            if p.is_alive():
                p.terminate()
        for p in self._procs:
            p.join(timeout=5.0)
        # drainers exit via their dead-process grace polls (suppressed
        # by _closing); only then is it safe to close the queues under
        # them
        for t in self._drainers:
            t.join(timeout=drainer_timeout)
        for q in (*self._result_qs, *self._task_qs):
            q.close()
            q.cancel_join_thread()

    def close(self) -> None:
        self._closing = True
        for p in self._procs:
            if p.is_alive():
                p.terminate()
        for p in self._procs:
            p.join(timeout=5.0)
        for q in (*self._result_qs, *self._task_qs):
            q.close()
            q.cancel_join_thread()
