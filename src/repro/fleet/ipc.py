"""FCS-over-IPC process workers: the fleet replay engine past the GIL.

Thread-per-job replay (``FleetReplayer.replay_dir``) is byte-equivalent
to serial but GIL-bound — per-step diagnosis interleaves short Python
sections with numpy windows, so two worker threads on two cores buy
~1.08x, not 2x.  This module ships each job's whole pipeline — decode ->
step-aligned ingest -> ``evaluate_step_batch`` on a private
:class:`~repro.core.engine.DiagnosticEngine` — into a worker *process*,
and moves data across the boundary in the cheapest shapes the codebase
already has:

  * **inputs**: replay workers read trace files straight from disk (no
    event rows cross at all); live-streaming callers ship
    :class:`~repro.core.columnar.EventBatch` chunks as FCS-encoded
    segments (``repro.store.encode_batch_bytes`` — the archival spill
    format, ~11.5 B/event at 256 ranks) instead of numpy pickles;
  * **outputs**: anomalies stream back incrementally per file on a
    BOUNDED result queue (backpressure: a slow parent stalls its
    workers, not the box's memory), followed by one terminal envelope
    per job carrying the compact serialized end state — job-local
    ``ReplayStats``, the recorded fleet-tier observation sequence
    (``defer_fleet_tier(record=True)``), the worker's intern tables,
    a telemetry snapshot, and the store/engine summary the parent
    mirrors back onto its own ``FleetJob``.

Determinism contract: a worker owns exactly one job at a time and ships
that job's anomalies in push order; the parent re-pushes on ITS stream
(per-job order preserved; the stream's ``(ts, job_id, seq)`` drain sort
already makes cross-job interleave scheduling-independent), merges
intern tables and stats in sorted-path group order, and replays the
recorded fleet-tier observations through ``resolve_fleet_tier`` in the
same two phases serial replay produces (ingest-phase in group order,
flush-phase in registration order).  Diagnosis output is therefore
byte-equivalent to serial by construction — asserted end to end in
``benchmarks/fleet.py`` and ``tests/test_fleet.py``.

Worker entry points are top-level functions with picklable arguments,
so the pool works under both ``fork`` (Linux default) and ``spawn``.
"""
from __future__ import annotations

import multiprocessing as mp
import queue as _queue
import threading
import traceback
from typing import Callable, Optional

# task envelope: ("replay", job_id, [paths], engine_cfg, record_fleet)
#             or ("batches", job_id, [fcs_bytes], engine_cfg, record_fleet)
#             or None (shutdown sentinel, one per worker)
TASK_REPLAY = "replay"
TASK_BATCHES = "batches"

# result envelopes, on the owning worker's bounded queue:
#   ("anomalies", job_id, [(ts, Anomaly), ...])   incremental, per file
#   ("job", job_id, payload_dict)                 terminal, per job
#   ("error", job_id, traceback_str)
#   ("exit",)                                     worker is done
_EXIT = ("exit",)


def _run_job(result_q, kind: str, job_id: str, payload, engine_cfg,
             record_fleet: bool, init: dict) -> None:
    """One job's full pipeline inside the worker process: private
    multiplexer + engine, eager flush (worker state dies with the
    process), results shipped as they appear."""
    # imported here, not at module top: repro.fleet.replay imports this
    # module, and the worker only pays the import once per process
    from repro.fleet.multiplexer import FleetConfig, FleetMultiplexer
    from repro.fleet.replay import FleetReplayer, ReplayStats
    from repro.store import decode_batch_bytes

    mux = FleetMultiplexer(FleetConfig(**init["fleet"]),
                           history=init["history"])
    mux.add_job(job_id, engine_cfg)
    # record the fleet-tier observation sequence for the parent (which
    # owns the actual cross-job detectors) — skipped when it has none
    mux.defer_fleet_tier(record=record_fleet)
    rep = FleetReplayer(mux, job_workers=1, **init["replay"])
    stats = ReplayStats(worker_kind="process")

    def _ship_anomalies() -> None:
        pend = mux.stream.drain_raw()
        if pend:
            result_q.put(("anomalies", job_id,
                          [(fa.ts, fa.anomaly) for fa in pend]))

    if kind == TASK_REPLAY:
        rep._replay_job(job_id, payload, stats, on_file=_ship_anomalies)
    elif kind == TASK_BATCHES:
        for blob in payload:
            batch = decode_batch_bytes(blob)
            stats.events += len(batch)
            stats.per_job[job_id] = stats.per_job.get(job_id, 0) \
                + len(batch)
            rep._ingest_step_aligned(job_id, batch)
            _ship_anomalies()
    else:
        raise ValueError(f"unknown worker task kind {kind!r}")

    # split the recorded fleet observations at the flush boundary: the
    # parent replays ingest-phase obs in group order and flush-phase obs
    # in registration order — the exact serial sequence
    obs_ingest = mux.drain_deferred_fleet().get(job_id, [])
    mux.flush(job_id)
    obs_flush = mux.drain_deferred_fleet().get(job_id, [])
    _ship_anomalies()
    job = mux.job(job_id)
    result_q.put(("job", job_id, {
        "stats": stats,
        "obs_ingest": obs_ingest,
        "obs_flush": obs_flush,
        "state": {
            "store": job.store.summary(),
            "last_closed": job.last_closed,
            "hang_reported": job.hang_reported,
            "evaluated_steps": sorted(job.engine.evaluated_steps),
        },
        "names": list(mux.interner.names),
        "groups": list(mux.interner.groups),
        "telemetry": mux.telemetry.snapshot(),
    }))


def _worker_main(task_q, result_q, init: dict) -> None:
    """Worker loop: pull job tasks until the shutdown sentinel.  An
    exception in one job is shipped as an ``error`` envelope and the
    worker moves on — partial fleet progress is never thrown away by
    one bad job."""
    while True:
        task = task_q.get()
        if task is None:
            break
        kind, job_id, payload, engine_cfg, record_fleet = task
        try:
            _run_job(result_q, kind, job_id, payload, engine_cfg,
                     record_fleet, init)
        except BaseException:
            try:
                result_q.put(("error", job_id, traceback.format_exc()))
            except Exception:
                break
    result_q.put(_EXIT)


class ProcessWorkerPool:
    """Fixed pool of job-replay worker processes.

    One shared task queue (jobs outnumber workers; each worker pulls its
    next job when free) and one BOUNDED result queue per worker — a
    worker handles one job at a time, so the bound is a per-job result
    budget: a parent that falls behind consuming anomalies stalls the
    producing worker instead of buffering unboundedly.

    Lifecycle: construct (forks/spawns immediately), ``submit`` every
    task, then ``drain`` exactly once — it enqueues one shutdown
    sentinel per worker, consumes every result, joins, and raises if
    any worker errored or died.  ``close`` is the unconditional cleanup
    (safe after ``drain``; terminates stragglers otherwise)."""

    def __init__(self, workers: int, init: dict, *, result_depth: int = 8,
                 mp_context=None):
        ctx = mp_context or mp.get_context()
        self._task_q = ctx.Queue()
        self._procs = []
        self._result_qs = []
        self._results: dict[str, dict] = {}
        self._errors: list[tuple[str, str]] = []
        for i in range(workers):
            rq = ctx.Queue(maxsize=max(result_depth, 2))
            p = ctx.Process(target=_worker_main, args=(self._task_q, rq, init),
                            daemon=True, name=f"flare-fleet-worker-{i}")
            p.start()
            self._procs.append(p)
            self._result_qs.append(rq)

    def submit(self, task) -> None:
        self._task_q.put(task)

    def drain(self, on_anomalies: Optional[Callable] = None
              ) -> dict[str, dict]:
        """Consume every worker's results until all exit; returns
        ``job_id -> terminal payload``.  ``on_anomalies(job_id, items)``
        fires for each incremental anomaly envelope (items are ``(ts,
        Anomaly)`` pairs in the worker's push order) — it may be called
        from several drainer threads at once, one per worker, so it must
        only touch internally-locked state (the anomaly stream is)."""
        for _ in self._procs:
            self._task_q.put(None)
        threads = [threading.Thread(
            target=self._drain_one, args=(p, rq, on_anomalies),
            daemon=True, name=f"flare-fleet-drain-{i}")
            for i, (p, rq) in enumerate(zip(self._procs, self._result_qs))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for p in self._procs:
            p.join(timeout=10.0)
        if self._errors:
            job_id, tb = self._errors[0]
            more = f" (+{len(self._errors) - 1} more)" \
                if len(self._errors) > 1 else ""
            raise RuntimeError(
                f"fleet replay worker failed on job {job_id!r}{more}:\n{tb}")
        return self._results

    def _drain_one(self, proc, rq, on_anomalies) -> None:
        dead_polls = 0
        while True:
            try:
                env = rq.get(timeout=0.2)
            except _queue.Empty:
                if not proc.is_alive():
                    # grace polls: the feeder pipe may still hold data
                    # written just before an abnormal death
                    dead_polls += 1
                    if dead_polls >= 3:
                        self._errors.append((
                            "<unknown>",
                            f"worker {proc.name} died without an exit "
                            f"envelope (exitcode {proc.exitcode})"))
                        return
                continue
            dead_polls = 0
            kind = env[0]
            if kind == "exit":
                return
            if kind == "anomalies":
                if on_anomalies is not None:
                    on_anomalies(env[1], env[2])
            elif kind == "job":
                self._results[env[1]] = env[2]
            elif kind == "error":
                self._errors.append((env[1], env[2]))

    def close(self) -> None:
        for p in self._procs:
            if p.is_alive():
                p.terminate()
        for p in self._procs:
            p.join(timeout=5.0)
        for q in (*self._result_qs, self._task_q):
            q.close()
            q.cancel_join_thread()
