"""Merged fleet anomaly stream: job-tagged, timestamp-ordered, team-routed.

Each job's engine emits plain :class:`~repro.core.engine.Anomaly` objects;
the stream wraps them with the job id, the event time (end of the step
slice that fired), a fleet-wide arrival sequence number, and the routing
target for the anomaly's team (paper Table 1: operations / algorithm /
infrastructure / cross-team).  ``drain()`` returns everything pushed since
the last drain merged across jobs in ``(ts, job_id, seq)`` order — jobs
advance at their own pace, so total order is per drain; a terminal
``finalize`` drain is fully ordered, and equal-timestamp ties across jobs
break by job id, not by (thread-scheduling-dependent) arrival.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Optional

from repro.core.engine import Anomaly, Team

DEFAULT_ROUTES: dict[Team, str] = {
    Team.OPERATIONS: "oncall-operations",
    Team.ALGORITHM: "oncall-algorithm",
    Team.INFRASTRUCTURE: "oncall-infrastructure",
    Team.CROSS_TEAM: "cross-team-review",
}


@dataclass
class FleetAnomaly:
    job_id: str
    ts: float                # event time: end of the slice that fired
    seq: int                 # fleet-wide arrival order (total tie-break)
    anomaly: Anomaly
    route: str
    origin: str = "job"      # "job" (per-job engine) | "fleet" (cross-job tier)

    @property
    def team(self) -> Team:
        return self.anomaly.team

    def __str__(self):
        tag = "" if self.origin == "job" else f" ({self.origin})"
        return f"[{self.ts:10.3f}s] {self.job_id}{tag} -> {self.route}: " \
               f"{self.anomaly}"


class AnomalyStream:
    """Collects per-job anomalies; drains them merged and ordered.
    Push/drain are thread-safe (jobs advance on their own threads)."""

    def __init__(self, routes: Optional[dict[Team, str]] = None):
        self.routes = dict(DEFAULT_ROUTES)
        if routes:
            self.routes.update(routes)
        self._pending: list[FleetAnomaly] = []
        self._lock = threading.Lock()
        self.total = 0

    def push(self, job_id: str, anomaly: Anomaly, ts: float,
             origin: str = "job") -> FleetAnomaly:
        with self._lock:
            fa = FleetAnomaly(
                job_id=job_id, ts=float(ts), seq=self.total, anomaly=anomaly,
                route=self.routes.get(anomaly.team,
                                      DEFAULT_ROUTES[Team.CROSS_TEAM]),
                origin=origin)
            self._pending.append(fa)
            self.total += 1
            return fa

    def drain(self) -> list[FleetAnomaly]:
        with self._lock:
            out, self._pending = self._pending, []
        # ts first; equal-ts ties break by job THEN arrival: within one
        # job arrival order is meaningful (one thread pushes that job's
        # anomalies in order) but ACROSS jobs it is thread-scheduling —
        # two jobs replaying the same recorded timestamps must drain
        # identically whether replayed serially or on parallel workers
        out.sort(key=lambda a: (a.ts, a.job_id, a.seq))
        return out

    def restore_seq(self, total: int) -> None:
        """Continue a checkpointed stream's fleet-wide sequence: the
        next push gets ``seq >= total``, so post-restore anomalies never
        reuse the sequence numbers of ones emitted before the snapshot
        (the ring and downstream consumers stay monotone)."""
        with self._lock:
            self.total = max(self.total, int(total))

    def drain_raw(self) -> list[FleetAnomaly]:
        """Pending anomalies in ARRIVAL order, no merge sort.  A replay
        worker process ships these across the IPC boundary; the parent
        re-pushes them onto ITS stream, which preserves per-job order —
        the only order that matters, since :meth:`drain`'s ``(ts,
        job_id, seq)`` sort already makes cross-job interleave
        scheduling-independent."""
        with self._lock:
            out, self._pending = self._pending, []
        return out

    def __len__(self) -> int:
        return len(self._pending)
