"""Offline replay of recorded fleet logs through the multiplexer.

Real deployments accumulate multi-GB trace logs per job — JSONL from the
historical daemons, FCS segments from the binary spill path, rotated
``.segNNN`` pieces from long runs — and replaying a directory of them
re-runs the exact online diagnosis offline.  ``FleetReplayer`` resolves
the codec per file (extension, then content sniff), so mixed-format
directories replay in one pass:

  * JSONL files split on line boundaries and decode concurrently
    (``executor="process"`` scales the json-parse-bound decode past the
    GIL — ``EventBatch`` pickles cheaply; small files auto-fall back to
    one serial pass);
  * FCS files memory-map and stream segment by segment (v2 segments
    inflate slab-wise), each segment ingested as step-aligned slices so
    the per-job watermark closes and diagnoses steps exactly as it would
    have live (and peak memory stays one step, not one file);
  * corrupt input is skipped and counted, never fatal: undecodable JSONL
    lines, truncated FCS tails from killed writers (every intact leading
    segment still replays), and unreadable files.

``replay_dir`` is a PARALLEL pipeline: per-job engines are lock-isolated
(``repro.fleet.multiplexer``), so one worker per job drives that job's
decode -> step-aligned ingest -> incremental diagnosis chain end to
end, overlapping jobs on a multi-core box.  A bounded per-job prefetch
queue lets each job's decode run a couple of chunks ahead of its
diagnosis (backpressure: a slow engine stalls its own decoder, not the
fleet's memory).  Workers come in two kinds:

  * ``worker_kind="thread"`` (default): cheap, shares the multiplexer
    directly — but GIL-bound, so it only overlaps the numpy windows
    (~1.08x at 2 workers / 2 cores);
  * ``worker_kind="process"``: each job's whole pipeline runs in a
    worker PROCESS (``repro.fleet.ipc``) on a private engine, anomalies
    and end state shipped back over bounded queues, event batches
    crossing the boundary (when they must at all) as FCS bytes — real
    multi-core scaling for the decode+diagnose hot path.

Either kind is byte-equivalent to serial replay:

  * jobs are registered up front in sorted path order, so registration
    (and thus flush/finalize) order never depends on worker timing;
  * per-worker ``ReplayStats`` merge deterministically after the join
    (``per_job`` is emitted key-sorted either way);
  * the order-sensitive fleet-scope detector tier never sees raw
    arrival order: observations are buffered under per-job cummax
    timestamp keys and resolved in one global sorted order
    (``FleetMultiplexer.resolve_fleet_all`` at the end of the drain) —
    the same order the live ``FleetService`` resolves incrementally at
    its frontier, so batch replay, parallel replay, and live streaming
    all emit byte-identical fleet-tier reclassifications.  Process
    workers RECORD their job's keyed observations and ship them back
    for the same resolution.
"""
from __future__ import annotations

import glob
import os
import queue
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Iterable, Iterator, Optional

from repro.fleet.multiplexer import FleetMultiplexer
from repro.store import (CodecError, Predicate, ScanStats, codec_for_path,
                         codecs, is_sidecar_path, job_id_for_path,
                         seg_index)


def _known_patterns() -> tuple[str, ...]:
    """One glob per registered codec extension, so a newly registered
    format replays without touching this module."""
    return tuple(f"*{ext}" for c in codecs().values()
                 for ext in c.extensions)


_END = object()


def _iter_prefetch(it: Iterable, depth: int) -> Iterator:
    """Pull ``it`` on a helper thread through a bounded queue: the
    producer (chunk decode) runs at most ``depth`` items ahead of the
    consumer (ingest + diagnosis).  Exceptions — including the
    ``CodecError`` a truncated tail raises mid-file — cross the queue
    and re-raise at the consumption point, after every chunk decoded
    before them was delivered (the skip-and-count contract)."""
    q: "queue.Queue" = queue.Queue(maxsize=max(depth, 1))
    cancel = threading.Event()

    def _put(pair) -> bool:
        while not cancel.is_set():
            try:
                q.put(pair, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False                         # consumer gone; stop pumping

    def _pump():
        end = (_END, None)
        try:
            for item in it:
                if not _put((item, None)):
                    return
        except BaseException as e:           # delivered, not swallowed
            end = (_END, e)
        _put(end)

    t = threading.Thread(target=_pump, daemon=True,
                         name="flare-replay-prefetch")
    t.start()
    try:
        while True:
            item, exc = q.get()
            if item is _END:
                if exc is not None:
                    raise exc
                return
            yield item
    finally:
        cancel.set()
        t.join(timeout=5.0)


@dataclass
class ReplayStats:
    files: int = 0
    events: int = 0
    skipped_lines: int = 0       # corrupt JSONL lines skipped
    corrupt_files: int = 0       # files with a CodecError (bad magic,
    #                              truncated FCS tail, unknown format)
    skipped_segments: int = 0    # FCS v3 segments pruned on stats alone
    bytes_decoded: int = 0       # segment bytes actually decoded (FCS)
    bytes_skipped: int = 0       # segment bytes hopped over by pushdown
    seconds: float = 0.0
    job_workers: int = 1         # workers the replay actually used
    worker_kind: str = "serial"  # "serial" | "thread" | "process"
    per_job: dict = field(default_factory=dict)   # job_id -> events

    @property
    def events_per_s(self) -> float:
        return self.events / self.seconds if self.seconds > 0 else 0.0

    def merge(self, other: "ReplayStats") -> None:
        """Fold one worker's job-local stats in (call in a deterministic
        job order — the parallel path merges sorted-by-job after the
        join, so totals and ``per_job`` never depend on thread timing)."""
        self.files += other.files
        self.events += other.events
        self.skipped_lines += other.skipped_lines
        self.corrupt_files += other.corrupt_files
        self.skipped_segments += other.skipped_segments
        self.bytes_decoded += other.bytes_decoded
        self.bytes_skipped += other.bytes_skipped
        for job_id, ev in other.per_job.items():
            self.per_job[job_id] = self.per_job.get(job_id, 0) + ev


class FleetReplayer:
    """Replays trace directories into a :class:`FleetMultiplexer`.

    ``chunk_bytes``/``max_workers``/``executor``/``serial_below`` tune
    the per-file chunk decode (JSONL); ``job_workers`` caps the per-job
    workers of :meth:`replay_dir` (``None`` = auto; ``1`` = serial; an
    explicit ``N`` is always honored); ``worker_kind`` picks what a
    worker IS — ``"thread"`` (default; auto stays serial below 4 cores,
    where GIL convoying beats the overlap) or ``"process"``
    (``repro.fleet.ipc``; auto uses one worker per core from 2 cores up,
    since processes don't convoy); ``prefetch`` bounds how many decoded
    chunks each job may queue ahead of its diagnosis (``0`` disables
    the pipeline and decodes inline).

    ``predicate`` (a :class:`repro.store.Predicate`) pushes segment
    pruning into the decode: FCS v3 segments whose stats prove no row
    can match are hopped over without inflating a slab (counted in
    ``ReplayStats.skipped_segments`` / ``bytes_skipped``).  Pruning is
    segment-granular — yielded segments still carry all their rows —
    and v1/v2/JSONL inputs simply decode everything, so a predicate
    never changes which FORMATS replay, only how much I/O v3 archives
    pay.  Use it to re-diagnose a step/time window out of a months-long
    archive without paying a full decode."""

    def __init__(self, mux: FleetMultiplexer, *, chunk_bytes: int = 8 << 20,
                 max_workers: Optional[int] = None,
                 executor: str = "thread",
                 serial_below: Optional[int] = None,
                 job_workers: Optional[int] = None,
                 worker_kind: str = "thread",
                 prefetch: int = 2,
                 predicate: Optional[Predicate] = None):
        if worker_kind not in ("thread", "process"):
            raise ValueError(
                f"worker_kind must be 'thread' or 'process', "
                f"got {worker_kind!r}")
        self.mux = mux
        self.chunk_bytes = chunk_bytes
        self.max_workers = max_workers
        self.executor = executor
        self.serial_below = serial_below
        self.job_workers = job_workers
        self.worker_kind = worker_kind
        self.prefetch = prefetch
        self.predicate = predicate

    def _ingest_step_aligned(self, job_id: str, batch) -> None:
        """Step-aligned ingest — the logic lives on the multiplexer now
        (``FleetMultiplexer.ingest_step_aligned``) so the live service
        feeds wire frames through the exact same slicing."""
        self.mux.ingest_step_aligned(job_id, batch)

    def replay_file(self, job_id: str, path: str,
                    stats: Optional[ReplayStats] = None) -> tuple[int, int]:
        """Stream one job's log into the multiplexer chunk by chunk;
        returns ``(events, skipped_lines)``.  A ``CodecError`` mid-file
        (truncated FCS tail) keeps everything already ingested and is
        counted on ``stats`` instead of raising."""
        codec = codec_for_path(path)
        events = skipped = 0
        scan = ScanStats()
        try:
            chunks = codec.iter_chunks(
                path, chunk_bytes=self.chunk_bytes,
                max_workers=self.max_workers, executor=self.executor,
                serial_below=self.serial_below,
                predicate=self.predicate, scan=scan)
            if self.prefetch > 0:
                chunks = _iter_prefetch(chunks, self.prefetch)
            for batch, sk in chunks:
                events += len(batch)
                skipped += sk
                self._ingest_step_aligned(job_id, batch)
        except CodecError:
            if stats is None:
                raise
            stats.corrupt_files += 1
        if stats is not None:
            stats.skipped_segments += scan.segments_skipped
            stats.bytes_decoded += scan.bytes_decoded
            stats.bytes_skipped += scan.bytes_skipped
        return events, skipped

    def _replay_job(self, job_id: str, paths: list[str],
                    stats: ReplayStats, on_file=None) -> ReplayStats:
        """One job's full pipeline: every rotated/renamed piece in
        order, decode -> step-aligned ingest -> incremental diagnosis on
        that job's (lock-isolated) engine.  Accounting lands on the
        caller-supplied ``stats`` — job-local in the parallel path.
        ``on_file`` fires after each file — the process worker ships
        accumulated anomalies there, for incremental backpressure."""
        for path in paths:
            pre_corrupt = stats.corrupt_files
            try:
                ev, sk = self.replay_file(job_id, path, stats)
            except CodecError:
                stats.corrupt_files += 1
                continue
            finally:
                if on_file is not None:
                    on_file()
            if ev == 0 and stats.corrupt_files > pre_corrupt:
                continue               # nothing usable before the corruption
            stats.files += 1
            stats.events += ev
            stats.skipped_lines += sk
            stats.per_job[job_id] = stats.per_job.get(job_id, 0) + ev
        return stats

    def _resolve_job_workers(self, n_jobs: int, override: Optional[int],
                             kind: str = "thread") -> int:
        w = override if override is not None else self.job_workers
        if w is None:
            cores = os.cpu_count() or 1
            if kind == "process":
                # processes don't convoy on the GIL: one worker per core
                # wins from 2 cores up (spawn cost amortizes over any
                # real replay; tiny dirs stay near-serial anyway)
                w = cores
            else:
                # Thread auto mode is conservative: per-step diagnosis
                # interleaves short GIL-held Python with GIL-releasing
                # numpy windows, so worker threads only overlap usefully
                # when there are enough cores for the windows to land
                # on; measured on a 2-core box the convoy cost makes
                # even independent replays ~0.5-0.8x.  Explicit
                # ``job_workers=N`` always honors the caller.
                w = 1 if cores < 4 else cores
        return max(1, min(w, n_jobs))

    def replay_dir(self, directory: str, *, pattern: Optional[str] = None,
                   flush: bool = True,
                   job_workers: Optional[int] = None,
                   worker_kind: Optional[str] = None) -> ReplayStats:
        """Replay every trace file in ``directory`` (all registered
        formats when ``pattern`` is None), then flush the fleet so
        trailing steps and hangs are diagnosed.  Rotated spill files
        (``job.fcs``, ``job.seg001.fcs``, …) replay into one job, in
        order; files that fail to decode are skipped and counted;
        archive sidecars (rollup caches, telemetry exports) are never
        treated as trace logs.

        Multi-job directories replay in PARALLEL, one worker per job
        (capped by ``job_workers``/cores), each worker owning its job's
        decode -> ingest -> diagnose chain — worker threads by default,
        worker PROCESSES with ``worker_kind="process"`` (the GIL-free
        path; see ``repro.fleet.ipc``).  Anomalies and stats are
        byte-equivalent to a ``job_workers=1`` serial replay either way
        (see module docstring for how ordering is pinned).  Anomalies
        are left in the multiplexer's stream for the caller to
        ``poll()``.  Returns throughput stats."""
        kind = worker_kind if worker_kind is not None else self.worker_kind
        if kind not in ("thread", "process"):
            raise ValueError(
                f"worker_kind must be 'thread' or 'process', got {kind!r}")
        patterns = (pattern,) if pattern is not None else _known_patterns()
        # numeric rotation order: lexicographic sorting would put
        # seg1000 before seg999 on months-long streams
        paths = sorted({p for pat in patterns
                        for p in glob.glob(os.path.join(directory, pat))
                        if not is_sidecar_path(p)},
                       key=lambda p: (job_id_for_path(p), seg_index(p), p))
        groups: dict[str, list[str]] = {}
        for p in paths:
            groups.setdefault(job_id_for_path(p), []).append(p)
        workers = self._resolve_job_workers(len(groups), job_workers, kind)
        stats = ReplayStats(job_workers=workers,
                            worker_kind=kind if workers > 1 else "serial")
        t0 = time.perf_counter()
        if workers <= 1:
            for job_id, jpaths in groups.items():
                self._replay_job(job_id, jpaths, stats)
        elif kind == "process":
            self._replay_dir_process(groups, workers, stats)
        else:
            # registration order must not depend on which worker ingests
            # first: it decides flush/finalize order and fleet-tier
            # resolution order
            for job_id in groups:
                self.mux.add_job(job_id)
            with ThreadPoolExecutor(
                    workers, thread_name_prefix="flare-replay") as ex:
                futs = {job_id: ex.submit(self._replay_job, job_id,
                                          jpaths, ReplayStats())
                        for job_id, jpaths in groups.items()}
                # merge in sorted-path (group) order, not completion
                # order: totals are sums either way, but determinism
                # is the contract
                for job_id in groups:
                    stats.merge(futs[job_id].result())
        if flush:
            self.mux.flush()
        # a directory drain is an end of stream: resolve every buffered
        # fleet-tier observation in the global sorted order (anomalies
        # are ready at the caller's next poll(), no finalize needed)
        self.mux.resolve_fleet_all()
        stats.seconds = time.perf_counter() - t0
        stats.per_job = dict(sorted(stats.per_job.items()))
        self._publish_telemetry(stats)
        return stats

    def _replay_dir_process(self, groups: dict, workers: int,
                            stats: ReplayStats) -> None:
        """Process-sharded replay: each job's pipeline runs in a worker
        process (``repro.fleet.ipc``); the parent re-pushes shipped
        anomalies as they arrive (bounded queues give backpressure),
        buffers the workers' keyed fleet-tier observation shipments
        (incremental ``"fleet"`` envelopes plus each job's terminal
        remainder, concatenated in per-job ship order), and after the
        join merges everything back DETERMINISTICALLY in sorted-path
        group order — intern tables, telemetry, per-job end state,
        stats.  ``resolve_fleet_all`` at the end of ``replay_dir`` then
        sorts the merged observations into the same global order the
        serial path produces."""
        from repro.fleet.ipc import TASK_REPLAY, ProcessWorkerPool
        mux = self.mux
        for job_id in groups:
            mux.add_job(job_id)
        record_fleet = bool(mux.fleet_detectors)
        init = {
            "history": mux.history,
            "fleet": {"watermark_delay": mux.cfg.watermark_delay,
                      "backend": mux.cfg.backend,
                      "max_pending_rows": mux.cfg.max_pending_rows},
            "replay": {"chunk_bytes": self.chunk_bytes,
                       "max_workers": self.max_workers,
                       "executor": self.executor,
                       "serial_below": self.serial_below,
                       "prefetch": self.prefetch,
                       "predicate": self.predicate},
        }

        def _on_anomalies(job_id: str, items) -> None:
            # stream + counter are internally locked; per-job push order
            # is the worker's push order (FIFO queue), which is all the
            # drain sort needs for scheduling-independent output
            job = mux.job(job_id)
            for ts, a in items:
                mux.stream.push(job_id, a, ts)
                job.count_anomaly()

        pool = ProcessWorkerPool(workers, init)
        try:
            for job_id, jpaths in groups.items():
                pool.submit((TASK_REPLAY, job_id, jpaths,
                             mux.job(job_id).engine.cfg, record_fleet))
            results = pool.drain(on_anomalies=_on_anomalies)
        finally:
            pool.close()
        missing = [j for j in groups if j not in results]
        if missing:     # drain() raises on worker errors; belt + braces
            raise RuntimeError(
                f"fleet replay workers returned no result for {missing}")
        for job_id in groups:
            res = results[job_id]
            mux.interner.merge_tables(res["names"], res["groups"])
            mux.telemetry.absorb(res["telemetry"])
            mux.restore_job_state(job_id, res["state"])
            stats.merge(res["stats"])
            mux.buffer_fleet_observations(
                job_id, pool.fleet_observations.get(job_id, []))
            mux.buffer_fleet_observations(job_id, res["obs"])

    def _publish_telemetry(self, stats: ReplayStats) -> None:
        """Land one replay's accounting in the multiplexer's telemetry
        registry (counters accumulate across successive replays into the
        same mux; the rate gauge reflects the latest run)."""
        reg = self.mux.telemetry
        for name, val in (("replay.files", stats.files),
                          ("replay.events", stats.events),
                          ("replay.skipped_lines", stats.skipped_lines),
                          ("replay.corrupt_files", stats.corrupt_files),
                          ("replay.skipped_segments",
                           stats.skipped_segments),
                          ("replay.bytes_decoded", stats.bytes_decoded),
                          ("replay.bytes_skipped", stats.bytes_skipped)):
            if val:
                reg.counter(name).inc(val)
        reg.gauge("replay.events_per_s").set(stats.events_per_s)
        for job_id, ev in stats.per_job.items():
            reg.counter("replay.events", job=job_id).inc(ev)
