"""Chunked/parallel JSONL replay of recorded fleet logs.

Real deployments accumulate multi-GB JSONL logs per job (daemon
``log_path`` output, killed jobs included — hence the tolerant decoder).
Replaying a directory of them through the multiplexer re-runs the exact
online diagnosis offline: each ``<job_id>.jsonl`` file is split on line
boundaries, chunks decode into ``EventBatch``es concurrently
(``columnar.iter_jsonl_chunks``), and every decoded chunk feeds
``mux.ingest`` in file order so the per-job watermark closes and diagnoses
steps exactly as it would have live.
"""
from __future__ import annotations

import glob
import os
import time
from dataclasses import dataclass, field
from typing import Optional

from repro.core.columnar import iter_jsonl_chunks
from repro.fleet.multiplexer import FleetMultiplexer


@dataclass
class ReplayStats:
    files: int = 0
    events: int = 0
    skipped_lines: int = 0
    seconds: float = 0.0
    per_job: dict = field(default_factory=dict)   # job_id -> events

    @property
    def events_per_s(self) -> float:
        return self.events / self.seconds if self.seconds > 0 else 0.0


class FleetReplayer:
    def __init__(self, mux: FleetMultiplexer, *, chunk_bytes: int = 8 << 20,
                 max_workers: Optional[int] = None):
        self.mux = mux
        self.chunk_bytes = chunk_bytes
        self.max_workers = max_workers

    def replay_file(self, job_id: str, path: str) -> tuple[int, int]:
        """Stream one job's log into the multiplexer chunk by chunk;
        returns ``(events, skipped_lines)``."""
        events = skipped = 0
        for batch, sk in iter_jsonl_chunks(path, chunk_bytes=self.chunk_bytes,
                                           max_workers=self.max_workers):
            events += len(batch)
            skipped += sk
            self.mux.ingest(job_id, batch)
        return events, skipped

    def replay_dir(self, directory: str, *, pattern: str = "*.jsonl",
                   flush: bool = True) -> ReplayStats:
        """Replay every ``pattern`` file in ``directory`` (job id = file
        stem), then flush the fleet so trailing steps and hangs are
        diagnosed.  Anomalies are left in the multiplexer's stream for the
        caller to ``poll()``.  Returns throughput stats."""
        stats = ReplayStats()
        t0 = time.perf_counter()
        for path in sorted(glob.glob(os.path.join(directory, pattern))):
            job_id = os.path.splitext(os.path.basename(path))[0]
            ev, sk = self.replay_file(job_id, path)
            stats.files += 1
            stats.events += ev
            stats.skipped_lines += sk
            stats.per_job[job_id] = ev
        if flush:
            self.mux.flush()
        stats.seconds = time.perf_counter() - t0
        return stats
