"""Offline replay of recorded fleet logs through the multiplexer.

Real deployments accumulate multi-GB trace logs per job — JSONL from the
historical daemons, FCS segments from the binary spill path, rotated
``.segNNN`` pieces from long runs — and replaying a directory of them
re-runs the exact online diagnosis offline.  ``FleetReplayer`` resolves
the codec per file (extension, then content sniff), so mixed-format
directories replay in one pass:

  * JSONL files split on line boundaries and decode concurrently
    (``executor="process"`` scales the json-parse-bound decode past the
    GIL — ``EventBatch`` pickles cheaply);
  * FCS files memory-map and stream segment by segment, each segment
    ingested as step-aligned slices so the per-job watermark closes and
    diagnoses steps exactly as it would have live (and peak memory stays
    one step, not one file);
  * corrupt input is skipped and counted, never fatal: undecodable JSONL
    lines, truncated FCS tails from killed writers (every intact leading
    segment still replays), and unreadable files.
"""
from __future__ import annotations

import glob
import os
import time
from dataclasses import dataclass, field
from typing import Optional

from repro.fleet.multiplexer import FleetMultiplexer
from repro.store import (CodecError, codec_for_path, codecs,
                         job_id_for_path, seg_index)


def _known_patterns() -> tuple[str, ...]:
    """One glob per registered codec extension, so a newly registered
    format replays without touching this module."""
    return tuple(f"*{ext}" for c in codecs().values()
                 for ext in c.extensions)


@dataclass
class ReplayStats:
    files: int = 0
    events: int = 0
    skipped_lines: int = 0       # corrupt JSONL lines skipped
    corrupt_files: int = 0       # files with a CodecError (bad magic,
    #                              truncated FCS tail, unknown format)
    seconds: float = 0.0
    per_job: dict = field(default_factory=dict)   # job_id -> events

    @property
    def events_per_s(self) -> float:
        return self.events / self.seconds if self.seconds > 0 else 0.0


class FleetReplayer:
    def __init__(self, mux: FleetMultiplexer, *, chunk_bytes: int = 8 << 20,
                 max_workers: Optional[int] = None,
                 executor: str = "thread"):
        self.mux = mux
        self.chunk_bytes = chunk_bytes
        self.max_workers = max_workers
        self.executor = executor

    def _ingest_step_aligned(self, job_id: str, batch) -> None:
        """Feed one decoded chunk as per-step slices in step order, so a
        whole-file segment (FCS, or any codec whose chunks span many
        steps) advances the watermark incrementally instead of arriving
        as one monolithic batch.  Single-step chunks — the common JSONL
        case — pass straight through.

        Step-sorted chunks (FCS segments written from step-ordered runs —
        the overwhelmingly common shape) are sliced as ZERO-COPY views
        (``slice_rows``): the engine aggregates straight off the decoded
        memmap columns, no per-step ``take`` copy.  Only genuinely
        interleaved chunks pay the permutation."""
        order, uniq, bounds = batch.step_index()
        if uniq.size <= 1:
            self.mux.ingest(job_id, batch)
            return
        if batch.is_step_sorted():
            # sorted => the stable argsort is the identity, so bounds are
            # direct row offsets into the original columns
            for j in range(uniq.size):
                self.mux.ingest(job_id, batch.slice_rows(
                    int(bounds[j]), int(bounds[j + 1])))
            return
        for j in range(uniq.size):
            self.mux.ingest(job_id, batch.take(order[bounds[j]:bounds[j + 1]]))

    def replay_file(self, job_id: str, path: str,
                    stats: Optional[ReplayStats] = None) -> tuple[int, int]:
        """Stream one job's log into the multiplexer chunk by chunk;
        returns ``(events, skipped_lines)``.  A ``CodecError`` mid-file
        (truncated FCS tail) keeps everything already ingested and is
        counted on ``stats`` instead of raising."""
        codec = codec_for_path(path)
        events = skipped = 0
        try:
            for batch, sk in codec.iter_chunks(
                    path, chunk_bytes=self.chunk_bytes,
                    max_workers=self.max_workers, executor=self.executor):
                events += len(batch)
                skipped += sk
                self._ingest_step_aligned(job_id, batch)
        except CodecError:
            if stats is None:
                raise
            stats.corrupt_files += 1
        return events, skipped

    def replay_dir(self, directory: str, *, pattern: Optional[str] = None,
                   flush: bool = True) -> ReplayStats:
        """Replay every trace file in ``directory`` (all registered
        formats when ``pattern`` is None), then flush the fleet so
        trailing steps and hangs are diagnosed.  Rotated spill files
        (``job.fcs``, ``job.seg001.fcs``, …) replay into one job, in
        order; files that fail to decode are skipped and counted.
        Anomalies are left in the multiplexer's stream for the caller to
        ``poll()``.  Returns throughput stats."""
        patterns = (pattern,) if pattern is not None else _known_patterns()
        # numeric rotation order: lexicographic sorting would put
        # seg1000 before seg999 on months-long streams
        paths = sorted({p for pat in patterns
                        for p in glob.glob(os.path.join(directory, pat))},
                       key=lambda p: (job_id_for_path(p), seg_index(p), p))
        stats = ReplayStats()
        t0 = time.perf_counter()
        for path in paths:
            job_id = job_id_for_path(path)
            pre_corrupt = stats.corrupt_files
            try:
                ev, sk = self.replay_file(job_id, path, stats)
            except CodecError:
                stats.corrupt_files += 1
                continue
            if ev == 0 and stats.corrupt_files > pre_corrupt:
                continue               # nothing usable before the corruption
            stats.files += 1
            stats.events += ev
            stats.skipped_lines += sk
            stats.per_job[job_id] = stats.per_job.get(job_id, 0) + ev
        if flush:
            self.mux.flush()
        stats.seconds = time.perf_counter() - t0
        return stats
