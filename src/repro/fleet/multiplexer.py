"""FleetMultiplexer — streaming multi-job ingest + incremental diagnosis.

The paper's headline deployment is not one job but a fleet: Flare ran for
eight months over 6,000 GPUs, ingesting every concurrent job's daemon
streams and diagnosing them *online*.  This module is that layer:

  * many jobs ingest concurrently into per-job step-partitioned columnar
    stores with fleet-shared name/group interning (``fleet.store``);
  * each job is evaluated INCREMENTALLY: a per-job watermark closes step
    ``s`` once data for step ``s + watermark_delay`` has been seen
    (out-of-order chunks within the window are fine; rows arriving for an
    already-diagnosed step are counted as late and dropped);
  * closed steps run through the job's own ``DiagnosticEngine`` via
    ``evaluate_step_batch`` — the same stateful detectors as a terminal
    ``evaluate_all``, so streaming diagnosis equals batch diagnosis;
  * hang suspects are tracked per job as chunks arrive; when a majority of
    the job's ranks report, pending steps are flushed and the hang is
    diagnosed immediately (a hung job stops producing events — waiting for
    a watermark that will never advance would mask exactly the anomaly the
    daemons are screaming about);
  * a second, FLEET-SCOPE detector tier (``FleetConfig.fleet_detectors``,
    resolved through the same registry at scope ``"fleet"``) observes
    every closed step's anomalies together with the job -> rack/switch
    topology (``set_topology``) — e.g. ``CrossJobFailSlowCorrelator``
    reclassifies co-occurring fail-slows on shared hardware as
    INFRASTRUCTURE.  Its emissions land on the same stream tagged
    ``origin="fleet"``;
  * everything lands in one merged, timestamp-ordered, team-routed
    :class:`~repro.fleet.stream.AnomalyStream` tagged with job ids.

Feed it from live ``TracingDaemon``s (``daemon.attach_fleet(mux, job)``),
from simulators (``mux.ingest(job, batch)``), or from recorded JSONL logs
(``fleet.replay``).  Ingest is thread-safe and parallel across jobs:
each job has its own lock (a global lock guards only the job registry;
the shared interner, the anomaly stream, and the fleet-detector tier lock
internally), so daemon background threads feeding different jobs never
serialize each other's diagnosis.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Optional

from repro.core.columnar import EventBatch
from repro.core.detectors.fleet import FleetContext
from repro.core.detectors.registry import resolve_detectors
from repro.core.engine import DiagnosticEngine, EngineConfig, Team
from repro.core.history import HistoryStore
from repro.core.telemetry import Counter, Gauge, TelemetryRegistry
from repro.fleet.store import SharedInterner, StepPartitionedStore
from repro.fleet.stream import AnomalyStream, FleetAnomaly


@dataclass
class FleetConfig:
    watermark_delay: int = 1    # steps behind max-seen before a step closes
    backend: str = "dense-train"
    routes: Optional[dict[Team, str]] = None
    # fleet-scope detector tier: registry names (scope "fleet"),
    # DetectorSpecs, classes, or instances.  Default: none.
    fleet_detectors: Optional[list] = None
    # job_id -> {"rack": ..., "switch": ...}; extend live via set_topology
    topology: Optional[dict[str, dict]] = None
    # self-telemetry registry; None = a private one per multiplexer.
    # ``telemetry_snapshot()`` merges attached daemons' registries in.
    telemetry: Optional[TelemetryRegistry] = None
    # per-job memory cap on the step-partitioned store, in buffered ROWS
    # (None = unbounded).  When a job's pending slices exceed the cap,
    # the oldest pending steps are force-closed (evaluated early) until
    # under it — bounded memory at the cost of possibly dropping
    # late-arriving rows for those steps on pathologically out-of-order
    # streams.  Deterministic per job (depends only on that job's own
    # ingest sequence), so serial/thread/process replays stay
    # byte-equivalent at any cap.  ``fleet.forced_closes{job=}`` counts.
    max_pending_rows: Optional[int] = None


@dataclass
class FleetJob:
    job_id: str
    store: StepPartitionedStore
    engine: DiagnosticEngine
    # telemetry handles (fleet.late_rows{job=}, fleet.watermark_lag{job=},
    # fleet.pending_steps{job=}) — created by add_job from the mux registry
    late_rows: Optional[Counter] = None
    watermark_lag: Optional[Gauge] = None
    pending_depth: Optional[Gauge] = None
    last_closed: int = -1
    hang_reported: bool = False
    daemon: object = None
    anomaly_count: int = 0
    # graceful leave: a departed job is fully diagnosed (flushed, hang
    # checked, detectors finalized) and no longer holds back the fleet
    # frontier; rows arriving afterwards are dropped and counted
    departed: bool = False
    # per-job lock: jobs share no mutable state except the interner and
    # the anomaly stream (each locked internally), so concurrent daemon
    # threads diagnose different jobs in parallel instead of serializing
    # the whole fleet behind one lock
    lock: threading.Lock = field(default_factory=threading.Lock)
    # leaf lock for anomaly_count only: the fleet tier credits a VICTIM
    # job from another job's ingest thread, which must not acquire the
    # victim's work lock (lock-order inversion with its own _observe_fleet)
    counter_lock: threading.Lock = field(default_factory=threading.Lock)

    def count_anomaly(self, n: int = 1) -> None:
        with self.counter_lock:
            self.anomaly_count += n

    @property
    def late_events(self) -> int:
        """Rows that arrived for an already-diagnosed step (historical
        name; the series is ``fleet.late_rows{job=...}``)."""
        return self.late_rows.value if self.late_rows is not None else 0

    @property
    def evaluated(self) -> set:
        """Diagnosed steps — the engine's record is the single source of
        truth (it marks steps in ``evaluate_step_batch``)."""
        return self.engine.evaluated_steps


class FleetMultiplexer:
    def __init__(self, config: Optional[FleetConfig] = None,
                 history: Optional[HistoryStore] = None):
        self.cfg = config or FleetConfig()
        self.history = history or HistoryStore()
        self.interner = SharedInterner()
        self.telemetry = self.cfg.telemetry or TelemetryRegistry()
        self.stream = AnomalyStream(self.cfg.routes)
        # deep-copy the inner attr dicts: set_topology mutates them, and a
        # FleetConfig reused across multiplexers must stay pristine
        self.topology: dict[str, dict] = {
            k: dict(v) for k, v in (self.cfg.topology or {}).items()}
        self.fleet_detectors = resolve_detectors(
            self.cfg.fleet_detectors, scope="fleet")
        self._fleet_ctx = FleetContext(topology=self.topology,
                                       config=self.cfg)
        for fd in self.fleet_detectors:
            fd.bind(self._fleet_ctx)
        self._jobs: dict[str, FleetJob] = {}
        self._lock = threading.RLock()    # job REGISTRY only; work is
        #                                   guarded by each job's own lock
        self._fleet_det_lock = threading.Lock()   # cross-job tier state
        # Fleet-tier frontier state.  Cross-job detectors are ORDER-
        # sensitive (a correlation window closes against whichever
        # observation arrived last), so observations are never fed to
        # them in raw arrival order.  Every closed step's anomalies are
        # buffered per job under a deterministic sort KEY — the job's
        # running max of closed-step timestamps (a cummax, so keys are
        # monotone per job regardless of per-step ts jitter) — and
        # resolved in global ``(key, job_id, per-job order)`` order once
        # the FRONTIER (min progress over active jobs) passes the key.
        # Because every job's future keys are >= its current progress,
        # each resolved batch is a prefix of the full sorted sequence:
        # incremental (live) resolution and one-shot end-of-stream
        # resolution produce byte-identical emissions.
        self._fleet_buf: dict[str, list] = {}       # job -> [(key, step, anoms, ts)]
        self._fleet_progress: dict[str, float] = {}  # job -> cummax closed ts
        # record mode: buffer observations even with no local fleet
        # detectors (a worker process records for its parent's tier) and
        # never resolve locally — drain_fleet_observations ships them
        self._record_fleet = False

    # ------------------------------------------------------------------ #
    # job registry
    # ------------------------------------------------------------------ #
    def add_job(self, job_id: str,
                engine_cfg: Optional[EngineConfig] = None) -> FleetJob:
        """Register a job.  Without an ``engine_cfg`` (and thus without a
        learned profile for its backend/scale) the job still gets the
        macro fail-slow and hang paths; regressions need history."""
        with self._lock:
            if job_id in self._jobs:
                return self._jobs[job_id]
            cfg = engine_cfg or EngineConfig(backend=self.cfg.backend)
            job = FleetJob(
                job_id=job_id,
                store=StepPartitionedStore(self.interner),
                engine=DiagnosticEngine(cfg, self.history),
                late_rows=self.telemetry.counter("fleet.late_rows",
                                                 job=job_id),
                watermark_lag=self.telemetry.gauge("fleet.watermark_lag",
                                                   job=job_id),
                pending_depth=self.telemetry.gauge("fleet.pending_steps",
                                                   job=job_id))
            self._jobs[job_id] = job
            return job

    def job(self, job_id: str) -> FleetJob:
        with self._lock:
            return self._jobs[job_id]

    @property
    def jobs(self) -> list[FleetJob]:
        with self._lock:
            return list(self._jobs.values())

    def set_topology(self, job_id: str, **attrs) -> None:
        """Annotate a job with placement metadata for the fleet-scope
        detector tier (e.g. ``set_topology("job-a", rack="r12",
        switch="sw3")``).  Merges into any attrs set earlier."""
        with self._fleet_det_lock:
            self.topology.setdefault(job_id, {}).update(attrs)

    def register_daemon(self, job_id: str, daemon,
                        engine_cfg: Optional[EngineConfig] = None) -> FleetJob:
        job = self.add_job(job_id, engine_cfg)
        job.daemon = daemon
        return job

    def attach_daemon(self, job_id: str, daemon):
        """Convenience for ``daemon.attach_fleet(self, job_id)``."""
        return daemon.attach_fleet(self, job_id)

    # ------------------------------------------------------------------ #
    # ingest + incremental evaluation
    # ------------------------------------------------------------------ #
    def ingest(self, job_id: str, events) -> None:
        """Append one chunk of a job's stream: an ``EventBatch``, a flat
        ``list[TraceEvent]`` (daemon sink shape), or the legacy
        rank -> event-list dict.  Closes and diagnoses every step the
        chunk's watermark completed."""
        if isinstance(events, EventBatch):
            batch = events
        elif isinstance(events, dict):
            batch = EventBatch.from_events_by_rank(events)
        else:
            batch = EventBatch.from_events(events)
        if not len(batch):
            return
        with self._lock:
            job = self._jobs.get(job_id) or self.add_job(job_id)
        if job.departed:
            # graceful-leave contract: a retired job's diagnosis is
            # closed; stragglers are dropped and counted, never revived
            self.telemetry.counter("fleet.departed_rows",
                                   job=job_id).inc(len(batch))
            return
        with job.lock:
            touched = job.store.append(batch)
            for s, nrows in touched.items():
                if s in job.evaluated:
                    job.late_rows.inc(nrows)
                    job.store.drop_step(s)
            self._advance(job)
            self._maybe_hang(job)
        self.resolve_fleet_ready()

    def ingest_step_aligned(self, job_id: str, batch: EventBatch) -> None:
        """Feed one decoded chunk as per-step slices in step order, so a
        segment spanning many steps (a whole FCS file, a big wire frame)
        advances the watermark incrementally instead of arriving as one
        monolithic batch — diagnosis becomes independent of how the
        stream happened to be chunked on disk or on the wire.
        Single-step chunks pass straight through.

        Step-sorted chunks (the overwhelmingly common shape) are sliced
        as ZERO-COPY views (``slice_rows``); only genuinely interleaved
        chunks pay the ``take`` permutation."""
        order, uniq, bounds = batch.step_index()
        if uniq.size <= 1:
            self.ingest(job_id, batch)
            return
        if batch.is_step_sorted():
            # sorted => the stable argsort is the identity, so bounds are
            # direct row offsets into the original columns
            for j in range(uniq.size):
                self.ingest(job_id, batch.slice_rows(
                    int(bounds[j]), int(bounds[j + 1])))
            return
        for j in range(uniq.size):
            self.ingest(job_id, batch.take(order[bounds[j]:bounds[j + 1]]))

    @staticmethod
    def _job_ranks(job: FleetJob) -> int:
        """Job-wide rank count: the configured engine scale wins over the
        ranks seen so far — early chunks (one daemon's first drain) may
        show a tiny subset, which would skew per-rank metrics and let a
        single suspect clear the majority-hang threshold."""
        return max(job.store.num_ranks, job.engine.cfg.num_ranks)

    def _close_step(self, job: FleetJob, s: int) -> None:
        sb = job.store.pop_step(s)
        anoms = job.engine.evaluate_step_batch(
            sb, s, num_ranks=self._job_ranks(job))
        ts = float(sb.end_ts.max()) if len(sb) else job.store.last_ts
        job.last_closed = s
        for a in anoms:
            self.stream.push(job.job_id, a, ts)
            job.count_anomaly()
        self._observe_fleet(job.job_id, s, anoms, ts)

    def _advance(self, job: FleetJob, flush: bool = False) -> None:
        limit = None if flush \
            else job.store.max_step_seen - self.cfg.watermark_delay
        for s in job.store.pending_steps():
            if limit is not None and s > limit:
                break
            self._close_step(job, s)
        # memory cap: if the pending slices still exceed the per-job row
        # budget, force-close oldest-first until under it (the newest
        # pending step always stays buffered — it is the one still
        # filling).  Early closure means late rows for those steps get
        # dropped, which is the documented trade-off of the cap.
        cap = self.cfg.max_pending_rows
        if cap is not None and not flush and job.store.buffered_rows > cap:
            forced = 0
            while job.store.buffered_rows > cap:
                pending = job.store.pending_steps()
                if len(pending) <= 1:
                    break
                self._close_step(job, pending[0])
                forced += 1
            if forced:
                self.telemetry.counter("fleet.forced_closes",
                                       job=job.job_id).inc(forced)
        # watermark lag = steps seen but not yet closed; pending depth =
        # step buckets currently held (the mux's "queue")
        job.watermark_lag.set(max(job.store.max_step_seen - job.last_closed,
                                  0))
        job.pending_depth.set(len(job.store.pending_steps()))

    # ------------------------------------------------------------------ #
    # fleet tier: deterministic frontier resolution
    # ------------------------------------------------------------------ #
    def record_fleet_observations(self, on: bool = True) -> None:
        """Record mode for worker processes: buffer observations even
        when THIS multiplexer has no fleet detectors, and never resolve
        locally.  :meth:`drain_fleet_observations` ships the keyed
        sequence to the parent (which owns the real detectors)."""
        with self._fleet_det_lock:
            self._record_fleet = bool(on)

    def drain_fleet_observations(self) -> dict[str, list]:
        """Take the buffered ``job_id -> [(key, step, anomalies, ts)]``
        observations (recording stays on).  Keys are the per-job cummax
        described in :meth:`resolve_fleet_ready`; shipping them (rather
        than recomputing from the anomalous subset) keeps the parent's
        global sort identical to an in-process run."""
        with self._fleet_det_lock:
            out, self._fleet_buf = self._fleet_buf, {}
        return out

    def buffer_fleet_observations(self, job_id: str, obs) -> None:
        """Append a worker's shipped ``[(key, step, anomalies, ts)]``
        sequence (in per-job order) to the local buffer.  Keys are
        re-cummaxed against anything already buffered for the job, so
        incremental shipments concatenate cleanly."""
        if not obs:
            return
        with self._fleet_det_lock:
            buf = self._fleet_buf.setdefault(job_id, [])
            prog = self._fleet_progress.get(job_id, float("-inf"))
            for key, step, anoms, ts in obs:
                prog = max(prog, float(key))
                buf.append((prog, int(step), list(anoms), float(ts)))
            self._fleet_progress[job_id] = prog

    def note_fleet_progress(self, job_id: str, ts: float) -> None:
        """Advance a job's fleet frontier (cummax) without an
        observation — how a parent mirrors the progress a worker process
        reports for anomaly-free stretches of a job's stream."""
        with self._fleet_det_lock:
            if ts > self._fleet_progress.get(job_id, float("-inf")):
                self._fleet_progress[job_id] = float(ts)

    def fleet_progress(self, job_id: str) -> float:
        """The job's fleet-tier progress (cummax of closed-step ts)."""
        with self._fleet_det_lock:
            return self._fleet_progress.get(job_id, float("-inf"))

    def _frontier_locked(self) -> float:
        """Min progress over active (non-departed) jobs — the largest
        key the global sorted observation order is already complete up
        to.  Jobs that never closed a step pin it at -inf (their first
        observation could sort anywhere); departed jobs don't count."""
        lo = float("inf")
        with self._lock:
            jobs = list(self._jobs.values())
        for j in jobs:
            if j.departed:
                continue
            p = self._fleet_progress.get(j.job_id, float("-inf"))
            if p < lo:
                lo = p
        return lo

    def _resolve_locked(self, lo: float) -> None:
        """Feed every buffered observation with key strictly below
        ``lo`` to the fleet detectors, in ``(key, job_id, per-job
        order)`` order.  Ties at the frontier are held back until every
        active job's progress passes them (or the job departs), so
        successive calls emit prefixes of one global total order."""
        if not self.fleet_detectors:
            return
        batch: list = []
        done: list[str] = []
        for job_id, buf in self._fleet_buf.items():
            n = 0
            while n < len(buf) and buf[n][0] < lo:
                n += 1
            if n:
                batch.extend((key, job_id, step, anoms, ts)
                             for key, step, anoms, ts in buf[:n])
                del buf[:n]
            if not buf:
                done.append(job_id)
        for job_id in done:
            del self._fleet_buf[job_id]
        if not batch:
            return
        # stable sort: per-job buffers are already in order, so equal
        # (key, job_id) pairs keep their per-job sequence
        batch.sort(key=lambda r: (r[0], r[1]))
        for key, job_id, step, anoms, ts in batch:
            for fd in self.fleet_detectors:
                for jid, a in fd.observe_step(job_id, step, anoms, ts):
                    self.stream.push(jid, a, ts, origin="fleet")
                    with self._lock:
                        j = self._jobs.get(jid)
                    if j is not None:
                        j.count_anomaly()

    def resolve_fleet_ready(self) -> None:
        """Resolve every fleet observation the frontier has passed —
        this is what makes cross-job reclassification fire LIVE: call
        it after ingest progress (the mux does so itself on ingest /
        flush) or after buffering worker shipments."""
        # unlocked fast path: nothing buffered (or no detectors) is the
        # overwhelmingly common per-chunk case — a stale read just means
        # the next call resolves, so ingest never serializes here
        if not self.fleet_detectors or not self._fleet_buf:
            return
        with self._fleet_det_lock:
            self._resolve_locked(self._frontier_locked())

    def resolve_fleet_all(self) -> None:
        """End-of-stream resolution: resolve everything still buffered
        regardless of frontier.  ``replay_dir`` calls this when a
        directory drain completes; ``finalize()`` calls it before the
        detectors' own ``finalize()`` sweep."""
        with self._fleet_det_lock:
            self._resolve_locked(float("inf"))

    def _observe_fleet(self, job_id: str, step: int, anoms: list,
                       ts: float) -> None:
        """Buffer one closed step's anomalies for the fleet-scope tier
        (and advance the job's frontier progress).  Resolution happens
        separately — see :meth:`resolve_fleet_ready`."""
        if not (self.fleet_detectors or self._record_fleet):
            return
        with self._fleet_det_lock:
            prog = max(self._fleet_progress.get(job_id, float("-inf")),
                       float(ts))
            self._fleet_progress[job_id] = prog
            if anoms:
                self._fleet_buf.setdefault(job_id, []).append(
                    (prog, step, list(anoms), ts))

    def restore_job_state(self, job_id: str, state: dict) -> None:
        """Mirror a replay worker process's per-job end state onto this
        (parent) multiplexer: store summary facts, watermark position,
        hang flag, and the engine's evaluated-step record — so
        ``stats()``, a later ``flush()``, and late-row bookkeeping
        behave exactly as if the job had been replayed in-process.
        Anomaly counts are NOT restored; the parent counts them as it
        re-pushes the worker's shipped anomalies."""
        job = self.job(job_id)
        with job.lock:
            job.store.restore_summary(state["store"])
            job.last_closed = max(job.last_closed, int(state["last_closed"]))
            job.hang_reported = job.hang_reported or bool(
                state["hang_reported"])
            job.engine.adopt_evaluated(state["evaluated_steps"])
            job.watermark_lag.set(
                max(job.store.max_step_seen - job.last_closed, 0))
            job.pending_depth.set(len(job.store.pending_steps()))

    # ------------------------------------------------------------------ #
    # service checkpoints: full pipeline state transfer
    # ------------------------------------------------------------------ #
    def snapshot_job_state(self, job_id: str) -> dict:
        """Complete picklable state of ONE job's pipeline — store
        (pending slices included), engine (evaluated set, baseline,
        detector instances), watermark position, flags, counters, and
        the job's fleet-frontier progress.  Unlike the worker terminal
        ``summary()`` (lossy by design), a pipeline restored from this
        continues the stream byte-equivalently."""
        job = self.job(job_id)
        with job.lock:
            state = {
                "store": job.store.snapshot_state(),
                "engine": job.engine.snapshot_state(),
                "last_closed": job.last_closed,
                "hang_reported": job.hang_reported,
                "departed": job.departed,
                "anomaly_count": job.anomaly_count,
            }
        with self._fleet_det_lock:
            state["fleet_progress"] = self._fleet_progress.get(
                job_id, float("-inf"))
        return state

    def restore_job_pipeline(self, job_id: str, state: dict) -> None:
        """Inverse of :meth:`snapshot_job_state` onto an ``add_job``-ed
        job with the same engine config, on an interner that already
        adopted the checkpointed tables."""
        job = self.job(job_id)
        with job.lock:
            job.store.restore_state(state["store"])
            job.engine.restore_state(state["engine"])
            job.last_closed = int(state["last_closed"])
            job.hang_reported = bool(state["hang_reported"])
            job.departed = bool(state["departed"])
            with job.counter_lock:
                job.anomaly_count = int(state["anomaly_count"])
            job.watermark_lag.set(
                max(job.store.max_step_seen - job.last_closed, 0))
            job.pending_depth.set(len(job.store.pending_steps()))
        with self._fleet_det_lock:
            self._fleet_progress[job_id] = float(state["fleet_progress"])

    def snapshot_fleet_state(self) -> dict:
        """Fleet-tier (cross-job) picklable state: the shared intern
        tables (the live list objects — pickled in the same dump as the
        job states so slice identity survives), topology, the buffered
        observation sequences + frontier progress, every fleet
        detector's instance state, and the stream's sequence counter.
        Take it quiesced (no concurrent ingest) with the stream drained."""
        with self._fleet_det_lock:
            return {
                "names": self.interner.names,
                "groups": self.interner.groups,
                "topology": {k: dict(v) for k, v in self.topology.items()},
                "fleet_buf": {j: list(b)
                              for j, b in self._fleet_buf.items()},
                "fleet_progress": dict(self._fleet_progress),
                "fleet_detectors": [(type(fd).name, fd.state_dict())
                                    for fd in self.fleet_detectors],
                "stream_total": self.stream.total,
                "history_profiles": self.history.snapshot_profiles(),
            }

    def restore_fleet_state(self, state: dict) -> None:
        """Inverse of :meth:`snapshot_fleet_state` on a fresh
        multiplexer with the same fleet-detector config.  Call BEFORE
        restoring any job pipeline (they expect the adopted tables).
        Topology merges (``self.topology`` is the live object the bound
        ``FleetContext`` reads, so it mutates in place)."""
        have = [type(fd).name for fd in self.fleet_detectors]
        want = [nm for nm, _ in state["fleet_detectors"]]
        if have != want:
            raise ValueError(
                f"fleet-detector set mismatch restoring state: "
                f"checkpoint has {want}, multiplexer has {have}")
        self.interner.restore_tables(state["names"], state["groups"])
        with self._fleet_det_lock:
            for job_id, attrs in state["topology"].items():
                self.topology.setdefault(job_id, {}).update(attrs)
            self._fleet_buf = {j: list(b)
                               for j, b in state["fleet_buf"].items()}
            self._fleet_progress = dict(state["fleet_progress"])
            for fd, (_nm, fs) in zip(self.fleet_detectors,
                                     state["fleet_detectors"]):
                fd.load_state(fs)
        self.stream.restore_seq(state["stream_total"])
        self.history.restore_profiles(state["history_profiles"])

    def _maybe_hang(self, job: FleetJob) -> None:
        stacks = job.store.hang_stacks
        if job.hang_reported or not stacks:
            return
        if len(stacks) < max(self._job_ranks(job) // 2, 1):
            return
        # a hung job's stream stops: flush pending steps (matching the
        # terminal evaluate_all order), then diagnose from the stacks.
        self._advance(job, flush=True)
        anoms = job.engine.on_hang(dict(stacks), None)
        for a in anoms:
            self.stream.push(job.job_id, a, job.store.last_ts)
            job.count_anomaly()
        self._observe_fleet(job.job_id, -1, anoms, job.store.last_ts)
        job.hang_reported = True

    # ------------------------------------------------------------------ #
    # draining / shutdown
    # ------------------------------------------------------------------ #
    def poll(self) -> list[FleetAnomaly]:
        """New anomalies since the last poll, merged + ordered."""
        return self.stream.drain()

    def flush(self, job_id: Optional[str] = None) -> None:
        """Evaluate pending steps (ignoring watermarks) and run the hang
        check for one job or all jobs.  Anomalies stay in the stream for
        the next ``poll()`` — use ``finalize`` to flush AND drain."""
        targets = [self.job(job_id)] if job_id is not None else self.jobs
        for job in targets:
            with job.lock:
                self._advance(job, flush=True)
                self._maybe_hang(job)
        self.resolve_fleet_ready()

    def retire_job(self, job_id: str) -> None:
        """Graceful LEAVE of one job mid-run, without finalizing the
        fleet: flush its pending steps, run its hang check, run its
        engine's end-of-stream detector finalize, then mark it departed
        — its frontier contribution becomes +inf (so buffered cross-job
        observations from other jobs stop waiting on it) and any rows
        that straggle in afterwards are dropped and counted
        (``fleet.departed_rows{job=}``).  Deterministic: retiring a job
        at its end of stream and finalizing the fleet later yields the
        same merged output as one terminal ``finalize()`` (engine
        finalize is idempotent; the stream drain order is
        ``(ts, job_id, seq)``).  Anomalies stay queued for ``poll()``."""
        job = self.job(job_id)
        with job.lock:
            if job.departed:
                return
            self._advance(job, flush=True)
            self._maybe_hang(job)
            for a in job.engine.finalize_detectors():
                self.stream.push(job.job_id, a, job.store.last_ts)
                job.count_anomaly()
            job.departed = True
        with self._fleet_det_lock:
            self._fleet_progress[job_id] = float("inf")
        self.resolve_fleet_ready()

    def finalize(self, job_id: Optional[str] = None) -> list[FleetAnomaly]:
        """``flush`` + end-of-stream detector finalize + drain: returns
        the merged remaining stream."""
        self.flush(job_id)
        targets = [self.job(job_id)] if job_id is not None else self.jobs
        for job in targets:
            with job.lock:
                for a in job.engine.finalize_detectors():
                    self.stream.push(job.job_id, a, job.store.last_ts)
                    job.count_anomaly()
        if job_id is None:
            self.resolve_fleet_all()
            with self._fleet_det_lock:
                for fd in self.fleet_detectors:
                    for jid, a in fd.finalize():
                        self.stream.push(jid, a, self.stream_last_ts(jid),
                                         origin="fleet")
        else:
            self.resolve_fleet_ready()
        return self.stream.drain()

    def stream_last_ts(self, job_id: str) -> float:
        with self._lock:
            j = self._jobs.get(job_id)
        return j.store.last_ts if j is not None else 0.0

    def close(self) -> list[FleetAnomaly]:
        """Stop every job's attached daemon (idempotent ``stop()``), then
        finalize the whole fleet."""
        for job in self.jobs:
            if job.daemon is not None:
                job.daemon.stop()
        return self.finalize()

    def telemetry_snapshot(self) -> dict:
        """One JSON-ready snapshot of the whole pipeline's self-telemetry:
        this multiplexer's registry (per-job late rows, watermark lag,
        pending depth, plus whatever replay published) merged with every
        attached daemon's registry, the latter re-tagged ``job=<id>`` so
        per-daemon series stay distinguishable.  Daemons sharing the mux
        registry (``DaemonConfig(telemetry=mux.telemetry)``) are already
        in and are not double-counted."""
        snap = self.telemetry.snapshot()
        for job in self.jobs:
            reg = getattr(job.daemon, "telemetry", None)
            if reg is not None and reg is not self.telemetry:
                snap = self.telemetry.merge_snapshot(
                    reg.snapshot(), into=snap,
                    extra_tags={"job": job.job_id})
        return snap

    def stats(self) -> dict[str, dict]:
        out = {}
        for j in self.jobs:
            with j.lock:
                out[j.job_id] = {
                    "events": j.store.events_total,
                    "ranks": j.store.num_ranks,
                    "steps_evaluated": len(j.evaluated),
                    "max_step_seen": j.store.max_step_seen,
                    "late_events": j.late_events,
                    "anomalies": j.anomaly_count,
                    "hang_reported": j.hang_reported,
                }
        return out
