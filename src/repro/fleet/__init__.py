"""FLARE fleet subsystem: streaming multi-job multiplexing, incremental
per-step diagnosis, and mixed-format log replay (the paper's eight-month,
6,000-GPU continuous-operation layer).

Quickstart::

    from repro.fleet import FleetMultiplexer, FleetConfig
    mux = FleetMultiplexer(FleetConfig(watermark_delay=1), history=store)
    mux.add_job("job-a", EngineConfig(backend="dense-train", num_ranks=256))
    mux.ingest("job-a", batch_or_events)      # per chunk, any producer
    for fa in mux.poll():                     # merged, ts-ordered, routed
        print(fa)
    mux.finalize()                            # flush watermarks + hangs

Live daemons plug in via ``daemon.attach_fleet(mux, "job-a")``; recorded
logs via ``FleetReplayer(mux).replay_dir("logs/")`` — add
``worker_kind="process"`` to shard per-job pipelines across worker
processes (``repro.fleet.ipc``), byte-equivalent to serial and free of
the GIL.

Cross-job diagnosis plugs in through the fleet-scope detector tier::

    mux = FleetMultiplexer(FleetConfig(
        fleet_detectors=["cross_job_failslow"]), history=store)
    mux.set_topology("job-a", rack="r12", switch="sw3")

(see ``repro.core.detectors`` — co-occurring fail-slows on a shared
rack/switch are reclassified as INFRASTRUCTURE, ``origin="fleet"``).
"""
from repro.core.detectors.fleet import (CrossJobFailSlowCorrelator,  # noqa: F401
                                        FleetContext, FleetDetector)
from repro.fleet.ipc import ProcessWorkerPool  # noqa: F401
from repro.fleet.multiplexer import (FleetConfig, FleetJob,  # noqa: F401
                                     FleetMultiplexer)
from repro.fleet.replay import FleetReplayer, ReplayStats  # noqa: F401
from repro.fleet.store import (SharedInterner,  # noqa: F401
                               StepPartitionedStore)
from repro.fleet.stream import (DEFAULT_ROUTES, AnomalyStream,  # noqa: F401
                                FleetAnomaly)

__all__ = [
    "FleetConfig", "FleetJob", "FleetMultiplexer",
    "FleetReplayer", "ReplayStats", "ProcessWorkerPool",
    "SharedInterner", "StepPartitionedStore",
    "AnomalyStream", "FleetAnomaly", "DEFAULT_ROUTES",
    "FleetDetector", "FleetContext", "CrossJobFailSlowCorrelator",
]
