"""TraceArchive — the query surface over a directory of rotated segments.

A months-long deployment leaves behind a directory of rotated trace
files per job (``job-a.fcs3``, ``job-a.seg001.fcs3``, …, possibly mixed
with older v1/v2/JSONL pieces).  On-call questions against that archive
are not "replay everything" questions — they are *predicates*
("job B, steps 4000–5000", "any critical event on rack r12 last hour")
and *dashboards* (per-step throughput, anomaly counts per team), asked
repeatedly.  ``TraceArchive`` answers both at interactive latency:

  * **query_events** pushes the predicate into the FCS v3 stats
    directory (``repro.store.stats``): segments that provably contain no
    matching row are hopped over without inflating a slab, then the
    exact row filter runs on what remains — byte-identical results to a
    full decode, a fraction of the bytes (see ``benchmarks/archive.py``).
  * **query_metrics** serves per-job, per-step rollup records
    (throughput, t_step, issue p99, per-rank FLOPS, void fractions)
    from a cache built once per FILE via ``aggregate_slice`` and
    invalidated by (size, mtime) fingerprint — a segment append or
    rotation re-rolls only the file it touched, and warm queries never
    touch the trace bytes at all.  Rollups also PERSIST as
    ``<trace>.rollup.json`` sidecars keyed by the same fingerprint, so
    a cold archive process (tomorrow's dashboard restart) answers
    ``query_metrics`` warm without re-decoding a single segment.
  * **query_anomalies** replays the directory once through a private
    :class:`~repro.fleet.FleetMultiplexer` (same engines, detectors and
    watermark semantics as the live pipeline), caches the merged
    anomaly stream against the directory fingerprint, and filters by
    job / time-range / team.
  * **fleet_weather** condenses all of the above into the per-job
    throughput-trend + anomaly-count report an on-call bot would post.
  * **export_telemetry / telemetry_snapshots** persist pipeline
    self-telemetry (``repro.core.telemetry``) as ``telemetry-NNN.json``
    next to the segments, so "how the pipeline felt" rides along with
    the data it produced.

Every query transparently refreshes against the directory first, so an
archive object can sit behind a dashboard while daemons keep appending.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
import re
from typing import Optional

import numpy as np

from repro.core.anomaly import Team
from repro.core.columnar import EventBatch
from repro.core.engine import EngineConfig
from repro.core.history import HistoryStore
from repro.core.metrics import aggregate_slice
from repro.core.telemetry import TelemetryRegistry
from repro.fleet.multiplexer import FleetConfig, FleetMultiplexer
from repro.fleet.replay import FleetReplayer
from repro.store import (ROLLUP_SUFFIX, Predicate, ScanStats,
                         codec_for_path, codecs, is_sidecar_path,
                         job_id_for_path, seg_index)
from repro.store.fcs import iter_segments

# scalar rollup fields (events-weighted on merge/bucket); "rank_flops"
# is the one dict-valued metric and merges rank-wise
SCALAR_METRICS = ("throughput", "t_step", "v_inter", "v_minority",
                  "issue_p99", "bandwidth", "events")
_TELEMETRY_RE = re.compile(r"^telemetry-(\d+)\.json$")


def _file_patterns() -> tuple[str, ...]:
    return tuple(f"*{ext}" for c in codecs().values()
                 for ext in c.extensions)


def _fingerprint(path: str) -> tuple:
    st = os.stat(path)
    return (st.st_size, st.st_mtime_ns)


def _rollup_record(m, events: int) -> dict:
    """One step's cached rollup: plain floats/dicts, no numpy arrays, so
    records are JSON-able and cheap to keep for months of steps."""
    lat = m.issue_latencies
    rank_flops: dict[int, float] = {}
    counts: dict[int, int] = {}
    for per_rank in m.flops.values():
        for r, f in per_rank.items():
            r = int(r)
            rank_flops[r] = rank_flops.get(r, 0.0) + float(f)
            counts[r] = counts.get(r, 0) + 1
    rank_flops = {r: v / counts[r] for r, v in rank_flops.items()}
    bw = float(np.mean(list(m.bandwidth.values()))) if m.bandwidth else 0.0
    return {
        "throughput": float(m.throughput),
        "t_step": float(m.t_step),
        "v_inter": float(m.v_inter),
        "v_minority": float(m.v_minority),
        "issue_p99": float(np.percentile(lat, 99)) if lat.size else 0.0,
        "bandwidth": bw,
        "events": float(events),
        "rank_flops": rank_flops,
    }


def _merge_records(a: dict, b: dict) -> dict:
    """Events-weighted merge of two records for the SAME step (a step
    split across rotated files — each side saw only its rows, so the
    merged numbers are an approximation, weighted by how many rows each
    side aggregated)."""
    wa, wb = a["events"], b["events"]
    tot = wa + wb
    if tot <= 0:
        return dict(a)
    out = {}
    for k in SCALAR_METRICS:
        if k == "events":
            out[k] = tot
        else:
            out[k] = (a[k] * wa + b[k] * wb) / tot
    rf: dict[int, float] = {}
    for r in set(a["rank_flops"]) | set(b["rank_flops"]):
        fa, fb = a["rank_flops"].get(r), b["rank_flops"].get(r)
        if fa is None:
            rf[r] = fb
        elif fb is None:
            rf[r] = fa
        else:
            rf[r] = (fa * wa + fb * wb) / tot
    out["rank_flops"] = rf
    return out


class TraceArchive:
    """Queryable archive over ``directory``'s rotated trace files.

    ``history``/``engine_config``/``fleet_config`` configure the private
    replay pipeline behind :meth:`query_anomalies` (a learned
    :class:`HistoryStore` enables the profile-relative detectors, an
    :class:`EngineConfig` pins detector set and rank count per job).
    ``telemetry`` shares a registry with the rest of the pipeline —
    archive cache behavior lands there too (``archive.rollup_builds``
    vs ``archive.rollup_hits`` vs ``archive.rollup_disk_hits``,
    ``archive.queries{kind=...}``).  ``persist_rollups=False`` disables
    the on-disk sidecar cache (e.g. for read-only media; a failed
    sidecar write is silently skipped anyway)."""

    def __init__(self, directory: str, *,
                 history: Optional[HistoryStore] = None,
                 engine_config: Optional[EngineConfig] = None,
                 fleet_config: Optional[FleetConfig] = None,
                 telemetry: Optional[TelemetryRegistry] = None,
                 pattern: Optional[str] = None,
                 persist_rollups: bool = True):
        self.directory = directory
        self.history = history
        self.engine_config = engine_config
        self.fleet_config = fleet_config
        self.telemetry = telemetry or TelemetryRegistry()
        self.pattern = pattern
        self.persist_rollups = persist_rollups
        # job_id -> [paths] in rotation order, refreshed per query
        self._files: dict[str, list[str]] = {}
        # path -> (fingerprint, {step: record})
        self._rollups: dict[str, tuple[tuple, dict[int, dict]]] = {}
        # anomaly cache: (dir fingerprint, [FleetAnomaly]), plus the
        # mux that produced it (kept for telemetry_snapshot merging)
        self._anomaly_fp: Optional[tuple] = None
        self._anomalies: list = []
        self._mux: Optional[FleetMultiplexer] = None
        self._c_builds = self.telemetry.counter("archive.rollup_builds")
        self._c_hits = self.telemetry.counter("archive.rollup_hits")
        self._c_disk_hits = self.telemetry.counter(
            "archive.rollup_disk_hits")

    # ------------------------------------------------------------------ #
    # discovery
    # ------------------------------------------------------------------ #
    def refresh(self) -> dict[str, list[str]]:
        """Re-scan the directory; returns job_id -> ordered path list."""
        patterns = (self.pattern,) if self.pattern else _file_patterns()
        paths = sorted({p for pat in patterns
                        for p in glob.glob(
                            os.path.join(self.directory, pat))
                        if not is_sidecar_path(p)},
                       key=lambda p: (job_id_for_path(p), seg_index(p), p))
        files: dict[str, list[str]] = {}
        for p in paths:
            files.setdefault(job_id_for_path(p), []).append(p)
        self._files = files
        return files

    @property
    def jobs(self) -> list[str]:
        self.refresh()
        return sorted(self._files)

    def _job_paths(self, job: str) -> list[str]:
        self.refresh()
        if job not in self._files:
            raise KeyError(f"no trace files for job {job!r} under "
                           f"{self.directory} (known: {sorted(self._files)})")
        return self._files[job]

    def segment_stats(self, job: str):
        """Per-segment :class:`~repro.store.SegmentStats` for every FCS
        file of ``job``, in rotation order — the raw pruning directory,
        without decoding a slab."""
        from repro.store.fcs import segment_stats as _seg_stats
        for path in self._job_paths(job):
            if codec_for_path(path).name.startswith("fcs"):
                yield from _seg_stats(path)

    # ------------------------------------------------------------------ #
    # events: predicate-pushdown reads
    # ------------------------------------------------------------------ #
    def query_events(self, job: str,
                     predicate: Optional[Predicate] = None, *,
                     step_range: Optional[tuple] = None,
                     time_range: Optional[tuple] = None,
                     ranks=None, kinds=None, severity: Optional[str] = None,
                     columns: Optional[dict] = None,
                     max_bytes: Optional[int] = None,
                     pushdown: bool = True, with_scan: bool = False):
        """Exact matching rows for ``job`` as one :class:`EventBatch`.

        Build the predicate inline (``step_range=...``/``severity=...``/
        ``columns={"flops": (lo, hi)}`` — per-column value bounds pruned
        against the v3 per-column min/max) or pass one.
        ``pushdown=False`` decodes every segment (the equivalence oracle
        — same row filter, same concat order, so results are
        byte-identical; benchmarks assert it).  With ``with_scan=True``
        returns ``(batch, ScanStats)`` so callers see how many bytes the
        stats directory saved.

        ``max_bytes`` is a per-query DECODE budget: the scan stops at
        the first segment boundary past it (stats-pruned bytes are
        free — only inflated bytes spend budget) and flags
        ``ScanStats.truncated`` — the result is the archive-order prefix
        the budget affords, deterministic for a given archive.  A
        dashboard query against a months-long job can therefore never
        decode the world; it says "truncated" instead."""
        self.telemetry.counter("archive.queries", kind="events").inc()
        if predicate is None:
            predicate = Predicate(step_range=step_range,
                                  time_range=time_range, ranks=ranks,
                                  kinds=kinds, severity=severity,
                                  columns=columns)
        scan = ScanStats()
        parts: list[EventBatch] = []
        for path in self._job_paths(job):
            if scan.truncated:
                break
            codec = codec_for_path(path)
            if codec.name.startswith("fcs"):
                it = iter_segments(path,
                                   predicate=predicate if pushdown else None,
                                   scan=scan)
                for seg in it:
                    parts.append(predicate.filter(seg))
                    if max_bytes is not None \
                            and scan.bytes_decoded >= max_bytes:
                        scan.truncated = True
                        break
            else:
                # non-segmented formats decode whole-file; budget them
                # by on-disk size so mixed archives still terminate
                for batch, _sk in codec.iter_chunks(path):
                    scan.segments += 1
                    scan.rows += len(batch)
                    parts.append(predicate.filter(batch))
                try:
                    scan.bytes_decoded += os.path.getsize(path)
                except OSError:
                    pass
                if max_bytes is not None \
                        and scan.bytes_decoded >= max_bytes:
                    scan.truncated = True
        if scan.truncated:
            self.telemetry.counter("archive.truncated_queries",
                                   kind="events").inc()
        out = EventBatch.concat(parts) if parts else EventBatch.empty()
        return (out, scan) if with_scan else out

    # ------------------------------------------------------------------ #
    # metrics: cached per-file rollups
    # ------------------------------------------------------------------ #
    def _rollup_sidecar(self, path: str) -> str:
        return path + ROLLUP_SUFFIX

    def _load_disk_rollup(self, path: str, fp: tuple
                          ) -> Optional[dict[int, dict]]:
        """Sidecar rollup for ``path`` if present AND fingerprint-fresh;
        any unreadable/stale/mismatched sidecar means rebuild."""
        try:
            with open(self._rollup_sidecar(path)) as f:
                data = json.load(f)
        except (OSError, ValueError):
            return None
        if tuple(data.get("fingerprint", ())) != fp:
            return None
        rollup: dict[int, dict] = {}
        try:
            for s, rec in data["rollup"].items():
                rec = dict(rec)
                rec["rank_flops"] = {int(r): v for r, v
                                     in rec["rank_flops"].items()}
                rollup[int(s)] = rec
        except (KeyError, TypeError, ValueError):
            return None                    # malformed sidecar: rebuild
        return rollup

    def _store_disk_rollup(self, path: str, fp: tuple,
                           rollup: dict[int, dict]) -> None:
        """Best-effort atomic sidecar write (tmp + fsync + rename); a
        read-only archive directory just stays cold."""
        sidecar = self._rollup_sidecar(path)
        tmp = sidecar + ".tmp"
        try:
            with open(tmp, "w") as f:
                json.dump({"fingerprint": list(fp), "rollup": rollup}, f,
                          separators=(",", ":"))
                # fsync BEFORE the rename: otherwise a crash can leave
                # the sidecar name pointing at not-yet-flushed bytes —
                # a torn rollup that parses as garbage on the next boot
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, sidecar)
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass

    def _file_rollup(self, path: str) -> dict[int, dict]:
        """step -> record for one file, (size, mtime)-cached in memory
        AND on disk (``<trace>.rollup.json``): an append or rotation
        invalidates exactly the file it touched; a fresh process warms
        from the sidecars without decoding anything."""
        fp = _fingerprint(path)
        cached = self._rollups.get(path)
        if cached is not None and cached[0] == fp:
            self._c_hits.inc()
            return cached[1]
        if self.persist_rollups:
            rollup = self._load_disk_rollup(path, fp)
            if rollup is not None:
                self._c_disk_hits.inc()
                self._rollups[path] = (fp, rollup)
                return rollup
        self._c_builds.inc()
        batch = codec_for_path(path).read(path)
        rollup: dict[int, dict] = {}
        if len(batch):
            order, uniq, bounds = batch.step_index()
            num_ranks = batch.num_distinct_ranks()
            sorted_ = batch.is_step_sorted()
            for j in range(uniq.size):
                s = int(uniq[j])
                if s < 0:
                    continue            # unattributed rows roll up nowhere
                sb = batch.slice_rows(int(bounds[j]), int(bounds[j + 1])) \
                    if sorted_ else batch.take(order[bounds[j]:bounds[j + 1]])
                m = aggregate_slice(sb, s, num_ranks=num_ranks)
                if m is not None:
                    rollup[s] = _rollup_record(m, len(sb))
        self._rollups[path] = (fp, rollup)
        if self.persist_rollups:
            self._store_disk_rollup(path, fp, rollup)
        return rollup

    def rollups(self, job: str, *, max_bytes: Optional[int] = None,
                with_truncation: bool = False):
        """Merged step -> record across the job's rotated files.

        ``max_bytes`` budgets the files folded in by their ON-DISK size
        — rotation-order prefix, so the answer is deterministic for a
        given archive regardless of which rollups happened to be cached
        (a warm cache makes the same truncated query faster, never
        different).  ``with_truncation=True`` returns
        ``(rollup, truncated)``."""
        out: dict[int, dict] = {}
        used = 0
        truncated = False
        for path in self._job_paths(job):
            if max_bytes is not None and used >= max_bytes:
                truncated = True
                break
            try:
                used += os.path.getsize(path)
            except OSError:
                pass
            for s, rec in self._file_rollup(path).items():
                out[s] = _merge_records(out[s], rec) if s in out else rec
        return (out, truncated) if with_truncation else out

    def query_metrics(self, job: str,
                      step_range: Optional[tuple] = None,
                      metric: str = "throughput", *,
                      bucket: int = 1,
                      max_bytes: Optional[int] = None,
                      with_truncation: bool = False):
        """``[(step, value), ...]`` for one rollup metric, step-sorted.

        ``metric`` is one of ``throughput | t_step | v_inter |
        v_minority | issue_p99 | bandwidth | events | rank_flops``
        (the last returns a per-rank dict per step).  ``bucket > 1``
        groups steps into ``bucket``-wide buckets keyed by their first
        step, events-weighted.  ``max_bytes`` budgets the rollup as in
        :meth:`rollups`; ``with_truncation=True`` returns
        ``(series, truncated)``."""
        if metric != "rank_flops" and metric not in SCALAR_METRICS:
            raise ValueError(f"unknown metric {metric!r}; known: "
                             f"{SCALAR_METRICS + ('rank_flops',)}")
        self.telemetry.counter("archive.queries", kind="metrics").inc()
        recs, truncated = self.rollups(job, max_bytes=max_bytes,
                                       with_truncation=True)
        if truncated:
            self.telemetry.counter("archive.truncated_queries",
                                   kind="metrics").inc()
        if step_range is not None:
            lo, hi = step_range
            recs = {s: r for s, r in recs.items() if lo <= s <= hi}
        if bucket > 1:
            grouped: dict[int, dict] = {}
            for s in sorted(recs):
                b = (s // bucket) * bucket
                grouped[b] = _merge_records(grouped[b], recs[s]) \
                    if b in grouped else dict(recs[s])
            recs = grouped
        series = [(s, recs[s][metric]) for s in sorted(recs)]
        return (series, truncated) if with_truncation else series

    # ------------------------------------------------------------------ #
    # anomalies: cached full-archive replay
    # ------------------------------------------------------------------ #
    def _dir_fingerprint(self) -> tuple:
        self.refresh()
        return tuple((p, _fingerprint(p))
                     for paths in self._files.values() for p in paths)

    def _replay_all(self) -> list:
        fp = self._dir_fingerprint()
        if self._anomaly_fp == fp:
            self.telemetry.counter("archive.replay_cache_hits").inc()
            return self._anomalies
        cfg = self.fleet_config or FleetConfig()
        if cfg.telemetry is None:
            cfg = dataclasses.replace(cfg, telemetry=self.telemetry)
        mux = FleetMultiplexer(cfg, self.history)
        if self.engine_config is not None:
            for job_id in self._files:
                mux.add_job(job_id, self.engine_config)
        replayer = FleetReplayer(mux)
        replayer.replay_dir(self.directory, pattern=self.pattern,
                            flush=False)
        anomalies = mux.finalize()
        self._anomaly_fp = fp
        self._anomalies = anomalies
        self._mux = mux
        return anomalies

    def query_anomalies(self, job: Optional[str] = None,
                        time_range: Optional[tuple] = None,
                        team=None) -> list:
        """Diagnosed :class:`~repro.fleet.stream.FleetAnomaly` list for
        the whole archive (cached until any file changes), filtered by
        job, event-time range, and owning team (a
        :class:`~repro.core.anomaly.Team` or its string value)."""
        self.telemetry.counter("archive.queries", kind="anomalies").inc()
        out = self._replay_all()
        if job is not None:
            out = [a for a in out if a.job_id == job]
        if time_range is not None:
            t0, t1 = time_range
            out = [a for a in out if t0 <= a.ts <= t1]
        if team is not None:
            want = team if isinstance(team, Team) else Team(team)
            out = [a for a in out if a.team is want]
        return list(out)

    # ------------------------------------------------------------------ #
    # fleet weather
    # ------------------------------------------------------------------ #
    def fleet_weather(self) -> dict:
        """Per-job health summary + fleet totals: steps/events covered,
        mean throughput, the throughput TREND (% change, second half of
        the step range vs the first), and anomaly counts by team."""
        anomalies = self._replay_all()
        report: dict = {"jobs": {}, "fleet": {}}
        tot_events = tot_steps = tot_anoms = 0
        for job in sorted(self._files):
            recs = self.rollups(job)
            steps = sorted(recs)
            thr = [recs[s]["throughput"] for s in steps]
            trend = 0.0
            if len(thr) >= 4:
                half = len(thr) // 2
                a, b = float(np.mean(thr[:half])), float(np.mean(thr[half:]))
                if a > 0:
                    trend = (b - a) / a * 100.0
            by_team: dict[str, int] = {}
            ja = [a for a in anomalies if a.job_id == job]
            for a in ja:
                by_team[a.team.value] = by_team.get(a.team.value, 0) + 1
            events = int(sum(recs[s]["events"] for s in steps))
            report["jobs"][job] = {
                "steps": len(steps),
                "events": events,
                "throughput_mean": float(np.mean(thr)) if thr else 0.0,
                "throughput_trend_pct": trend,
                "anomalies": len(ja),
                "anomalies_by_team": dict(sorted(by_team.items())),
            }
            tot_events += events
            tot_steps += len(steps)
            tot_anoms += len(ja)
        report["fleet"] = {"jobs": len(report["jobs"]),
                           "steps": tot_steps, "events": tot_events,
                           "anomalies": tot_anoms}
        return report

    # ------------------------------------------------------------------ #
    # telemetry export
    # ------------------------------------------------------------------ #
    def telemetry_snapshot(self) -> dict:
        """This archive's own registry, merged with the replay
        pipeline's (mux + replay counters) when a cached replay exists.
        When both share one registry the merge is the identity."""
        mux = self._mux
        if mux is not None and mux.telemetry is not self.telemetry:
            return self.telemetry.merge_snapshot(mux.telemetry_snapshot())
        return self.telemetry.snapshot()

    def export_telemetry(self, snapshot: Optional[dict] = None) -> str:
        """Write a telemetry snapshot (default: :meth:`telemetry_snapshot`)
        as ``telemetry-NNN.json`` next to the segments; returns the path.
        Successive exports number upward, so the directory accumulates a
        coarse time series of pipeline health alongside the traces."""
        snap = snapshot if snapshot is not None else self.telemetry_snapshot()
        existing = [int(m.group(1)) for f in os.listdir(self.directory)
                    if (m := _TELEMETRY_RE.match(f))]
        nxt = max(existing, default=-1) + 1
        path = os.path.join(self.directory, f"telemetry-{nxt:03d}.json")
        with open(path, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
        return path

    def telemetry_snapshots(self) -> list[dict]:
        """Every exported snapshot in export order."""
        found = sorted((int(m.group(1)), f)
                       for f in os.listdir(self.directory)
                       if (m := _TELEMETRY_RE.match(f)))
        out = []
        for _, f in found:
            with open(os.path.join(self.directory, f)) as fh:
                out.append(json.load(fh))
        return out


def format_fleet_weather(report: dict) -> str:
    """Render :meth:`TraceArchive.fleet_weather` as the fixed-width
    table an on-call channel would receive."""
    lines = [f"{'job':<12} {'steps':>6} {'events':>9} {'tok/s':>12} "
             f"{'trend':>8}  anomalies"]
    for job, j in report["jobs"].items():
        teams = ", ".join(f"{t}:{n}" for t, n in
                          j["anomalies_by_team"].items()) or "-"
        lines.append(f"{job:<12} {j['steps']:>6} {j['events']:>9} "
                     f"{j['throughput_mean']:>12.1f} "
                     f"{j['throughput_trend_pct']:>+7.1f}%  {teams}")
    f = report["fleet"]
    lines.append(f"fleet: {f['jobs']} jobs, {f['steps']} steps, "
                 f"{f['events']} events, {f['anomalies']} anomalies")
    return "\n".join(lines)
