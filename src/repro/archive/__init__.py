"""Queryable trace archive: the serving surface over recorded fleets.

::

    from repro.archive import TraceArchive

    ar = TraceArchive("logs/", history=history)
    batch = ar.query_events("job-b", step_range=(40, 60))     # pushdown
    curve = ar.query_metrics("job-b", metric="throughput")    # cached
    crit  = ar.query_anomalies(team="infrastructure")
    print(format_fleet_weather(ar.fleet_weather()))

See ``src/repro/archive/README.md`` for the full API reference.
"""
from repro.archive.archive import (SCALAR_METRICS, TraceArchive,
                                   format_fleet_weather)

__all__ = ["TraceArchive", "format_fleet_weather", "SCALAR_METRICS"]
