"""Sharding rules: logical activation axes + path-matched parameter specs.

Models are mesh-agnostic: they annotate activations with *logical* axis names
via a ``constrain`` callback and parameters are matched by (parent, leaf)
path.  ``MeshRules`` binds logical names to mesh axes.

Default mapping (Megatron-style TP on ``model``, DP over ``pod``+``data``):
    batch   -> (pod, data)        heads/kv_heads/ff/experts/vocab -> model
    seq     -> None  (or model when sequence parallelism is on)
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _dp_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


@dataclass
class MeshRules:
    """Binds logical activation axes to mesh axes; used as model `constrain`."""

    mesh: Mesh
    sequence_parallel: bool = False
    rules: dict = field(default_factory=dict)

    def __post_init__(self):
        dp = _dp_axes(self.mesh)
        defaults = {
            "batch": dp,
            "seq": "model" if self.sequence_parallel else None,
            "embed": None,
            "heads": "model",
            "kv_heads": "model",
            "ff": "model",
            "vocab": "model",
            "experts": "model",
        }
        defaults.update(self.rules)
        self.rules = defaults

    def spec(self, axes: tuple) -> P:
        return P(*(self.rules.get(a) if a is not None else None for a in axes))

    def __call__(self, x: jax.Array, axes: tuple) -> jax.Array:
        if x.ndim != len(axes):
            # models sometimes constrain flattened/extra-dim tensors; skip
            return x
        spec = self.spec(axes)
        # Never shard a dim that isn't divisible AND smaller than the axis
        # (GSPMD pads otherwise, which is fine, but a dim of size 1 over a
        # 16-way axis is pure waste — drop the constraint there).
        cleaned = []
        for dim, entry in zip(x.shape, spec):
            if entry is None:
                cleaned.append(None)
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            n = math.prod(self.mesh.shape[a] for a in names)
            cleaned.append(entry if (dim >= n and dim % n == 0) else None)
        # a mesh axis may appear only once: with sequence parallelism both
        # "seq" and "heads"/"ff" map to model — the LATER (more specific)
        # dim wins, the earlier one is replicated
        seen: set = set()
        for i in range(len(cleaned) - 1, -1, -1):
            e = cleaned[i]
            if e is None:
                continue
            names = set(e if isinstance(e, tuple) else (e,))
            if names & seen:
                cleaned[i] = None
            else:
                seen |= names
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*cleaned)))


# --------------------------------------------------------------------------- #
# Parameter specs by (parent, leaf) path matching
# --------------------------------------------------------------------------- #
# trailing-dim specs; leading stacked scan dims are padded with None
_PARAM_RULES: dict = {
    ("embed", "embedding"): ("model", None),
    ("head", "w"): (None, "model"),
    ("attn", "wq"): (None, "model", None),
    ("attn", "wk"): (None, "model", None),
    ("attn", "wv"): (None, "model", None),
    ("attn", "wo"): ("model", None, None),
    ("attn", "bq"): ("model", None),
    ("attn", "bk"): ("model", None),
    ("attn", "bv"): ("model", None),
    ("attn", "gate"): (),
    ("mlp", "wi_gate"): (None, "model"),
    ("mlp", "wi_up"): (None, "model"),
    ("mlp", "wo"): ("model", None),
    ("moe", "router"): (None, None),
    ("moe", "wi_gate"): ("model", None, None),
    ("moe", "wi_up"): ("model", None, None),
    ("moe", "wo"): ("model", None, None),
    ("mamba", "in_z"): (None, "model"),
    ("mamba", "in_x"): (None, "model"),
    ("mamba", "in_B"): (None, None),
    ("mamba", "in_C"): (None, None),
    ("mamba", "in_dt"): (None, "model"),
    ("mamba", "conv_w"): (None, None),
    ("mamba", "conv_b"): (None,),
    ("mamba", "dt_bias"): ("model",),
    ("mamba", "A_log"): ("model",),
    ("mamba", "D"): ("model",),
    ("mamba", "out"): ("model", None),
    ("cross", "kv_proj"): (None, None),
    (None, "gate_mlp"): (),
    (None, "scale"): (None,),  # all norm scales, incl. mamba gated norm
}


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return out


def _match(path_names: list[str], leaf_ndim: int):
    leaf = path_names[-1]
    parent = path_names[-2] if len(path_names) > 1 else None
    for key in ((parent, leaf), (None, leaf)):
        if key in _PARAM_RULES:
            trailing = _PARAM_RULES[key]
            pad = leaf_ndim - len(trailing)
            if pad < 0:
                continue
            return (None,) * pad + tuple(trailing)
    # mamba norm scale lives at ('mamba','norm','scale'): parent='norm'
    if leaf == "scale":
        return (None,) * (leaf_ndim - 1) + (None,)
    return (None,) * leaf_ndim


def param_specs(params) -> Any:
    """PartitionSpec pytree matching `params` (shapes or arrays)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        names = _path_names(path)
        ndim = len(getattr(leaf, "shape", ()))
        specs.append(P(*_match(names, ndim)))
    return jax.tree_util.tree_unflatten(treedef, specs)


def sanitize_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Make a spec legal for `shape` on `mesh`: any sharded dim must divide.

    If the preferred dim doesn't divide (e.g. kv_heads=2 on a 16-way model
    axis), relocate the axis to the LAST other dim that divides (head_dim,
    then d_model) — the Megatron GQA-replication fallback — else replicate.
    """
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, (e, d) in enumerate(zip(entries, shape)):
        if e is None:
            continue
        names = e if isinstance(e, tuple) else (e,)
        n = math.prod(mesh.shape[a] for a in names if a in mesh.axis_names)
        if n <= 1:
            continue
        if d % n == 0 and d >= n:
            continue
        entries[i] = None
        for j in range(len(shape) - 1, 0, -1):  # never the leading scan dim
            if j == i or entries[j] is not None:
                continue
            if shape[j] % n == 0 and shape[j] >= n:
                entries[j] = e
                break
    return P(*entries)


def sanitize_specs(spec_tree, shape_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s, sh: sanitize_spec(s, sh.shape, mesh),
        spec_tree, shape_tree, is_leaf=lambda x: isinstance(x, P))


def zero_spec(spec: P, shape: tuple, mesh: Mesh,
              axes: tuple = ("data",)) -> P:
    """ZeRO: additionally shard an (optimizer-state) tensor over data axes.

    Picks the first dimension that is currently unsharded and divisible by
    the data-axis extent; falls back to the original spec.
    """
    usable = tuple(a for a in axes if a in mesh.axis_names)
    if not usable:
        return spec
    n = math.prod(mesh.shape[a] for a in usable)
    entries = list(spec) + [None] * (len(shape) - len(spec))
    # already ZeRO/FSDP-sharded over (any of) these axes -> no-op
    used = set()
    for e in entries:
        if e is not None:
            used |= set(e if isinstance(e, tuple) else (e,))
    if used & set(usable):
        return P(*entries)
    for i, (dim, entry) in enumerate(zip(shape, entries)):
        if entry is None and dim % n == 0 and dim >= n:
            entries[i] = usable if len(usable) > 1 else usable[0]
            return P(*entries)
    return spec


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def shaped_with_sharding(shape_tree, spec_tree, mesh: Mesh, dtype_tree=None):
    """ShapeDtypeStructs carrying shardings (dry-run inputs)."""
    def mk(leaf, spec):
        return jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype, sharding=NamedSharding(mesh, spec))
    return jax.tree.map(mk, shape_tree, spec_tree,
                        is_leaf=lambda x: isinstance(x, P) or hasattr(x, "shape"))
