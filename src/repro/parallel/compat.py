"""JAX version compatibility shims.

``shard_map`` moved from ``jax.experimental.shard_map`` (<= 0.4.x, with
``check_rep``) to ``jax.shard_map`` (with ``check_vma``).  Call sites use
this wrapper with the modern keyword; we translate for old installs.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)
