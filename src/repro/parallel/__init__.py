from repro.parallel.sharding import MeshRules, param_specs, zero_spec  # noqa: F401
