"""Progress-instrumented ring collectives.

FLARE's intra-kernel inspecting (paper §5.1, Fig 6) reads per-ring-step
progress counters out of a hung collective to localize the faulty machine in
O(1).  On GPU the paper attaches CUDA-GDB to NCCL kernels; XLA collectives
are compiler-generated, so we instead make progress export a *first-class
output of the collective itself*: our ring reduce-scatter / all-gather
return a per-rank vector of completed ring steps alongside the result.  On a
real TPU fleet those counters would be streamed to host-visible memory per
step; under a hang the frozen counters are exactly the state the inspector
needs (see repro.core.inspecting).

These collectives run inside ``shard_map`` over one mesh axis and use
``lax.ppermute`` rings — the same schedule NCCL uses, expressed jax-natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.compat import shard_map


def _ring_perm(n: int, reverse: bool = False):
    if reverse:
        return [(i, (i - 1) % n) for i in range(n)]
    return [(i, (i + 1) % n) for i in range(n)]


def ring_reduce_scatter_local(x, axis_name: str, axis_size: int,
                              with_progress: bool = True):
    """Per-shard body: x [N*chunk, ...] -> (owned chunk [chunk, ...], progress).

    Classic ring reduce-scatter: N-1 steps; at step s each rank sends the
    chunk it just accumulated to its right neighbour.  progress[s] = 1 once
    step s completed on this rank.
    """
    n = axis_size
    rank = jax.lax.axis_index(axis_name)
    chunks = x.reshape((n,) + (x.shape[0] // n,) + x.shape[1:])
    perm = _ring_perm(n)

    def body(s, carry):
        acc, progress = carry
        # chunk index this rank SENDS at step s: (rank - s) mod n
        send_idx = (rank - s) % n
        recv_idx = (rank - s - 1) % n
        sent = jax.lax.ppermute(acc[send_idx], axis_name, perm)
        acc = acc.at[recv_idx].add(sent)
        progress = progress.at[s].set(1) if with_progress else progress
        return acc, progress

    progress0 = jnp.zeros((max(n - 1, 1),), jnp.int32)
    acc, progress = jax.lax.fori_loop(0, n - 1, body, (chunks, progress0))
    owned = acc[(rank + 1) % n]
    return owned, progress


def ring_all_gather_local(x, axis_name: str, axis_size: int,
                          with_progress: bool = True, slot_offset: int = 0):
    """Per-shard body: x [chunk, ...] -> (gathered [N*chunk, ...], progress).

    `slot_offset`: rank r's local chunk is global chunk (r + slot_offset)
    mod N — reduce-scatter hands rank r chunk (r+1), so the composed
    all-reduce passes slot_offset=1.
    """
    n = axis_size
    rank = jax.lax.axis_index(axis_name)
    my_slot = (rank + slot_offset) % n
    out = jnp.zeros((n,) + x.shape, x.dtype).at[my_slot].set(x)
    perm = _ring_perm(n)

    def body(s, carry):
        out, cur, progress = carry
        nxt = jax.lax.ppermute(cur, axis_name, perm)
        # received value originated at rank (rank - s - 1)
        slot = (rank - s - 1 + slot_offset) % n
        out = out.at[slot].set(nxt)
        progress = progress.at[s].set(1) if with_progress else progress
        return out, nxt, progress

    progress0 = jnp.zeros((max(n - 1, 1),), jnp.int32)
    out, _, progress = jax.lax.fori_loop(0, n - 1, body, (out, x, progress0))
    return out.reshape((n * x.shape[0],) + x.shape[1:]), progress


def ring_all_reduce_local(x, axis_name: str, axis_size: int,
                          with_progress: bool = True):
    """reduce-scatter + all-gather ring; 2(N-1) progress steps."""
    owned, p1 = ring_reduce_scatter_local(x, axis_name, axis_size,
                                          with_progress)
    # reduce-scatter leaves rank r holding fully-reduced chunk (r+1) % N
    full, p2 = ring_all_gather_local(owned, axis_name, axis_size,
                                     with_progress, slot_offset=1)
    return full, jnp.concatenate([p1, p2])


def ring_all_reduce(x, mesh: Mesh, axis: str = "model",
                    with_progress: bool = True):
    """jit-level wrapper: all-reduce `x` (replicated result) over `axis`.

    x's leading dim must be divisible by the axis size.  Returns
    (result, progress [axis_size, 2*(N-1)]).
    """
    n = mesh.shape[axis]

    def body(xs):
        return ring_all_reduce_local(xs, axis, n, with_progress)

    other = tuple(a for a in mesh.axis_names if a != axis)
    res, prog = shard_map(
        body, mesh=mesh,
        in_specs=P(),
        out_specs=(P(), P(axis)),
        check_vma=False,
    )(x)
    return res, prog.reshape(n, -1)


# --------------------------------------------------------------------------- #
# int8-compressed gradient all-reduce (distributed-optimization trick)
# --------------------------------------------------------------------------- #
def quantize_int8(x, block: int = 256, rng=None):
    """Block-wise absmax int8 quantization with optional stochastic rounding."""
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block).astype(jnp.float32)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = blocks / scale
    if rng is not None:
        q = jnp.floor(q + jax.random.uniform(rng, q.shape))
    else:
        q = jnp.round(q)
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return q, scale[:, 0], x.shape, pad


def dequantize_int8(q, scale, shape, pad):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compressed_psum_local(x, axis_name: str, error: jax.Array | None = None,
                          block: int = 256):
    """int8 all-reduce with error feedback, inside shard_map.

    Quantizes the local contribution, psums int32-accumulated values, and
    carries the quantization error to the next call (error feedback keeps
    SGD/Adam convergence — Karimireddy et al. 2019).
    Returns (reduced fp32, new_error).
    """
    xf = x.astype(jnp.float32)
    if error is not None:
        xf = xf + error
    # shared per-block scale: psum-max of local absmax (tiny collective),
    # then int8 payload psum'd in int32 — exact shared-scale semantics, the
    # local quantization error goes into error feedback.
    flat = xf.reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    local_max = jnp.max(jnp.abs(blocks), axis=1)
    scale = jax.lax.pmax(local_max, axis_name) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127)
    local_dq = (q * scale[:, None]).reshape(-1)
    local_dq = local_dq[:local_dq.size - pad] if pad else local_dq
    new_error = xf - local_dq.reshape(xf.shape)
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
    out = (summed.astype(jnp.float32) * scale[:, None]).reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(xf.shape), new_error
