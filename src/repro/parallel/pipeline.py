"""GPipe-style pipeline parallelism over a ``stage`` mesh axis.

Provided as a first-class feature (the assignment requires PP support at
scale) but not used by the default configs: on a 2-pod v5e slice every
assigned arch fits with ZeRO-DP x TP (+ int8 optimizer state), where PP's
bubble only hurts (see DESIGN.md §5).

The schedule is the classic GPipe fill-drain loop expressed with shard_map
over the ``stage`` axis + ppermute of microbatch activations.  With M
microbatches and S stages the bubble fraction is (S-1)/(M+S-1).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.compat import shard_map


def pipeline_apply(stage_fn: Callable, params_stacked, x_microbatches,
                   mesh: Mesh, axis: str = "stage"):
    """Run microbatches through S pipeline stages.

    stage_fn(stage_params, x) -> x        (one stage's layers)
    params_stacked: pytree with leading [S] dim, sharded over `axis`
    x_microbatches: [M, mb, ...] activations (M >= S recommended)
    Returns [M, mb, ...] outputs (from the last stage, gathered).
    """
    S = mesh.shape[axis]
    M = x_microbatches.shape[0]
    T = M + S - 1  # total ticks (fill + steady + drain)

    def shard_body(sparams, xs):
        stage = jax.lax.axis_index(axis)
        # per-shard param block keeps a leading [1] stage dim — drop it
        sparams = jax.tree.map(lambda a: a[0], sparams)
        mb_shape = xs.shape[1:]
        buf = jnp.zeros(mb_shape, xs.dtype)  # current activation
        outs = jnp.zeros((M,) + mb_shape, xs.dtype)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if in range); others take the
            # activation permuted from the previous stage.
            feed = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, M - 1), axis=0, keepdims=False)
            inp = jnp.where(stage == 0, feed, buf)
            mb_idx = t - stage  # microbatch this stage processes at tick t
            active = (mb_idx >= 0) & (mb_idx < M)
            y = stage_fn(sparams, inp)
            y = jnp.where(active, y, buf)
            # pass activation to next stage (ring permute; last->0 unused)
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % S) for i in range(S)])
            outs = jax.lax.cond(
                active & (stage == S - 1),
                lambda o: o.at[jnp.clip(mb_idx, 0, M - 1)].set(y),
                lambda o: o, outs)
            return nxt, outs

        buf, outs = jax.lax.fori_loop(0, T, tick, (buf, outs))
        # only the last stage holds real outputs; psum broadcasts them
        outs = outs * (stage == S - 1)
        return jax.lax.psum(outs, axis)

    return shard_map(
        shard_body, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_vma=False,
    )(params_stacked, x_microbatches)
