"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/_util.emit).
  Fig 8   -> overhead        Fig 9  -> logsize
  Fig 10  -> hang            Fig 11 -> issue_dist
  Table 4 -> regression      Fig 12 -> case2_matmul
  Table 5 -> vminority       §Roofline -> roofline (reads dryrun_out/)
  §Scale  -> ingest (columnar pipeline throughput; BENCH_ingest.json)
  §Fleet  -> fleet (multi-job incremental diagnosis + JSONL replay;
             BENCH_fleet.json)
  §Store  -> storage (JSONL vs FCS bytes/event + replay Mev/s;
             BENCH_storage.json)
  §Robust -> scenarios (fault matrix, scored detector P/R;
             BENCH_scenarios.json)
  §Query  -> archive (predicate-pushdown reads + rollup cache;
             BENCH_archive.json)
  §Live   -> live (socket/tail ingest Mev/s + event->anomaly latency,
             byte-equivalence gated; BENCH_live.json)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (archive, case2_matmul, fleet, hang, ingest,
                            issue_dist, live, logsize, overhead, regression,
                            roofline, scenarios, storage, vminority)
    sections = [
        ("fig8_overhead", overhead.main),
        ("fig9_logsize", logsize.main),
        ("fig10_hang", hang.main),
        ("fig11_issue_dist", issue_dist.main),
        ("table4_regression", regression.main),
        ("fig12_case2", case2_matmul.main),
        ("table5_vminority", vminority.main),
        ("roofline", roofline.main),
        ("scale_ingest", ingest.main),
        ("scale_fleet", fleet.main),
        ("scale_storage", storage.main),
        ("robust_scenarios", scenarios.main),
        ("query_archive", archive.main),
        ("live_serve", live.main),
    ]
    print("name,us_per_call,derived")
    failures = []
    for name, fn in sections:
        print(f"# --- {name} ---")
        try:
            fn()
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"# FAILED sections: {failures}")
        sys.exit(1)
    print("# all benchmark sections completed")


if __name__ == "__main__":
    main()
