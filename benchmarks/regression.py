"""Table 4 + §7.3: regression/fail-slow detection + routing accuracy.

The paper reports, over 113 jobs: 9 true regressions found via issue
latency + void percentage, 2 false positives (1.9% FP rate, 81.8% TP
accuracy), later fixed by per-backend profiles.  We run a labeled batch of
simulated jobs spanning every Table-4 row and score detection + routing,
including the two paper false-positive scenarios (multi-modal imbalance,
CPU-heavy backend) handled by backend-keyed profiles.
"""
from __future__ import annotations

from benchmarks._util import emit
from repro.configs import get_config
from repro.core.engine import DiagnosticEngine, EngineConfig
from repro.core.history import HistoryStore
from repro.core.timeline import ClusterSimulator, Injection, program_from_config

N = 64

CASES = [
    # (name, injections, expected (kind, metric, team)) — Table 4 rows
    ("gpu_underclock", [Injection(kind="underclock", ranks=(9,), factor=2.2,
                                  start_step=3)],
     ("fail_slow", "throughput", "operations")),
    ("network_jitter", [Injection(kind="network_jitter", factor=3.0,
                                  start_step=3)],
     ("fail_slow", "bandwidth", "operations")),
    ("python_gc", [Injection(kind="gc", duration=0.25, period_ops=5)],
     ("regression", "issue_latency", "algorithm")),
    ("unnecessary_sync", [Injection(kind="sync_after_comm")],
     ("regression", "issue_latency", "algorithm")),
    ("package_checking", [Injection(kind="pyapi_stall", duration=0.3,
                                    period_ops=8,
                                    api_name="pkg_resources@working_set")],
     ("regression", "issue_latency", "algorithm")),
    ("minority_kernels", [Injection(kind="minority_kernels", factor=0.4)],
     ("regression", "v_minority", "infrastructure")),
    ("dataloader_64k_mask", [Injection(kind="slow_dataloader",
                                       duration=8.0)],
     ("regression", "v_inter", "algorithm")),
    ("backend_migration_layout", [Injection(kind="slow_compute",
                                            op_match="ffn_matmul",
                                            factor=2.88)],
     ("regression", "flops", "infrastructure")),
]


def _world(backend="dense-train", seed0=0):
    cfg = get_config("llama-20b-paper")
    prog = program_from_config(cfg, num_chips=N)
    store = HistoryStore()
    eng = DiagnosticEngine(EngineConfig(backend=backend, num_ranks=N), store)
    for s in range(3):
        eng.ingest_batch(
            ClusterSimulator(N, prog, seed=seed0 + s).run_batch(4))
    eng.learn_healthy()
    return prog, store


def main():
    prog, store = _world()
    shapes = {f"ffn_matmul[{g}]": (8192, 8484) for g in range(8)}
    tp = mis = 0
    for i, (name, inj, (kind, metric, team)) in enumerate(CASES):
        eng = DiagnosticEngine(EngineConfig(
            backend="dense-train", num_ranks=N, kernel_shapes=shapes), store)
        sim = ClusterSimulator(N, prog, seed=50 + i, injections=inj)
        eng.ingest_batch(sim.run_batch(7))
        found = eng.evaluate_all()
        hit = any(a.kind == kind and a.metric == metric
                  and a.team.value == team for a in found)
        tp += hit
        mis += not hit
        emit(f"regression/{name}", 0.0,
             f"detected={hit};routed_to={team}")
    # false-positive check on healthy jobs
    fp = 0
    n_healthy = 10
    for s in range(n_healthy):
        eng = DiagnosticEngine(EngineConfig(
            backend="dense-train", num_ranks=N), store)
        eng.ingest_batch(
            ClusterSimulator(N, prog, seed=300 + s).run_batch(5))
        if any(a.kind == "regression" for a in eng.evaluate_all()):
            fp += 1
    emit("regression/summary", 0.0,
         f"tp={tp}/{len(CASES)};fp={fp}/{n_healthy};"
         f"paper=9tp_2fp_of_113jobs")
    # ---- the paper's 2 false positives, fixed by per-backend profiles --- #
    # a vlm job with imbalanced per-rank compute looks GC-like under the
    # dense profile but is HEALTHY under its own vlm profile
    cfg = get_config("llama-3.2-vision-11b")
    vprog = program_from_config(cfg, num_chips=N)
    veng = DiagnosticEngine(EngineConfig(backend="vlm-train", num_ranks=N),
                            store)
    for s in range(3):
        sim = ClusterSimulator(N, vprog, seed=400 + s, injections=[
            Injection(kind="straggler",
                      ranks=tuple(range(0, N, 4)), factor=1.6)])
        veng.ingest_batch(sim.run_batch(4))
    veng.learn_healthy()
    eng = DiagnosticEngine(EngineConfig(backend="vlm-train", num_ranks=N),
                           store)
    sim = ClusterSimulator(N, vprog, seed=500, injections=[
        Injection(kind="straggler", ranks=tuple(range(0, N, 4)),
                  factor=1.6)])
    eng.ingest_batch(sim.run_batch(5))
    fps = [a for a in eng.evaluate_all() if a.kind == "regression"]
    emit("regression/vlm_imbalance_fp_fixed", 0.0,
         f"false_positive={bool(fps)};paper_fixed=True")
    return tp, fp


if __name__ == "__main__":
    main()
