"""Generate the §Dry-run and §Roofline sections of EXPERIMENTS.md from the
dryrun_out/*.json artifacts (run after `dryrun --all` on both meshes)."""
from __future__ import annotations

import glob
import json
import os


def _fmt_bytes(b):
    return f"{b / 2 ** 30:.2f}"


def load_all(out_dir="dryrun_out"):
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        r["_file"] = os.path.basename(path)
        rows.append(r)
    return rows


def is_baseline(r):
    stem = r["_file"][: -len(".json")]
    return stem.endswith(r["mesh"].replace("x", "-"))


def dryrun_section(rows):
    out = ["## §Dry-run", "",
           "Every assigned (arch × shape) cell lowered + compiled with "
           "`jax.jit(...).lower().compile()` on BOTH production meshes "
           "(16×16 = 256 chips; 2×16×16 = 512 chips, `pod` = outer DP "
           "axis). Columns from `compiled.memory_analysis()` and the "
           "scan-aware HLO analysis (collective payloads multiplied "
           "through loop trip counts).", "",
           "| arch | shape | mesh | args GiB/dev | temp GiB/dev | peak "
           "GiB/dev | HLO GFLOP/dev | wire GB/dev | top collectives |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        m = r["memory"]
        colls = {k: v for k, v in r["collectives"].items()
                 if isinstance(v, dict) and v.get("count")}
        top = sorted(colls.items(), key=lambda kv: -kv[1]["wire_bytes"])[:2]
        tops = ", ".join(f"{k}×{v['count']}" for k, v in top) or "—"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {_fmt_bytes(m['argument_bytes'])} "
            f"| {_fmt_bytes(m['temp_bytes'])} "
            f"| {_fmt_bytes(m['peak_bytes'])} "
            f"| {r['hlo_flops_per_device'] / 1e9:.0f} "
            f"| {r.get('total_wire_bytes', 0) / 1e9:.1f} "
            f"| {tops} |")
    return "\n".join(out)


def roofline_section(rows):
    out = ["## §Roofline (single-pod 16×16, 256 × TPU v5e)", "",
           "Terms: compute = HLO_FLOPs/dev ÷ 197 TF/s; memory = HBM "
           "traffic/dev ÷ 819 GB/s; collective = ring wire bytes/dev ÷ "
           "50 GB/s. `useful` = MODEL_FLOPS (6·N·D train / 2·N·D serve, "
           "N = active params) ÷ HLO_FLOPs.", "",
           "| arch | shape | compute s | memory s | collective s | "
           "dominant | useful | one-line diagnosis |",
           "|---|---|---|---|---|---|---|---|"]
    notes = {
        ("dense", "train"): "FSDP param all-gathers per microbatch drive "
        "the collective term — hillclimb H2",
        ("moe", "train"): "EP psum + FSDP gathers; dispatch is sort-based "
        "so compute stays near model FLOPs",
        ("moe", "prefill"): "expert streaming: every expert's weights are "
        "read per token block — memory-bound",
        ("dense", "prefill"): "logit + attention traffic; flash custom-VJP "
        "keeps memory O(S)",
        ("dense", "decode"): "KV-cache streaming bound (classic decode)",
        ("moe", "decode"): "KV cache + expert weight streaming",
        ("ssm", "train"): "SSD intra-chunk decay tensors dominate HBM "
        "traffic",
        ("ssm", "prefill"): "state-passing collectives on seq sharding",
        ("ssm", "decode"): "O(1) state update; tiny",
        ("hybrid", "train"): "mamba traffic + shared-attn collectives",
    }
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        roof = r["roofline_s"]
        fam_kind = (r.get("family") or "", r["kind"])
        out.append(
            f"| {r['arch']} | {r['shape']} | {roof['compute']:.3f} "
            f"| {roof['memory']:.3f} | {roof['collective']:.3f} "
            f"| **{r['dominant']}** | {r['useful_flops_ratio']:.2f} "
            f"| {notes.get(fam_kind, '')} |")
    return "\n".join(out)


def main():
    rows = [r for r in load_all() if is_baseline(r)]
    single = [r for r in rows if r["mesh"] == "16x16"]
    multi = [r for r in rows if r["mesh"] == "2x16x16"]
    print(dryrun_section(rows))
    print()
    print(roofline_section(single))
    print(f"\nCells compiled: {len(single)} single-pod, {len(multi)} "
          f"multi-pod (of 32 runnable; 8 long_500k cells skipped per "
          f"assignment for pure full-attention archs).")


if __name__ == "__main__":
    main()
