"""Columnar event-pipeline throughput at thousand-plus simulated ranks.

Measures, per cluster size, events/s for
  * sim-emit:        ClusterSimulator.run_batch (columnar emission)
  * engine-diagnose: ingest_batch + evaluate_all (vectorized metrics sweep
                     + detectors, against a learned healthy profile)
and writes ``BENCH_ingest.json`` so later PRs can track the trajectory.

Seed baselines (pre-columnar, 1024 ranks x 10 steps, one host):
  sim emit 0.34 Mev/s, engine diagnose 0.10 Mev/s (list-of-dataclass path,
  per-step rescans).  Acceptance for the columnar PR: >= 3x emit and
  >= 0.6 Mev/s diagnose.
"""
from __future__ import annotations

import time

from benchmarks._util import emit, merge_bench_json
from repro.configs import get_config
from repro.core.engine import DiagnosticEngine, EngineConfig
from repro.core.history import HistoryStore
from repro.core.timeline import ClusterSimulator, program_from_config

RANKS = (256, 1024, 4096)
STEPS = 10
OUT_JSON = "BENCH_ingest.json"


def _bench_scale(n: int, steps: int = STEPS):
    cfg = get_config("llama-20b-paper")
    prog = program_from_config(cfg, num_chips=n)

    # ---- simulator emission ------------------------------------------- #
    sim = ClusterSimulator(n, prog, seed=0)
    t0 = time.perf_counter()
    batch = sim.run_batch(steps)
    emit_s = time.perf_counter() - t0
    nev = len(batch)

    # ---- healthy profile (not timed: one-off per backend/scale) ------- #
    store = HistoryStore()
    learner = DiagnosticEngine(
        EngineConfig(backend="dense-train", num_ranks=n), store)
    learner.ingest_batch(ClusterSimulator(n, prog, seed=1).run_batch(3))
    learner.learn_healthy()

    # ---- engine: ingest + full diagnosis ------------------------------ #
    eng = DiagnosticEngine(
        EngineConfig(backend="dense-train", num_ranks=n), store)
    t0 = time.perf_counter()
    eng.ingest_batch(batch)
    eng.evaluate_all()
    diag_s = time.perf_counter() - t0

    return nev, nev / emit_s, nev / diag_s


def main():
    scales = {}
    for n in RANKS:
        nev, emit_evs, diag_evs = _bench_scale(n)
        scales[str(n)] = {
            "events": nev,
            "sim_emit_events_per_s": emit_evs,
            "engine_diagnose_events_per_s": diag_evs,
        }
        emit(f"ingest/sim_emit_{n}r", 1e6 / emit_evs,
             f"{emit_evs / 1e6:.2f}Mev_s;n_events={nev}")
        emit(f"ingest/engine_diagnose_{n}r", 1e6 / diag_evs,
             f"{diag_evs / 1e6:.2f}Mev_s;n_events={nev}")
    # merge (keyed by scale) so the bench trajectory accumulates across
    # PRs / partial runs instead of clobbering unmeasured scales
    results = merge_bench_json(OUT_JSON, scales, meta={"steps": STEPS})
    emit("ingest/json", 0.0, f"merged={OUT_JSON}")
    return results


if __name__ == "__main__":
    main()
