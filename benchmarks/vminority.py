"""Table 5: V_minority growth as PE/ACT/NORM ops are left un-optimized.

Paper ladder: Healthy 9% -> -PE 14% -> -PE-ACT 15% -> -PE-ACT-NORM 28%,
with normalized TFLOPS 1 / 0.95 / 0.93 / 0.83.  Minority-kernel time is
modeled as un-instrumented device time proportional to each op family's
cost; FLARE's V_minority must track the ladder and the fused kernel
(repro.kernels.fused_norm) removes the NORM share.
"""
from __future__ import annotations

import numpy as np

from benchmarks._util import emit
from repro.configs import get_config
from repro.core.metrics import aggregate_step, steps_in
from repro.core.timeline import ClusterSimulator, Injection, program_from_config

N = 32
# minority device-time fractions per un-optimized op family (of compute time)
LADDER = [("healthy", 0.095), ("-PE", 0.16), ("-PE-ACT", 0.175),
          ("-PE-ACT-NORM", 0.40)]


def main():
    cfg = get_config("llama-20b-paper")
    prog = program_from_config(cfg, num_chips=N)
    results = []
    for name, frac in LADDER:
        sim = ClusterSimulator(N, prog, seed=3, injections=[
            Injection(kind="minority_kernels", factor=frac)])
        ev = sim.run_batch(3)   # columnar path
        vs, ts = [], []
        for s in steps_in(ev)[1:]:
            m = aggregate_step(ev, s)
            vs.append(m.v_minority)
            ts.append(m.t_step)
        v = float(np.mean(vs))
        tflops_norm = ts[0] and (min(ts) / float(np.mean(ts)))
        results.append((name, v))
        emit(f"vminority/{name}", float(np.mean(ts)) * 1e6,
             f"V_minority={v:.3f};paper="
             + {"healthy": "0.09", "-PE": "0.14", "-PE-ACT": "0.15",
                "-PE-ACT-NORM": "0.28"}[name])
    # monotone ladder, healthy lowest (paper's qualitative claim)
    vals = [v for _, v in results]
    assert vals == sorted(vals), vals
    # fused kernel exists and is exact (the infra-team fix for NORM)
    import jax.numpy as jnp
    from repro.kernels.fused_norm.ops import fused_residual_rmsnorm
    from repro.kernels.fused_norm.ref import fused_ref
    x = jnp.ones((64, 64)) * 0.5
    r = jnp.ones((64, 64)) * 0.1
    s = jnp.ones((64,))
    y, h = fused_residual_rmsnorm(x, r, s)
    yr, hr = fused_ref(x, r, s)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-5)
    emit("vminority/fused_norm_fix", 0.0, "fused_residual_rmsnorm=allclose")


if __name__ == "__main__":
    main()
