"""Fig 9: trace-log bytes per GPU per step — FLARE selective tracing vs a
full-profiler dump.

The paper: PyTorch full profiler = 5.5 GB/step (451 MB compressed) for
Llama-70B@512; FLARE <= 0.78 MB/GPU/step (16 A100s) and 1.5 MB/GPU total on
a real 1536-GPU job.  We reproduce the RATIO on the simulated Llama-20B
program: a 'full' dump logs every sub-kernel event with stacks + layouts at
op granularity; FLARE logs only the selective events.
"""
from __future__ import annotations

import json
import os
import tempfile

from benchmarks._util import emit
from repro.configs import get_config
from repro.core.events import dump_jsonl
from repro.core.timeline import ClusterSimulator, program_from_config

FULL_DUMP_EXPANSION = 64  # sub-kernels per instrumented op in a full dump
# (matmul decomposes into grad/transpose/cast kernels, each with a full
#  python stack + tensor layout record — measured 5.5GB vs FLARE's selective
#  log in the paper; 64 sub-events/op at ~3x record size reproduces it)


def main():
    cfg = get_config("llama-20b-paper")
    prog = program_from_config(cfg, num_chips=8, layer_groups=31)
    sim = ClusterSimulator(1, prog, seed=0)
    events = sim.run(1)[0]

    with tempfile.TemporaryDirectory() as d:
        flare_path = os.path.join(d, "flare.jsonl")
        flare_bytes = dump_jsonl(events, flare_path)

        from repro import store
        from repro.core.columnar import EventBatch
        batch = EventBatch.from_events(events)
        fcs_bytes = store.write_trace(batch, os.path.join(d, "flare.fcs"))

        # archival formats compared at archival granularity: one segment
        # per step of a multi-rank run (the daemon-drain / rotation
        # shape), not the single-rank single-step sliver above — the
        # fixed-size v3 stats block amortizes over a real segment
        b8 = ClusterSimulator(8, prog, seed=0).run_batch(2)
        order, uniq, bounds = b8.step_index()
        fcs2_bytes = fcs3_bytes = 0
        for i in range(uniq.size):
            sb = b8.take(order[bounds[i]:bounds[i + 1]])
            fcs2_bytes += store.write_fcs(sb, os.path.join(d, "a.fcs2"),
                                          version=2)
            fcs3_bytes += store.write_fcs(sb, os.path.join(d, "a.fcs3"),
                                          version=3)
        n8 = len(b8)

        full_path = os.path.join(d, "full.jsonl")
        full_bytes = 0
        with open(full_path, "a") as f:
            for ev in events:
                for sub in range(FULL_DUMP_EXPANSION):
                    rec = {"k": ev.kind.value, "n": f"{ev.name}#{sub}",
                           "ts": ev.start_ts, "dur": ev.duration,
                           "stack": [f"frame_{i}" for i in range(24)],
                           "layout": [1, 128, 4096, 64],
                           "meta": ev.meta and dict(ev.meta)}
                    line = json.dumps(rec)
                    f.write(line + "\n")
                    full_bytes += len(line) + 1

    ratio = full_bytes / max(flare_bytes, 1)
    emit("logsize/flare_MB_per_step", flare_bytes / 1e6 * 1e6,
         f"MB={flare_bytes / 1e6:.3f};paper<=0.78MB")
    emit("logsize/flare_fcs_MB_per_step", fcs_bytes / 1e6 * 1e6,
         f"MB={fcs_bytes / 1e6:.3f};"
         f"ratio={fcs_bytes / max(flare_bytes, 1):.3f}x_of_jsonl")
    emit("logsize/flare_fcs2_B_per_event", fcs2_bytes / max(n8, 1),
         f"B_per_event={fcs2_bytes / max(n8, 1):.1f};segments={uniq.size}")
    # v3 = v2 + the 272-byte stats block per segment; the whole point of
    # the stats directory is that pruning is ~free at rest
    v3_overhead = fcs3_bytes / max(fcs2_bytes, 1)
    assert v3_overhead <= 1.05, (
        f"FCS v3 stats-directory overhead {v3_overhead:.3f}x over v2 "
        "exceeds the 1.05x budget")
    emit("logsize/flare_fcs3_B_per_event", fcs3_bytes / max(n8, 1),
         f"B_per_event={fcs3_bytes / max(n8, 1):.1f};"
         f"stats_overhead={v3_overhead:.4f}x_of_v2(max1.05)")
    emit("logsize/full_profiler_MB_per_step", full_bytes / 1e6 * 1e6,
         f"MB={full_bytes / 1e6:.1f};ratio={ratio:.0f}x;paper~7000x")
    return flare_bytes, full_bytes


if __name__ == "__main__":
    main()
