"""Shared benchmark helpers: timing + the run.py CSV contract."""
from __future__ import annotations

import json
import os
import time
from contextlib import contextmanager

ROWS: list[tuple] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}")


def time_it(fn, *args, repeat: int = 3, warmup: int = 1, **kw) -> float:
    for _ in range(warmup):
        fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn(*args, **kw)
    return (time.perf_counter() - t0) / repeat


@contextmanager
def timed():
    box = {}
    t0 = time.perf_counter()
    yield box
    box["s"] = time.perf_counter() - t0


def merge_bench_json(path: str, results: dict, meta: dict | None = None,
                     section: str = "scales") -> dict:
    """Merge per-scale results into an accumulating BENCH_*.json file so
    the trajectory survives across PRs and partial (e.g. --quick) runs:
    only the scale keys measured THIS run are replaced, everything else is
    kept.  A missing or corrupt file starts fresh."""
    data: dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (ValueError, OSError):
            data = {}
    if meta:
        data.update(meta)
    data.setdefault(section, {}).update(results)
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
    return data
