"""Shared benchmark helpers: timing + the run.py CSV contract."""
from __future__ import annotations

import time
from contextlib import contextmanager

ROWS: list[tuple] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}")


def time_it(fn, *args, repeat: int = 3, warmup: int = 1, **kw) -> float:
    for _ in range(warmup):
        fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn(*args, **kw)
    return (time.perf_counter() - t0) / repeat


@contextmanager
def timed():
    box = {}
    t0 = time.perf_counter()
    yield box
    box["s"] = time.perf_counter() - t0
