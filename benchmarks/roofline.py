"""§Roofline: aggregate the dry-run JSONs into the per-(arch x shape) table.

Reads dryrun_out/*.json produced by repro.launch.dryrun; emits a markdown
table + CSV rows with the three roofline terms, the dominant bottleneck,
MODEL_FLOPS/HLO_FLOPS, and peak bytes/device.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks._util import emit

HEADER = ("| arch | shape | mesh | peak GiB/dev | compute s | memory s | "
          "collective s | dominant | useful ratio |")
SEP = "|" + "---|" * 9


def load(out_dir: str = "dryrun_out", mesh: str | None = "16-16",
         tag: str | None = None) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        base = os.path.basename(path)
        if mesh and f"_{mesh}" not in base:
            continue
        is_tagged = base.count("_") > 2 + base.count("x")  # crude
        with open(path) as f:
            r = json.load(f)
        r["_file"] = base
        if tag is None and not base.replace(".json", "").endswith(
                r["mesh"].replace("x", "-")):
            continue  # skip tagged (perf-iteration) runs in the base table
        if tag is not None and not base.replace(".json", "").endswith(tag):
            continue
        rows.append(r)
    return rows


def table(rows: list[dict]) -> str:
    out = [HEADER, SEP]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        roof = r["roofline_s"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['memory']['peak_bytes'] / 2 ** 30:.2f} "
            f"| {roof['compute']:.3f} | {roof['memory']:.3f} "
            f"| {roof['collective']:.3f} | {r['dominant']} "
            f"| {r['useful_flops_ratio']:.2f} |")
    return "\n".join(out)


def main(out_dir: str = "dryrun_out"):
    rows = load(out_dir)
    if not rows:
        emit("roofline/missing", 0.0, "run repro.launch.dryrun --all first")
        return
    print(table(rows))
    for r in rows:
        roof = r["roofline_s"]
        dom = max(roof.values())
        emit(f"roofline/{r['arch']}/{r['shape']}", dom * 1e6,
             f"dominant={r['dominant']};useful={r['useful_flops_ratio']:.2f};"
             f"peakGiB={r['memory']['peak_bytes'] / 2 ** 30:.1f}")


if __name__ == "__main__":
    main()
