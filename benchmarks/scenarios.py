"""Scenario matrix benchmark: scored detector precision/recall in CI.

Sweeps the labelled fault library (``repro.scenarios``) over model-zoo
configs, grades every cell against its machine-readable ground truth, and
asserts hard floors — CI FAILS when a fault is missed, routed to the
wrong team, attributed to the wrong ranks, or a healthy run raises any
anomaly.  Per-detector precision/recall merge into ``BENCH_scenarios.json``
keyed by config so the trajectory accumulates across partial runs.

Floors:
  * every faulty cell caught (matrix recall == 1.0)
  * team + culprit-rank + onset attribution correct on every catch
  * healthy cells raise ZERO anomalies
  * micro precision >= 0.95 (allowed secondary symptoms don't count
    against precision; anything else does)

    PYTHONPATH=src python -m benchmarks.scenarios [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks._util import emit, merge_bench_json
from repro.scenarios import run_matrix, score_matrix
from repro.scenarios.library import FAULT_KINDS, SCENARIOS

OUT_JSON = "BENCH_scenarios.json"

QUICK_CONFIGS = ["qwen2-0.5b"]
FULL_CONFIGS = ["qwen2-0.5b", "llama3.2-1b", "mamba2-780m", "dbrx-132b"]

PRECISION_FLOOR = 0.95
RECALL_FLOOR = 1.0
MIN_QUICK_SCENARIOS = 6      # ISSUE 6 CI floor
MIN_FAULT_KINDS = 8


def assert_floors(cells, scores) -> None:
    faulty = [c for c in cells if not c.healthy]
    assert len({c.scenario for c in cells}) >= MIN_QUICK_SCENARIOS, \
        f"matrix too small: {len(cells)} cells"
    assert len([k for k in FAULT_KINDS if k]) >= MIN_FAULT_KINDS, \
        f"fault taxonomy shrank: {FAULT_KINDS}"
    missed = [f"{c.scenario}@{c.config}" for c in faulty if not c.caught]
    assert not missed, f"MISSED anomalies: {missed}"
    bad_team = [f"{c.scenario}@{c.config}" for c in faulty
                if c.caught and not c.team_ok]
    assert not bad_team, f"wrong team routing: {bad_team}"
    bad_ranks = [f"{c.scenario}@{c.config}" for c in faulty
                 if c.caught and not c.ranks_ok]
    assert not bad_ranks, f"culprit ranks not attributed: {bad_ranks}"
    bad_onset = [f"{c.scenario}@{c.config}" for c in faulty
                 if c.caught and not c.onset_ok]
    assert not bad_onset, f"fired before injection onset: {bad_onset}"
    noisy = [f"{c.scenario}@{c.config}" for c in cells
             if c.healthy and c.anomalies]
    assert not noisy, f"healthy cells raised anomalies: {noisy}"
    assert scores["micro_precision"] >= PRECISION_FLOOR, \
        f"precision {scores['micro_precision']:.3f} < {PRECISION_FLOOR} " \
        f"(false positives: {scores['false_positive_cells']})"
    assert scores["micro_recall"] >= RECALL_FLOOR, \
        f"recall {scores['micro_recall']:.3f} < {RECALL_FLOOR}"


def main(quick: bool = False) -> dict:
    configs = QUICK_CONFIGS if quick else FULL_CONFIGS
    results = {}
    all_cells = []
    for config_name in configs:
        t0 = time.perf_counter()
        cells = run_matrix([config_name])
        dt = time.perf_counter() - t0
        all_cells.extend(cells)
        scores = score_matrix(cells)
        results[config_name] = {
            "cells": scores["cells"],
            "caught": scores["cells"] - len(scores["missed"]),
            "micro_precision": round(scores["micro_precision"], 4),
            "micro_recall": round(scores["micro_recall"], 4),
            "detectors": scores["detectors"],
            "seconds": round(dt, 2),
        }
        emit(f"scenarios[{config_name}]", 1e6 * dt / max(len(cells), 1),
             f"{scores['cells']} cells "
             f"P={scores['micro_precision']:.2f} "
             f"R={scores['micro_recall']:.2f}")

    scores = score_matrix(all_cells)
    assert_floors(all_cells, scores)
    emit("scenarios[matrix]", 0.0,
         f"{scores['cells']} cells {scores['faulty_cells']} faulty "
         f"P={scores['micro_precision']:.2f} "
         f"R={scores['micro_recall']:.2f}")
    merge_bench_json(
        OUT_JSON, results,
        meta={"scenarios": len(SCENARIOS),
              "fault_kinds": list(FAULT_KINDS),
              "precision_floor": PRECISION_FLOOR,
              "recall_floor": RECALL_FLOOR},
        section="configs")
    return scores


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true",
                   help="smallest config only (CI tier)")
    args = p.parse_args()
    try:
        main(quick=args.quick)
    except AssertionError as e:
        print(f"# SCENARIO FLOOR VIOLATION: {e}")
        sys.exit(1)
    print("# scenario matrix floors held")
