"""Archive query latency: predicate pushdown + rollup cache (ISSUE 7).

Builds a multi-job archive of rotated FCS v3 segments (one segment per
step — the tight-stats shape a rotating daemon spill produces) and
measures the two mechanisms that make the archive interactive:

  * **pushdown**: ``query_events`` over a narrow step-range predicate
    (<= 20% of steps), with the stats directory vs the full-decode
    oracle.  ASSERTS the pruned read decodes >= 5x fewer bytes AND
    returns a byte-identical EventBatch (acceptance criteria);
  * **rollups**: ``query_metrics`` cold (per-file rollup build) vs warm
    (fingerprint cache hit) — the dashboard refresh path.

Results merge into ``BENCH_archive.json`` keyed by scale.

    PYTHONPATH=src python benchmarks/archive.py [--quick]
"""
from __future__ import annotations

import argparse
import os
import shutil
import tempfile
import time

from benchmarks._util import emit, merge_bench_json
from repro import store
from repro.archive import TraceArchive
from repro.configs import get_config
from repro.core.timeline import (ClusterSimulator, Injection,
                                 program_from_config)

OUT_JSON = "BENCH_archive.json"

_COLS = ("kind", "name_id", "rank", "issue_ts", "start_ts", "end_ts",
         "step", "flops", "nbytes", "tokens", "group_id")


def _batches_byte_equal(a, b) -> bool:
    return (all(getattr(a, c).tobytes() == getattr(b, c).tobytes()
                for c in _COLS)
            and a.names == b.names and a.groups == b.groups
            and a.extra == b.extra)


def _best(fn, repeat=3):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def _build_archive(logdir: str, num_ranks: int, steps: int,
                   jobs: int) -> None:
    cfg = get_config("llama-20b-paper")
    prog = program_from_config(cfg, num_chips=num_ranks)
    scenarios = [
        [],
        [Injection(kind="underclock", ranks=(num_ranks // 3,), factor=2.5,
                   start_step=steps // 2)],
        [Injection(kind="gc", duration=0.02, period_ops=5)],
    ]
    for j in range(jobs):
        b = ClusterSimulator(num_ranks, prog, seed=40 + j,
                             injections=scenarios[j % len(scenarios)]
                             ).run_batch(steps)
        # rotate_bytes=1 => one file per segment write; one write per
        # step => per-segment step ranges are single steps (max pruning
        # power, and the shape a size-rotated daemon spill converges to)
        w = store.SegmentedTraceWriter(
            os.path.join(logdir, f"job-{j:02d}.fcs3"), codec="fcs3",
            rotate_bytes=1)
        order, uniq, bounds = b.step_index()
        for i in range(uniq.size):
            w.write(b.take(order[bounds[i]:bounds[i + 1]]))


def run_scale(num_ranks: int, steps: int, jobs: int) -> dict:
    tag = f"r{num_ranks}_s{steps}_j{jobs}"
    results: dict = {}
    with tempfile.TemporaryDirectory() as d:
        _build_archive(d, num_ranks, steps, jobs)
        ar = TraceArchive(d)
        job = "job-00"
        # <= 20% of the step range (acceptance criterion shape; ~1/6th
        # keeps the asserted 5x byte floor honest margin, not a knife
        # edge at exactly 1/5)
        lo = steps - max(steps // 6, 1)
        win = (lo, steps - 1)

        s_push, (pruned, scan) = _best(
            lambda: ar.query_events(job, step_range=win, with_scan=True))
        s_full, (full, scan_full) = _best(
            lambda: ar.query_events(job, step_range=win, pushdown=False,
                                    with_scan=True))
        assert _batches_byte_equal(pruned, full), \
            "pruned query != full-decode oracle"
        assert scan.bytes_decoded > 0 and scan_full.bytes_decoded > 0
        byte_ratio = scan_full.bytes_decoded / scan.bytes_decoded
        assert byte_ratio >= 5.0, (
            f"pushdown decoded only {byte_ratio:.1f}x fewer bytes "
            f"({scan.bytes_decoded} vs {scan_full.bytes_decoded}) on a "
            f"<=20% step predicate — acceptance floor is 5x")
        emit(f"archive/{tag}/query_pushdown_ms", s_push * 1e6,
             f"ms={s_push * 1e3:.2f};"
             f"segments_skipped={scan.segments_skipped}/{scan.segments}")
        emit(f"archive/{tag}/query_full_ms", s_full * 1e6,
             f"ms={s_full * 1e3:.2f};bytes_ratio={byte_ratio:.1f}x(min5x)")

        # rollups: cold build vs warm fingerprint hits
        t0 = time.perf_counter()
        curve = ar.query_metrics(job, metric="throughput")
        s_cold = time.perf_counter() - t0
        assert len(curve) == steps
        s_warm, _ = _best(
            lambda: ar.query_metrics(job, metric="throughput"))
        emit(f"archive/{tag}/rollup_cold_ms", s_cold * 1e6,
             f"ms={s_cold * 1e3:.2f};steps={steps}")
        emit(f"archive/{tag}/rollup_warm_ms", s_warm * 1e6,
             f"ms={s_warm * 1e3:.2f};"
             f"speedup={s_cold / max(s_warm, 1e-9):.0f}x")

        results[tag] = {
            "num_ranks": num_ranks, "steps": steps, "jobs": jobs,
            "query_pushdown_s": s_push, "query_full_s": s_full,
            "bytes_decoded_pruned": scan.bytes_decoded,
            "bytes_decoded_full": scan_full.bytes_decoded,
            "bytes_ratio": byte_ratio,
            "segments_skipped": scan.segments_skipped,
            "rollup_cold_s": s_cold, "rollup_warm_s": s_warm,
        }
    return results


def main(quick: bool = False):
    scales = [(16, 20, 2)] if quick else [(64, 30, 3), (128, 30, 3)]
    results = {}
    for num_ranks, steps, jobs in scales:
        results.update(run_scale(num_ranks, steps, jobs))
    out = os.path.join(os.path.dirname(__file__), "..", OUT_JSON)
    merge_bench_json(os.path.normpath(out), results,
                     meta={"bench": "archive"})
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="one small scale (CI)")
    args = ap.parse_args()
    main(quick=args.quick)
