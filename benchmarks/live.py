"""Live fleet service: sustained socket/tail ingest + event->anomaly latency.

Measures, per (jobs x ranks x steps) scale and worker kind:
  * live-socket: a rack-degradation fleet (half the jobs jittering on
    shared racks, ``cross_job_failslow`` registered) streamed as FLW
    BATCH frames — one FCS segment per step, the daemon wire shape —
    into a resident :class:`~repro.serve.service.FleetService`;
    sustained aggregate ingest+diagnose rate (Mev/s) and per-anomaly
    event->anomaly latency (send time of the anomaly's step frame ->
    ``on_anomaly`` delivery; includes the watermark by design — that IS
    the pipeline's time-to-diagnosis), p50/p99;
  * live-tail: the same fleet spilled to disk and followed by the
    ``FileTailer`` plane;
  * graceful leave: one job BYEs mid-run while the rest keep streaming,
    then a straggler frame arrives post-BYE (dropped + counted);
  * chaos (``--chaos-quick`` / full): the tail-plane fleet is KILLED at
    a deterministic mid-stream point right after a checkpoint (half the
    segments on disk), two corrupt checkpoint generations are planted
    NEWER than the real one, and a fresh service must restore (skipping
    both), replay only the spill suffix (proven by bytes-decoded
    accounting: every byte decoded exactly once across incarnations,
    suffix strictly less than full), and finish the run — the
    pre-kill + post-restore anomaly stream, stats signature, and
    fleet-tier reclassification set must be byte-equivalent to the
    uninterrupted oracle, for BOTH worker kinds.  Recovery time
    (checkpoint load + suffix replay) lands in ``BENCH_live.json``.

Every arm is HARD-GATED on byte-equivalence with ``replay_dir`` over
the same recorded files: anomaly stream (after the ``(ts, job_id,
seq)`` merge sort), ``ReplayStats`` signature, and the fleet-tier
reclassification count must all be identical, or the bench raises —
this is the CI gate for the live planes.  Results merge into
``BENCH_live.json``.

    PYTHONPATH=src python -m benchmarks.live [--quick]
"""
from __future__ import annotations

import argparse
import os
import shutil
import tempfile
import time

from benchmarks._util import emit, merge_bench_json
from repro import store as trace_store
from repro.configs import get_config
from repro.core.engine import DiagnosticEngine, EngineConfig
from repro.core.history import HistoryStore
from repro.core.timeline import (ClusterSimulator, Injection,
                                 program_from_config)
from repro.fleet import FleetConfig, FleetMultiplexer, FleetReplayer
from repro.serve import FleetService, LiveClient, ServiceConfig

OUT_JSON = "BENCH_live.json"


def _learned_store(prog, ranks: int) -> HistoryStore:
    store = HistoryStore()
    learner = DiagnosticEngine(
        EngineConfig(backend="dense-train", num_ranks=ranks), store)
    learner.ingest_batch(ClusterSimulator(ranks, prog, seed=1).run_batch(3))
    learner.learn_healthy()
    return store


def _make_fleet(prog, jobs: int, ranks: int, steps: int):
    """Rack-degradation fleet: first half jitters on shared racks (two
    jobs per rack) — hang-free, so diagnosis is bit-exact live (see
    src/repro/serve/README.md caveats)."""
    chunk_lists, topo, total = {}, {}, 0
    n_slow = max(jobs // 2, 2)
    for i in range(jobs):
        inj = [Injection(kind="network_jitter", factor=3.0, start_step=3)] \
            if i < n_slow else []
        sim = ClusterSimulator(ranks, prog, seed=100 + i, injections=inj)
        batch = sim.run_batch(steps)
        job_id = f"lv{i:02d}-{'jitter' if i < n_slow else 'healthy'}"
        order, uniq, bounds = batch.step_index()
        chunk_lists[job_id] = [batch.take(order[bounds[j]:bounds[j + 1]])
                               for j in range(uniq.size)]
        topo[job_id] = {"rack": f"rack{i // 2}", "switch": f"sw{i // 4}"}
        total += len(batch)
    return chunk_lists, topo, total


def _write_logs(logdir: str, chunk_lists: dict) -> None:
    for job_id, chunks in chunk_lists.items():
        path = os.path.join(logdir, f"{job_id}.fcs")
        for c in chunks:               # one segment per step, daemon-shaped
            trace_store.write_trace(c, path, codec="fcs")


def _mk_mux(store, topo) -> FleetMultiplexer:
    return FleetMultiplexer(FleetConfig(
        watermark_delay=1, fleet_detectors=["cross_job_failslow"],
        topology=topo), history=store)


def _ecfg(ranks: int) -> EngineConfig:
    return EngineConfig(backend="dense-train", num_ranks=ranks)


def _oracle(logdir, store, topo, chunk_lists, ranks):
    """Serial ``replay_dir`` + finalize on the recorded files: the
    ground truth every live arm must reproduce byte-for-byte."""
    mux = _mk_mux(store, topo)
    for job_id in chunk_lists:
        mux.add_job(job_id, _ecfg(ranks))
    stats = FleetReplayer(mux, chunk_bytes=4 << 20).replay_dir(
        logdir, job_workers=1)
    out = sorted(mux.finalize(), key=lambda a: (a.ts, a.job_id, a.seq))
    anoms = [str(fa) for fa in out]
    reclass = sum(1 for fa in out if fa.origin == "fleet")
    sig = (stats.events, dict(sorted(stats.per_job.items())))
    return anoms, sig, reclass


def _assert_equivalent(arm: str, got, oracle) -> None:
    g_anoms, g_sig, g_reclass = got
    o_anoms, o_sig, o_reclass = oracle
    if g_anoms != o_anoms:
        raise AssertionError(
            f"{arm} diagnosis differs from replay_dir: "
            f"live={g_anoms!r} replay={o_anoms!r}")
    if g_sig != o_sig:
        raise AssertionError(
            f"{arm} stats differ from replay_dir: "
            f"live={g_sig!r} replay={o_sig!r}")
    if g_reclass != o_reclass:
        raise AssertionError(
            f"{arm} fleet tier differs from replay_dir: "
            f"{o_reclass} vs {g_reclass} reclassifications")


def _pct(sorted_vals: list, q: float) -> float:
    if not sorted_vals:
        return float("nan")
    i = min(int(q * len(sorted_vals)), len(sorted_vals) - 1)
    return sorted_vals[i]


def _wait(pred, timeout_s: float = 120.0) -> None:
    deadline = time.monotonic() + timeout_s
    while not pred():
        if time.monotonic() > deadline:
            raise TimeoutError("live bench: service did not drain in time")
        time.sleep(0.005)


def bench_socket(jobs: int, ranks: int, steps: int,
                 worker_kind: str) -> dict:
    cfg = get_config("llama-20b-paper")
    prog = program_from_config(cfg, num_chips=ranks)
    store = _learned_store(prog, ranks)
    chunk_lists, topo, total_events = _make_fleet(prog, jobs, ranks, steps)
    label = f"{jobs}j_{ranks}r"
    leaver = sorted(chunk_lists)[0]

    logdir = tempfile.mkdtemp(prefix="flare_live_bench_")
    try:
        _write_logs(logdir, chunk_lists)
        oracle = _oracle(logdir, store, topo, chunk_lists, ranks)

        arrivals: list = []      # (FleetAnomaly, arrival_monotonic)
        svc = FleetService(
            _mk_mux(store, topo),
            ServiceConfig(port=0, worker_kind=worker_kind,
                          drain_interval_s=0.01,
                          default_engine=_ecfg(ranks)),
            on_anomaly=lambda fa, t: arrivals.append((fa, t))).start()
        cl = LiveClient("127.0.0.1", svc.port)
        for job_id in sorted(chunk_lists):
            cl.hello(job_id, topology=topo[job_id])

        # stream round-robin (concurrent jobs), one frame per step; the
        # leaver finishes first, BYEs mid-run, then a straggler frame
        # tests the graceful-leave drop path
        frames = {j: [(int(c.step[0]), trace_store.encode_batch_bytes(c))
                      for c in chunks]
                  for j, chunks in chunk_lists.items()}
        t_sent: dict = {}
        t0 = time.monotonic()
        pending = {j: list(f) for j, f in frames.items()}
        byed = False
        while any(pending.values()):
            for job_id in sorted(pending):
                if pending[job_id]:
                    step, payload = pending[job_id].pop(0)
                    t_sent[(job_id, step)] = time.monotonic()
                    cl.send_batch(job_id, payload)
            if not pending[leaver] and not byed:
                byed = True
                cl.bye(leaver)
                cl.send_batch(leaver, frames[leaver][-1][1])  # straggler
        for job_id in sorted(chunk_lists):
            if job_id != leaver:
                cl.bye(job_id)
        cl.close()
        # sustained rate: until every frame is ingested AND every job's
        # pipeline has drained (BYE -> departed covers diagnosis too)
        _wait(lambda: svc.stats.events >= total_events and all(
            svc.mux.job(j).departed for j in chunk_lists))
        elapsed = time.monotonic() - t0
        svc.finalize()

        got = sorted((fa for fa, _ in arrivals),
                     key=lambda a: (a.ts, a.job_id, a.seq))
        sig = (svc.stats.events, dict(sorted(svc.stats.per_job.items())))
        reclass = sum(1 for fa in got if fa.origin == "fleet")
        _assert_equivalent(f"live-socket[{worker_kind}]",
                           ([str(fa) for fa in got], sig, reclass), oracle)
        counters = svc.telemetry.snapshot()["counters"]
        dropped = counters.get("serve.dropped_frames", 0)
        departed_rows = counters.get(
            f"fleet.departed_rows{{job={leaver}}}", 0)
        if departed_rows != len(chunk_lists[leaver][-1]):
            raise AssertionError(
                f"graceful leave: straggler not counted "
                f"({departed_rows} rows)")
    finally:
        shutil.rmtree(logdir, ignore_errors=True)

    lat_ms = sorted(
        (t - t_sent[(fa.job_id, int(fa.anomaly.step))]) * 1e3
        for fa, t in arrivals
        if (fa.job_id, int(fa.anomaly.step)) in t_sent)
    p50, p99 = _pct(lat_ms, 0.50), _pct(lat_ms, 0.99)
    evs = total_events / elapsed
    emit(f"live/socket_{worker_kind}_{label}", 1e6 / evs,
         f"{evs / 1e6:.2f}Mev_s;p50_ms={p50:.1f};p99_ms={p99:.1f};"
         f"anomalies={len(got)};reclassified={reclass};"
         f"dropped={dropped};equivalent=TRUE;leave=TRUE")
    return {
        "jobs": jobs, "ranks": ranks, "steps": steps,
        "events": total_events, "worker_kind": worker_kind,
        "ingest_events_per_s": evs,
        "latency_p50_ms": p50, "latency_p99_ms": p99,
        "latency_samples": len(lat_ms),
        "anomalies": len(got), "fleet_reclassified": reclass,
        "diagnosis_byte_equivalent": True,
        "graceful_leave_correct": True,
    }


def bench_tail(jobs: int, ranks: int, steps: int) -> dict:
    cfg = get_config("llama-20b-paper")
    prog = program_from_config(cfg, num_chips=ranks)
    store = _learned_store(prog, ranks)
    chunk_lists, topo, total_events = _make_fleet(prog, jobs, ranks, steps)
    label = f"{jobs}j_{ranks}r"

    logdir = tempfile.mkdtemp(prefix="flare_live_tail_bench_")
    try:
        _write_logs(logdir, chunk_lists)
        oracle = _oracle(logdir, store, topo, chunk_lists, ranks)

        got: list = []
        svc = FleetService(
            _mk_mux(store, topo),
            ServiceConfig(port=None, tail_dir=logdir, tail_poll_s=0.005,
                          drain_interval_s=0.01,
                          default_engine=_ecfg(ranks)),
            on_anomaly=lambda fa, t: got.append(fa))
        for job_id in chunk_lists:     # tier needs topology before resolve
            svc.mux.set_topology(job_id, **topo[job_id])
        t0 = time.monotonic()
        svc.start()
        _wait(lambda: svc.tailer.stats.events >= total_events)
        elapsed = time.monotonic() - t0
        svc.finalize()

        out = sorted(got, key=lambda a: (a.ts, a.job_id, a.seq))
        sig = (svc.tailer.stats.events,
               dict(sorted(svc.tailer.stats.per_job.items())))
        reclass = sum(1 for fa in out if fa.origin == "fleet")
        _assert_equivalent("live-tail",
                           ([str(fa) for fa in out], sig, reclass), oracle)
    finally:
        shutil.rmtree(logdir, ignore_errors=True)

    evs = total_events / elapsed
    emit(f"live/tail_{label}", 1e6 / evs,
         f"{evs / 1e6:.2f}Mev_s;events={total_events};"
         f"anomalies={len(out)};reclassified={reclass};equivalent=TRUE")
    return {
        "jobs": jobs, "ranks": ranks, "steps": steps,
        "events": total_events,
        "tail_events_per_s": evs,
        "anomalies": len(out), "fleet_reclassified": reclass,
        "diagnosis_byte_equivalent": True,
    }


def bench_chaos(jobs: int, ranks: int, steps: int,
                worker_kind: str) -> dict:
    """Kill-and-restore equivalence gate: checkpoint mid-stream, kill
    abruptly, plant torn/garbage checkpoints above the good one, restore
    into a fresh service, finish the run — and require the stitched
    anomaly stream to be indistinguishable from never having crashed."""
    cfg = get_config("llama-20b-paper")
    prog = program_from_config(cfg, num_chips=ranks)
    store = _learned_store(prog, ranks)
    chunk_lists, topo, total_events = _make_fleet(prog, jobs, ranks, steps)
    label = f"{jobs}j_{ranks}r"

    # deterministic kill point: only the first half of each job's
    # segments exist when the checkpoint is cut, the rest land on disk
    # while the first service is dead
    first = {j: c[:len(c) // 2] for j, c in chunk_lists.items()}
    rest = {j: c[len(c) // 2:] for j, c in chunk_lists.items()}
    half_events = sum(len(c) for cs in first.values() for c in cs)

    logdir = tempfile.mkdtemp(prefix="flare_live_chaos_")
    ckptdir = os.path.join(logdir, "_ckpt")
    scfg = ServiceConfig(port=None, tail_dir=logdir, tail_poll_s=0.005,
                         drain_interval_s=0.01, worker_kind=worker_kind,
                         default_engine=_ecfg(ranks),
                         checkpoint_dir=ckptdir,
                         checkpoint_on_finalize=False)
    try:
        _write_logs(logdir, first)
        arrivals1: list = []
        svc1 = FleetService(
            _mk_mux(store, topo), scfg,
            on_anomaly=lambda fa, t: arrivals1.append(fa)).start()
        _wait(lambda: svc1.tailer.stats.events >= half_events)
        meta = svc1.checkpoint()
        svc1.kill()
        emitted = meta["anomalies_emitted"]
        pre = arrivals1[:emitted]
        if len(pre) != emitted:
            raise AssertionError(
                f"chaos[{worker_kind}]: checkpoint claims {emitted} "
                f"anomalies but only {len(pre)} were delivered")
        if meta["tail_bytes_decoded"] <= 0:
            raise AssertionError(
                f"chaos[{worker_kind}]: checkpoint cut before any tail "
                "progress — kill point is not mid-stream")

        # the crashed service never saw these
        _write_logs(logdir, rest)
        oracle = _oracle(logdir, store, topo, chunk_lists, ranks)
        full_bytes = sum(
            os.path.getsize(os.path.join(logdir, f))
            for f in os.listdir(logdir) if f.endswith(".fcs"))

        # plant corruption ABOVE the good generation: restore must skip
        # back past both, never misparse either
        with open(meta["path"], "rb") as f:
            good = f.read()
        with open(os.path.join(ckptdir, "ckpt-99999990.flc"), "wb") as f:
            f.write(b"\xde\xad\xbe\xef garbage, not a checkpoint " * 64)
        with open(os.path.join(ckptdir, "ckpt-99999991.flc"), "wb") as f:
            f.write(good[:max(len(good) // 2, 16)])     # torn mid-write

        arrivals2: list = []
        svc2 = FleetService(
            _mk_mux(store, topo), scfg,
            on_anomaly=lambda fa, t: arrivals2.append(fa))
        t0 = time.monotonic()
        meta2 = svc2.restore()
        load_ms = (time.monotonic() - t0) * 1e3
        if meta2 is None or meta2["generation"] != meta["generation"]:
            raise AssertionError(
                f"chaos[{worker_kind}]: restored "
                f"{meta2 and meta2['generation']}, wanted generation "
                f"{meta['generation']} (corrupt ones must be skipped)")
        if len(meta2["skipped"]) < 2:
            raise AssertionError(
                f"chaos[{worker_kind}]: planted 2 corrupt checkpoints, "
                f"skipped only {meta2['skipped']!r}")
        svc2.start()
        _wait(lambda: svc2.tailer.stats.events >= total_events)
        recovery_ms = (time.monotonic() - t0) * 1e3
        svc2.finalize()

        # suffix-only replay, proven by byte accounting: every spill
        # byte decoded exactly once across the two incarnations
        suffix = full_bytes - meta["tail_bytes_decoded"]
        if svc2.tailer.stats.bytes_decoded != full_bytes:
            raise AssertionError(
                f"chaos[{worker_kind}]: {svc2.tailer.stats.bytes_decoded}"
                f" bytes decoded across incarnations, disk holds "
                f"{full_bytes} — restore re-decoded or skipped data")
        if not 0 < suffix < full_bytes:
            raise AssertionError(
                f"chaos[{worker_kind}]: suffix {suffix}B of {full_bytes}B"
                " — replay after restore was not strictly partial")

        merged = sorted(pre + arrivals2,
                        key=lambda a: (a.ts, a.job_id, a.seq))
        sig = (svc2.tailer.stats.events,
               dict(sorted(svc2.tailer.stats.per_job.items())))
        reclass = sum(1 for fa in merged if fa.origin == "fleet")
        _assert_equivalent(f"chaos[{worker_kind}]",
                           ([str(fa) for fa in merged], sig, reclass),
                           oracle)
    finally:
        shutil.rmtree(logdir, ignore_errors=True)

    emit(f"live/chaos_{worker_kind}_{label}", recovery_ms * 1e3,
         f"recovery_ms={recovery_ms:.1f};load_ms={load_ms:.1f};"
         f"suffix_bytes={suffix};full_bytes={full_bytes};"
         f"skipped_ckpts={len(meta2['skipped'])};"
         f"anomalies={len(merged)};reclassified={reclass};"
         f"equivalent=TRUE")
    return {
        "jobs": jobs, "ranks": ranks, "steps": steps,
        "events": total_events, "worker_kind": worker_kind,
        "recovery_ms": recovery_ms, "checkpoint_load_ms": load_ms,
        "suffix_bytes": suffix, "full_bytes": full_bytes,
        "checkpoint_bytes": meta["bytes"],
        "corrupt_checkpoints_skipped": len(meta2["skipped"]),
        "anomalies": len(merged), "fleet_reclassified": reclass,
        "diagnosis_byte_equivalent": True,
    }


def main(quick: bool = False, chaos_only: bool = False):
    results = {}
    jobs, ranks, steps = (4, 16, 6) if quick else (8, 64, 8)
    scale = f"{jobs}x{ranks}x{steps}"
    if not chaos_only:
        for kind in ("inline", "process"):
            results[f"socket_{kind}_{scale}"] = bench_socket(
                jobs, ranks, steps, worker_kind=kind)
        results[f"tail_{scale}"] = bench_tail(jobs, ranks, steps)
    if chaos_only or not quick:     # CI runs the chaos gate as its own arm
        for kind in ("inline", "process"):
            results[f"chaos_{kind}_{scale}"] = bench_chaos(
                jobs, ranks, steps, worker_kind=kind)
    merge_bench_json(OUT_JSON, results)
    emit("live/json", 0.0, f"merged={OUT_JSON}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small scale for CI smoke runs")
    ap.add_argument("--chaos-quick", action="store_true",
                    help="small scale, kill-and-restore gate only")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(quick=args.quick or args.chaos_quick,
         chaos_only=args.chaos_quick)
