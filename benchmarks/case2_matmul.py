"""Fig 12 / Case-2: FFN matmul FLOPS before/after alignment padding.

Paper: migrating Llama-80B FSDP->Megatron TP=4 changed the FFN weight from
[8192 x 33936] to [8192 x 8484]; 8484 is not 128-aligned, the kernel lost
65.3% FLOPS, and the fix (pad to 8512) recovered it (job MFU 27% -> 36%).

Two measurements:
  * modeled-TPU: MXU tile-quantization efficiency N / (ceil(N/128)*128) and
    the (empirical, from the paper) partial-tile penalty — this is the
    structural effect the layout advisor reasons about;
  * measured-CPU: wall time of XLA matmul at both shapes (reduced M/K) and
    of the Pallas padded_matmul kernel (interpret), demonstrating the fix's
    correctness at the exact shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import emit, time_it
from repro.core.regression import layout_advice
from repro.kernels.padded_matmul.ops import padded_matmul
from repro.kernels.padded_matmul.ref import matmul_ref


def mxu_efficiency(n: int, tile: int = 128) -> float:
    full = (n // tile) * tile
    eff_full = full / n
    # partial tile runs at the paper-observed degraded rate
    return eff_full + (n - full) / n * 0.35 if n % tile else 1.0


def main():
    # ---- modeled TPU effect -------------------------------------------- #
    for n in (33936, 8484, 8512):
        adv = layout_advice((8192, n))
        eff = mxu_efficiency(n)
        emit(f"case2/modeled_N{n}", 0.0,
             f"mxu_tile_eff={eff:.3f};aligned={adv is None};"
             + (f"advice_pad_to={adv['padded_dims'][0]}" if adv else ""))

    # ---- measured (reduced shapes, CPU XLA) ----------------------------- #
    M, K = 256, 512
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    for n in (848, 852, 896):  # 848=aligned/16? use 832? keep misaligned 852
        b = jnp.asarray(rng.standard_normal((K, n)), jnp.float32)
        f = jax.jit(lambda x, y: x @ y)
        t = time_it(lambda: jax.block_until_ready(f(a, b)), repeat=5)
        emit(f"case2/xla_cpu_N{n}", t * 1e6, f"gflops={2 * M * K * n / t / 1e9:.1f}")

    # ---- Pallas padded kernel correctness at the paper's exact N -------- #
    K2 = 128
    a2 = jnp.asarray(rng.standard_normal((128, K2)), jnp.float32)
    b2 = jnp.asarray(rng.standard_normal((K2, 8484 // 4)), jnp.float32)
    out = padded_matmul(a2, b2)
    np.testing.assert_allclose(out, matmul_ref(a2, b2), rtol=1e-4, atol=1e-3)
    emit("case2/padded_kernel_correct", 0.0,
         "N=2121(pad->2176)allclose=True;paper_fix=pad_8484_to_8512")


if __name__ == "__main__":
    main()
