"""Fig 10 + Table 2: communication-hang localization latency.

Intra-kernel inspecting is O(1) in cluster size (paper: 29.4-309.2 s,
constant); the NCCL-test baseline grows with #groups (paper: >= 30 min at
thousand-GPU scale).  We (a) verify diagnosis CORRECTNESS on the simulated
ring at each scale, and (b) report the wall-clock cost models side by side.
"""
from __future__ import annotations

import time

from benchmarks._util import emit
from repro.core.inspecting import (diagnose_ring, inspect_cost_model,
                                   probe_search_cost)
from repro.core.timeline import ClusterSimulator, Injection, SimOp

SCALES = [16, 64, 256, 1024, 2048]


def main():
    for n in SCALES:
        fault = (7 * n) // 16
        prog = [SimOp("allreduce[0]", "comm", 1e-3, bytes=1 << 20)]
        sim = ClusterSimulator(n, prog, injections=[
            Injection(kind="hang", ranks=(fault,), at_step=0)])
        t0 = time.perf_counter()
        sim.run(1)
        d = diagnose_ring(sim.hang.ring_progress)
        engine_us = (time.perf_counter() - t0) * 1e6
        assert fault in d.machines, (n, fault, d)
        flare_s = inspect_cost_model(n, "SIMPLE", inter_server=True)
        probe_s = probe_search_cost(n)
        emit(f"hang/{n}gpus", engine_us,
             f"flare_wallclock_s={flare_s:.0f};probe_baseline_s={probe_s:.0f};"
             f"correct=True")
    # protocol sweep at fixed scale (paper Fig 10 bars)
    for proto in ("SIMPLE", "LL128", "LL"):
        for inter in (False, True):
            c = inspect_cost_model(1024, proto, inter)
            emit(f"hang/protocol_{proto}_{'inter' if inter else 'intra'}",
                 c * 1e6, f"s={c:.1f};paper_band=29.4-309.2")


if __name__ == "__main__":
    main()
