"""Fig 11: issue-latency distributions — Healthy vs Unhealthy-GC vs
Unhealthy-Sync on Llama-20B at 256 simulated ranks.

The paper's claim: healthy CDF rises ~linearly; GC/Sync CDFs rise much
faster (latencies compressed), with GC worse than Sync.  We report CDF
quantiles + the normalized W1 distances the detector uses.
"""
from __future__ import annotations

import numpy as np

from benchmarks._util import emit
from repro.configs import get_config
from repro.core.metrics import aggregate_step, steps_in
from repro.core.timeline import ClusterSimulator, Injection, program_from_config
from repro.core.wasserstein import normalized_w1

N = 256


def _latencies(injections, seed=0, steps=3):
    cfg = get_config("llama-20b-paper")
    prog = program_from_config(cfg, num_chips=N)
    sim = ClusterSimulator(N, prog, seed=seed, injections=injections)
    ev = sim.run_batch(steps)   # columnar: aggregate via vectorized sweep
    lats = []
    for s in steps_in(ev)[1:]:
        m = aggregate_step(ev, s)
        lats.append(m.issue_latencies)
    return np.concatenate(lats)


def main():
    healthy = _latencies([])
    gc = _latencies([Injection(kind="gc", duration=0.35, period_ops=5)])
    sync = _latencies([Injection(kind="sync_after_comm")])

    qs = [0.1, 0.25, 0.5, 0.75, 0.9]
    for name, lat in [("healthy", healthy), ("unhealthy_gc", gc),
                      ("unhealthy_sync", sync)]:
        quant = np.quantile(lat, qs)
        w1 = normalized_w1(lat, healthy)
        emit(f"issue_dist/{name}", float(np.median(lat)) * 1e6,
             "cdf_q=" + "/".join(f"{q * 1e3:.0f}ms" for q in quant)
             + f";W1_vs_healthy={w1:.3f}")
    # robust Fig-11 claims: BOTH unhealthy CDFs are compressed vs healthy
    # and sit far past the learned W1 threshold.  (The paper additionally
    # orders GC below Sync; that ordering depends on the GC-pause
    # magnitude regime — in our bounded-queue timeline model, synchronized
    # sync-stalls form the latency floor.  Documented in EXPERIMENTS.md.)
    assert np.median(gc) < np.median(healthy)
    assert np.median(sync) < np.median(healthy)
    assert normalized_w1(gc, healthy) > 0.15
    assert normalized_w1(sync, healthy) > 0.15
    med_h = float(np.median(healthy))
    emit("issue_dist/ordering", med_h * 1e6,
         "unhealthy_medians<healthy=True;W1>threshold=True (paper Fig 11)")


if __name__ == "__main__":
    main()
