"""Fig 8: FLARE runtime latency overhead across backends/models.

The paper measures 0.43% mean overhead on 1024 H800s (LLM backends) and
1.02% for TorchRec.  Here: reduced configs of three backend families
(dense / MoE / SSM), trained with and without the daemon attached, on CPU.
"""
from __future__ import annotations

import numpy as np

from benchmarks._util import emit
from repro.configs import get_reduced
from repro.optim.adamw import AdamWConfig
from repro.runtime.train import RunConfig, Trainer

MODELS = [("llama3.2-1b", "dense"), ("dbrx-132b", "moe"),
          ("mamba2-780m", "ssm")]


def _steps_per_s(arch: str, flare: bool, steps: int = 14) -> float:
    cfg = get_reduced(arch)
    run = RunConfig(model=cfg, global_batch=4, seq_len=64, steps=steps,
                    peak_lr=1e-3, opt=AdamWConfig(lr=1e-3), flare=flare)
    hist = Trainer(run).train()
    times = [h["step_time_s"] for h in hist[3:]]  # skip compile steps
    return float(np.median(times))


def main() -> list[tuple]:
    out = []
    for arch, family in MODELS:
        base = _steps_per_s(arch, flare=False)
        traced = _steps_per_s(arch, flare=True)
        overhead = (traced - base) / base * 100.0
        emit(f"overhead/{family}", traced * 1e6,
             f"flare_overhead_pct={overhead:.2f};paper=0.43")
        out.append((family, overhead))
    return out


if __name__ == "__main__":
    main()
