"""Fleet multiplexer throughput: concurrent jobs, incremental diagnosis,
and chunked JSONL replay.

Measures, per (jobs x ranks x steps) scale:
  * fleet-incremental: round-robin per-step chunk ingest of every job into
    a ``FleetMultiplexer`` + incremental per-step evaluation + finalize —
    the paper's continuous-operation mode (aggregate events/s across jobs);
  * replay-decode: chunked/parallel ``EventBatch.from_jsonl_chunked``
    vs the line-by-line decoder on one job's log;
  * replay-e2e: ``FleetReplayer.replay_dir`` over every job's JSONL log
    into a fresh multiplexer (decode + ingest + incremental diagnosis);
  * crossjob: a rack-degradation fleet (half the jobs jittering on shared
    racks) with the ``cross_job_failslow`` fleet detector registered —
    the cross-job correlation tier's overhead on the same ingest path,
    plus the count of INFRASTRUCTURE reclassifications it emits;
  * parallel-replay: serial (``job_workers=1``) vs parallel (one worker
    per job) ``replay_dir`` over FCS logs, for BOTH worker kinds —
    ``thread`` (ISSUE 5, GIL-bound) and ``process`` (ISSUE 8, FCS-over-
    IPC job workers) — asserting byte-equivalent diagnosis: anomaly
    stream, ``ReplayStats`` signature, and ``cross_job_failslow``
    fleet-tier reclassifications all identical to serial.

Acceptance (ISSUE 2): >= 8 concurrent jobs at 256+ ranks each with
incremental diagnosis sustaining >= 1 Mev/s aggregate.  Results merge into
``BENCH_fleet.json`` keyed by scale so the trajectory accumulates.

    PYTHONPATH=src python benchmarks/fleet.py [--quick]
"""
from __future__ import annotations

import argparse
import os
import shutil
import tempfile
import time

from benchmarks._util import emit, merge_bench_json
from repro import store as trace_store
from repro.configs import get_config
from repro.core.engine import DiagnosticEngine, EngineConfig
from repro.core.history import HistoryStore
from repro.core.timeline import (ClusterSimulator, Injection,
                                 program_from_config)
from repro.fleet import FleetConfig, FleetMultiplexer, FleetReplayer

OUT_JSON = "BENCH_fleet.json"

SCENARIOS = [
    ("healthy", lambda n: []),
    ("gc", lambda n: [Injection(kind="gc", duration=0.05, period_ops=4)]),
    ("underclock", lambda n: [Injection(kind="underclock",
                                        ranks=(7 % n,), factor=2.4,
                                        start_step=3)]),
    ("jitter", lambda n: [Injection(kind="network_jitter", factor=3.0,
                                    start_step=3)]),
]


def _make_fleet(prog, jobs: int, ranks: int, steps: int):
    """Per-job per-step chunk lists + total event count (emission is not
    part of the timed fleet path)."""
    chunk_lists, total = {}, 0
    for i in range(jobs):
        name, inj_fn = SCENARIOS[i % len(SCENARIOS)]
        sim = ClusterSimulator(ranks, prog, seed=100 + i,
                               injections=inj_fn(ranks))
        batch = sim.run_batch(steps)
        order, uniq, bounds = batch.step_index()
        chunk_lists[f"job{i:02d}-{name}"] = \
            [batch.take(order[bounds[j]:bounds[j + 1]])
             for j in range(uniq.size)]
        total += len(batch)
    return chunk_lists, total


def bench_scale(jobs: int, ranks: int, steps: int) -> dict:
    # ---- healthy profile (one-off per backend/scale, not timed) ------- #
    cfg = get_config("llama-20b-paper")
    prog = program_from_config(cfg, num_chips=ranks)
    store = HistoryStore()
    learner = DiagnosticEngine(
        EngineConfig(backend="dense-train", num_ranks=ranks), store)
    learner.ingest_batch(ClusterSimulator(ranks, prog, seed=1).run_batch(3))
    learner.learn_healthy()

    # ---- pre-generate every job's per-step chunks (emission not timed)  #
    chunk_lists, total_events = _make_fleet(prog, jobs, ranks, steps)
    label = f"{jobs}j_{ranks}r"

    # ---- fleet incremental: ingest + per-step diagnosis --------------- #
    # best of 3 repeats: the rate is deterministic work / wall time, and
    # shared-CPU noise only ever slows a run down
    inc_s, fleet_anoms = float("inf"), 0
    for _ in range(3):
        mux = FleetMultiplexer(FleetConfig(watermark_delay=1),
                               history=store)
        for job_id in chunk_lists:
            mux.add_job(job_id, EngineConfig(backend="dense-train",
                                             num_ranks=ranks))
        t0 = time.perf_counter()
        pending = {j: list(c) for j, c in chunk_lists.items()}
        while any(pending.values()):
            for job_id, chunks in pending.items():
                if chunks:
                    mux.ingest(job_id, chunks.pop(0))
        fleet_anoms = len(mux.finalize())
        inc_s = min(inc_s, time.perf_counter() - t0)
    inc_evs = total_events / inc_s
    emit(f"fleet/incremental_{label}", 1e6 / inc_evs,
         f"{inc_evs / 1e6:.2f}Mev_s;events={total_events};"
         f"anomalies={fleet_anoms}")

    # ---- JSONL logs for the replay paths (write not timed) ------------ #
    logdir = tempfile.mkdtemp(prefix="flare_fleet_bench_")
    try:
        log_events = {}
        for job_id, chunks in chunk_lists.items():
            path = os.path.join(logdir, f"{job_id}.jsonl")
            n = 0
            for c in chunks:
                trace_store.write_trace(c, path)
                n += len(c)
            log_events[job_id] = n
        one = os.path.join(logdir, next(iter(chunk_lists)) + ".jsonl")
        one_n = log_events[next(iter(chunk_lists))]

        t0 = time.perf_counter()
        trace_store.read_jsonl(one)
        line_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        trace_store.read_jsonl_chunked(one, chunk_bytes=4 << 20)
        chunk_s = time.perf_counter() - t0
        # process-executor chunk decode (serial_below=0 forces the
        # chunked path even on bench-sized files; spawn cost is real
        # and recorded — it amortizes on multi-GB logs)
        t0 = time.perf_counter()
        trace_store.read_jsonl_chunked(one, chunk_bytes=256 << 10,
                                       executor="process", serial_below=0)
        proc_s = time.perf_counter() - t0
        line_evs, chunk_evs = one_n / line_s, one_n / chunk_s
        proc_evs = one_n / proc_s
        emit(f"fleet/decode_line_{label}", 1e6 / line_evs,
             f"{line_evs / 1e6:.2f}Mev_s;events={one_n}")
        emit(f"fleet/decode_chunked_{label}", 1e6 / chunk_evs,
             f"{chunk_evs / 1e6:.2f}Mev_s;events={one_n}")
        emit(f"fleet/decode_chunked_proc_{label}", 1e6 / proc_evs,
             f"{proc_evs / 1e6:.2f}Mev_s;events={one_n}")

        rmux = FleetMultiplexer(FleetConfig(watermark_delay=1),
                                history=store)
        for job_id in chunk_lists:
            rmux.add_job(job_id, EngineConfig(backend="dense-train",
                                              num_ranks=ranks))
        rstats = FleetReplayer(rmux, chunk_bytes=4 << 20).replay_dir(logdir)
        emit(f"fleet/replay_e2e_{label}", 1e6 / rstats.events_per_s,
             f"{rstats.events_per_s / 1e6:.2f}Mev_s;"
             f"events={rstats.events};files={rstats.files}")
    finally:
        shutil.rmtree(logdir, ignore_errors=True)

    return {
        "jobs": jobs, "ranks": ranks, "steps": steps,
        "events": total_events,
        "anomalies": fleet_anoms,
        "incremental_diagnose_events_per_s": inc_evs,
        "jsonl_decode_line_events_per_s": line_evs,
        "jsonl_decode_chunked_events_per_s": chunk_evs,
        "jsonl_decode_chunked_process_events_per_s": proc_evs,
        "replay_e2e_events_per_s": rstats.events_per_s,
    }


def _stats_sig(stats) -> tuple:
    """The deterministic part of ``ReplayStats`` (everything but wall
    time and worker bookkeeping) — must be identical across worker
    kinds for the same directory."""
    return (stats.files, stats.events, stats.skipped_lines,
            stats.corrupt_files, stats.skipped_segments,
            dict(sorted(stats.per_job.items())))


def bench_parallel_replay(jobs: int, ranks: int, steps: int,
                          worker_kind: str = "thread") -> dict:
    """Serial vs parallel ``replay_dir`` over per-job FCS logs (decode is
    ~free, so this times the diagnosis pipeline itself), ASSERTING the
    diagnosis is byte-equivalent: anomaly stream (``str(fa)`` includes
    the fleet-tier origin), ``ReplayStats`` signature, and the
    ``cross_job_failslow`` reclassifications all identical to serial.

    ``worker_kind="thread"`` scaling is bounded by cores AND the GIL
    share of per-step detector work (~1.08x at 2 threads/2 cores);
    ``"process"`` ships each job's pipeline into a worker process over
    FCS-encoded IPC (``repro.fleet.ipc``) and is bounded by cores only.
    """
    cfg = get_config("llama-20b-paper")
    prog = program_from_config(cfg, num_chips=ranks)
    store = HistoryStore()
    learner = DiagnosticEngine(
        EngineConfig(backend="dense-train", num_ranks=ranks), store)
    learner.ingest_batch(ClusterSimulator(ranks, prog, seed=1).run_batch(3))
    learner.learn_healthy()

    # rack-degradation fleet (first half jitters, two jobs per rack) so
    # the fleet correlation tier is part of the equivalence surface
    chunk_lists, total_events, topo = {}, 0, {}
    n_slow = max(jobs // 2, 2)
    for i in range(jobs):
        inj = [Injection(kind="network_jitter", factor=3.0, start_step=3)] \
            if i < n_slow else []
        sim = ClusterSimulator(ranks, prog, seed=100 + i, injections=inj)
        batch = sim.run_batch(steps)
        job_id = f"pr{i:02d}-{'jitter' if i < n_slow else 'healthy'}"
        order, uniq, bounds = batch.step_index()
        chunk_lists[job_id] = [batch.take(order[bounds[j]:bounds[j + 1]])
                               for j in range(uniq.size)]
        topo[job_id] = {"rack": f"rack{i // 2}", "switch": f"sw{i // 4}"}
        total_events += len(batch)
    label = f"{jobs}j_{ranks}r"

    logdir = tempfile.mkdtemp(prefix="flare_preplay_bench_")
    try:
        for job_id, chunks in chunk_lists.items():
            path = os.path.join(logdir, f"{job_id}.fcs")
            for c in chunks:           # one segment per step, daemon-shaped
                trace_store.write_trace(c, path, codec="fcs")

        def _run(jw, kind):
            best, anoms, sig, reclass = float("inf"), None, None, 0
            for _ in range(3):
                mux = FleetMultiplexer(FleetConfig(
                    watermark_delay=1,
                    fleet_detectors=["cross_job_failslow"],
                    topology=topo), history=store)
                for job_id in chunk_lists:
                    mux.add_job(job_id, EngineConfig(
                        backend="dense-train", num_ranks=ranks))
                t0 = time.perf_counter()
                stats = FleetReplayer(mux, chunk_bytes=4 << 20).replay_dir(
                    logdir, job_workers=jw, worker_kind=kind)
                dt = time.perf_counter() - t0
                assert stats.events == total_events
                if dt < best:
                    best = dt
                out = mux.poll()
                anoms = [str(fa) for fa in out]
                reclass = sum(1 for fa in out if fa.origin == "fleet")
                sig = _stats_sig(stats)
            return best, anoms, sig, reclass

        serial_s, serial_anoms, serial_sig, serial_reclass = \
            _run(1, "thread")
        cores = os.cpu_count() or 1
        if worker_kind == "process":
            # one worker per job, floor of 2: the process path must
            # demonstrate real concurrency even when the pool is tiny
            # (on a 1-core box this records honest contention, not a
            # fabricated speedup — cores is in the row)
            par_workers = min(jobs, max(cores, 2))
        else:
            # threads oversubscribing a small box just measure GIL
            # convoying — cap at the cores that can actually run them
            par_workers = min(jobs, cores)
        par_s, par_anoms, par_sig, par_reclass = \
            _run(par_workers, worker_kind)
        # hard equivalence gate (ISSUE 5 / ISSUE 8): anomaly stream,
        # replay stats, and fleet-tier reclassifications all identical
        if par_anoms != serial_anoms:
            raise AssertionError(
                f"{worker_kind} replay diagnosis differs from serial: "
                f"serial={serial_anoms!r} parallel={par_anoms!r}")
        if par_sig != serial_sig:
            raise AssertionError(
                f"{worker_kind} replay stats differ from serial: "
                f"serial={serial_sig!r} parallel={par_sig!r}")
        if par_reclass != serial_reclass:
            raise AssertionError(
                f"{worker_kind} replay fleet tier differs from serial: "
                f"{serial_reclass} vs {par_reclass} reclassifications")
    finally:
        shutil.rmtree(logdir, ignore_errors=True)

    serial_evs, par_evs = total_events / serial_s, total_events / par_s
    speedup = par_evs / serial_evs
    key = f"fleet/parallel_replay_{label}" if worker_kind == "thread" \
        else f"fleet/parallel_replay_process_{label}"
    emit(key, 1e6 / par_evs,
         f"{par_evs / 1e6:.2f}Mev_s;serial={serial_evs / 1e6:.2f}Mev_s;"
         f"{speedup:.2f}x;kind={worker_kind};workers={par_workers};"
         f"cores={cores};equivalent=TRUE")
    return {
        "jobs": jobs, "ranks": ranks, "steps": steps,
        "events": total_events, "cores": cores,
        "job_workers": par_workers,
        "worker_kind": worker_kind,
        "replay_serial_events_per_s": serial_evs,
        "replay_parallel_events_per_s": par_evs,
        "parallel_speedup": speedup,
        "diagnosis_byte_equivalent": True,
        "fleet_reclassified": serial_reclass,
        "anomalies": len(serial_anoms),
    }


def bench_crossjob(jobs: int, ranks: int, steps: int) -> dict:
    """Rack-degradation fleet: the first half of the jobs jitter on shared
    racks (two jobs per rack), the rest stay healthy.  Times the same
    round-robin ingest WITH the fleet-scope correlator registered and
    checks it actually reclassifies every afflicted rack."""
    cfg = get_config("llama-20b-paper")
    prog = program_from_config(cfg, num_chips=ranks)
    store = HistoryStore()
    learner = DiagnosticEngine(
        EngineConfig(backend="dense-train", num_ranks=ranks), store)
    learner.ingest_batch(ClusterSimulator(ranks, prog, seed=1).run_batch(3))
    learner.learn_healthy()

    chunk_lists, total_events, topo = {}, 0, {}
    n_slow = max(jobs // 2, 2)
    for i in range(jobs):
        inj = [Injection(kind="network_jitter", factor=3.0, start_step=3)] \
            if i < n_slow else []
        sim = ClusterSimulator(ranks, prog, seed=300 + i, injections=inj)
        batch = sim.run_batch(steps)
        job_id = f"cj{i:02d}-{'jitter' if i < n_slow else 'healthy'}"
        order, uniq, bounds = batch.step_index()
        chunk_lists[job_id] = [batch.take(order[bounds[j]:bounds[j + 1]])
                               for j in range(uniq.size)]
        topo[job_id] = {"rack": f"rack{i // 2}", "switch": f"sw{i // 4}"}
        total_events += len(batch)
    label = f"{jobs}j_{ranks}r"

    best_s, reclass, fleet_anoms = float("inf"), 0, 0
    for _ in range(3):
        mux = FleetMultiplexer(FleetConfig(
            watermark_delay=1, fleet_detectors=["cross_job_failslow"],
            topology=topo), history=store)
        for job_id in chunk_lists:
            mux.add_job(job_id, EngineConfig(backend="dense-train",
                                             num_ranks=ranks))
        t0 = time.perf_counter()
        pending = {j: list(c) for j, c in chunk_lists.items()}
        while any(pending.values()):
            for job_id, chunks in pending.items():
                if chunks:
                    mux.ingest(job_id, chunks.pop(0))
        out = mux.finalize()
        best_s = min(best_s, time.perf_counter() - t0)
        fleet_anoms = len(out)
        reclass = sum(1 for fa in out if fa.origin == "fleet")
    assert reclass >= 2 * (n_slow // 2), \
        f"correlator reclassified {reclass}, expected >= {2 * (n_slow // 2)}"
    evs = total_events / best_s
    emit(f"fleet/crossjob_{label}", 1e6 / evs,
         f"{evs / 1e6:.2f}Mev_s;events={total_events};"
         f"reclassified={reclass}")
    return {
        "jobs": jobs, "ranks": ranks, "steps": steps,
        "events": total_events,
        "anomalies": fleet_anoms,
        "fleet_reclassified": reclass,
        "crossjob_diagnose_events_per_s": evs,
    }


def main(quick: bool = False, process_replay_only: bool = False):
    results = {}
    pr_jobs, pr_ranks, pr_steps = (4, 64, 6) if quick else (4, 256, 8)
    if not process_replay_only:
        scales = [(4, 64, 4)] if quick else [(8, 256, 8), (12, 256, 8)]
        for jobs, ranks, steps in scales:
            r = bench_scale(jobs, ranks, steps)
            results[f"{jobs}x{ranks}x{steps}"] = r
        cj_jobs, cj_ranks, cj_steps = (4, 64, 6) if quick else (8, 256, 8)
        results[f"crossjob_{cj_jobs}x{cj_ranks}x{cj_steps}"] = \
            bench_crossjob(cj_jobs, cj_ranks, cj_steps)
        results[f"parallel_replay_{pr_jobs}x{pr_ranks}x{pr_steps}"] = \
            bench_parallel_replay(pr_jobs, pr_ranks, pr_steps,
                                  worker_kind="thread")
    results[f"parallel_replay_process_{pr_jobs}x{pr_ranks}x{pr_steps}"] = \
        bench_parallel_replay(pr_jobs, pr_ranks, pr_steps,
                              worker_kind="process")
    merge_bench_json(OUT_JSON, results)
    emit("fleet/json", 0.0, f"merged={OUT_JSON}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small scale for CI smoke runs")
    ap.add_argument("--process-replay-only", action="store_true",
                    help="only the process-sharded replay bench (the CI "
                         "byte-equivalence gate)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(quick=args.quick, process_replay_only=args.process_replay_only)
