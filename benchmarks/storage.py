"""Trace-storage codecs: bytes/event and replay throughput, JSONL vs
FCS v1 vs FCS v2 (compressed archival segments).

Measures, per rank scale:
  * write: bytes/event on disk for each codec (the continuous-tracing
    storage bill — ISSUE 3 target: FCS <= 0.3x JSONL; ISSUE 5 target:
    FCS v2 <= 0.5x v1);
  * decode: full-file -> EventBatch Mev/s for JSONL (line, chunked with
    auto serial fallback, forced chunking, chunked processes), FCS v1
    (memmap segments), and FCS v2 (slab inflate) — the replay bottleneck
    the ROADMAP flagged (ISSUE 3 target: FCS >= 5x JSONL);
  * replay-e2e: ``FleetReplayer.replay_dir`` into a multiplexer with
    incremental diagnosis, per codec plus serial-vs-parallel workers,
    ASSERTING the anomaly streams are byte-equivalent across all of
    them (the FCS files are written from the JSONL-decoded batch, so
    every format carries identical values).

Results merge into ``BENCH_storage.json`` keyed by scale.

    PYTHONPATH=src python benchmarks/storage.py [--quick]
"""
from __future__ import annotations

import argparse
import os
import shutil
import tempfile
import time

from benchmarks._util import emit, merge_bench_json
from repro import store
from repro.configs import get_config
from repro.core.engine import DiagnosticEngine, EngineConfig
from repro.core.history import HistoryStore
from repro.core.timeline import (ClusterSimulator, Injection,
                                 program_from_config)
from repro.fleet import FleetConfig, FleetMultiplexer, FleetReplayer

OUT_JSON = "BENCH_storage.json"

SCENARIOS = [
    ("healthy", lambda n: []),
    ("gc", lambda n: [Injection(kind="gc", duration=0.05, period_ops=4)]),
    ("underclock", lambda n: [Injection(kind="underclock",
                                        ranks=(7 % n,), factor=2.4,
                                        start_step=3)]),
]


def _best(fn, repeat=3):
    """Best-of-N wall time: deterministic work, noise only slows runs."""
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def bench_scale(ranks: int, steps: int, jobs: int) -> dict:
    cfg = get_config("llama-20b-paper")
    prog = program_from_config(cfg, num_chips=ranks)
    hist = HistoryStore()
    learner = DiagnosticEngine(
        EngineConfig(backend="dense-train", num_ranks=ranks), hist)
    learner.ingest_batch(ClusterSimulator(ranks, prog, seed=1).run_batch(3))
    learner.learn_healthy()
    label = f"{ranks}r"

    logdir = tempfile.mkdtemp(prefix="flare_storage_bench_")
    jdir, fdir = os.path.join(logdir, "jsonl"), os.path.join(logdir, "fcs")
    f2dir = os.path.join(logdir, "fcs2")
    os.makedirs(jdir)
    os.makedirs(fdir)
    os.makedirs(f2dir)
    try:
        # ---- write all three codecs (FCS v1/v2 from the JSONL-decoded
        # batch, so every directory carries bit-identical values) ------ #
        total_events = jsonl_bytes = fcs_bytes = fcs2_bytes = 0
        for i in range(jobs):
            name, inj_fn = SCENARIOS[i % len(SCENARIOS)]
            batch = ClusterSimulator(ranks, prog, seed=100 + i,
                                     injections=inj_fn(ranks)
                                     ).run_batch(steps)
            total_events += len(batch)
            jp = os.path.join(jdir, f"job{i:02d}-{name}.jsonl")
            jsonl_bytes += store.write_trace(batch, jp)
            rounded = store.read_jsonl(jp)
            fcs_bytes += store.write_trace(
                rounded, os.path.join(fdir, f"job{i:02d}-{name}.fcs"))
            fcs2_bytes += store.write_trace(
                rounded, os.path.join(f2dir, f"job{i:02d}-{name}.fcs2"),
                codec="fcs2")
        per_ev_jsonl = jsonl_bytes / total_events
        per_ev_fcs = fcs_bytes / total_events
        per_ev_fcs2 = fcs2_bytes / total_events
        size_ratio = fcs_bytes / jsonl_bytes
        v2_ratio = fcs2_bytes / fcs_bytes
        emit(f"storage/bytes_per_event_jsonl_{label}", per_ev_jsonl,
             f"total={jsonl_bytes}")
        emit(f"storage/bytes_per_event_fcs_{label}", per_ev_fcs,
             f"total={fcs_bytes};ratio={size_ratio:.3f}x;target<=0.3x")
        emit(f"storage/bytes_per_event_fcs2_{label}", per_ev_fcs2,
             f"total={fcs2_bytes};vs_v1={v2_ratio:.3f}x;target<=0.5x;"
             f"zstd={store.have_zstd()}")

        # ---- decode throughput: one job's file, full decode ----------- #
        one_j = sorted(os.listdir(jdir))[0]
        one_f = sorted(os.listdir(fdir))[0]
        one_f2 = sorted(os.listdir(f2dir))[0]
        jp, fp = os.path.join(jdir, one_j), os.path.join(fdir, one_f)
        f2p = os.path.join(f2dir, one_f2)
        one_n = len(store.read_jsonl(jp))

        decode = {}
        for key, fn in [
            ("jsonl_line", lambda: store.read_jsonl(jp)),
            # auto-falls back to one serial pass on small files — the
            # mid-scale regression fix; forced chunking stays measurable
            # via serial_below=0
            ("jsonl_chunked", lambda: store.read_jsonl_chunked(
                jp, chunk_bytes=4 << 20)),
            ("jsonl_chunked_forced", lambda: store.read_jsonl_chunked(
                jp, chunk_bytes=4 << 20, serial_below=0)),
            ("jsonl_process", lambda: store.read_jsonl_chunked(
                jp, chunk_bytes=1 << 20, executor="process")),
            ("fcs", lambda: store.read_fcs(fp)),
            ("fcs2", lambda: store.read_fcs(f2p)),
        ]:
            s, out = _best(fn)
            decode[key] = one_n / s
            emit(f"storage/decode_{key}_{label}", 1e6 / decode[key],
                 f"{decode[key] / 1e6:.2f}Mev_s;events={one_n}")
        replay_speedup = decode["fcs"] / decode["jsonl_line"]
        emit(f"storage/fcs_decode_speedup_{label}", 0.0,
             f"{replay_speedup:.1f}x_vs_jsonl_line;target>=5x")

        # ---- replay e2e (decode + ingest + incremental diagnosis) ----- #
        def _replay(directory, job_workers=1):
            mux = FleetMultiplexer(FleetConfig(watermark_delay=1),
                                   history=hist)
            stats = FleetReplayer(mux, chunk_bytes=4 << 20).replay_dir(
                directory, job_workers=job_workers)
            return stats, [str(a) for a in mux.poll()]

        t0 = time.perf_counter()
        sj, anoms_jsonl = _replay(jdir)
        jsonl_e2e = sj.events / (time.perf_counter() - t0)
        t0 = time.perf_counter()
        sf, anoms_fcs = _replay(fdir)
        fcs_e2e = sf.events / (time.perf_counter() - t0)
        t0 = time.perf_counter()
        sf2, anoms_fcs2 = _replay(f2dir)
        fcs2_e2e = sf2.events / (time.perf_counter() - t0)
        t0 = time.perf_counter()
        sp, anoms_par = _replay(fdir, job_workers=jobs)   # parallel
        par_e2e = sp.events / (time.perf_counter() - t0)
        assert sj.events == sf.events == sf2.events == sp.events \
            == total_events
        # hard equivalence gates: across codecs (ISSUE 3) and across
        # serial/parallel replay (ISSUE 5)
        if anoms_jsonl != anoms_fcs or anoms_fcs != anoms_fcs2:
            raise AssertionError(
                "fleet diagnosis differs between codecs: "
                f"jsonl={anoms_jsonl!r} fcs={anoms_fcs!r} "
                f"fcs2={anoms_fcs2!r}")
        if anoms_par != anoms_fcs:
            raise AssertionError(
                "parallel replay diagnosis differs from serial: "
                f"serial={anoms_fcs!r} parallel={anoms_par!r}")
        emit(f"storage/replay_e2e_jsonl_{label}", 1e6 / jsonl_e2e,
             f"{jsonl_e2e / 1e6:.2f}Mev_s;anomalies={len(anoms_jsonl)}")
        emit(f"storage/replay_e2e_fcs_{label}", 1e6 / fcs_e2e,
             f"{fcs_e2e / 1e6:.2f}Mev_s;equivalent=TRUE;"
             f"{fcs_e2e / jsonl_e2e:.1f}x")
        emit(f"storage/replay_e2e_fcs2_{label}", 1e6 / fcs2_e2e,
             f"{fcs2_e2e / 1e6:.2f}Mev_s;equivalent=TRUE;"
             f"{fcs2_e2e / fcs_e2e:.2f}x_vs_v1")
        emit(f"storage/replay_e2e_fcs_parallel_{label}", 1e6 / par_e2e,
             f"{par_e2e / 1e6:.2f}Mev_s;equivalent=TRUE;"
             f"{par_e2e / fcs_e2e:.2f}x_vs_serial;"
             f"workers={sp.job_workers}")
    finally:
        shutil.rmtree(logdir, ignore_errors=True)

    return {
        "ranks": ranks, "steps": steps, "jobs": jobs,
        "events": total_events,
        "bytes_per_event_jsonl": per_ev_jsonl,
        "bytes_per_event_fcs": per_ev_fcs,
        "bytes_per_event_fcs2": per_ev_fcs2,
        "size_ratio_fcs_vs_jsonl": size_ratio,
        "size_ratio_fcs2_vs_fcs": v2_ratio,
        "zstd_available": store.have_zstd(),
        "decode_events_per_s": decode,
        "fcs_decode_speedup_vs_jsonl_line": replay_speedup,
        "replay_e2e_events_per_s": {"jsonl": jsonl_e2e, "fcs": fcs_e2e,
                                    "fcs2": fcs2_e2e,
                                    "fcs_parallel": par_e2e},
        "diagnosis_byte_equivalent": True,
        "anomalies": len(anoms_jsonl),
    }


def main(quick: bool = False):
    scales = [(64, 4, 2)] if quick else [(256, 8, 3), (512, 6, 3)]
    results = {}
    for ranks, steps, jobs in scales:
        results[f"{ranks}r"] = bench_scale(ranks, steps, jobs)
    merge_bench_json(OUT_JSON, results)
    emit("storage/json", 0.0, f"merged={OUT_JSON}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small scale for CI smoke runs")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(quick=args.quick)
