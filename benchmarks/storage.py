"""Trace-storage codecs: bytes/event and replay throughput, JSONL vs FCS.

Measures, per rank scale:
  * write: bytes/event on disk for each codec (the continuous-tracing
    storage bill — ISSUE 3 target: FCS <= 0.3x JSONL);
  * decode: full-file -> EventBatch Mev/s for JSONL (line, chunked
    threads, chunked processes) and FCS (memmap segments) — the replay
    bottleneck the ROADMAP flagged (ISSUE 3 target: FCS >= 5x JSONL);
  * replay-e2e: ``FleetReplayer.replay_dir`` into a multiplexer with
    incremental diagnosis, per codec, ASSERTING the anomaly streams are
    byte-equivalent (the FCS file is written from the JSONL-decoded
    batch, so both formats carry identical values).

Results merge into ``BENCH_storage.json`` keyed by scale.

    PYTHONPATH=src python benchmarks/storage.py [--quick]
"""
from __future__ import annotations

import argparse
import os
import shutil
import tempfile
import time

from benchmarks._util import emit, merge_bench_json
from repro import store
from repro.configs import get_config
from repro.core.engine import DiagnosticEngine, EngineConfig
from repro.core.history import HistoryStore
from repro.core.timeline import (ClusterSimulator, Injection,
                                 program_from_config)
from repro.fleet import FleetConfig, FleetMultiplexer, FleetReplayer

OUT_JSON = "BENCH_storage.json"

SCENARIOS = [
    ("healthy", lambda n: []),
    ("gc", lambda n: [Injection(kind="gc", duration=0.05, period_ops=4)]),
    ("underclock", lambda n: [Injection(kind="underclock",
                                        ranks=(7 % n,), factor=2.4,
                                        start_step=3)]),
]


def _best(fn, repeat=3):
    """Best-of-N wall time: deterministic work, noise only slows runs."""
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def bench_scale(ranks: int, steps: int, jobs: int) -> dict:
    cfg = get_config("llama-20b-paper")
    prog = program_from_config(cfg, num_chips=ranks)
    hist = HistoryStore()
    learner = DiagnosticEngine(
        EngineConfig(backend="dense-train", num_ranks=ranks), hist)
    learner.ingest_batch(ClusterSimulator(ranks, prog, seed=1).run_batch(3))
    learner.learn_healthy()
    label = f"{ranks}r"

    logdir = tempfile.mkdtemp(prefix="flare_storage_bench_")
    jdir, fdir = os.path.join(logdir, "jsonl"), os.path.join(logdir, "fcs")
    os.makedirs(jdir)
    os.makedirs(fdir)
    try:
        # ---- write both codecs (FCS from the JSONL-decoded batch, so
        # the two directories carry bit-identical event values) -------- #
        total_events = jsonl_bytes = fcs_bytes = 0
        for i in range(jobs):
            name, inj_fn = SCENARIOS[i % len(SCENARIOS)]
            batch = ClusterSimulator(ranks, prog, seed=100 + i,
                                     injections=inj_fn(ranks)
                                     ).run_batch(steps)
            total_events += len(batch)
            jp = os.path.join(jdir, f"job{i:02d}-{name}.jsonl")
            jsonl_bytes += store.write_trace(batch, jp)
            rounded = store.read_jsonl(jp)
            fcs_bytes += store.write_trace(
                rounded, os.path.join(fdir, f"job{i:02d}-{name}.fcs"))
        per_ev_jsonl = jsonl_bytes / total_events
        per_ev_fcs = fcs_bytes / total_events
        size_ratio = fcs_bytes / jsonl_bytes
        emit(f"storage/bytes_per_event_jsonl_{label}", per_ev_jsonl,
             f"total={jsonl_bytes}")
        emit(f"storage/bytes_per_event_fcs_{label}", per_ev_fcs,
             f"total={fcs_bytes};ratio={size_ratio:.3f}x;target<=0.3x")

        # ---- decode throughput: one job's file, full decode ----------- #
        one_j = sorted(os.listdir(jdir))[0]
        one_f = sorted(os.listdir(fdir))[0]
        jp, fp = os.path.join(jdir, one_j), os.path.join(fdir, one_f)
        one_n = len(store.read_jsonl(jp))

        decode = {}
        for key, fn in [
            ("jsonl_line", lambda: store.read_jsonl(jp)),
            ("jsonl_chunked", lambda: store.read_jsonl_chunked(
                jp, chunk_bytes=4 << 20)),
            ("jsonl_process", lambda: store.read_jsonl_chunked(
                jp, chunk_bytes=1 << 20, executor="process")),
            ("fcs", lambda: store.read_fcs(fp)),
        ]:
            s, out = _best(fn)
            decode[key] = one_n / s
            emit(f"storage/decode_{key}_{label}", 1e6 / decode[key],
                 f"{decode[key] / 1e6:.2f}Mev_s;events={one_n}")
        replay_speedup = decode["fcs"] / decode["jsonl_line"]
        emit(f"storage/fcs_decode_speedup_{label}", 0.0,
             f"{replay_speedup:.1f}x_vs_jsonl_line;target>=5x")

        # ---- replay e2e (decode + ingest + incremental diagnosis) ----- #
        def _replay(directory):
            mux = FleetMultiplexer(FleetConfig(watermark_delay=1),
                                   history=hist)
            stats = FleetReplayer(mux, chunk_bytes=4 << 20).replay_dir(
                directory)
            return stats, [str(a) for a in mux.poll()]

        t0 = time.perf_counter()
        sj, anoms_jsonl = _replay(jdir)
        jsonl_e2e = sj.events / (time.perf_counter() - t0)
        t0 = time.perf_counter()
        sf, anoms_fcs = _replay(fdir)
        fcs_e2e = sf.events / (time.perf_counter() - t0)
        assert sj.events == sf.events == total_events
        if anoms_jsonl != anoms_fcs:   # hard equivalence gate (ISSUE 3)
            raise AssertionError(
                "fleet diagnosis differs between codecs: "
                f"jsonl={anoms_jsonl!r} fcs={anoms_fcs!r}")
        emit(f"storage/replay_e2e_jsonl_{label}", 1e6 / jsonl_e2e,
             f"{jsonl_e2e / 1e6:.2f}Mev_s;anomalies={len(anoms_jsonl)}")
        emit(f"storage/replay_e2e_fcs_{label}", 1e6 / fcs_e2e,
             f"{fcs_e2e / 1e6:.2f}Mev_s;equivalent=TRUE;"
             f"{fcs_e2e / jsonl_e2e:.1f}x")
    finally:
        shutil.rmtree(logdir, ignore_errors=True)

    return {
        "ranks": ranks, "steps": steps, "jobs": jobs,
        "events": total_events,
        "bytes_per_event_jsonl": per_ev_jsonl,
        "bytes_per_event_fcs": per_ev_fcs,
        "size_ratio_fcs_vs_jsonl": size_ratio,
        "decode_events_per_s": decode,
        "fcs_decode_speedup_vs_jsonl_line": replay_speedup,
        "replay_e2e_events_per_s": {"jsonl": jsonl_e2e, "fcs": fcs_e2e},
        "diagnosis_byte_equivalent": True,
        "anomalies": len(anoms_jsonl),
    }


def main(quick: bool = False):
    scales = [(64, 4, 2)] if quick else [(256, 8, 3), (512, 6, 3)]
    results = {}
    for ranks, steps, jobs in scales:
        results[f"{ranks}r"] = bench_scale(ranks, steps, jobs)
    merge_bench_json(OUT_JSON, results)
    emit("storage/json", 0.0, f"merged={OUT_JSON}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small scale for CI smoke runs")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(quick=args.quick)
