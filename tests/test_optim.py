"""Optimizer: convergence across state dtypes, quantizer bounds, ZeRO specs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import (AdamWConfig, _q_dec, _q_enc, adamw_init,
                               adamw_update)
from repro.optim.schedule import warmup_cosine


@pytest.mark.parametrize("sd", ["float32", "bfloat16", "int8"])
def test_adamw_converges_quadratic(sd):
    cfg = AdamWConfig(lr=0.1, state_dtype=sd, weight_decay=0.0)
    params = {"w": jnp.array([[3.0, -2.0, 1.5]] * 5), "b": jnp.float32(4.0)}
    state = adamw_init(params, cfg)
    for _ in range(250):
        g = jax.tree.map(lambda w: 2 * w, params)
        params, state, _ = adamw_update(g, state, params, cfg, 0.05)
    assert float(jnp.abs(params["w"]).max()) < 0.06
    assert abs(float(params["b"])) < 0.06


def test_grad_clip_reported():
    cfg = AdamWConfig(lr=0.1, grad_clip=1.0)
    params = {"w": jnp.ones(4)}
    state = adamw_init(params, cfg)
    g = {"w": jnp.full(4, 100.0)}
    _, _, m = adamw_update(g, state, params, cfg, 0.1)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_quantizer_roundtrip_bound(rng):
    x = jnp.asarray(rng.standard_normal((7, 300)) * 5, jnp.float32)
    dec = _q_dec(_q_enc(x), x.shape)
    err = np.abs(np.asarray(dec - x))
    bound = np.abs(np.asarray(x)).max() / 127.0 + 1e-6
    assert err.max() <= bound * 1.01


def test_quantizer_preserves_shape(rng):
    for shape in [(5,), (3, 4), (2, 3, 257), ()]:
        x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        enc = _q_enc(x)
        if shape:
            assert enc["q"].shape == shape
        dec = _q_dec(enc, shape if shape else (1,))
        assert dec.shape == (shape if shape else (1,))


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(s, peak_lr=1.0, warmup_steps=10,
                               total_steps=100)) for s in range(100)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1.0)
    assert lrs[5] < lrs[9]  # warming up
    assert lrs[99] < lrs[50]  # decaying
    assert lrs[99] >= 0.1  # min ratio floor
