"""End-to-end system behaviour: real training with the FLARE daemon
attached, loss decreasing, trace log emitted, and the Case-3 dataloader
regression visible in REAL (not simulated) events."""
import os
import tempfile

import numpy as np

from repro.configs import get_reduced
from repro.core.events import load_jsonl
from repro.core.metrics import aggregate_step, steps_in
from repro.optim.adamw import AdamWConfig
from repro.runtime.serve import ServeConfig, Server
from repro.runtime.train import RunConfig, Trainer


def _train_with_log(log_path, *, steps=10, mask_mode="none", seq=64,
                    lr=1e-3, prefetch=True):
    cfg = get_reduced("llama3.2-1b")
    run = RunConfig(model=cfg, global_batch=4, seq_len=seq, steps=steps,
                    peak_lr=lr, warmup_steps=5, opt=AdamWConfig(lr=lr),
                    flare=True, mask_mode=mask_mode, flare_log=log_path,
                    data_prefetch=prefetch)
    t = Trainer(run)
    hist = t.train()
    return t, hist


def test_train_loss_decreases_with_flare(tmp_path):
    log = str(tmp_path / "trace.jsonl")
    t, hist = _train_with_log(log, steps=30, lr=3e-3)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.2, (first, last)
    # trace log exists, is small (paper: ~1.5MB/GPU on a real job), and
    # contains step + dataloader + device events
    assert 0 < t.daemon.bytes_logged < 5e6
    events = load_jsonl(log)
    kinds = {e.kind.value for e in events}
    assert {"step", "dataloader", "k_comp"} <= kinds


def test_case3_v_inter_from_real_events(tmp_path):
    """naive O(L^2) mask generation must raise v_inter vs the fast path.

    Paper §7.3.3: at 64k the quadratic mask generation exceeded the step
    time — prefetch cannot hide it.  We reproduce the regime with a long
    seq relative to the (reduced) model and a synchronous loader."""
    def v_inter_for(mask_mode):
        log = str(tmp_path / f"{mask_mode}.jsonl")
        _train_with_log(log, steps=6, mask_mode=mask_mode, seq=512,
                        prefetch=False)
        events = load_jsonl(log)
        by_rank = {0: events}
        vs = [aggregate_step(by_rank, s).v_inter
              for s in steps_in(by_rank)[2:]]
        return float(np.mean(vs))

    v_fast = v_inter_for("fast")
    v_naive = v_inter_for("naive")
    assert v_naive > 2.0 * v_fast, (v_fast, v_naive)
    assert v_naive > 0.05, v_naive


def test_serve_generates():
    cfg = get_reduced("qwen2-0.5b")
    server = Server(ServeConfig(model=cfg, batch=2, max_seq=64, flare=True))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (2, 16)).astype(np.int32)
    out = server.generate(prompts, new_tokens=8)
    assert out.shape == (2, 24)
    server.close()
