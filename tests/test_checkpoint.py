"""Checkpoint/restore tests (ISSUE 10).

Covered:
  * FLCK container: roundtrip, atomic write (no torn final name),
    generation numbering + pruning;
  * torn-state handling: truncated header/payload, bit-flipped CRC, bad
    magic, implausible length — all detected, skipped back past to the
    previous valid generation, never misparsed;
  * newer-format-version refusal: :class:`CheckpointVersionError`, loud,
    never skipped;
  * no-checkpoint fallback: ``restore()`` returns ``None`` and the
    service runs a full replay with unchanged results;
  * worker-kind mismatch refusal;
  * service-level kill-and-restore: an inline tail service checkpointed
    mid-stream (pending step buffers live, watermark open), killed, and
    restored into a fresh process resumes at the recorded tail offsets,
    replays only the suffix, and stitches an anomaly stream
    byte-equivalent to an uninterrupted run.
"""
import os
import struct
import time
import zlib

import pytest

from repro import store as trace_store
from repro.configs import get_config
from repro.core.engine import DiagnosticEngine, EngineConfig
from repro.core.history import HistoryStore
from repro.core.timeline import (ClusterSimulator, Injection,
                                 program_from_config)
from repro.fleet import FleetConfig, FleetMultiplexer, FleetReplayer
from repro.serve import FleetService, ServiceConfig
from repro.serve.checkpoint import (FORMAT_VERSION, MAGIC, CheckpointError,
                                    CheckpointStore, CheckpointVersionError,
                                    read_checkpoint, write_checkpoint)

N = 4
STEPS = 8


@pytest.fixture(scope="module")
def world():
    cfg = get_config("llama-20b-paper")
    prog = program_from_config(cfg, num_chips=N)
    store = HistoryStore()
    eng = DiagnosticEngine(
        EngineConfig(backend="dense-train", num_ranks=N), store)
    for seed in range(3):
        eng.ingest_batch(ClusterSimulator(N, prog, seed=seed).run_batch(3))
    eng.learn_healthy()
    return prog, store


def _mk_jobs(prog, jobs=4, steps=STEPS):
    chunk_lists, topo = {}, {}
    for i in range(jobs):
        inj = [Injection(kind="network_jitter", factor=3.0, start_step=3)] \
            if i < jobs // 2 else []
        sim = ClusterSimulator(N, prog, seed=100 + i, injections=inj)
        batch = sim.run_batch(steps)
        jid = f"ck{i:02d}-{'jit' if i < jobs // 2 else 'ok'}"
        order, uniq, bounds = batch.step_index()
        chunk_lists[jid] = [batch.take(order[bounds[j]:bounds[j + 1]])
                            for j in range(uniq.size)]
        topo[jid] = {"rack": f"r{i // 2}", "switch": f"s{i // 4}"}
    return chunk_lists, topo


def _write_logs(logdir, chunk_lists):
    for jid, chunks in chunk_lists.items():
        path = os.path.join(logdir, f"{jid}.fcs")
        for c in chunks:
            trace_store.write_trace(c, path, codec="fcs")


def _mk_mux(store, topo):
    return FleetMultiplexer(
        FleetConfig(watermark_delay=1,
                    fleet_detectors=["cross_job_failslow"], topology=topo),
        history=store)


def _ecfg():
    return EngineConfig(backend="dense-train", num_ranks=N)


def _oracle(logdir, store, topo, jobs):
    mux = _mk_mux(store, topo)
    for jid in jobs:
        mux.add_job(jid, _ecfg())
    stats = FleetReplayer(mux).replay_dir(logdir, job_workers=1)
    out = sorted(mux.finalize(), key=lambda a: (a.ts, a.job_id, a.seq))
    return [str(fa) for fa in out], stats


def _sorted_strs(fas):
    return [str(fa)
            for fa in sorted(fas, key=lambda a: (a.ts, a.job_id, a.seq))]


def _wait(pred, timeout_s=30.0):
    deadline = time.monotonic() + timeout_s
    while not pred():
        if time.monotonic() > deadline:
            raise TimeoutError("checkpoint test: condition not reached")
        time.sleep(0.01)


# ---------------------------------------------------------------------- #
# container
# ---------------------------------------------------------------------- #
def test_container_roundtrip_and_atomicity(tmp_path):
    state = {"a": [1, 2.5, "x"], "nested": {"b": (3, None)},
             "blob": b"\x00\xff" * 100}
    path = str(tmp_path / "ckpt-00000001.flc")
    n = write_checkpoint(path, state)
    assert os.path.getsize(path) == n
    assert read_checkpoint(path) == state
    assert not os.path.exists(path + ".tmp")     # tmp renamed away

    # single-pickle payload preserves identity between shared references
    shared = ["names"]
    st2 = {"interner": shared, "batch_ref": shared}
    p2 = str(tmp_path / "ckpt-00000002.flc")
    write_checkpoint(p2, st2)
    back = read_checkpoint(p2)
    assert back["interner"] is back["batch_ref"]


def test_store_generations_and_pruning(tmp_path):
    cs = CheckpointStore(str(tmp_path), keep=2)
    for i in range(4):
        path, gen, _ = cs.save({"gen": i})
        assert gen == i + 1
    assert cs.generations() == [3, 4]            # pruned down to keep=2
    state, path, gen, skipped = cs.load_latest()
    assert (state["gen"], gen, skipped) == (3, 4, [])


def test_empty_store_loads_none(tmp_path):
    assert CheckpointStore(str(tmp_path)).load_latest() is None


@pytest.mark.parametrize("corrupt", ["truncate_header", "truncate_payload",
                                     "flip_payload", "bad_magic",
                                     "absurd_length"])
def test_torn_checkpoints_detected_and_skipped(tmp_path, corrupt):
    """Every torn/corrupt shape raises a clear CheckpointError on direct
    read, and load_latest skips back to the previous valid generation
    (reporting what it passed over) instead of misparsing."""
    cs = CheckpointStore(str(tmp_path))
    cs.save({"gen": 1, "payload": list(range(256))})
    path, gen2, _ = cs.save({"gen": 2, "payload": list(range(256))})
    blob = bytearray(open(path, "rb").read())
    if corrupt == "truncate_header":
        blob = blob[:10]
    elif corrupt == "truncate_payload":
        blob = blob[:len(blob) // 2]
    elif corrupt == "flip_payload":
        blob[-1] ^= 0xFF                          # CRC catches the flip
    elif corrupt == "bad_magic":
        blob[:4] = b"NOPE"
    elif corrupt == "absurd_length":
        struct.pack_into("<Q", blob, 8, 1 << 40)
    with open(path, "wb") as f:
        f.write(bytes(blob))

    with pytest.raises(CheckpointError):
        read_checkpoint(path)
    state, _, gen, skipped = cs.load_latest()
    assert (state["gen"], gen) == (1, 1)
    assert len(skipped) == 1 and os.path.basename(path) in skipped[0]


def test_crc_mismatch_message_names_the_file(tmp_path):
    path = str(tmp_path / "ckpt-00000001.flc")
    write_checkpoint(path, {"x": 1})
    blob = bytearray(open(path, "rb").read())
    blob[-1] ^= 0x01
    with open(path, "wb") as f:
        f.write(bytes(blob))
    with pytest.raises(CheckpointError, match="CRC mismatch"):
        read_checkpoint(path)


def test_newer_format_version_refuses_never_skips(tmp_path):
    """A checkpoint from a NEWER build must refuse loudly — silently
    skipping back would restore an older view of the world while a
    perfectly good (but not-understood) snapshot sits on disk."""
    cs = CheckpointStore(str(tmp_path))
    cs.save({"gen": 1})
    payload = b"future-format bytes"
    newer = os.path.join(str(tmp_path), "ckpt-00000002.flc")
    with open(newer, "wb") as f:
        f.write(struct.pack("<4sHHQI", MAGIC, FORMAT_VERSION + 1, 0,
                            len(payload), zlib.crc32(payload)))
        f.write(payload)
    with pytest.raises(CheckpointVersionError, match="newer"):
        read_checkpoint(newer)
    with pytest.raises(CheckpointVersionError):
        cs.load_latest()


# ---------------------------------------------------------------------- #
# service-level restore
# ---------------------------------------------------------------------- #
def test_restore_none_without_checkpoint_full_replay(world, tmp_path):
    """checkpoint_dir configured but empty: restore() returns None and
    the service falls back to a cold full replay — results unchanged."""
    prog, store = world
    chunk_lists, topo = _mk_jobs(prog, jobs=2)
    logdir = str(tmp_path / "logs")
    os.makedirs(logdir)
    _write_logs(logdir, chunk_lists)
    oracle, ostats = _oracle(logdir, store, topo, chunk_lists)

    got = []
    svc = FleetService(
        _mk_mux(store, topo),
        ServiceConfig(port=None, tail_dir=logdir,
                      checkpoint_dir=str(tmp_path / "ckpt"),
                      checkpoint_on_finalize=False,
                      default_engine=_ecfg()),
        on_anomaly=lambda fa, t: got.append(fa))
    assert svc.restore() is None
    svc.start()
    _wait(lambda: svc.tailer.stats.events >= ostats.events)
    svc.finalize()
    assert _sorted_strs(got) == oracle
    assert svc.telemetry.value("serve.restore_fallbacks") == 1


def test_restore_refuses_worker_kind_mismatch(tmp_path):
    cs = CheckpointStore(str(tmp_path))
    cs.save({"worker_kind": "inline", "service": {}, "fleet": {},
             "jobs": {}, "telemetry": {}, "tail": None})
    svc = FleetService(
        FleetMultiplexer(FleetConfig()),
        ServiceConfig(port=None, worker_kind="process", workers=1,
                      checkpoint_dir=str(tmp_path)))
    with pytest.raises(CheckpointError, match="worker_kind"):
        svc.restore()


def test_kill_and_restore_inline_tail_equivalence(world, tmp_path):
    """The tentpole contract at test scale: checkpoint mid-stream (the
    watermark holds pending step buffers open), kill abruptly, land the
    rest of the data while the service is dead, restore a fresh process
    — the stitched anomaly stream, stats signature, and fleet-tier
    reclassification set equal an uninterrupted run's, and only the
    spill suffix was decoded again."""
    prog, store = world
    chunk_lists, topo = _mk_jobs(prog)
    logdir = str(tmp_path / "logs")
    os.makedirs(logdir)
    first = {j: c[:len(c) // 2] for j, c in chunk_lists.items()}
    rest = {j: c[len(c) // 2:] for j, c in chunk_lists.items()}
    half_events = sum(len(c) for cs in first.values() for c in cs)
    scfg = ServiceConfig(port=None, tail_dir=logdir, tail_poll_s=0.005,
                         drain_interval_s=0.01,
                         checkpoint_dir=str(tmp_path / "ckpt"),
                         checkpoint_on_finalize=False,
                         default_engine=_ecfg())

    _write_logs(logdir, first)
    got1 = []
    svc1 = FleetService(_mk_mux(store, topo), scfg,
                        on_anomaly=lambda fa, t: got1.append(fa)).start()
    _wait(lambda: svc1.tailer.stats.events >= half_events)
    meta = svc1.checkpoint()
    svc1.kill()
    assert meta["generation"] == 1
    assert 0 < meta["tail_bytes_decoded"]
    pre = got1[:meta["anomalies_emitted"]]
    assert len(pre) == meta["anomalies_emitted"]

    _write_logs(logdir, rest)              # lands while the service is dead
    oracle, ostats = _oracle(logdir, store, topo, chunk_lists)
    full_bytes = sum(os.path.getsize(os.path.join(logdir, f))
                     for f in os.listdir(logdir) if f.endswith(".fcs"))

    got2 = []
    svc2 = FleetService(_mk_mux(store, topo), scfg,
                        on_anomaly=lambda fa, t: got2.append(fa))
    meta2 = svc2.restore()
    assert meta2["generation"] == meta["generation"]
    assert meta2["skipped"] == []
    assert meta2["anomalies_emitted"] == meta["anomalies_emitted"]
    svc2.start()
    _wait(lambda: svc2.tailer.stats.events >= ostats.events)
    svc2.finalize()

    assert _sorted_strs(pre + got2) == oracle
    assert svc2.tailer.stats.events == ostats.events
    assert dict(sorted(svc2.tailer.stats.per_job.items())) == ostats.per_job
    # suffix-only replay: every byte decoded exactly once across the two
    # incarnations, and the restored one decoded strictly less than all
    assert svc2.tailer.stats.bytes_decoded == full_bytes
    assert 0 < full_bytes - meta["tail_bytes_decoded"] < full_bytes


def test_graceful_finalize_writes_checkpoint(world, tmp_path):
    """checkpoint_on_finalize (the default): a clean shutdown leaves a
    restorable generation behind without any explicit checkpoint call."""
    prog, store = world
    chunk_lists, topo = _mk_jobs(prog, jobs=2)
    logdir = str(tmp_path / "logs")
    os.makedirs(logdir)
    _write_logs(logdir, chunk_lists)
    ckptdir = str(tmp_path / "ckpt")
    svc = FleetService(
        _mk_mux(store, topo),
        ServiceConfig(port=None, tail_dir=logdir, checkpoint_dir=ckptdir,
                      default_engine=_ecfg())).start()
    _wait(lambda: svc.tailer.stats.events > 0)
    svc.finalize()
    assert CheckpointStore(ckptdir).generations() == [1]
    assert svc.telemetry.value("serve.checkpoints") == 1
