"""Live fleet service tests (ISSUE 9).

Covered:
  * FLW wire protocol: roundtrip, clean EOF, torn frame, corrupt
    magic/CRC, hello payload validation;
  * socket ingest (inline AND process workers) byte-equivalent to
    ``replay_dir`` on the same recorded traces — anomaly stream, stats
    signature, and ``cross_job_failslow`` reclassifications;
  * file-tail ingest equivalence, including growing files (segment
    boundaries as commit points), rotation, truncated tails and
    structural corruption counted like replay;
  * graceful join/leave mid-run: a departing job's diagnosis closes
    without disturbing the other jobs'; post-leave frames drop counted;
  * ``FleetMultiplexer.retire_job`` equivalence to one terminal
    finalize;
  * torn-frame / corrupt-frame connections counted and dropped without
    hurting healthy connections;
  * the daemon's ``live_endpoint`` sink (ships real drains; counted
    drops against a dead service, never an exception);
  * archive per-query byte budgets (``max_bytes`` -> honest truncated
    prefix) and the HTTP query plane;
  * robustness (ISSUE 10): connection cap rejects cleanly and counted;
    per-job overload shedding drops counted frames without decoding;
    the daemon's live sink re-HELLOs (topology + engine) after a
    service restart, spill staying the source of truth while the
    service is down; a dead worker process triggers checkpoint-based
    recovery with duplicate anomalies suppressed.
"""
import json
import os
import socket
import time
import urllib.error
import urllib.request

import pytest

from repro import store as trace_store
from repro.configs import get_config
from repro.core.daemon import DaemonConfig, TracingDaemon
from repro.core.engine import DiagnosticEngine, EngineConfig
from repro.core.events import EventKind
from repro.core.history import HistoryStore
from repro.core.telemetry import TelemetryRegistry
from repro.core.timeline import (ClusterSimulator, Injection,
                                 program_from_config)
from repro.fleet import FleetConfig, FleetMultiplexer, FleetReplayer
from repro.serve import (FRAME_BATCH, FleetService, LiveBatchSink,
                         LiveClient, ProtocolError, ServiceConfig,
                         batch_frame, bye_frame, encode_frame, hello_frame,
                         parse_hello, read_frame)
from repro.serve.tail import FileTailer
from repro.store import CodecError, tail_complete_segments

N = 4           # ranks: small fleet, fast tests
STEPS = 8


@pytest.fixture(scope="module")
def world():
    cfg = get_config("llama-20b-paper")
    prog = program_from_config(cfg, num_chips=N)
    store = HistoryStore()
    eng = DiagnosticEngine(
        EngineConfig(backend="dense-train", num_ranks=N), store)
    for seed in range(3):
        eng.ingest_batch(ClusterSimulator(N, prog, seed=seed).run_batch(3))
    eng.learn_healthy()
    return prog, store


def _mk_jobs(prog, jobs=4, steps=STEPS):
    """Hang-free mixed fleet: first half jitters on shared racks (the
    cross-job tier's trigger), rest healthy.  Returns per-job step
    chunks + topology."""
    chunk_lists, topo = {}, {}
    for i in range(jobs):
        inj = [Injection(kind="network_jitter", factor=3.0, start_step=3)] \
            if i < jobs // 2 else []
        sim = ClusterSimulator(N, prog, seed=100 + i, injections=inj)
        batch = sim.run_batch(steps)
        jid = f"lv{i:02d}-{'jit' if i < jobs // 2 else 'ok'}"
        order, uniq, bounds = batch.step_index()
        chunk_lists[jid] = [batch.take(order[bounds[j]:bounds[j + 1]])
                            for j in range(uniq.size)]
        topo[jid] = {"rack": f"r{i // 2}", "switch": f"s{i // 4}"}
    return chunk_lists, topo


def _write_logs(logdir, chunk_lists, codec="fcs"):
    for jid, chunks in chunk_lists.items():
        path = os.path.join(logdir, f"{jid}.{codec}")
        for c in chunks:
            trace_store.write_trace(c, path, codec=codec)


def _mk_mux(store, topo):
    return FleetMultiplexer(
        FleetConfig(watermark_delay=1,
                    fleet_detectors=["cross_job_failslow"], topology=topo),
        history=store)


def _ecfg():
    return EngineConfig(backend="dense-train", num_ranks=N)


def _oracle(logdir, store, topo, jobs):
    """Serial replay + finalize: (sorted anomaly strings, stats)."""
    mux = _mk_mux(store, topo)
    for jid in jobs:
        mux.add_job(jid, _ecfg())
    stats = FleetReplayer(mux).replay_dir(logdir, job_workers=1)
    out = sorted(mux.finalize(), key=lambda a: (a.ts, a.job_id, a.seq))
    return [str(fa) for fa in out], stats


def _sorted_strs(fas):
    return [str(fa)
            for fa in sorted(fas, key=lambda a: (a.ts, a.job_id, a.seq))]


def _stream_all(client, chunk_lists, logdir):
    """The equivalence-bench protocol: HELLO every job up front (the
    frontier must know the join set), then stream each job's recorded
    chunks, then BYE."""
    for jid in sorted(chunk_lists):
        client.hello(jid)
    for jid in sorted(chunk_lists):
        path = os.path.join(logdir, f"{jid}.fcs")
        for batch, _sk in trace_store.iter_trace_chunks(path):
            client.send_batch(jid, batch)
    for jid in sorted(chunk_lists):
        client.bye(jid)


# ---------------------------------------------------------------------- #
# wire protocol
# ---------------------------------------------------------------------- #
def test_protocol_roundtrip_and_clean_eof():
    a, b = socket.socketpair()
    try:
        a.sendall(hello_frame("job-x", topology={"rack": "r1"}))
        a.sendall(batch_frame("job-x", b"\x01payload"))
        a.sendall(bye_frame("job-x"))
        a.close()
        ftype, jid, payload = read_frame(b)
        assert (ftype, jid) == (1, "job-x")
        assert parse_hello(payload)["topology"] == {"rack": "r1"}
        ftype, jid, payload = read_frame(b)
        assert (ftype, jid, payload) == (FRAME_BATCH, "job-x", b"\x01payload")
        assert read_frame(b)[0] == 3
        assert read_frame(b) is None        # clean EOF at boundary
    finally:
        b.close()


def test_protocol_torn_and_corrupt_frames():
    # torn: EOF mid-frame
    a, b = socket.socketpair()
    a.sendall(batch_frame("j", b"x" * 64)[:20])
    a.close()
    with pytest.raises(ProtocolError, match="torn"):
        read_frame(b)
    b.close()
    # corrupt magic
    a, b = socket.socketpair()
    a.sendall(b"NOPE" + batch_frame("j", b"x")[4:])
    a.close()
    with pytest.raises(ProtocolError, match="magic"):
        read_frame(b)
    b.close()
    # CRC mismatch
    a, b = socket.socketpair()
    frame = bytearray(batch_frame("j", b"hello"))
    frame[-1] ^= 0xFF
    a.sendall(bytes(frame))
    a.close()
    with pytest.raises(ProtocolError, match="CRC"):
        read_frame(b)
    b.close()
    # unknown type
    a, b = socket.socketpair()
    a.sendall(encode_frame(9, "j", b""))
    a.close()
    with pytest.raises(ProtocolError, match="type"):
        read_frame(b)
    b.close()
    with pytest.raises(ProtocolError):
        parse_hello(b"not json")


# ---------------------------------------------------------------------- #
# socket ingest equivalence
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("worker_kind", ["inline", "process"])
def test_socket_ingest_matches_replay(world, tmp_path, worker_kind):
    prog, store = world
    chunk_lists, topo = _mk_jobs(prog)
    logdir = str(tmp_path)
    _write_logs(logdir, chunk_lists)
    oracle, ostats = _oracle(logdir, store, topo, chunk_lists)
    assert oracle and any("(fleet)" in s for s in oracle)

    got = []
    svc = FleetService(
        _mk_mux(store, topo),
        ServiceConfig(port=0, worker_kind=worker_kind, workers=2,
                      default_engine=_ecfg()),
        on_anomaly=lambda fa, t: got.append(fa)).start()
    try:
        cl = LiveClient("127.0.0.1", svc.port)
        _stream_all(cl, chunk_lists, logdir)
        cl.close()
        if worker_kind == "process":
            deadline = time.time() + 30
            while time.time() < deadline and not all(
                    svc.mux.job(j).departed for j in chunk_lists):
                time.sleep(0.02)
    finally:
        svc.finalize()
    assert _sorted_strs(got) == oracle
    assert svc.stats.events == ostats.events
    assert dict(sorted(svc.stats.per_job.items())) == ostats.per_job
    snap = svc.telemetry.snapshot()
    counters = snap.get("counters", snap)
    assert counters["serve.frames"] == sum(
        len(c) for c in chunk_lists.values())
    assert counters["serve.bytes_in"] > 0
    assert counters.get("serve.dropped_frames", 0) == 0


def test_socket_join_leave_mid_run_isolated(world, tmp_path):
    """A job joins late, leaves early; frames after BYE are dropped and
    counted; the OTHER jobs' diagnosis equals a fleet that never saw
    the extra frames at all."""
    prog, store = world
    chunk_lists, topo = _mk_jobs(prog)
    logdir = str(tmp_path)
    _write_logs(logdir, chunk_lists)
    leaver = sorted(chunk_lists)[0]

    oracle, _ = _oracle(logdir, store, topo, chunk_lists)

    got = []
    svc = FleetService(
        _mk_mux(store, topo),
        ServiceConfig(port=0, default_engine=_ecfg()),
        on_anomaly=lambda fa, t: got.append(fa)).start()
    try:
        cl = LiveClient("127.0.0.1", svc.port)
        for jid in sorted(chunk_lists):
            cl.hello(jid)
        # leaver streams fully and BYEs while the others are mid-stream
        paths = {jid: os.path.join(logdir, f"{jid}.fcs")
                 for jid in chunk_lists}
        chunks = {jid: [b for b, _ in
                        trace_store.iter_trace_chunks(paths[jid])]
                  for jid in chunk_lists}
        for b in chunks[leaver]:
            cl.send_batch(leaver, b)
        cl.bye(leaver)
        straggler = chunks[leaver][-1]
        cl.send_batch(leaver, straggler)    # post-BYE: dropped, counted
        for jid in sorted(chunk_lists):
            if jid == leaver:
                continue
            for b in chunks[jid]:
                cl.send_batch(jid, b)
            cl.bye(jid)
        cl.close()
        deadline = time.time() + 10
        while time.time() < deadline and not svc.mux.job(leaver).departed:
            time.sleep(0.02)
    finally:
        svc.finalize()
    # the straggler frame changed nothing: full equivalence holds
    assert _sorted_strs(got) == oracle
    snap = svc.telemetry.snapshot()
    counters = snap.get("counters", snap)
    assert counters[f"fleet.departed_rows{{job={leaver}}}"] == \
        len(straggler)


def test_torn_connection_counted_and_isolated(world, tmp_path):
    """A connection dying mid-frame (and one sending a corrupt BATCH)
    costs counted drops; a healthy job on another connection is
    diagnosed exactly as if the bad connections never happened."""
    prog, store = world
    chunk_lists, topo = _mk_jobs(prog, jobs=2)
    logdir = str(tmp_path)
    _write_logs(logdir, chunk_lists)
    oracle, _ = _oracle(logdir, store, topo, chunk_lists)

    got = []
    svc = FleetService(
        _mk_mux(store, topo),
        ServiceConfig(port=0, default_engine=_ecfg()),
        on_anomaly=lambda fa, t: got.append(fa)).start()
    try:
        # torn: half a frame then EOF
        s = socket.create_connection(("127.0.0.1", svc.port))
        s.sendall(batch_frame("torn-job", b"x" * 256)[:30])
        s.close()
        # corrupt payload: valid frame, garbage FCS bytes
        s2 = socket.create_connection(("127.0.0.1", svc.port))
        s2.sendall(hello_frame("bad-job"))
        s2.sendall(batch_frame("bad-job", b"this is not FCS"))
        time.sleep(0.2)
        s2.close()
        cl = LiveClient("127.0.0.1", svc.port)
        _stream_all(cl, chunk_lists, logdir)
        cl.close()
        deadline = time.time() + 10
        while time.time() < deadline and not all(
                svc.mux.job(j).departed for j in chunk_lists):
            time.sleep(0.02)
    finally:
        svc.finalize()
    assert _sorted_strs(got) == oracle
    snap = svc.telemetry.snapshot()
    counters = snap.get("counters", snap)
    assert counters["serve.dropped_frames"] == 2


# ---------------------------------------------------------------------- #
# file-tail ingest
# ---------------------------------------------------------------------- #
def test_tail_ingest_matches_replay(world, tmp_path):
    prog, store = world
    chunk_lists, topo = _mk_jobs(prog)
    logdir = str(tmp_path)
    _write_logs(logdir, chunk_lists)
    oracle, ostats = _oracle(logdir, store, topo, chunk_lists)

    got = []
    svc = FleetService(
        _mk_mux(store, topo),
        ServiceConfig(port=None, tail_dir=logdir, default_engine=_ecfg()),
        on_anomaly=lambda fa, t: got.append(fa)).start()
    deadline = time.time() + 10
    while time.time() < deadline and svc.tailer.stats.events < ostats.events:
        time.sleep(0.05)
    svc.finalize()
    assert _sorted_strs(got) == oracle
    assert svc.tailer.stats.events == ostats.events
    assert svc.tailer.stats.files == ostats.files
    assert dict(sorted(svc.tailer.stats.per_job.items())) == ostats.per_job


def test_tail_growing_file_segment_commit_points(world, tmp_path):
    """A half-written segment is invisible; completing it delivers it.
    The offset never rewinds, so bytes are decoded exactly once."""
    prog, _ = world
    batch = ClusterSimulator(N, prog, seed=5).run_batch(3)
    full = os.path.join(str(tmp_path), "done.fcs")
    trace_store.write_trace(batch, full, codec="fcs")
    blob = open(full, "rb").read()

    grow = os.path.join(str(tmp_path), "grow.fcs")
    sunk = []
    tailer = FileTailer(str(tmp_path), lambda j, b: sunk.append((j, b)))
    with open(grow, "wb") as f:
        f.write(blob[:len(blob) // 2])
        f.flush()
        tailer.poll_once()
        assert [j for j, _ in sunk] == ["done"]     # partial: held back
        f.write(blob[len(blob) // 2:])
    tailer.poll_once()
    assert sorted(j for j, _ in sunk) == ["done", "grow"]
    assert sum(len(b) for j, b in sunk if j == "grow") == len(batch)
    # idempotent: nothing new on a re-poll
    n = len(sunk)
    tailer.poll_once()
    assert len(sunk) == n


def test_tail_corruption_counted_like_replay(world, tmp_path):
    """Truncated tail (killed writer) and structural garbage both land
    as ``corrupt_files`` with intact leading segments still delivered —
    the same accounting replay produces on the same files."""
    prog, store = world
    batch = ClusterSimulator(N, prog, seed=5).run_batch(3)
    d = str(tmp_path)
    ok = os.path.join(d, "ok.fcs")
    trace_store.write_trace(batch, ok, codec="fcs")
    blob = open(ok, "rb").read()
    with open(os.path.join(d, "torn.fcs"), "wb") as f:
        f.write(blob + blob[:len(blob) // 3])       # killed mid-segment
    with open(os.path.join(d, "garbage.fcs"), "wb") as f:
        f.write(b"\x00garbage not a segment" * 8)

    sunk = []
    tailer = FileTailer(d, lambda j, b: sunk.append((j, len(b))))
    tailer.poll_once()
    tailer.finish()
    # replay oracle on the same directory
    mux = FleetMultiplexer(FleetConfig(), history=store)
    rstats = FleetReplayer(mux).replay_dir(d, job_workers=1)
    assert tailer.stats.corrupt_files == rstats.corrupt_files == 2
    assert tailer.stats.events == rstats.events
    assert tailer.stats.files == rstats.files
    # torn file's intact leading segment was still delivered
    assert sum(n for j, n in sunk if j == "torn") == len(batch)

    # tail_complete_segments itself raises on structural garbage
    with pytest.raises(CodecError):
        tail_complete_segments(os.path.join(d, "garbage.fcs"))


def test_tail_jsonl_skips_corrupt_lines(world, tmp_path):
    prog, _ = world
    batch = ClusterSimulator(N, prog, seed=5).run_batch(2)
    path = os.path.join(str(tmp_path), "j1.jsonl")
    trace_store.write_trace(batch, path, codec="jsonl")
    with open(path, "a") as f:
        f.write("{not valid json\n")
    sunk = []
    tailer = FileTailer(str(tmp_path), lambda j, b: sunk.append(len(b)))
    tailer.poll_once()
    tailer.finish()
    assert sum(sunk) == len(batch)
    assert tailer.stats.skipped_lines == 1
    assert tailer.stats.files == 1


# ---------------------------------------------------------------------- #
# graceful leave at the multiplexer level
# ---------------------------------------------------------------------- #
def test_retire_job_equivalent_to_terminal_finalize(world):
    """Retiring each job at its end of stream, then finalizing, yields
    the same merged output as one terminal finalize — and a retired
    job's stragglers are dropped, counted, and change nothing."""
    prog, store = world
    chunk_lists, topo = _mk_jobs(prog)

    def run(retire: bool):
        mux = _mk_mux(store, topo)
        for jid in chunk_lists:
            mux.add_job(jid, _ecfg())
        out = []
        for jid in sorted(chunk_lists):
            for c in chunk_lists[jid]:
                mux.ingest(jid, c)
            if retire:
                mux.retire_job(jid)
                out.extend(mux.poll())
                mux.ingest(jid, chunk_lists[jid][-1])   # straggler
        out.extend(mux.finalize())
        return mux, _sorted_strs(out)

    mux_a, plain = run(retire=False)
    mux_b, retired = run(retire=True)
    assert retired == plain and plain
    jid0 = sorted(chunk_lists)[0]
    snap = mux_b.telemetry.snapshot()
    counters = snap.get("counters", snap)
    assert counters[f"fleet.departed_rows{{job={jid0}}}"] == \
        len(chunk_lists[jid0][-1])
    assert mux_b.job(jid0).departed


# ---------------------------------------------------------------------- #
# daemon live sink
# ---------------------------------------------------------------------- #
def test_live_batch_sink_counted_drop_never_raises(world):
    prog, _ = world
    # a port with nothing listening
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    reg = TelemetryRegistry()
    sink = LiveBatchSink(f"127.0.0.1:{port}", "j1", telemetry=reg,
                         timeout=0.2, backoff_s=60.0)
    batch = ClusterSimulator(N, prog, seed=5).run_batch(1)
    assert sink(batch) is False
    t0 = time.perf_counter()
    assert sink(batch) is False             # inside backoff: instant drop
    assert time.perf_counter() - t0 < 0.1
    snap = reg.snapshot()
    counters = snap.get("counters", snap)
    assert counters["daemon.live_dropped"] == 2
    sink.close()


def test_daemon_live_endpoint_streams_to_service(world):
    prog, store = world
    svc = FleetService(
        FleetMultiplexer(FleetConfig(), history=store),
        ServiceConfig(port=0, default_engine=_ecfg())).start()
    try:
        d = TracingDaemon(DaemonConfig(
            rank=0, drain_interval=0.01,
            live_endpoint=f"127.0.0.1:{svc.port}", live_job_id="dj",
            live_topology={"rack": "r9"}))
        d.attach()
        for s in range(3):
            d.step_begin(s)
            t0 = time.perf_counter()
            d.record_span(EventKind.KERNEL_COMPUTE, "mm", t0, t0 + 1e-4)
            d.step_end()
        time.sleep(0.3)
        d.detach()
        deadline = time.time() + 5
        while time.time() < deadline and \
                svc.mux.job("dj").store.events_total == 0:
            time.sleep(0.05)
        assert svc.mux.job("dj").store.events_total > 0
        assert svc.mux.topology["dj"] == {"rack": "r9"}
        counters = d.telemetry.snapshot().get("counters", {})
        assert counters["daemon.live_frames"] > 0
        assert counters.get("daemon.live_dropped", 0) == 0
    finally:
        svc.finalize()


# ---------------------------------------------------------------------- #
# robustness: connection caps, shedding, restart, worker death
# ---------------------------------------------------------------------- #
def test_max_connections_rejected_cleanly(world):
    prog, store = world
    svc = FleetService(
        FleetMultiplexer(FleetConfig(), history=store),
        ServiceConfig(port=0, max_connections=1,
                      default_engine=_ecfg())).start()
    try:
        cl = LiveClient("127.0.0.1", svc.port)
        cl.hello("j-keep")
        deadline = time.time() + 5
        while time.time() < deadline and "j-keep" not in svc.mux.topology \
                and not svc.mux.jobs:
            time.sleep(0.01)
        # over the cap: the service closes immediately and counts it
        s = socket.create_connection(("127.0.0.1", svc.port), timeout=5)
        s.settimeout(5)
        assert s.recv(1) == b""            # clean server-side close
        s.close()
        assert svc.telemetry.value("serve.rejected_connections") == 1
        # the accepted connection is unharmed: it can still ingest
        batch = ClusterSimulator(N, prog, seed=5).run_batch(1)
        cl.send_batch("j-keep", trace_store.encode_batch_bytes(batch))
        deadline = time.time() + 5
        while time.time() < deadline and \
                svc.mux.job("j-keep").store.events_total == 0:
            time.sleep(0.02)
        assert svc.mux.job("j-keep").store.events_total == len(batch)
        cl.close()
    finally:
        svc.finalize()
    assert svc.telemetry.value("serve.dropped_frames") == 0


def test_overload_shedding_counted_per_job(world):
    """Over the per-job inflight cap, frames are dropped WITHOUT
    decoding and counted per job; under the cap they flow again."""
    prog, store = world
    svc = FleetService(
        FleetMultiplexer(FleetConfig(), history=store),
        ServiceConfig(port=None, worker_kind="process", workers=1,
                      max_inflight_frames=4, default_engine=_ecfg()))
    svc.start()
    try:
        batch = ClusterSimulator(N, prog, seed=5).run_batch(1)
        payload = trace_store.encode_batch_bytes(batch)
        svc.join_job("shed-j")
        # pin the inflight depth at the cap: the next frame must shed
        with svc._reg_lock:
            svc._inflight["shed-j"] = 4
        svc.ingest_frame("shed-j", payload)
        assert svc.telemetry.value("serve.shed_frames", job="shed-j") == 1
        svc.ingest_frame("shed-j", payload)
        assert svc.telemetry.value("serve.shed_frames", job="shed-j") == 2
        with svc._reg_lock:                # backlog drained: flows again
            svc._inflight["shed-j"] = 0
        svc.ingest_frame("shed-j", payload)
        assert svc.telemetry.value("serve.shed_frames", job="shed-j") == 2
        deadline = time.time() + 10
        while time.time() < deadline and \
                svc.telemetry.value("serve.inflight", job="shed-j") > 0:
            time.sleep(0.02)
    finally:
        svc.finalize()
    # exactly the one accepted frame was ingested
    assert svc.stats.events == len(batch)


def test_live_sink_rehellos_after_service_restart(world):
    """Kill the service mid-stream: the daemon's sink takes counted
    drops (its spill stays the source of truth), then the next backoff
    reconnect re-sends HELLO — a restarted service learns the job's
    topology again with no daemon-side special case."""
    prog, store = world
    svc1 = FleetService(
        FleetMultiplexer(FleetConfig(), history=store),
        ServiceConfig(port=0, default_engine=_ecfg())).start()
    port = svc1.port
    reg = TelemetryRegistry()
    sink = LiveBatchSink(f"127.0.0.1:{port}", "dj",
                         topology={"rack": "r9"}, telemetry=reg,
                         timeout=2.0, backoff_s=0.05, backoff_max_s=0.05)
    batch = ClusterSimulator(N, prog, seed=5).run_batch(1)
    try:
        assert sink(batch) is True
        deadline = time.time() + 5
        while time.time() < deadline and \
                svc1.mux.topology.get("dj") != {"rack": "r9"}:
            time.sleep(0.02)
        assert svc1.mux.topology["dj"] == {"rack": "r9"}

        svc1.kill()                        # crash, not graceful
        # service down: counted drop, never an exception — the daemon's
        # spill keeps the authoritative copy of anything dropped here
        time.sleep(0.1)
        dropped_any = False
        for _ in range(20):
            if sink(batch) is False:
                dropped_any = True
                break
            time.sleep(0.05)
        assert dropped_any

        svc2 = FleetService(
            FleetMultiplexer(FleetConfig(), history=store),
            ServiceConfig(port=port, default_engine=_ecfg())).start()
        try:
            # backoff reconnect re-sends HELLO: the fresh service (which
            # never saw the original registration) learns the topology
            deadline = time.time() + 10
            sent = False
            while time.time() < deadline and not sent:
                sent = sink(batch)
                if not sent:
                    time.sleep(0.05)
            assert sent
            deadline = time.time() + 5
            while time.time() < deadline and \
                    svc2.mux.topology.get("dj") != {"rack": "r9"}:
                time.sleep(0.02)
            assert svc2.mux.topology["dj"] == {"rack": "r9"}
            deadline = time.time() + 5
            while time.time() < deadline and \
                    svc2.mux.job("dj").store.events_total == 0:
                time.sleep(0.02)
            assert svc2.mux.job("dj").store.events_total == len(batch)
        finally:
            svc2.finalize()
        counters = reg.snapshot()["counters"]
        assert counters["daemon.live_reconnects"] >= 1
        assert counters["daemon.live_dropped"] >= 1
    finally:
        sink.close()


def test_worker_death_recovers_from_checkpoint(world, tmp_path):
    """Kill a worker process mid-run: the service rewinds to its newest
    checkpoint, respawns the pool, replays the tail suffix, suppresses
    the anomalies it already delivered since the checkpoint — and the
    complete delivery stream still equals the uninterrupted oracle."""
    prog, store = world
    chunk_lists, topo = _mk_jobs(prog)
    logdir = str(tmp_path / "logs")
    os.makedirs(logdir)
    first = {j: c[:len(c) // 2] for j, c in chunk_lists.items()}
    rest = {j: c[len(c) // 2:] for j, c in chunk_lists.items()}
    half_events = sum(len(c) for cs in first.values() for c in cs)
    total_events = sum(len(c) for cs in chunk_lists.values() for c in cs)

    _write_logs(logdir, first)
    got = []
    svc = FleetService(
        _mk_mux(store, topo),
        ServiceConfig(port=None, tail_dir=logdir, tail_poll_s=0.005,
                      drain_interval_s=0.01, worker_kind="process",
                      workers=2, checkpoint_dir=str(tmp_path / "ckpt"),
                      checkpoint_on_finalize=False,
                      default_engine=_ecfg()),
        on_anomaly=lambda fa, t: got.append(fa)).start()
    try:
        deadline = time.time() + 30
        while time.time() < deadline and \
                svc.tailer.stats.events < half_events:
            time.sleep(0.01)
        meta = svc.checkpoint()
        emitted = meta["anomalies_emitted"]

        _write_logs(logdir, rest)
        # let post-checkpoint diagnosis flow so the dedup path has
        # something real to suppress, then kill a worker process
        deadline = time.time() + 30
        while time.time() < deadline and len(got) <= emitted:
            time.sleep(0.01)
        victim = svc._pool.worker_for(sorted(chunk_lists)[0])
        svc._pool.kill_worker(victim)
        deadline = time.time() + 60
        while time.time() < deadline and \
                svc.telemetry.value("serve.worker_respawns") < 1:
            time.sleep(0.05)
        assert svc.telemetry.value("serve.worker_respawns") >= 1
        deadline = time.time() + 60
        while time.time() < deadline and \
                svc.tailer.stats.events < total_events:
            time.sleep(0.02)
    finally:
        svc.finalize()

    oracle, ostats = _oracle(logdir, store, topo, chunk_lists)
    assert _sorted_strs(got) == oracle
    assert svc.tailer.stats.events == ostats.events
    assert dict(sorted(svc.tailer.stats.per_job.items())) == ostats.per_job
    # the suppressed duplicates are exactly the post-checkpoint
    # deliveries the first incarnation already made
    assert svc.telemetry.value("serve.deduped_anomalies") >= 1
    assert svc.telemetry.value("serve.recovery_dedup_mismatch") == 0
    assert svc.telemetry.value("serve.worker_deaths") >= 1


# ---------------------------------------------------------------------- #
# archive byte budgets + HTTP query plane
# ---------------------------------------------------------------------- #
def test_archive_byte_budgets(world, tmp_path):
    from repro.archive import TraceArchive
    prog, _ = world
    d = str(tmp_path)
    from repro.store import seg_path
    for part in range(3):
        batch = ClusterSimulator(N, prog, seed=20 + part).run_batch(3)
        trace_store.write_trace(
            batch, seg_path(os.path.join(d, "big.fcs3"), part),
            codec="fcs3")
    arch = TraceArchive(d)
    full, scan_full = arch.query_events("big", with_scan=True)
    assert not scan_full.truncated
    cut, scan_cut = arch.query_events("big", with_scan=True, max_bytes=1)
    assert scan_cut.truncated
    assert 0 < len(cut) < len(full)
    # deterministic prefix: same budget, same answer
    cut2, _ = arch.query_events("big", with_scan=True, max_bytes=1)
    assert len(cut2) == len(cut)

    series = arch.query_metrics("big")
    short, truncated = arch.query_metrics("big", max_bytes=1,
                                          with_truncation=True)
    assert truncated and 0 < len(short) <= len(series)
    # deterministic: same budget, same prefix answer (cache-independent)
    short2, t2 = arch.query_metrics("big", max_bytes=1,
                                    with_truncation=True)
    assert t2 and short2 == short
    counters = arch.telemetry.snapshot().get("counters", {})
    assert counters["archive.truncated_queries{kind=events}"] == 2
    assert counters["archive.truncated_queries{kind=metrics}"] == 2


def test_query_plane_endpoints(world, tmp_path):
    prog, store = world
    chunk_lists, topo = _mk_jobs(prog, jobs=2)
    logdir = str(tmp_path)
    _write_logs(logdir, chunk_lists)
    svc = FleetService(
        _mk_mux(store, topo),
        ServiceConfig(port=0, query_port=0, tail_dir=logdir,
                      archive_dir=logdir, archive_max_bytes=1 << 20,
                      default_engine=_ecfg())).start()
    try:
        deadline = time.time() + 10
        while time.time() < deadline and svc.tailer.stats.files < 2:
            time.sleep(0.05)
        svc.collect()
        base = f"http://127.0.0.1:{svc.query_port}"

        def get(p):
            with urllib.request.urlopen(base + p, timeout=10) as r:
                return json.load(r)

        jobs = get("/jobs")["jobs"]
        assert set(jobs) == set(chunk_lists)
        assert all(j["open"] for j in jobs.values())
        anoms = get("/anomalies?n=5")["anomalies"]
        assert anoms and {"job", "kind", "team", "origin"} <= set(anoms[0])
        weather = get("/weather")
        assert weather["jobs_open"] == 2
        assert weather["anomalies_recent"] > 0
        tele = get("/telemetry")
        assert "serve.tail_segments" in tele["telemetry"].get(
            "counters", tele["telemetry"])
        assert "per_job" in tele["queues"]
        jid = sorted(chunk_lists)[0]
        ev = get(f"/archive/events?job={jid}&step_lo=0&step_hi=3&limit=5")
        assert ev["rows"] > 0 and len(ev["events"]) <= 5
        assert not ev["truncated"]
        ev_cut = get(f"/archive/events?job={jid}&max_bytes=1")
        assert ev_cut["truncated"]
        met = get(f"/archive/metrics?job={jid}&metric=throughput")
        assert met["series"] and not met["truncated"]
        with pytest.raises(urllib.error.HTTPError):
            get("/nope")
    finally:
        svc.finalize()
