"""Fault-injector plugin layer: frozen-oracle byte-equivalence of the
legacy kinds, registry semantics, custom injectors end-to-end, and
cross-process trace reproducibility (the ``hash()`` phase fix).

The digests pin the exact EventBatch every legacy ``Injection.kind``
emits on the 16-rank llama-20b program (all nine kinds verified
byte-identical to the pre-registry monolithic emitter at refactor time —
except ``gc``/``pyapi_stall``, whose periodic-stall phase intentionally
moved from salted ``hash((step, kind))`` to CRC32 so the same seed
reproduces the same trace in every process).  Any simulator or injector
edit that shifts one RNG draw changes a digest and fails loudly here.
"""
import hashlib
import subprocess
import sys

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.injectors import (DuplicateInjectorError, FaultInjector,
                                  Injection, UnknownInjectorError,
                                  get_injector, injector_names,
                                  register_injector, resolve_injections,
                                  stall_phase, unregister_injector)
from repro.core.timeline import ClusterSimulator, program_from_config

N, STEPS, SEED = 16, 4, 7


def batch_digest(batch) -> str:
    h = hashlib.sha256()
    for col in (batch.kind, batch.name_id, batch.rank, batch.issue_ts,
                batch.start_ts, batch.end_ts, batch.step, batch.flops,
                batch.nbytes):
        h.update(np.ascontiguousarray(col).tobytes())
    h.update("\x00".join(batch.names).encode())
    h.update(repr(sorted((int(k), sorted(v.items())) for k, v in
                         batch.extra.items())).encode())
    return h.hexdigest()[:16]


LEGACY_CASES = {
    "healthy": [],
    "gc": [Injection(kind="gc", duration=0.02, period_ops=5)],
    "pyapi_stall": [Injection(kind="pyapi_stall", duration=0.03,
                              period_ops=7,
                              api_name="importlib.metadata.version")],
    "sync_after_comm": [Injection(kind="sync_after_comm")],
    "straggler": [Injection(kind="straggler", ranks=(3, 7), factor=2.0,
                            start_step=2)],
    "underclock": [Injection(kind="underclock", ranks=(5,), factor=2.5,
                             start_step=3)],
    "slow_compute": [Injection(kind="slow_compute", op_match="ffn_matmul",
                               factor=2.88)],
    "slow_dataloader": [Injection(kind="slow_dataloader", factor=1.0,
                                  duration=2.0)],
    "network_jitter": [Injection(kind="network_jitter", factor=3.0,
                                 start_step=3)],
    "minority_kernels": [Injection(kind="minority_kernels", factor=0.35)],
    "hang": [Injection(kind="hang", ranks=(11,), at_step=2)],
    "combo": [Injection(kind="gc", duration=0.02, period_ops=5),
              Injection(kind="underclock", ranks=(5,), factor=2.5,
                        start_step=3),
              Injection(kind="network_jitter", factor=3.0, start_step=3)],
}

ORACLE = {
    "healthy": "5c9ff3291a34cb53",
    "gc": "e6367f43e80ead7e",
    "pyapi_stall": "e566d55db7d0e8b0",
    "sync_after_comm": "e1529f484b102c66",
    "straggler": "e921200023f52fc7",
    "underclock": "b7afb32d51eef4d5",
    "slow_compute": "d3d9790c187b83e7",
    "slow_dataloader": "9e376f1460ebee42",
    "network_jitter": "4ebe32959720dd13",
    "minority_kernels": "7318eed41d71ff19",
    "hang": "1d7e46fc1981699c",
    "combo": "a19b62b9c14c8235",
}


@pytest.fixture(scope="module")
def prog():
    return program_from_config(get_config("llama-20b-paper"), num_chips=N)


@pytest.mark.parametrize("case", sorted(LEGACY_CASES))
def test_legacy_kind_byte_equivalent(prog, case):
    sim = ClusterSimulator(N, prog, seed=SEED,
                           injections=LEGACY_CASES[case])
    assert batch_digest(sim.run_batch(STEPS)) == ORACLE[case], \
        f"trace for {case!r} drifted from the frozen oracle"


def test_hang_state_preserved(prog):
    sim = ClusterSimulator(N, prog, seed=SEED,
                           injections=LEGACY_CASES["hang"])
    sim.run_batch(STEPS)
    assert sim.hang is not None and 11 in sim.hang.stacks


# --------------------------------------------------------------------- #
# registry semantics
# --------------------------------------------------------------------- #
def test_all_kinds_registered():
    names = injector_names()
    for kind in ("gc", "pyapi_stall", "sync_after_comm", "straggler",
                 "underclock", "slow_compute", "network_jitter",
                 "slow_dataloader", "minority_kernels", "hang",
                 "checkpoint_write_storm", "ecc_throttle", "network_flap",
                 "moe_straggler", "serving_interference"):
        assert kind in names


def test_unknown_kind_is_loud(prog):
    with pytest.raises(UnknownInjectorError) as ei:
        ClusterSimulator(N, prog, injections=[Injection(kind="nope")])
    assert "nope" in str(ei.value) and "gc" in str(ei.value)


def test_duplicate_registration_refused():
    with pytest.raises(DuplicateInjectorError):
        @register_injector
        class Dup(FaultInjector):  # noqa: F811
            name = "gc"


def test_replace_and_restore():
    original = get_injector("gc")

    @register_injector(replace=True)
    class Quiet(FaultInjector):
        name = "gc"

    try:
        assert get_injector("gc") is Quiet
    finally:
        register_injector(original, replace=True)
    assert get_injector("gc") is original


def test_unnamed_injector_rejected():
    with pytest.raises(Exception, match="name"):
        @register_injector
        class NoName(FaultInjector):
            pass


def test_resolve_rejects_garbage():
    with pytest.raises(Exception, match="neither"):
        resolve_injections(["gc"])


# --------------------------------------------------------------------- #
# custom injectors end-to-end
# --------------------------------------------------------------------- #
def test_custom_injector_via_registry(prog):
    @register_injector
    class DoubleCompute(FaultInjector):
        name = "test_double_compute"

        def device_duration(self, sim, op, step, dur):
            if op.kind == "compute":
                return dur * 2.0
            return dur

    try:
        base = ClusterSimulator(N, prog, seed=SEED).run_batch(2)
        sim = ClusterSimulator(
            N, prog, seed=SEED,
            injections=[Injection(kind="test_double_compute")])
        slow = sim.run_batch(2)
        assert slow.end_ts.max() > base.end_ts.max() * 1.3
    finally:
        unregister_injector("test_double_compute")
    with pytest.raises(UnknownInjectorError):
        get_injector("test_double_compute")


def test_injector_instance_without_registration(prog):
    """resolve_injections accepts pre-built FaultInjector instances —
    one-off faults need no registry entry."""
    class OneOff(FaultInjector):
        def __init__(self):
            super().__init__(Injection(kind="one_off"))

        def cpu_duration(self, sim, op, step, dur):
            return dur + 5.0

    base = ClusterSimulator(N, prog, seed=SEED).run_batch(2)
    sim = ClusterSimulator(N, prog, seed=SEED, injections=[OneOff()])
    assert sim.run_batch(2).end_ts.max() > base.end_ts.max() + 5.0


def test_noop_injector_is_byte_invisible(prog):
    """An injector that overrides nothing must not perturb the trace —
    hooks run before the noise draws, consuming no RNG."""
    class Noop(FaultInjector):
        def __init__(self):
            super().__init__(Injection(kind="noop"))

    sim = ClusterSimulator(N, prog, seed=SEED, injections=[Noop()])
    assert batch_digest(sim.run_batch(STEPS)) == ORACLE["healthy"]


# --------------------------------------------------------------------- #
# cross-process reproducibility (the hash() phase fix)
# --------------------------------------------------------------------- #
def test_stall_phase_deterministic():
    assert stall_phase(3, "gc", 5) == stall_phase(3, "gc", 5)
    assert stall_phase(0, "gc", 0) == 0   # period 0 must not divide by 0
    phases = {stall_phase(s, "gc", 7) for s in range(20)}
    assert len(phases) > 1, "phase must vary across steps"


_SUBPROC = """
import sys
sys.path.insert(0, {src!r})
from tests.test_injectors import LEGACY_CASES, batch_digest
from repro.configs import get_config
from repro.core.timeline import ClusterSimulator, program_from_config
prog = program_from_config(get_config("llama-20b-paper"), num_chips={n})
for case in ("gc", "pyapi_stall"):
    sim = ClusterSimulator({n}, prog, seed={seed},
                           injections=LEGACY_CASES[case])
    print(case, batch_digest(sim.run_batch({steps})))
"""


def test_gc_trace_stable_across_hash_seeds(tmp_path):
    """The legacy ``hash((step, kind))`` phase made gc/pyapi traces differ
    between processes with different PYTHONHASHSEED — the exact bug the
    CRC32 phase fixes.  Two subprocesses with adversarial hash seeds must
    emit identical traces (and match this process's oracle)."""
    import os
    import pathlib
    root = str(pathlib.Path(__file__).resolve().parent.parent)
    code = _SUBPROC.format(src=root, n=N, seed=SEED, steps=STEPS)
    outs = []
    for hash_seed in ("0", "424242"):
        env = dict(os.environ, PYTHONHASHSEED=hash_seed,
                   PYTHONPATH=os.pathsep.join(
                       [os.path.join(root, "src"), root]))
        r = subprocess.run([sys.executable, "-c", code], env=env,
                           capture_output=True, text=True, timeout=120)
        assert r.returncode == 0, r.stderr
        outs.append(r.stdout)
    assert outs[0] == outs[1]
    assert f"gc {ORACLE['gc']}" in outs[0]
    assert f"pyapi_stall {ORACLE['pyapi_stall']}" in outs[0]
