"""Data pipeline determinism + mask equivalence + checkpoint fault safety."""
import os

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, ShardedLoader
from repro.data.masks import (mask_fast_linear, mask_naive_quadratic,
                              materialize_from_starts,
                              segment_ids_from_docs)
from repro.data.synthetic import SyntheticCorpus


def test_corpus_deterministic():
    c = SyntheticCorpus(1000, seed=3)
    it1 = c.batch_iter(4, 64)
    it2 = c.batch_iter(4, 64)
    b1, b2 = next(it1), next(it2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # different shards differ
    it3 = c.batch_iter(4, 64, shard=1)
    assert not np.array_equal(next(it3)["tokens"], b1["tokens"])


def test_labels_are_shifted_tokens():
    c = SyntheticCorpus(1000)
    b = next(c.batch_iter(2, 32))
    assert b["tokens"].shape == b["labels"].shape == (2, 32)


def test_mask_naive_equals_fast(rng):
    for _ in range(5):
        lens = rng.integers(1, 30, 4).tolist()
        L = 64
        seg = segment_ids_from_docs(lens, L)
        naive = mask_naive_quadratic(seg)
        fast = materialize_from_starts(mask_fast_linear(seg))
        np.testing.assert_array_equal(naive, fast)


def test_loader_prefetch_thread():
    l = ShardedLoader(DataConfig(vocab_size=100, batch=2, seq_len=16,
                                 prefetch=2))
    l.start()
    bs = [l.next_batch() for _ in range(5)]
    l.stop()
    assert all(b["tokens"].shape == (2, 16) for b in bs)


# --------------------------------------------------------------------- ckpt
def test_checkpoint_roundtrip_and_gc(tmp_path):
    import jax.numpy as jnp
    cm = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "n": {"b": jnp.ones(4)}}
    for s in (1, 3, 5, 9):
        cm.save(s, tree, {"step": s})
    assert cm.all_steps() == [5, 9]  # gc keeps 2
    got = cm.restore(tree, step=9)
    np.testing.assert_allclose(got["a"], tree["a"])
    assert cm.metadata(9)["metadata"]["step"] == 9


def test_checkpoint_atomicity(tmp_path):
    """A .tmp dir from a crashed save must never be listed as a step."""
    import jax.numpy as jnp
    cm = CheckpointManager(str(tmp_path))
    cm.save(2, {"x": jnp.ones(3)})
    os.makedirs(str(tmp_path / "step_00000007.tmp"))
    assert cm.all_steps() == [2]
    assert cm.latest_step() == 2
