"""Fault-tolerant supervisor: restart-from-checkpoint + FLARE-driven actions."""
import pytest

from repro.configs import get_reduced
from repro.core.engine import Anomaly, Team
from repro.optim.adamw import AdamWConfig
from repro.runtime.supervisor import SimulatedFault, Supervisor
from repro.runtime.train import RunConfig, Trainer


def test_restart_from_checkpoint_continues(tmp_path):
    cfg = get_reduced("qwen2-0.5b")
    crashed = {"flag": False}

    def fault_hook(step):
        if step == 6 and not crashed["flag"]:
            crashed["flag"] = True
            raise SimulatedFault("injected node failure at step 6")

    def make_trainer():
        run = RunConfig(model=cfg, global_batch=2, seq_len=32, steps=10,
                        peak_lr=1e-3, opt=AdamWConfig(lr=1e-3),
                        checkpoint_dir=str(tmp_path), checkpoint_every=2,
                        flare=False)
        return Trainer(run, fault_hook=fault_hook)

    sup = Supervisor(max_restarts=2)
    hist = sup.run(make_trainer, steps=10)
    assert sup.restarts == 1
    steps = [h["step"] for h in hist]
    # crash at 6 after ckpt at 5 -> resume from 6; every step covered once+
    assert steps[-1] == 9
    assert set(range(10)) <= set(steps)
    assert any(a.kind == "restart" for a in sup.actions)


def test_apply_diagnosis_runbook():
    sup = Supervisor()
    anomalies = [
        Anomaly(kind="hang", metric="intra_kernel_inspecting",
                team=Team.OPERATIONS, root_cause="link 3->4", ranks=[3, 4]),
        Anomaly(kind="fail_slow", metric="throughput",
                team=Team.OPERATIONS, root_cause="underclock", ranks=[7]),
        Anomaly(kind="regression", metric="issue_latency",
                team=Team.ALGORITHM, root_cause="gc"),
    ]
    actions = sup.apply_diagnosis(anomalies)
    kinds = [a.kind for a in actions]
    assert "isolate" in kinds and "restart" in kinds and "drain" in kinds
    # algorithm-team regressions are tickets, not cluster actions
    assert not any(set(a.ranks) == set() and a.kind == "drain"
                   for a in actions)
