"""Reports + ASCII timeline rendering."""
from repro.core.engine import Anomaly, Team
from repro.core.events import EventKind, TraceEvent
from repro.core.report import anomalies_json, anomaly_report, ascii_timeline


def test_anomaly_report_groups_by_team():
    an = [
        Anomaly(kind="regression", metric="issue_latency",
                team=Team.ALGORITHM, root_cause="python runtime GC",
                step=4, severity=3.2, evidence={"w1": 0.5}),
        Anomaly(kind="hang", metric="intra_kernel_inspecting",
                team=Team.OPERATIONS, root_cause="link 3->4", ranks=[3, 4]),
    ]
    txt = anomaly_report(an)
    assert "ALGORITHM" in txt and "OPERATIONS" in txt
    assert "GC" in txt
    js = anomalies_json(an)
    assert "issue_latency" in js


def test_ascii_timeline_lanes():
    evs = [
        TraceEvent(EventKind.STEP, "step_0", 0, 0.0, 0.0, 1.0, step=0),
        TraceEvent(EventKind.DATALOADER, "dl", 0, 0.0, 0.0, 0.2, step=0),
        TraceEvent(EventKind.GC, "gc", 0, 0.3, 0.3, 0.4, step=0),
        TraceEvent(EventKind.KERNEL_COMPUTE, "mm", 0, 0.2, 0.4, 0.7, step=0),
        TraceEvent(EventKind.KERNEL_COMM, "ar", 0, 0.5, 0.7, 0.95, step=0),
    ]
    txt = ascii_timeline(evs, rank=0, step=0, width=60)
    assert "CPU |" in txt and "DEV |" in txt
    assert "#" in txt and "~" in txt and "G" in txt and "D" in txt
    assert ascii_timeline([], 0, 0) == "(no events)"
