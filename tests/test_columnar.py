"""Columnar event pipeline: lossless conversion + metrics equivalence.

The EventBatch path must be an *observationally identical* replacement for
the list-of-dataclass path: same events after round-trip, same JSONL
lines, and the vectorized ``aggregate_all`` must reproduce the legacy
per-step ``aggregate_step`` metrics on real simulator traces (healthy and
injected), so every detector sees the same numbers.
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.columnar import EventBatch, next_ge, prev_le
from repro.core.engine import DiagnosticEngine, EngineConfig
from repro.core.events import EventKind, TraceEvent, dump_jsonl, load_jsonl
from repro.core.history import HistoryStore
from repro.core.metrics import (_aggregate_step_events, aggregate_all,
                                aggregate_step, steps_in)
from repro.core.timeline import (ClusterSimulator, Injection,
                                 program_from_config)

N = 64


def _sim(injections=None, seed=9, steps=3):
    cfg = get_config("llama-20b-paper")
    prog = program_from_config(cfg, num_chips=N)
    return ClusterSimulator(N, prog, seed=seed,
                            injections=injections or []).run_batch(steps)


def _assert_events_equal(a: TraceEvent, b: TraceEvent):
    assert a.kind == b.kind and a.name == b.name and a.rank == b.rank
    assert a.issue_ts == b.issue_ts and a.start_ts == b.start_ts
    assert a.end_ts == b.end_ts and a.step == b.step
    assert a.meta == b.meta


# --------------------------------------------------------------------- #
# round-trips
# --------------------------------------------------------------------- #
def test_roundtrip_batch_events_batch():
    batch = _sim([Injection(kind="gc", duration=0.25, period_ops=5)])
    events = batch.to_events()
    again = EventBatch.from_events(events)
    assert len(again) == len(batch) == len(events)
    for a, b in zip(events, again.to_events()):
        _assert_events_equal(a, b)


def test_roundtrip_events_by_rank():
    batch = _sim()
    by_rank = batch.to_events_by_rank()
    assert sorted(by_rank) == list(range(N))
    again = EventBatch.from_events_by_rank(by_rank)
    by_rank2 = again.to_events_by_rank()
    for r in by_rank:
        assert len(by_rank[r]) == len(by_rank2[r])
        for a, b in zip(by_rank[r], by_rank2[r]):
            _assert_events_equal(a, b)


def test_roundtrip_jsonl(tmp_path):
    batch = _sim([Injection(kind="hang", ranks=(11,), at_step=2)])
    path = str(tmp_path / "trace.jsonl")
    nbytes = batch.write_jsonl(path)
    assert nbytes > 0
    # the legacy per-event loader and the batch loader read the same file
    legacy = load_jsonl(path)
    again = EventBatch.from_jsonl(path).to_events()
    assert len(legacy) == len(again) == len(batch)
    for a, b in zip(legacy, again):
        _assert_events_equal(a, b)
    # timestamps only rounded to 1e-6 by the shared codec (same as the
    # TraceEvent.to_json contract), everything else exact
    for ev, orig in zip(again, batch.to_events()):
        assert ev.kind == orig.kind and ev.name == orig.name
        assert ev.issue_ts == pytest.approx(orig.issue_ts, abs=1e-6)
        # hang stacks survive (truncated to 4 frames by the codec)
        if orig.kind == EventKind.HANG_SUSPECT:
            assert ev.meta["stack"] == list(orig.meta["stack"])[-4:]


def test_batch_lines_match_event_codec(tmp_path):
    """dump_jsonl(batch) byte-identical to dump_jsonl(events)."""
    batch = _sim(steps=1)
    p1, p2 = str(tmp_path / "a.jsonl"), str(tmp_path / "b.jsonl")
    n1 = dump_jsonl(batch, p1)
    n2 = dump_jsonl(batch.to_events(), p2)
    assert n1 == n2
    assert open(p1).read() == open(p2).read()


def test_concat_reindexes_names_and_extra():
    b1 = _sim(steps=1, seed=1)
    b2 = _sim([Injection(kind="hang", ranks=(3,), at_step=0, at_op=0,
                         meta={"noncomm_crash": True})], seed=2)
    cat = EventBatch.concat([b1, b2])
    assert len(cat) == len(b1) + len(b2)
    evs = cat.to_events()
    for a, b in zip(b1.to_events() + b2.to_events(), evs):
        _assert_events_equal(a, b)


# --------------------------------------------------------------------- #
# segmented query helpers
# --------------------------------------------------------------------- #
def test_prev_le_next_ge_match_bruteforce():
    rng = np.random.default_rng(0)
    for _ in range(20):
        nv, nq = rng.integers(0, 30, 2)
        vt = rng.random(nv) * 10
        vs = rng.integers(0, 4, nv)
        qt = rng.random(nq) * 10
        qs = rng.integers(0, 4, nq)
        got_prev = prev_le(vt, vs, qt, qs)
        got_next = next_ge(vt, vs, qt, qs)
        for i in range(nq):
            cand = [vt[j] for j in range(nv)
                    if vs[j] == qs[i] and vt[j] <= qt[i]]
            want = max(cand) if cand else None
            if want is None:
                assert got_prev[i] == -1
            else:
                assert vt[got_prev[i]] == want and vs[got_prev[i]] == qs[i]
            cand = [vt[j] for j in range(nv)
                    if vs[j] == qs[i] and vt[j] >= qt[i]]
            want = min(cand) if cand else None
            if want is None:
                assert got_next[i] == -1
            else:
                assert vt[got_next[i]] == want and vs[got_next[i]] == qs[i]


# --------------------------------------------------------------------- #
# metrics equivalence: vectorized sweep vs legacy per-step oracle
# --------------------------------------------------------------------- #
def _assert_metrics_equal(L, C):
    assert L.step == C.step and L.num_ranks == C.num_ranks
    assert np.isclose(L.t_step, C.t_step)
    assert np.isclose(L.throughput, C.throughput)
    assert set(L.flops) == set(C.flops)
    for nm in L.flops:
        assert set(L.flops[nm]) == set(C.flops[nm])
        for r in L.flops[nm]:
            assert np.isclose(L.flops[nm][r], C.flops[nm][r])
    assert L.flops_overlapped == C.flops_overlapped
    assert set(L.bandwidth) == set(C.bandwidth)
    for nm in L.bandwidth:
        assert np.isclose(L.bandwidth[nm], C.bandwidth[nm])
    # same multiset of issue latencies (storage order is not part of the
    # contract; every consumer is order-free)
    assert L.issue_latencies.size == C.issue_latencies.size
    assert np.allclose(np.sort(L.issue_latencies),
                       np.sort(C.issue_latencies))
    assert np.isclose(L.v_inter, C.v_inter)
    assert np.isclose(L.v_minority, C.v_minority)
    assert np.isclose(L.t_inter, C.t_inter)
    assert set(L.api_spans) == set(C.api_spans)
    for nm in L.api_spans:
        assert np.isclose(L.api_spans[nm], C.api_spans[nm])


@pytest.mark.parametrize("injections", [
    [],
    [Injection(kind="gc", duration=0.25, period_ops=5)],
    [Injection(kind="minority_kernels", factor=0.4)],
    [Injection(kind="slow_dataloader", duration=8.0)],
    [Injection(kind="sync_after_comm")],
], ids=["healthy", "gc", "minority", "dataloader", "sync"])
def test_aggregate_all_matches_legacy(injections):
    batch = _sim(injections)
    by_rank = batch.to_events_by_rank()
    all_m = aggregate_all(batch)
    assert sorted(all_m) == steps_in(by_rank) == steps_in(batch)
    for s in steps_in(by_rank):
        _assert_metrics_equal(_aggregate_step_events(by_rank, s), all_m[s])


def test_aggregate_step_polymorphic():
    batch = _sim(steps=2)
    m_batch = aggregate_step(batch, 1)
    m_dict = aggregate_step(batch.to_events_by_rank(), 1)
    _assert_metrics_equal(m_dict, m_batch)
    assert aggregate_step(batch, 99) is None


def test_handbuilt_voids_columnar():
    """The v_inter/v_minority edge semantics survive the columnar path."""
    def _ev(kind, name, rank, i, s, e, **meta):
        return TraceEvent(kind, name, rank, i, s, e, step=0, meta=meta)
    evs = {0: [
        _ev(EventKind.STEP, "step_0", 0, 0, 0, 6.0, tokens=600),
        _ev(EventKind.DATALOADER, "dl", 0, 0.0, 0.0, 1.0, tokens=600),
        _ev(EventKind.KERNEL_COMPUTE, "a", 0, 0.9, 1.0, 2.0, flops=100.0),
        _ev(EventKind.KERNEL_COMPUTE, "b", 0, 1.0, 2.0, 3.0, flops=100.0),
        _ev(EventKind.KERNEL_COMPUTE, "c", 0, 2.5, 4.0, 5.0, flops=100.0),
    ]}
    m = aggregate_all(EventBatch.from_events_by_rank(evs))[0]
    assert m.throughput == 100.0
    assert m.t_inter == 1.0
    assert abs(m.v_inter - 1.0 / 6.0) < 1e-9
    assert abs(m.v_minority - 1.0 / 5.0) < 1e-9
    assert m.flops["a"][0] == 100.0


# --------------------------------------------------------------------- #
# engine equivalence through the columnar store
# --------------------------------------------------------------------- #
def _world(n=32):
    cfg = get_config("llama-20b-paper")
    prog = program_from_config(cfg, num_chips=n)
    store = HistoryStore()
    eng0 = DiagnosticEngine(EngineConfig(backend="dense-train",
                                         num_ranks=n), store)
    for seed in range(3):
        eng0.ingest_batch(ClusterSimulator(n, prog, seed=seed).run_batch(4))
    eng0.learn_healthy()
    return prog, store


def test_engine_batch_vs_dict_ingest_same_diagnosis():
    n = 32
    prog, store = _world(n)
    inj = [Injection(kind="gc", duration=0.02, period_ops=5)]
    results = []
    for mode in ("batch", "dict", "list"):
        eng = DiagnosticEngine(EngineConfig(backend="dense-train",
                                            num_ranks=n), store)
        batch = ClusterSimulator(n, prog, seed=7,
                                 injections=inj).run_batch(6)
        if mode == "batch":
            eng.ingest_batch(batch)
        elif mode == "dict":
            eng.ingest_all(batch.to_events_by_rank())
        else:
            eng.ingest(batch.to_events())
        results.append([(a.kind, a.metric, a.team.value, a.step)
                        for a in eng.evaluate_all()])
    assert results[0] == results[1] == results[2]
    assert any(m == "issue_latency" for _, m, _, _ in results[0])


def test_engine_hang_path_through_batch():
    n = 32
    prog, store = _world(n)
    eng = DiagnosticEngine(EngineConfig(backend="dense-train",
                                        num_ranks=n), store)
    sim = ClusterSimulator(n, prog, seed=7,
                           injections=[Injection(kind="hang", ranks=(11,),
                                                 at_step=2)])
    eng.ingest_batch(sim.run_batch(6))
    assert sim.hang is not None
    found = eng.check_hangs(sim.hang.ring_progress)
    assert found and found[0].kind == "hang" and 11 in found[0].ranks


def test_engine_incremental_chunks_match_bulk():
    n = 32
    prog, store = _world(n)
    inj = [Injection(kind="minority_kernels", factor=0.4)]
    bulk = DiagnosticEngine(EngineConfig(backend="dense-train",
                                         num_ranks=n), store)
    bulk.ingest_batch(ClusterSimulator(n, prog, seed=3,
                                       injections=inj).run_batch(6))
    inc = DiagnosticEngine(EngineConfig(backend="dense-train",
                                        num_ranks=n), store)
    # same trace delivered as per-step chunks (streaming shape)
    full = ClusterSimulator(n, prog, seed=3, injections=inj).run_batch(6)
    by_rank = full.to_events_by_rank()
    for s in steps_in(by_rank):
        chunk = {r: [e for e in evs if e.step == s]
                 for r, evs in by_rank.items()}
        inc.ingest_all(chunk)
    key = lambda a: (a.kind, a.metric, a.team.value, a.step)  # noqa: E731
    assert sorted(map(key, bulk.evaluate_all())) == \
        sorted(map(key, inc.evaluate_all()))
