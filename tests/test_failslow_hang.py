"""Fail-slow monitor + hang call-stack classification units."""
import numpy as np

from repro.core.failslow import ThroughputMonitor, binary_search_plan
from repro.core.hang import classify_stacks, diagnose_hang


def test_throughput_monitor_detects_sudden_drop():
    m = ThroughputMonitor(window=6, drop_threshold=0.1)
    for _ in range(8):
        assert m.observe(100.0) is None
    drop = m.observe(70.0)
    assert drop is not None and abs(drop - 0.3) < 1e-9
    # regression-like uniformly-slow job never fires
    m2 = ThroughputMonitor(window=6, drop_threshold=0.1)
    for _ in range(10):
        assert m2.observe(60.0) is None


def test_binary_search_plan_depth():
    plan = binary_search_plan(1024)
    assert len(plan) <= 11  # log2 depth


def test_classify_noncomm():
    stacks = {0: ["train", "dataloader", "os.read"],
              **{r: ["train", "allreduce[3]"] for r in range(1, 8)}}
    kind, suspects = classify_stacks(stacks)
    assert kind == "non_comm" and suspects == [0]


def test_classify_comm_and_diagnose():
    stacks = {r: ["train", "all_gather[1]"] for r in range(8)}
    kind, suspects = classify_stacks(stacks)
    assert kind == "comm"
    progress = np.array([9, 9, 9, 4, 9, 9, 9, 9])  # rank 3 stalled first
    d = diagnose_hang(stacks, progress)
    assert d.used_inspector and d.link == (2, 3)
    d2 = diagnose_hang(stacks, None)
    assert not d2.used_inspector and "probe" in d2.detail
